"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the pytest suite (and hypothesis sweeps) hold
``kernels.lora`` / ``kernels.rmsnorm`` against.  Written in the most
obvious possible style on purpose — no tiling, no tricks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lora_matmul_ref(
    x: jax.Array, w: jax.Array, a: jax.Array, b: jax.Array, *, alpha: float = 1.0
) -> jax.Array:
    """y = x @ w + alpha * (x @ a) @ b, fp32."""
    x = x.astype(jnp.float32)
    base = jnp.matmul(x, w.astype(jnp.float32))
    low = jnp.matmul(jnp.matmul(x, a.astype(jnp.float32)), b.astype(jnp.float32))
    return base + alpha * low


def rmsnorm_ref(x: jax.Array, gain: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    """x * rsqrt(mean(x^2, -1) + eps) * gain, fp32."""
    x = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gain.astype(jnp.float32)
