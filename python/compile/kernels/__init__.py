"""Pallas kernels (L1) + pure-jnp oracles.

``lora_matmul`` / ``rmsnorm`` are the interpret-mode Pallas kernels used
by the L2 model; ``ref`` holds the oracles pytest compares them against.
"""

from .lora import lora_matmul, mxu_utilization_estimate, vmem_footprint_bytes
from .rmsnorm import rmsnorm

__all__ = [
    "lora_matmul",
    "rmsnorm",
    "vmem_footprint_bytes",
    "mxu_utilization_estimate",
]
