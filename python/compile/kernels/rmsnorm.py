"""L1 — RMSNorm Pallas kernel.

RMSNorm is applied twice per decoder layer (pre-attention, pre-MLP) plus
once before the LM head; on the device side of the split it brackets every
LoRA projection, so we keep it on the fast path as a row-tiled Pallas
kernel: each grid step normalizes a (bm, d) panel entirely in VMEM
(one HBM read + one HBM write per element, the roofline minimum).

Interpret-mode lowering for CPU PJRT, same as ``kernels.lora``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...]
    # mean of squares along the feature axis, fp32 accumulation
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = x * jax.lax.rsqrt(ms + eps) * g_ref[...]


def _pick_rows(m: int, preferred: int = 128) -> int:
    if m <= preferred:
        return m
    for cand in range(preferred, 0, -1):
        if m % cand == 0:
            return cand
    return m


def rmsnorm(x: jax.Array, gain: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    """Row-wise RMS normalization ``x * rsqrt(mean(x^2) + eps) * gain``.

    x: (..., d) float32, gain: (d,) float32 -> same shape as x.
    Differentiable via custom VJP (Pallas has no autodiff rule); the
    gain gradient IS computed exactly (it is cheap), even though the
    split-LoRA setup freezes it.
    """
    return _rmsnorm(x, gain, eps)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rmsnorm(x, gain, eps):
    return _rmsnorm_impl(x, gain, eps)


def _rmsnorm_fwd(x, gain, eps):
    return _rmsnorm_impl(x, gain, eps), (x, gain)


def _rmsnorm_bwd(eps, res, g):
    x, gain = res
    xf = x.astype(jnp.float32)
    d = xf.shape[-1]
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(ms + eps)
    gg = g.astype(jnp.float32) * gain.astype(jnp.float32)
    # dL/dx = gain*g*inv − x · (Σ_j g_j·gain_j·x_j / d) · inv³
    dx = gg * inv - xf * (jnp.sum(gg * xf, axis=-1, keepdims=True) / d) * inv**3
    dgain = jnp.sum(
        (g.astype(jnp.float32) * xf * inv).reshape(-1, d), axis=0
    ).astype(gain.dtype)
    return dx.astype(x.dtype), dgain


def _rmsnorm_impl(x, gain, eps):
    orig_shape = x.shape
    d = x.shape[-1]
    x2 = x.reshape(-1, d).astype(jnp.float32)
    m = x2.shape[0]
    bm = _pick_rows(m)

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        interpret=True,
    )(x2, gain.astype(jnp.float32))
    return out.reshape(orig_shape)


_rmsnorm.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)
