"""L1 — fused LoRA-linear Pallas kernel.

Computes the hot spot of split-LoRA fine-tuning (every adapted projection
in every transformer layer):

    y = x @ W + alpha * (x @ A) @ B

On GPU (the paper's testbed) this is two cuBLAS GEMMs plus an epilogue.
Re-thought for TPU (see DESIGN.md §7 Hardware-Adaptation):

  * the base GEMM ``x @ W`` runs on the MXU with (bm, bk) x (bk, bn)
    tiles staged HBM->VMEM by ``BlockSpec``;
  * the low-rank path is fused into the *same* K-loop: each ``x`` tile is
    read from HBM exactly once and contributes to both the ``x @ W``
    accumulator and an ``x @ A`` accumulator (bm, r) kept in VMEM
    scratch.  ``(x@A) @ B`` is applied once, at the last K step — the TPU
    analogue of CUDA epilogue fusion;
  * A (k, r) is sliced along K like W; B (r, bn) is sliced along N and is
    tiny (r <= 64), so the adapter adds no meaningful HBM traffic.

Lowered with ``interpret=True`` so the kernel becomes plain HLO and runs
on the CPU PJRT plugin (real-TPU lowering emits a Mosaic custom-call the
CPU client cannot execute).  Correctness oracle: ``kernels.ref``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(dim: int, preferred: int) -> int:
    """Largest divisor of ``dim`` that is <= ``preferred``.

    TPU tiles want MXU-aligned 128s; on the interpret path any divisor is
    legal, so we degrade gracefully for small/odd test shapes instead of
    padding (keeps the oracle comparison exact).
    """
    if dim <= preferred:
        return dim
    for cand in range(preferred, 0, -1):
        if dim % cand == 0:
            return cand
    return dim


def _lora_kernel(x_ref, w_ref, a_ref, b_ref, o_ref, xa_ref, *, alpha: float, nk: int):
    """One (i, j, k) grid step.

    x_ref: (bm, bk)  w_ref: (bk, bn)  a_ref: (bk, r)  b_ref: (r, bn)
    o_ref: (bm, bn) accumulator (same block for every k)
    xa_ref: (bm, r) VMEM scratch accumulating x @ A across the K loop
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        xa_ref[...] = jnp.zeros_like(xa_ref)

    x = x_ref[...]
    # Both accumulations consume the SAME VMEM-resident x tile: one HBM
    # read of x serves the base GEMM and the low-rank projection.
    o_ref[...] += jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    xa_ref[...] += jnp.dot(x, a_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[...] += alpha * jnp.dot(
            xa_ref[...], b_ref[...], preferred_element_type=jnp.float32
        )


def lora_matmul(
    x: jax.Array,
    w: jax.Array,
    a: jax.Array,
    b: jax.Array,
    *,
    alpha: float = 1.0,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
) -> jax.Array:
    """Fused ``x @ w + alpha * (x @ a) @ b`` as a Pallas kernel.

    Shapes: x (m, k), w (k, n), a (k, r), b (r, n) -> (m, n) float32.
    Leading batch dims of ``x`` are flattened into m.  Differentiable
    w.r.t. ``x``, ``a``, ``b`` (custom VJP); ``w`` is the FROZEN base
    weight — its cotangent is returned as zeros (never computing the
    d×d' weight-grad is exactly the LoRA saving the paper's cost model
    Eq. (7) relies on).
    """
    return _lora_mm(x, w, a, b, alpha, (bm, bn, bk))


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _lora_mm(x, w, a, b, alpha, blocks):
    return _lora_mm_impl(x, w, a, b, alpha, blocks)


def _lora_mm_fwd(x, w, a, b, alpha, blocks):
    return _lora_mm_impl(x, w, a, b, alpha, blocks), (x, w, a, b)


def _lora_mm_bwd(alpha, blocks, res, g):
    x, w, a, b = res
    k = x.shape[-1]
    x2 = x.reshape(-1, k).astype(jnp.float32)
    g2 = g.reshape(-1, g.shape[-1]).astype(jnp.float32)
    # Data gradient is the SAME fused kernel on transposed operands:
    #   dx = g @ Wᵀ + alpha * (g @ Bᵀ) @ Aᵀ
    dx = _lora_mm_impl(g, w.T, b.T, a.T, alpha, blocks).reshape(x.shape)
    # Adapter gradients (rank-r, cheap: O(m·k·r + m·r·n) FLOPs).
    gbt = jnp.matmul(g2, b.T.astype(jnp.float32))
    da = alpha * jnp.matmul(x2.T, gbt)
    db = alpha * jnp.matmul(jnp.matmul(x2, a.astype(jnp.float32)).T, g2)
    # Frozen base weight: cotangent intentionally zero (LoRA contract).
    dw = jnp.zeros_like(w)
    return dx, dw, da, db


def _lora_mm_impl(x, w, a, b, alpha, blocks):
    bm, bn, bk = blocks
    orig_shape = x.shape
    if x.ndim > 2:
        x = x.reshape(-1, x.shape[-1])
    m, kdim = x.shape
    k2, n = w.shape
    assert kdim == k2, f"x/w contraction mismatch: {kdim} vs {k2}"
    r = a.shape[1]
    assert a.shape == (kdim, r) and b.shape == (r, n), (a.shape, b.shape)

    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(kdim, bk)
    nk = kdim // bk

    out = pl.pallas_call(
        functools.partial(_lora_kernel, alpha=alpha, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),  # x
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),  # w
            pl.BlockSpec((bk, r), lambda i, j, k: (k, 0)),  # a
            pl.BlockSpec((r, bn), lambda i, j, k: (0, j)),  # b
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[_vmem_scratch(bm, r)],
        interpret=True,
    )(x.astype(jnp.float32), w, a, b)

    if len(orig_shape) > 2:
        out = out.reshape(*orig_shape[:-1], n)
    return out


_lora_mm.defvjp(_lora_mm_fwd, _lora_mm_bwd)


def _vmem_scratch(bm: int, r: int):
    """VMEM scratch allocation, tolerant of pallas API surface differences."""
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM((bm, r), jnp.float32)
    except Exception:  # pragma: no cover - fallback for non-tpu builds
        return pl.MemorySpace.ANY  # type: ignore[attr-defined]


def vmem_footprint_bytes(
    bm: int, bn: int, bk: int, r: int, dtype_bytes: int = 4
) -> int:
    """Static VMEM footprint of one grid step (perf model, DESIGN.md §9).

    x tile + w tile + a slice + b slice + out accumulator + xa scratch,
    double-buffered on the streamed inputs (x, w, a).
    """
    streamed = (bm * bk + bk * bn + bk * r) * dtype_bytes * 2  # double buffer
    resident = (r * bn + bm * bn + bm * r) * dtype_bytes
    return streamed + resident


def mxu_utilization_estimate(m: int, n: int, k: int, r: int, bm: int, bn: int) -> float:
    """Fraction of MXU-issue slots doing useful work (128x128 systolic).

    Tiles that are not multiples of 128 waste the remainder lanes; the
    low-rank path (r << 128) runs at r/128 occupancy but is a vanishing
    fraction of total FLOPs.
    """
    eff_m = bm / (128 * math.ceil(bm / 128))
    eff_n = bn / (128 * math.ceil(bn / 128))
    base_flops = 2 * m * n * k
    lora_flops = 2 * m * k * r + 2 * m * r * n
    lora_eff = r / (128 * math.ceil(r / 128))
    total = base_flops + lora_flops
    return (base_flops * eff_m * eff_n + lora_flops * lora_eff) / total
