"""Model configurations.

``nano``/``tiny`` are test-scale configs (pytest + Rust integration
tests), ``small`` drives the end-to-end split fine-tuning example, and
``llama1b`` mirrors the paper's LLaMA-3.2-1B ("32-layer transformer
decoders", §V) — it is used by the Rust cost model for the figures, and
is deliberately NOT compiled to artifacts (CPU-intractable; see
DESIGN.md §2 Substitutions).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab_size: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int
    batch_size: int
    lora_rank: int
    lora_alpha: float
    rope_theta: float = 10000.0
    rms_eps: float = 1e-6

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def lora_scale(self) -> float:
        return self.lora_alpha / self.lora_rank

    # ---- flat-vector lengths (layouts in params.py) -------------------
    @property
    def base_layer_len(self) -> int:
        d, f = self.d_model, self.d_ff
        return 4 * d * d + 3 * d * f + 2 * d

    @property
    def lora_layer_len(self) -> int:
        d, f, r = self.d_model, self.d_ff, self.lora_rank
        # q,k,v,o: A(d,r)+B(r,d); gate,up: A(d,r)+B(r,f); down: A(f,r)+B(r,d)
        return 4 * (d * r + r * d) + 2 * (d * r + r * f) + (f * r + r * d)

    @property
    def head_len(self) -> int:
        return self.d_model + self.d_model * self.vocab_size

    @property
    def n_params(self) -> int:
        return (
            self.vocab_size * self.d_model
            + self.n_layers * (self.base_layer_len + self.lora_layer_len)
            + self.head_len
        )

    @property
    def n_trainable(self) -> int:
        return self.n_layers * self.lora_layer_len

    def to_dict(self) -> dict:
        out = dataclasses.asdict(self)
        out.update(
            head_dim=self.head_dim,
            lora_scale=self.lora_scale,
            base_layer_len=self.base_layer_len,
            lora_layer_len=self.lora_layer_len,
            head_len=self.head_len,
            n_params=self.n_params,
            n_trainable=self.n_trainable,
        )
        return out


CONFIGS: dict[str, ModelConfig] = {
    # pytest-scale: segments trace + execute in < 1 s
    "nano": ModelConfig(
        name="nano", vocab_size=256, d_model=64, n_layers=4, n_heads=4,
        d_ff=192, seq_len=32, batch_size=2, lora_rank=4, lora_alpha=8.0,
    ),
    # Rust integration-test scale
    "tiny": ModelConfig(
        name="tiny", vocab_size=256, d_model=128, n_layers=6, n_heads=8,
        d_ff=384, seq_len=64, batch_size=4, lora_rank=8, lora_alpha=16.0,
    ),
    # end-to-end example scale (~7M params, byte-level vocab)
    "small": ModelConfig(
        name="small", vocab_size=256, d_model=256, n_layers=8, n_heads=8,
        d_ff=704, seq_len=128, batch_size=8, lora_rank=8, lora_alpha=16.0,
    ),
    # paper's model: cost-model parameterization ONLY (never compiled)
    "llama1b": ModelConfig(
        name="llama1b", vocab_size=128256, d_model=2048, n_layers=32,
        n_heads=32, d_ff=8192, seq_len=512, batch_size=8, lora_rank=16,
        lora_alpha=32.0, rope_theta=500000.0,
    ),
}
