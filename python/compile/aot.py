"""AOT lowering: JAX segments -> HLO *text* artifacts + manifest.json.

Interchange format is HLO text, NOT ``.serialize()``: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run once at build time (``make artifacts``); the Rust binary is fully
self-contained afterwards — Python is never on the request path.

Usage:  cd python && python -m compile.aot --config tiny --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .configs import CONFIGS, ModelConfig
from .params import (
    base_layer_layout,
    head_layout,
    layout_offsets,
    lora_layer_layout,
)

jax.config.update("jax_platform_name", "cpu")


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _dt(dtype) -> str:
    return {jnp.float32: "f32", jnp.int32: "i32"}[dtype]


def segment_table(cfg: ModelConfig):
    """name -> (fn, [(arg_name, shape, dtype)], [(out_name, shape, dtype)]).

    The donate list marks args whose buffer the runtime may alias into the
    output (adapter updates are in-place on TPU/real deployments).
    """
    b, s, d = cfg.batch_size, cfg.seq_len, cfg.d_model
    lb, ll, lh = cfg.base_layer_len, cfg.lora_layer_len, cfg.head_len
    nl, v = cfg.n_layers, cfg.vocab_size

    return {
        "embed_fwd": (
            lambda tokens, embed: (model.embed_fwd(tokens, embed),),
            [("tokens", (b, s), jnp.int32), ("embed", (v, d), jnp.float32)],
            [("h", (b, s, d), jnp.float32)],
        ),
        "layer_fwd": (
            lambda h, bv, lv: (model.layer_fwd(h, bv, lv, cfg),),
            [
                ("h", (b, s, d), jnp.float32),
                ("base_vec", (lb,), jnp.float32),
                ("lora_vec", (ll,), jnp.float32),
            ],
            [("h_out", (b, s, d), jnp.float32)],
        ),
        "layer_bwd": (
            lambda h_in, bv, lv, g: model.layer_bwd(h_in, bv, lv, g, cfg),
            [
                ("h_in", (b, s, d), jnp.float32),
                ("base_vec", (lb,), jnp.float32),
                ("lora_vec", (ll,), jnp.float32),
                ("g_out", (b, s, d), jnp.float32),
            ],
            [
                ("g_in", (b, s, d), jnp.float32),
                ("g_lora", (ll,), jnp.float32),
            ],
        ),
        "head_loss_grad": (
            lambda h, hv, labels: model.head_loss_grad(h, hv, labels, cfg),
            [
                ("h", (b, s, d), jnp.float32),
                ("head_vec", (lh,), jnp.float32),
                ("labels", (b, s), jnp.int32),
            ],
            [("loss", (), jnp.float32), ("g_h", (b, s, d), jnp.float32)],
        ),
        "adapter_sgd": (
            lambda lv, g, lr: (model.adapter_sgd(lv, g, lr),),
            [
                ("lora_vec", (ll,), jnp.float32),
                ("grad", (ll,), jnp.float32),
                ("lr", (1,), jnp.float32),
            ],
            [("lora_vec_out", (ll,), jnp.float32)],
        ),
        "train_step": (
            lambda tokens, labels, embed, bs, ls, hv, lr: model.train_step(
                tokens, labels, embed, bs, ls, hv, lr, cfg
            ),
            [
                ("tokens", (b, s), jnp.int32),
                ("labels", (b, s), jnp.int32),
                ("embed", (v, d), jnp.float32),
                ("base_stack", (nl, lb), jnp.float32),
                ("lora_stack", (nl, ll), jnp.float32),
                ("head_vec", (lh,), jnp.float32),
                ("lr", (1,), jnp.float32),
            ],
            [
                ("loss", (), jnp.float32),
                ("lora_stack_out", (nl, ll), jnp.float32),
            ],
        ),
    }


def lower_config(cfg: ModelConfig, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest: dict = {"config": cfg.to_dict(), "artifacts": {}, "layouts": {}}

    for name, (fn, in_specs, out_specs) in segment_table(cfg).items():
        specs = [_spec(shape, dt) for _, shape, dt in in_specs]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": fname,
            "inputs": [
                {"name": n, "shape": list(shape), "dtype": _dt(dt)}
                for n, shape, dt in in_specs
            ],
            "outputs": [
                {"name": n, "shape": list(shape), "dtype": _dt(dt)}
                for n, shape, dt in out_specs
            ],
        }
        print(f"  {name}: {len(text)} chars -> {fname}")

    for lname, layout in (
        ("base_layer", base_layer_layout(cfg)),
        ("lora_layer", lora_layer_layout(cfg)),
        ("head", head_layout(cfg)),
    ):
        manifest["layouts"][lname] = [
            {"name": n, "offset": off, "shape": list(shape)}
            for n, off, shape in layout_offsets(layout)
        ]

    path = os.path.join(out_dir, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"  manifest -> {path}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", default="tiny", choices=sorted(CONFIGS))
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()

    cfg = CONFIGS[args.config]
    if cfg.name == "llama1b":
        raise SystemExit(
            "llama1b parameterizes the Rust cost model only; compiling its "
            "artifacts is intentionally unsupported (DESIGN.md §2)."
        )
    out = os.path.join(args.out_dir, cfg.name)
    print(f"AOT-lowering config '{cfg.name}' ({cfg.n_params/1e6:.1f}M params) -> {out}")
    lower_config(cfg, out)


if __name__ == "__main__":
    main()
