"""Independent pure-jnp reference of the full model (no Pallas).

Used by pytest as an end-to-end oracle for the L2 segments: identical
parameters in -> allclose logits/loss/grads out.  Deliberately written
against ``kernels.ref`` so a bug in the Pallas kernels or the segment
plumbing cannot cancel itself out.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.ref import lora_matmul_ref, rmsnorm_ref
from .layers import apply_rope, rope_angles
from .params import (
    base_layer_layout,
    head_layout,
    lora_layer_layout,
    unflatten,
)


def _proj(h, base, lora, name, cfg):
    w = base[f"w{name}" if name in ("q", "k", "v", "o") else f"w_{name}"]
    return lora_matmul_ref(
        h, w, lora[f"a_{name}"], lora[f"b_{name}"], alpha=cfg.lora_scale
    )


def ref_decoder_layer(h, base_vec, lora_vec, cfg: ModelConfig):
    base = unflatten(base_vec, base_layer_layout(cfg))
    lora = unflatten(lora_vec, lora_layer_layout(cfg))
    b, s, d = h.shape
    nh, hd = cfg.n_heads, cfg.head_dim

    x = rmsnorm_ref(h, base["rms1"], eps=cfg.rms_eps)
    q = _proj(x, base, lora, "q", cfg).reshape(b, s, nh, hd)
    k = _proj(x, base, lora, "k", cfg).reshape(b, s, nh, hd)
    v = _proj(x, base, lora, "v", cfg).reshape(b, s, nh, hd)
    ang = rope_angles(cfg)[:s]
    q, k = apply_rope(q, ang), apply_rope(k, ang)
    scores = jnp.einsum("bihd,bjhd->bhij", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    ctx = jnp.einsum(
        "bhij,bjhd->bihd", jax.nn.softmax(scores, axis=-1), v
    ).reshape(b, s, d)
    h = h + _proj(ctx, base, lora, "o", cfg)

    x = rmsnorm_ref(h, base["rms2"], eps=cfg.rms_eps)
    g = _proj(x, base, lora, "gate", cfg)
    u = _proj(x, base, lora, "up", cfg)
    h = h + _proj(jax.nn.silu(g) * u, base, lora, "down", cfg)
    return h


def ref_forward(tokens, embed, base_stack, lora_stack, head_vec,
                cfg: ModelConfig):
    h = embed[tokens]
    for i in range(cfg.n_layers):
        h = ref_decoder_layer(h, base_stack[i], lora_stack[i], cfg)
    head = unflatten(head_vec, head_layout(cfg))
    hn = rmsnorm_ref(h, head["rms_f"], eps=cfg.rms_eps)
    return jnp.matmul(hn, head["lm_head"])


def ref_loss(tokens, labels, embed, base_stack, lora_stack, head_vec,
             cfg: ModelConfig):
    logits = ref_forward(tokens, embed, base_stack, lora_stack, head_vec, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return jnp.mean(-jnp.take_along_axis(logp, labels[..., None], axis=-1))
