"""L2 — the split-segment functions that become HLO artifacts.

The cut layer changes every round (CARD), so the split must be dynamic at
runtime while HLO is static.  We therefore compile a small closed set of
*segment* functions; the Rust executor chains them (DESIGN.md §3):

    device FP  :  embed_fwd, then c × layer_fwd
    server FP  :  (I−c) × layer_fwd, then head_loss_grad
    server BP  :  (I−c) × layer_bwd (recompute-style VJP)
    device BP  :  c × layer_bwd after receiving the smashed-data gradient
    update     :  adapter_sgd per layer

Every function takes/returns flat f32 vectors (see params.py) so the Rust
side stays shape-agnostic.  A fused ``train_step`` over the whole model is
also exported to measure the chaining overhead (ablation A4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import rmsnorm
from .layers import decoder_layer
from .params import head_layout, unflatten


# ---------------------------------------------------------------------------
# segments
# ---------------------------------------------------------------------------


def embed_fwd(tokens: jax.Array, embed: jax.Array) -> jax.Array:
    """tokens (b, s) i32, embed (vocab, d) -> h (b, s, d)."""
    return embed[tokens]


def layer_fwd(h: jax.Array, base_vec: jax.Array, lora_vec: jax.Array,
              cfg: ModelConfig) -> jax.Array:
    """One decoder layer forward: h (b, s, d) -> (b, s, d)."""
    return decoder_layer(h, base_vec, lora_vec, cfg)


def layer_bwd(h_in: jax.Array, base_vec: jax.Array, lora_vec: jax.Array,
              g_out: jax.Array, cfg: ModelConfig):
    """Recompute-style VJP of one layer.

    Takes the layer's *input* activation (stashed during FP), recomputes
    the forward internally, and returns (g_in, g_lora).  The frozen base
    weights get no gradient (LoRA contract, kernels/lora.py).
    """
    _, vjp = jax.vjp(lambda h, lv: layer_fwd(h, base_vec, lv, cfg), h_in, lora_vec)
    g_in, g_lora = vjp(g_out)
    return g_in, g_lora


def head_loss_grad(h: jax.Array, head_vec: jax.Array, labels: jax.Array,
                   cfg: ModelConfig):
    """Final norm + LM head + mean token cross-entropy.

    h (b, s, d), labels (b, s) i32 -> (loss (), g_h (b, s, d)).
    The head is frozen (no LoRA) so only the activation gradient crosses
    back into the layer chain.
    """

    def loss_fn(h):
        head = unflatten(head_vec, head_layout(cfg))
        hn = rmsnorm(h, head["rms_f"], eps=cfg.rms_eps)
        logits = jnp.matmul(hn, head["lm_head"])  # (b, s, vocab)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)
        return jnp.mean(nll)

    loss, g_h = jax.value_and_grad(loss_fn)(h)
    return loss, g_h


def adapter_sgd(lora_vec: jax.Array, grad: jax.Array, lr: jax.Array) -> jax.Array:
    """SGD step on one layer's flat adapter vector; lr is a (1,) array so
    the same compiled executable serves any learning-rate schedule."""
    return lora_vec - lr[0] * grad


# ---------------------------------------------------------------------------
# fused whole-model train step (ablation A4: chaining overhead baseline)
# ---------------------------------------------------------------------------


def full_forward(tokens, embed, base_stack, lora_stack, head_vec,
                 cfg: ModelConfig):
    """Whole-model forward via lax.scan over the layer stack."""
    h = embed_fwd(tokens, embed)

    def body(h, vecs):
        bvec, lvec = vecs
        return layer_fwd(h, bvec, lvec, cfg), None

    h, _ = jax.lax.scan(body, h, (base_stack, lora_stack))
    head = unflatten(head_vec, head_layout(cfg))
    hn = rmsnorm(h, head["rms_f"], eps=cfg.rms_eps)
    return jnp.matmul(hn, head["lm_head"])


def full_loss(tokens, labels, embed, base_stack, lora_stack, head_vec,
              cfg: ModelConfig):
    logits = full_forward(tokens, embed, base_stack, lora_stack, head_vec, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)
    return jnp.mean(nll)


def train_step(tokens, labels, embed, base_stack, lora_stack, head_vec, lr,
               cfg: ModelConfig):
    """One fused SGD step on all LoRA adapters: returns (loss, new stack)."""
    loss, g = jax.value_and_grad(full_loss, argnums=4)(
        tokens, labels, embed, base_stack, lora_stack, head_vec, cfg
    )
    return loss, lora_stack - lr[0] * g
