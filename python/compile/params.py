"""Parameter layouts: structured pytrees <-> flat f32 vectors.

The Rust coordinator owns all state as flat f32 buffers (one per layer
for the frozen base weights, one per layer for the trainable LoRA
adapters, plus embed/head).  The HLO segment artifacts take those flat
vectors as arguments and unflatten them internally with static slices —
XLA folds the slicing away, and Rust never needs to know tensor shapes
beyond the manifest's layout table (exported by ``aot.py``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .configs import ModelConfig

# ---------------------------------------------------------------------------
# layout tables: (name, shape) in flat-vector order
# ---------------------------------------------------------------------------


def base_layer_layout(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    d, f = cfg.d_model, cfg.d_ff
    return [
        ("wq", (d, d)),
        ("wk", (d, d)),
        ("wv", (d, d)),
        ("wo", (d, d)),
        ("w_gate", (d, f)),
        ("w_up", (d, f)),
        ("w_down", (f, d)),
        ("rms1", (d,)),
        ("rms2", (d,)),
    ]


# projections carrying LoRA adapters, with (in_dim, out_dim) resolvers
LORA_PROJS: tuple[str, ...] = ("q", "k", "v", "o", "gate", "up", "down")


def _proj_dims(cfg: ModelConfig, proj: str) -> tuple[int, int]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "q": (d, d), "k": (d, d), "v": (d, d), "o": (d, d),
        "gate": (d, f), "up": (d, f), "down": (f, d),
    }[proj]


def lora_layer_layout(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    r = cfg.lora_rank
    out: list[tuple[str, tuple[int, ...]]] = []
    for proj in LORA_PROJS:
        din, dout = _proj_dims(cfg, proj)
        out.append((f"a_{proj}", (din, r)))
        out.append((f"b_{proj}", (r, dout)))
    return out


def head_layout(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    return [("rms_f", (cfg.d_model,)), ("lm_head", (cfg.d_model, cfg.vocab_size))]


def layout_len(layout: list[tuple[str, tuple[int, ...]]]) -> int:
    total = 0
    for _, shape in layout:
        n = 1
        for s in shape:
            n *= s
        total += n
    return total


def layout_offsets(
    layout: list[tuple[str, tuple[int, ...]]]
) -> list[tuple[str, int, tuple[int, ...]]]:
    """(name, offset, shape) triples — exported into manifest.json."""
    out, off = [], 0
    for name, shape in layout:
        n = 1
        for s in shape:
            n *= s
        out.append((name, off, shape))
        off += n
    return out


# ---------------------------------------------------------------------------
# flatten / unflatten
# ---------------------------------------------------------------------------


def flatten(tree: dict[str, jax.Array], layout) -> jax.Array:
    return jnp.concatenate([tree[name].reshape(-1) for name, _ in layout])


def unflatten(vec: jax.Array, layout) -> dict[str, jax.Array]:
    out, off = {}, 0
    for name, shape in layout:
        n = 1
        for s in shape:
            n *= s
        out[name] = jax.lax.slice(vec, (off,), (off + n,)).reshape(shape)
        off += n
    return out


# ---------------------------------------------------------------------------
# initialization
# ---------------------------------------------------------------------------


def init_base_layer(key: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Random 'pre-trained' base weights (frozen): scaled-normal matrices,
    unit RMS gains."""
    parts = {}
    for i, (name, shape) in enumerate(base_layer_layout(cfg)):
        k = jax.random.fold_in(key, i)
        if name.startswith("rms"):
            parts[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[0]
            parts[name] = (
                jax.random.normal(k, shape, jnp.float32) * (fan_in ** -0.5)
            )
    return flatten(parts, base_layer_layout(cfg))


def init_lora_layer(key: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Standard LoRA init: A ~ N(0, 0.02²), B = 0 (adapter starts as a
    no-op; the paper initializes adapters randomly — Stage 0)."""
    parts = {}
    for i, (name, shape) in enumerate(lora_layer_layout(cfg)):
        k = jax.random.fold_in(key, i)
        if name.startswith("a_"):
            parts[name] = jax.random.normal(k, shape, jnp.float32) * 0.02
        else:
            parts[name] = jnp.zeros(shape, jnp.float32)
    return flatten(parts, lora_layer_layout(cfg))


def init_embed(key: jax.Array, cfg: ModelConfig) -> jax.Array:
    return jax.random.normal(
        key, (cfg.vocab_size, cfg.d_model), jnp.float32
    ) * (cfg.d_model ** -0.5)


def init_head(key: jax.Array, cfg: ModelConfig) -> jax.Array:
    parts = {
        "rms_f": jnp.ones((cfg.d_model,), jnp.float32),
        "lm_head": jax.random.normal(
            key, (cfg.d_model, cfg.vocab_size), jnp.float32
        )
        * (cfg.d_model ** -0.5),
    }
    return flatten(parts, head_layout(cfg))


def init_all(seed: int, cfg: ModelConfig) -> dict[str, jax.Array]:
    """Full model state: embed, per-layer base stack, per-layer LoRA
    stack, head vec."""
    key = jax.random.key(seed)
    base = jnp.stack(
        [init_base_layer(jax.random.fold_in(key, 100 + i), cfg) for i in range(cfg.n_layers)]
    )
    lora = jnp.stack(
        [init_lora_layer(jax.random.fold_in(key, 200 + i), cfg) for i in range(cfg.n_layers)]
    )
    return {
        "embed": init_embed(jax.random.fold_in(key, 0), cfg),
        "base": base,
        "lora": lora,
        "head": init_head(jax.random.fold_in(key, 1), cfg),
    }
