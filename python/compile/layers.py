"""L2 building blocks: RoPE, causal MHA, SwiGLU — all LoRA-adapted
projections go through the fused Pallas kernel (``kernels.lora_matmul``),
all norms through ``kernels.rmsnorm``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import lora_matmul, rmsnorm
from .params import (
    base_layer_layout,
    lora_layer_layout,
    unflatten,
)


def rope_angles(cfg: ModelConfig) -> jax.Array:
    """(seq, head_dim/2) rotation angles, LLaMA half-split convention."""
    hd = cfg.head_dim
    inv_freq = cfg.rope_theta ** (
        -jnp.arange(0, hd, 2, dtype=jnp.float32) / hd
    )
    pos = jnp.arange(cfg.seq_len, dtype=jnp.float32)
    return pos[:, None] * inv_freq[None, :]


def apply_rope(x: jax.Array, angles: jax.Array) -> jax.Array:
    """x: (b, s, nh, hd) -> rotated; half-split (not interleaved)."""
    hd = x.shape[-1]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2 :]
    cos = jnp.cos(angles)[None, :, None, :]
    sin = jnp.sin(angles)[None, :, None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1
    )


def _proj(h, base, lora, name, cfg):
    """LoRA-adapted projection through the fused Pallas kernel."""
    return lora_matmul(
        h,
        base[f"w{name}" if name in ("q", "k", "v", "o") else f"w_{name}"],
        lora[f"a_{name}"],
        lora[f"b_{name}"],
        alpha=cfg.lora_scale,
    )


def attention(h, base, lora, cfg: ModelConfig) -> jax.Array:
    """Causal multi-head attention with RoPE, LoRA on q/k/v/o."""
    b, s, d = h.shape
    nh, hd = cfg.n_heads, cfg.head_dim

    q = _proj(h, base, lora, "q", cfg).reshape(b, s, nh, hd)
    k = _proj(h, base, lora, "k", cfg).reshape(b, s, nh, hd)
    v = _proj(h, base, lora, "v", cfg).reshape(b, s, nh, hd)

    ang = rope_angles(cfg)[:s]
    q, k = apply_rope(q, ang), apply_rope(k, ang)

    scores = jnp.einsum("bihd,bjhd->bhij", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhij,bjhd->bihd", probs, v).reshape(b, s, d)
    return _proj(ctx, base, lora, "o", cfg)


def swiglu(h, base, lora, cfg: ModelConfig) -> jax.Array:
    """SwiGLU MLP: down( silu(gate(h)) * up(h) ), LoRA on all three."""
    g = _proj(h, base, lora, "gate", cfg)
    u = _proj(h, base, lora, "up", cfg)
    return _proj(jax.nn.silu(g) * u, base, lora, "down", cfg)


def decoder_layer(h, base_vec, lora_vec, cfg: ModelConfig) -> jax.Array:
    """One pre-norm decoder layer over flat parameter vectors."""
    base = unflatten(base_vec, base_layer_layout(cfg))
    lora = unflatten(lora_vec, lora_layer_layout(cfg))
    h = h + attention(rmsnorm(h, base["rms1"], eps=cfg.rms_eps), base, lora, cfg)
    h = h + swiglu(rmsnorm(h, base["rms2"], eps=cfg.rms_eps), base, lora, cfg)
    return h
