"""AOT pipeline checks: HLO text well-formedness, manifest completeness,
and executable round-trip of the lowered segments on the CPU backend
(pre-flight for the Rust PJRT loader)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model, params
from compile.configs import CONFIGS

jax.config.update("jax_platform_name", "cpu")

CFG = CONFIGS["nano"]


@pytest.fixture(scope="module")
def lowered_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts") / CFG.name
    aot.lower_config(CFG, str(out))
    return str(out)


@pytest.fixture(scope="module")
def manifest(lowered_dir):
    with open(os.path.join(lowered_dir, "manifest.json")) as f:
        return json.load(f)


EXPECTED_SEGMENTS = (
    "embed_fwd",
    "layer_fwd",
    "layer_bwd",
    "head_loss_grad",
    "adapter_sgd",
    "train_step",
)


class TestManifest:
    def test_all_segments_present(self, manifest, lowered_dir):
        for seg in EXPECTED_SEGMENTS:
            assert seg in manifest["artifacts"]
            path = os.path.join(lowered_dir, manifest["artifacts"][seg]["file"])
            assert os.path.getsize(path) > 0

    def test_config_dims_exported(self, manifest):
        c = manifest["config"]
        for key in (
            "d_model", "n_layers", "seq_len", "batch_size",
            "base_layer_len", "lora_layer_len", "head_len", "lora_scale",
        ):
            assert key in c
        assert c["d_model"] == CFG.d_model

    def test_layouts_cover_vectors(self, manifest):
        for lname, total in (
            ("base_layer", CFG.base_layer_len),
            ("lora_layer", CFG.lora_layer_len),
            ("head", CFG.head_len),
        ):
            entries = manifest["layouts"][lname]
            last = entries[-1]
            n = 1
            for s in last["shape"]:
                n *= s
            assert last["offset"] + n == total

    def test_io_shapes_match_config(self, manifest):
        lf = manifest["artifacts"]["layer_fwd"]
        assert lf["inputs"][0]["shape"] == [CFG.batch_size, CFG.seq_len, CFG.d_model]
        assert lf["inputs"][2]["shape"] == [CFG.lora_layer_len]
        assert lf["outputs"][0]["shape"] == [CFG.batch_size, CFG.seq_len, CFG.d_model]


class TestHloText:
    def test_hlo_is_text_not_proto(self, manifest, lowered_dir):
        for seg in EXPECTED_SEGMENTS:
            path = os.path.join(lowered_dir, manifest["artifacts"][seg]["file"])
            with open(path, "rb") as f:
                head = f.read(64)
            text = head.decode("utf-8")  # must not raise
            assert "HloModule" in text

    def test_entry_computation_arity(self, manifest, lowered_dir):
        """Parameter count in the entry computation == manifest inputs,
        and the entry layout tuple matches the manifest output count."""
        for seg in EXPECTED_SEGMENTS:
            meta = manifest["artifacts"][seg]
            path = os.path.join(lowered_dir, meta["file"])
            with open(path) as f:
                text = f.read()
            # entry computation is the block after the line starting ENTRY
            entry = text.split("\nENTRY ", 1)[1]
            body = entry.split("\n}", 1)[0]
            n_params = sum(
                1 for line in body.splitlines() if " parameter(" in line
            )
            assert n_params == len(meta["inputs"]), (seg, n_params)
            # entry_computation_layout: "(...)->(out1, out2, ...)"
            layout = text.splitlines()[0].split("->", 1)[1]
            n_outs = layout.count("f32[") + layout.count("s32[") + layout.count("f32]")
            # scalars print as f32[] — count commas+1 inside the tuple instead
            inner = layout[layout.index("(") + 1 : layout.rindex(")")]
            depth, n_outs = 0, 1
            for ch in inner:
                if ch in "{[":
                    depth += 1
                elif ch in "}]":
                    depth -= 1
                elif ch == "," and depth == 0:
                    n_outs += 1
            assert n_outs == len(meta["outputs"]), (seg, layout)


class TestExecutableRoundTrip:
    """Compile the emitted HLO text back through XLA and compare against
    direct jax execution — the same numerics the Rust loader will see."""

    def _run_hlo(self, lowered_dir, manifest, seg, args):
        from jax._src.lib import xla_client as xc

        path = os.path.join(lowered_dir, manifest["artifacts"][seg]["file"])
        with open(path) as f:
            text = f.read()
        comp = xc.XlaComputation(
            xc._xla.hlo_module_proto_from_text(text).SerializeToString()
        )
        backend = jax.devices("cpu")[0].client
        exe = backend.compile(comp.as_serialized_hlo_module_proto())
        outs = exe.execute_sharded(
            [backend.buffer_from_pyval(np.asarray(a)) for a in args]
        )
        return [np.asarray(x[0]) for x in outs.disassemble_into_single_device_arrays()]

    def test_adapter_sgd_roundtrip(self, lowered_dir, manifest):
        ll = CFG.lora_layer_len
        v = np.random.default_rng(0).normal(size=ll).astype(np.float32)
        g = np.random.default_rng(1).normal(size=ll).astype(np.float32)
        lr = np.array([0.1], np.float32)
        try:
            outs = self._run_hlo(lowered_dir, manifest, "adapter_sgd", [v, g, lr])
        except Exception as e:  # pragma: no cover - API drift guard
            pytest.skip(f"direct XLA client API unavailable: {e}")
        np.testing.assert_allclose(outs[0], v - 0.1 * g, rtol=1e-6)

    def test_layer_fwd_roundtrip(self, lowered_dir, manifest):
        st = params.init_all(0, CFG)
        h = np.random.default_rng(2).normal(
            size=(CFG.batch_size, CFG.seq_len, CFG.d_model)
        ).astype(np.float32) * 0.1
        bv = np.asarray(st["base"][0])
        lv = np.asarray(st["lora"][0])
        try:
            outs = self._run_hlo(lowered_dir, manifest, "layer_fwd", [h, bv, lv])
        except Exception as e:  # pragma: no cover
            pytest.skip(f"direct XLA client API unavailable: {e}")
        want = model.layer_fwd(jnp.asarray(h), st["base"][0], st["lora"][0], CFG)
        np.testing.assert_allclose(outs[0], want, rtol=1e-4, atol=1e-4)
