"""L2 correctness: split segments vs fused model vs independent pure-jnp
reference, plus the training-dynamics sanity checks the split protocol
relies on (Stage 3/4 of the paper's framework)."""

import jax
import jax.numpy as jnp
import jax.random as jr
import numpy as np
import pytest

from compile import model, params
from compile.configs import CONFIGS
from compile.ref_model import ref_forward, ref_loss

jax.config.update("jax_platform_name", "cpu")

CFG = CONFIGS["nano"]


@pytest.fixture(scope="module")
def state():
    st = params.init_all(0, CFG)
    # randomize B matrices so adapters contribute (default init is B=0)
    st["lora"] = st["lora"] + jr.normal(jr.key(9), st["lora"].shape) * 0.01
    return st


@pytest.fixture(scope="module")
def batch():
    tok = jr.randint(jr.key(1), (CFG.batch_size, CFG.seq_len), 0, CFG.vocab_size)
    lab = jr.randint(jr.key(2), (CFG.batch_size, CFG.seq_len), 0, CFG.vocab_size)
    return tok, lab


def _chain_forward(st, tok):
    """Device FP (embed + c layers) then server FP — at any cut the chain
    is the same ops, so we run all layers and stash activations."""
    h = model.embed_fwd(tok, st["embed"])
    acts = [h]
    for i in range(CFG.n_layers):
        h = model.layer_fwd(h, st["base"][i], st["lora"][i], CFG)
        acts.append(h)
    return h, acts


class TestForwardConsistency:
    def test_fused_matches_independent_ref(self, state, batch):
        tok, _ = batch
        got = model.full_forward(
            tok, state["embed"], state["base"], state["lora"], state["head"], CFG
        )
        want = ref_forward(
            tok, state["embed"], state["base"], state["lora"], state["head"], CFG
        )
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_chained_segments_match_fused_loss(self, state, batch):
        tok, lab = batch
        h, _ = _chain_forward(state, tok)
        loss, _ = model.head_loss_grad(h, state["head"], lab, CFG)
        fused = model.full_loss(
            tok, lab, state["embed"], state["base"], state["lora"], state["head"], CFG
        )
        np.testing.assert_allclose(float(loss), float(fused), rtol=1e-5)

    def test_embed_fwd_is_gather(self, state):
        tok = jnp.array([[0, 1], [2, 3]], jnp.int32)
        h = model.embed_fwd(tok, state["embed"])
        np.testing.assert_allclose(h[0, 0], state["embed"][0])
        np.testing.assert_allclose(h[1, 1], state["embed"][3])

    def test_causality(self, state, batch):
        """Future tokens must not influence past positions (decoder mask)."""
        tok, _ = batch
        h1, _ = _chain_forward(state, tok)
        tok2 = tok.at[:, -1].set((tok[:, -1] + 1) % CFG.vocab_size)
        h2, _ = _chain_forward(state, tok2)
        np.testing.assert_allclose(
            h1[:, : CFG.seq_len - 1], h2[:, : CFG.seq_len - 1], rtol=1e-4, atol=1e-5
        )
        assert float(jnp.abs(h1[:, -1] - h2[:, -1]).max()) > 1e-4

    def test_lora_adapters_change_output(self, state, batch):
        tok, _ = batch
        h1, _ = _chain_forward(state, tok)
        st2 = dict(state)
        st2["lora"] = state["lora"] + 0.05
        h2, _ = _chain_forward(st2, tok)
        assert float(jnp.abs(h1 - h2).max()) > 1e-3


class TestBackwardConsistency:
    def test_chained_bwd_matches_fused_grad(self, state, batch):
        tok, lab = batch
        h, acts = _chain_forward(state, tok)
        _, g = model.head_loss_grad(h, state["head"], lab, CFG)
        per_layer = []
        for i in reversed(range(CFG.n_layers)):
            g, g_lora = model.layer_bwd(
                acts[i], state["base"][i], state["lora"][i], g, CFG
            )
            per_layer.append(g_lora)
        chained = jnp.stack(per_layer[::-1])
        fused = jax.grad(model.full_loss, argnums=4)(
            tok, lab, state["embed"], state["base"], state["lora"], state["head"], CFG
        )
        np.testing.assert_allclose(chained, fused, rtol=1e-4, atol=1e-6)

    def test_fused_grad_matches_ref_autodiff(self, state, batch):
        tok, lab = batch
        got = jax.grad(model.full_loss, argnums=4)(
            tok, lab, state["embed"], state["base"], state["lora"], state["head"], CFG
        )
        want = jax.grad(ref_loss, argnums=4)(
            tok, lab, state["embed"], state["base"], state["lora"], state["head"], CFG
        )
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-5)

    def test_smashed_gradient_nonzero_at_every_cut(self, state, batch):
        """Stage 4: the gradient crossing the cut must carry signal for
        every feasible cut layer."""
        tok, lab = batch
        h, acts = _chain_forward(state, tok)
        _, g = model.head_loss_grad(h, state["head"], lab, CFG)
        for i in reversed(range(CFG.n_layers)):
            assert float(jnp.abs(g).max()) > 0.0, f"zero smashed grad at layer {i}"
            g, _ = model.layer_bwd(acts[i], state["base"][i], state["lora"][i], g, CFG)


class TestTrainingDynamics:
    def test_sgd_step_reduces_loss(self, state, batch):
        tok, lab = batch
        lr = jnp.array([0.5], jnp.float32)
        loss0, lora1 = model.train_step(
            tok, lab, state["embed"], state["base"], state["lora"], state["head"],
            lr, CFG,
        )
        loss1, _ = model.train_step(
            tok, lab, state["embed"], state["base"], lora1, state["head"], lr, CFG
        )
        assert float(loss1) < float(loss0)

    def test_adapter_sgd_formula(self):
        v = jnp.arange(8.0)
        g = jnp.ones(8)
        out = model.adapter_sgd(v, g, jnp.array([0.25]))
        np.testing.assert_allclose(out, v - 0.25)

    def test_loss_is_log_vocab_at_init_uniformish(self, batch):
        """With B=0 LoRA init and random base, loss ≈ ln(vocab) ± slack."""
        st = params.init_all(3, CFG)
        tok, lab = batch
        loss = model.full_loss(
            tok, lab, st["embed"], st["base"], st["lora"], st["head"], CFG
        )
        assert abs(float(loss) - float(jnp.log(CFG.vocab_size))) < 2.0

    def test_b_zero_init_means_identity_adapter(self, batch):
        """Standard LoRA init (B=0): adapters are a no-op at step 0, so
        zeroing A too must not change the forward."""
        st = params.init_all(4, CFG)
        tok, _ = batch
        l1 = model.full_forward(tok, st["embed"], st["base"], st["lora"], st["head"], CFG)
        l2 = model.full_forward(
            tok, st["embed"], st["base"], jnp.zeros_like(st["lora"]), st["head"], CFG
        )
        np.testing.assert_allclose(l1, l2, rtol=1e-5, atol=1e-6)


class TestParamLayouts:
    def test_flat_lengths_match_config(self):
        assert params.layout_len(params.base_layer_layout(CFG)) == CFG.base_layer_len
        assert params.layout_len(params.lora_layer_layout(CFG)) == CFG.lora_layer_len
        assert params.layout_len(params.head_layout(CFG)) == CFG.head_len

    def test_flatten_unflatten_roundtrip(self):
        key = jr.key(0)
        layout = params.lora_layer_layout(CFG)
        tree = {
            name: jr.normal(jr.fold_in(key, i), shape)
            for i, (name, shape) in enumerate(layout)
        }
        rt = params.unflatten(params.flatten(tree, layout), layout)
        for name, _ in layout:
            np.testing.assert_allclose(rt[name], tree[name])

    def test_offsets_are_contiguous(self):
        offs = params.layout_offsets(params.base_layer_layout(CFG))
        running = 0
        for name, off, shape in offs:
            assert off == running
            n = 1
            for s in shape:
                n *= s
            running += n
        assert running == CFG.base_layer_len

    def test_all_compiled_configs_have_divisible_heads(self):
        for name, cfg in CONFIGS.items():
            assert cfg.d_model % cfg.n_heads == 0, name
