"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

This is the CORE correctness signal for the compute layer — hypothesis
sweeps shapes/ranks/alphas/block sizes and asserts allclose against
``kernels.ref``, for both the forward values and the custom-VJP
gradients.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    lora_matmul,
    mxu_utilization_estimate,
    rmsnorm,
    vmem_footprint_bytes,
)
from compile.kernels.ref import lora_matmul_ref, rmsnorm_ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, *shape, scale=1.0):
    return jax.random.normal(jax.random.key(key), shape) * scale


# ---------------------------------------------------------------------------
# lora_matmul forward
# ---------------------------------------------------------------------------


class TestLoraMatmulForward:
    def test_matches_ref_square(self):
        x, w = _rand(0, 64, 64), _rand(1, 64, 64)
        a, b = _rand(2, 64, 8), _rand(3, 8, 64)
        np.testing.assert_allclose(
            lora_matmul(x, w, a, b), lora_matmul_ref(x, w, a, b), rtol=1e-5, atol=1e-4
        )

    def test_alpha_zero_is_base_gemm(self):
        x, w = _rand(0, 32, 48), _rand(1, 48, 40)
        a, b = _rand(2, 48, 4), _rand(3, 4, 40)
        np.testing.assert_allclose(
            lora_matmul(x, w, a, b, alpha=0.0),
            jnp.matmul(x, w),
            rtol=1e-5,
            atol=1e-4,
        )

    def test_zero_base_is_scaled_lora(self):
        x = _rand(0, 16, 24)
        w = jnp.zeros((24, 20))
        a, b = _rand(1, 24, 4), _rand(2, 4, 20)
        np.testing.assert_allclose(
            lora_matmul(x, w, a, b, alpha=2.0),
            2.0 * (x @ a) @ b,
            rtol=1e-5,
            atol=1e-4,
        )

    def test_batched_input_3d(self):
        x = _rand(0, 4, 16, 32)
        w, a, b = _rand(1, 32, 24), _rand(2, 32, 8), _rand(3, 8, 24)
        y = lora_matmul(x, w, a, b, alpha=0.7)
        assert y.shape == (4, 16, 24)
        np.testing.assert_allclose(
            y, lora_matmul_ref(x, w, a, b, alpha=0.7), rtol=1e-5, atol=1e-4
        )

    def test_rank_one_adapter(self):
        x, w = _rand(0, 8, 8), _rand(1, 8, 8)
        a, b = _rand(2, 8, 1), _rand(3, 1, 8)
        np.testing.assert_allclose(
            lora_matmul(x, w, a, b), lora_matmul_ref(x, w, a, b), rtol=1e-5, atol=1e-4
        )

    def test_single_row(self):
        x, w = _rand(0, 1, 64), _rand(1, 64, 32)
        a, b = _rand(2, 64, 8), _rand(3, 8, 32)
        np.testing.assert_allclose(
            lora_matmul(x, w, a, b), lora_matmul_ref(x, w, a, b), rtol=1e-5, atol=1e-4
        )

    def test_explicit_blocks_partition_k(self):
        # K split across 4 grid steps exercises the accumulator init/epilogue.
        x, w = _rand(0, 32, 128), _rand(1, 128, 64)
        a, b = _rand(2, 128, 8), _rand(3, 8, 64)
        y = lora_matmul(x, w, a, b, alpha=0.3, bm=16, bn=32, bk=32)
        np.testing.assert_allclose(
            y, lora_matmul_ref(x, w, a, b, alpha=0.3), rtol=1e-5, atol=1e-4
        )

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.sampled_from([1, 3, 8, 17, 64]),
        k=st.sampled_from([4, 16, 48, 128]),
        n=st.sampled_from([2, 8, 40, 96]),
        r=st.sampled_from([1, 2, 4, 8, 16]),
        alpha=st.sampled_from([0.0, 0.5, 1.0, 2.0]),
    )
    def test_hypothesis_shape_sweep(self, m, k, n, r, alpha):
        x, w = _rand(m * 7 + 1, m, k), _rand(k * 5 + 2, k, n)
        a, b = _rand(n * 3 + 3, k, r, scale=0.3), _rand(r + 4, r, n, scale=0.3)
        np.testing.assert_allclose(
            lora_matmul(x, w, a, b, alpha=alpha),
            lora_matmul_ref(x, w, a, b, alpha=alpha),
            rtol=1e-4,
            atol=1e-3,
        )

    @settings(max_examples=10, deadline=None)
    @given(
        bm=st.sampled_from([8, 16, 32, 64]),
        bn=st.sampled_from([8, 16, 32, 64]),
        bk=st.sampled_from([8, 16, 32, 64]),
    )
    def test_hypothesis_block_sweep(self, bm, bn, bk):
        # Result must be block-shape independent.
        x, w = _rand(0, 64, 64), _rand(1, 64, 64)
        a, b = _rand(2, 64, 8, scale=0.3), _rand(3, 8, 64, scale=0.3)
        np.testing.assert_allclose(
            lora_matmul(x, w, a, b, bm=bm, bn=bn, bk=bk),
            lora_matmul_ref(x, w, a, b),
            rtol=1e-4,
            atol=1e-3,
        )

    def test_bf16_inputs_accumulate_f32(self):
        x = _rand(0, 32, 64).astype(jnp.bfloat16)
        w = _rand(1, 64, 32).astype(jnp.bfloat16)
        a, b = _rand(2, 64, 8), _rand(3, 8, 32)
        y = lora_matmul(x, w, a, b)
        assert y.dtype == jnp.float32
        np.testing.assert_allclose(
            y, lora_matmul_ref(x, w, a, b), rtol=2e-2, atol=2e-1
        )


# ---------------------------------------------------------------------------
# lora_matmul gradients (custom VJP)
# ---------------------------------------------------------------------------


class TestLoraMatmulGrad:
    def _setup(self):
        x = _rand(0, 4, 8, 32)
        w = _rand(1, 32, 24)
        a, b = _rand(2, 32, 4, scale=0.2), _rand(3, 4, 24, scale=0.2)
        return x, w, a, b

    def test_grads_match_ref_autodiff(self):
        x, w, a, b = self._setup()

        def f(x, a, b):
            return jnp.sum(jnp.tanh(lora_matmul(x, w, a, b, alpha=0.5)))

        def fr(x, a, b):
            return jnp.sum(jnp.tanh(lora_matmul_ref(x, w, a, b, alpha=0.5)))

        got = jax.grad(f, argnums=(0, 1, 2))(x, a, b)
        want = jax.grad(fr, argnums=(0, 1, 2))(x, a, b)
        for g, r in zip(got, want):
            np.testing.assert_allclose(g, r, rtol=1e-4, atol=1e-4)

    def test_frozen_base_weight_grad_is_zero(self):
        x, w, a, b = self._setup()
        dw = jax.grad(lambda w: jnp.sum(lora_matmul(x, w, a, b)))(w)
        assert float(jnp.abs(dw).max()) == 0.0

    def test_grad_through_jit(self):
        x, w, a, b = self._setup()
        f = jax.jit(lambda x, a, b: jnp.sum(lora_matmul(x, w, a, b) ** 2))
        g = jax.grad(f)(x, a, b)
        assert g.shape == x.shape and bool(jnp.all(jnp.isfinite(g)))

    @settings(max_examples=10, deadline=None)
    @given(
        m=st.sampled_from([2, 8, 16]),
        r=st.sampled_from([1, 4, 8]),
        alpha=st.sampled_from([0.25, 1.0]),
    )
    def test_hypothesis_grad_sweep(self, m, r, alpha):
        x, w = _rand(10 + m, m, 16), _rand(11, 16, 12)
        a, b = _rand(12 + r, 16, r, scale=0.3), _rand(13, r, 12, scale=0.3)

        def f(a, b):
            return jnp.sum(lora_matmul(x, w, a, b, alpha=alpha) ** 2)

        def fr(a, b):
            return jnp.sum(lora_matmul_ref(x, w, a, b, alpha=alpha) ** 2)

        got = jax.grad(f, argnums=(0, 1))(a, b)
        want = jax.grad(fr, argnums=(0, 1))(a, b)
        for g, rr in zip(got, want):
            np.testing.assert_allclose(g, rr, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------


class TestRmsNorm:
    def test_matches_ref(self):
        x, g = _rand(0, 16, 64), _rand(1, 64)
        np.testing.assert_allclose(
            rmsnorm(x, g), rmsnorm_ref(x, g), rtol=1e-5, atol=1e-5
        )

    def test_3d_input(self):
        x, g = _rand(0, 2, 8, 32), _rand(1, 32)
        y = rmsnorm(x, g)
        assert y.shape == x.shape
        np.testing.assert_allclose(y, rmsnorm_ref(x, g), rtol=1e-5, atol=1e-5)

    def test_unit_gain_unit_rows(self):
        # A row of constant v normalizes to ±1 with unit gain.
        x = jnp.full((4, 16), 3.0)
        y = rmsnorm(x, jnp.ones(16))
        np.testing.assert_allclose(y, jnp.ones((4, 16)), rtol=1e-4)

    def test_scale_invariance(self):
        # rmsnorm(c·x) == rmsnorm(x) for c > 0 (up to eps).
        x, g = _rand(0, 8, 48), _rand(1, 48)
        np.testing.assert_allclose(
            rmsnorm(100.0 * x, g), rmsnorm(x, g), rtol=1e-3, atol=1e-4
        )

    def test_grads_match_ref_autodiff(self):
        x, g = _rand(0, 4, 6, 32), _rand(1, 32)

        def f(x, g):
            return jnp.sum(jnp.sin(rmsnorm(x, g)))

        def fr(x, g):
            return jnp.sum(jnp.sin(rmsnorm_ref(x, g)))

        got = jax.grad(f, argnums=(0, 1))(x, g)
        want = jax.grad(fr, argnums=(0, 1))(x, g)
        for gg, rr in zip(got, want):
            np.testing.assert_allclose(gg, rr, rtol=1e-4, atol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(
        rows=st.sampled_from([1, 3, 16, 100]),
        d=st.sampled_from([1, 4, 64, 129]),
        scale=st.sampled_from([1e-3, 1.0, 1e3]),
    )
    def test_hypothesis_sweep(self, rows, d, scale):
        x, g = _rand(rows, rows, d, scale=scale), _rand(d, d)
        np.testing.assert_allclose(
            rmsnorm(x, g), rmsnorm_ref(x, g), rtol=1e-4, atol=1e-4
        )


# ---------------------------------------------------------------------------
# perf-model helpers (used by DESIGN.md §9 estimates)
# ---------------------------------------------------------------------------


class TestPerfModel:
    def test_vmem_footprint_within_budget_for_default_blocks(self):
        # Default 128³ tiles with r=16 must fit the ~16 MB VMEM budget.
        assert vmem_footprint_bytes(128, 128, 128, 16) < 16 * 2**20

    def test_footprint_monotone_in_blocks(self):
        assert vmem_footprint_bytes(256, 128, 128, 8) > vmem_footprint_bytes(
            128, 128, 128, 8
        )

    def test_mxu_utilization_aligned_tiles(self):
        u = mxu_utilization_estimate(1024, 1024, 1024, 16, 128, 128)
        assert 0.9 < u <= 1.0  # base GEMM fully aligned, small lora tax

    def test_mxu_utilization_misaligned_tiles_worse(self):
        good = mxu_utilization_estimate(1024, 1024, 1024, 16, 128, 128)
        bad = mxu_utilization_estimate(1024, 1024, 1024, 16, 72, 72)
        assert bad < good
