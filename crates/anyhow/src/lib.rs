//! Offline drop-in subset of the `anyhow` crate.
//!
//! The build container has no crates.io access, so this path crate
//! provides the exact API surface `edgesplit` uses: `Error`, `Result`,
//! the `Context` extension trait (on `Result` and `Option`), and the
//! `anyhow!` / `bail!` / `ensure!` macros.  Semantics mirror upstream
//! where it matters for the test-suite:
//!
//! * `{}`  (Display)            prints the outermost context only;
//! * `{:#}` (alternate Display) prints the whole chain, `": "`-joined;
//! * `{:?}` (Debug)             prints the message plus a `Caused by:`
//!   list, like upstream's report format;
//! * any `std::error::Error + Send + Sync + 'static` converts via `?`,
//!   capturing its `source()` chain.

use std::fmt;

/// A context-carrying error.  The chain is stored outermost-first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an additional layer of context (becomes outermost).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the context/cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: like upstream anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that is what makes this blanket `From` legal.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failure values, mirroring upstream `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless `$cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_outermost_alternate_chain() {
        let e: Error = Error::from(io_err()).context("reading config");
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(format!("{:#}", inner().unwrap_err()).contains("gone"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: gone");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
    }

    #[test]
    fn macros_format() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("unlucky {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(3).unwrap_err()), "unlucky 3");
        assert_eq!(format!("{}", f(11).unwrap_err()), "x too big: 11");
        let e = anyhow!("plain {}", "msg");
        assert_eq!(format!("{e}"), "plain msg");
    }

    #[test]
    fn debug_report_lists_causes() {
        let e = Error::from(io_err()).context("ctx");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("ctx"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("gone"));
    }
}
