//! Offline stub of the forked `xla` PJRT bindings.
//!
//! The real crate wraps `xla_extension` (PJRT CPU client + HLO-proto
//! compilation, with an untuple patch on `execute_b`).  That native
//! library is not available in this container, so this shim keeps the
//! whole workspace compiling and the artifact *plumbing* (manifest
//! parsing, shape/dtype validation, literal round-trips) fully
//! functional, while actual HLO execution reports a clear error at
//! `PjRtClient::compile` time.  Every runtime test that needs compiled
//! artifacts already self-skips when `artifacts/` is absent, so plain
//! `cargo test` stays green without a PJRT backend.
//!
//! API parity notes (only what `edgesplit::runtime` touches):
//! * `Literal::create_from_shape_and_untyped_data` / `array_shape` /
//!   `to_vec::<T>` / `to_tuple` are real host-side implementations;
//! * `PjRtClient::cpu()` succeeds (the store is constructible offline);
//! * `compile` / `execute` / `execute_b` return `Err` with a message
//!   naming this shim.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error` closely enough for `?` + context.
#[derive(Clone, Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const UNAVAILABLE: &str = "PJRT backend unavailable: this build uses the offline `xla` stub crate \
     (crates/xla); link the forked xla_extension bindings to execute HLO artifacts";

/// XLA primitive element types (subset relevant to the artifacts).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    Bf16,
    F16,
    F32,
    F64,
}

impl ElementType {
    /// Size of one element in bytes.
    pub fn byte_size(self) -> usize {
        match self {
            ElementType::Pred | ElementType::S8 | ElementType::U8 => 1,
            ElementType::S16 | ElementType::U16 | ElementType::Bf16 | ElementType::F16 => 2,
            ElementType::S32 | ElementType::U32 | ElementType::F32 => 4,
            ElementType::S64 | ElementType::U64 | ElementType::F64 => 8,
        }
    }
}

/// Shape of a (non-tuple) array literal.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Rust scalar types that map onto an XLA `ElementType`.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn from_le(bytes: &[u8]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le(b: &[u8]) -> Self {
        f32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le(b: &[u8]) -> Self {
        i32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }
}

/// Host-side literal: an array (shape + little-endian bytes) or a tuple.
#[derive(Clone, Debug)]
pub struct Literal {
    shape: ArrayShape,
    data: Vec<u8>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let n: usize = dims.iter().product();
        let want = n * ty.byte_size();
        if data.len() != want {
            return Err(Error(format!(
                "literal data size {} does not match shape {dims:?} of {ty:?} (want {want})",
                data.len()
            )));
        }
        Ok(Literal {
            shape: ArrayShape {
                dims: dims.iter().map(|&d| d as i64).collect(),
                ty,
            },
            data: data.to_vec(),
            tuple: None,
        })
    }

    /// Wrap parts into a tuple literal (what a compiled segment returns).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal {
            shape: ArrayShape {
                dims: Vec::new(),
                ty: ElementType::Pred,
            },
            data: Vec::new(),
            tuple: Some(parts),
        }
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        if self.tuple.is_some() {
            return Err(Error("tuple literal has no array shape".to_string()));
        }
        Ok(self.shape.clone())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.tuple.is_some() {
            return Err(Error("tuple literal has no elements".to_string()));
        }
        if self.shape.ty != T::TY {
            return Err(Error(format!(
                "literal is {:?}, requested {:?}",
                self.shape.ty,
                T::TY
            )));
        }
        Ok(self
            .data
            .chunks_exact(self.shape.ty.byte_size())
            .map(T::from_le)
            .collect())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        self.tuple
            .clone()
            .ok_or_else(|| Error("literal is not a tuple".to_string()))
    }
}

/// Parsed HLO module text (the stub stores the text verbatim).
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading HLO text {path:?}: {e}")))?;
        Ok(Self { text })
    }

    pub fn text(&self) -> &str {
        &self.text
    }
}

/// An XLA computation built from a module proto.
pub struct XlaComputation {
    text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            text: proto.text.clone(),
        }
    }

    pub fn text(&self) -> &str {
        &self.text
    }
}

/// PJRT client handle.  Constructible offline; compilation is not.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient(()))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(UNAVAILABLE.to_string()))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        lit: &Literal,
    ) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer { lit: lit.clone() })
    }
}

/// Device buffer (host-backed in the stub).
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

/// Compiled executable handle.  Never constructed by the stub (compile
/// always errors), but the methods exist so call sites type-check.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error(UNAVAILABLE.to_string()))
    }

    pub fn execute_b<B: Borrow<PjRtBuffer>>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error(UNAVAILABLE.to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let vals = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[3]);
        assert_eq!(shape.ty(), ElementType::F32);
        let back: Vec<f32> = lit.to_vec().unwrap();
        assert_eq!(back, vals);
    }

    #[test]
    fn literal_size_validated() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0u8; 7]).is_err()
        );
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::S32, &[1], &[0u8; 4]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![0]);
    }

    #[test]
    fn tuple_literals() {
        let a = Literal::create_from_shape_and_untyped_data(ElementType::S32, &[1], &[1, 0, 0, 0])
            .unwrap();
        let t = Literal::tuple(vec![a]);
        assert!(t.array_shape().is_err());
        assert_eq!(t.to_tuple().unwrap().len(), 1);
    }

    #[test]
    fn client_constructs_but_compile_errors() {
        let client = PjRtClient::cpu().unwrap();
        let proto = HloModuleProto {
            text: "HloModule m".to_string(),
        };
        let comp = XlaComputation::from_proto(&proto);
        let err = client.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("stub"));
    }

    #[test]
    fn buffers_round_trip_host_data() {
        let client = PjRtClient::cpu().unwrap();
        let lit = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[1], &[0u8; 4])
            .unwrap();
        let buf = client.buffer_from_host_literal(None, &lit).unwrap();
        let back = buf.to_literal_sync().unwrap();
        assert_eq!(back.to_vec::<f32>().unwrap(), vec![0.0]);
    }
}
