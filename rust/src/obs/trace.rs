//! Chrome `trace_event` emitter (load the output in `chrome://tracing`
//! or Perfetto).
//!
//! Two clock domains on two pid tracks (DESIGN.md §16):
//!
//! * **pid [`PID_WALL`] — wall time.**  `B`/`E` duration pairs around
//!   engine phases (whole run, per round), timestamped from one
//!   process-wide `Instant` epoch so every track shares an origin.
//! * **pid [`PID_SIM`] — simulated time.**  `X` complete events whose
//!   `ts`/`dur` are the DES virtual clock in microseconds: queue wait,
//!   batch service, whole device-rounds — one tid per cell — plus `i`
//!   instants for handover, straggler-drop, and churn cancellation.
//!
//! Recording is off until [`enable`] (the `--trace <path>` CLI flag or
//! [`crate::exp::ExperimentBuilder::trace`]); every record site guards
//! on the one relaxed-atomic [`active`] check, so an untraced run pays
//! a single load per site.  Events buffer in memory (capped at
//! [`MAX_EVENTS`]) and [`write_to`] sorts them by `(pid, tid, ts)` —
//! stable, so `B` keeps preceding its `E` at equal timestamps — then
//! writes `{"traceEvents": [...]}`.
//!
//! Zero-perturbation: recording never touches an RNG stream, and the
//! virtual-time spans are derived from quantities the simulation
//! already computes.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::{self, Json};

/// Track for wall-clock engine phases.
pub const PID_WALL: u64 = 1;
/// Track for simulated-time DES activity (tid = cell index).
pub const PID_SIM: u64 = 2;

/// In-memory event cap — past it, events are counted as dropped
/// instead of recorded ([`write_to`] reports the loss).
pub const MAX_EVENTS: usize = 1 << 22;

static ACTIVE: AtomicBool = AtomicBool::new(false);
static DROPPED: AtomicU64 = AtomicU64::new(0);
static EVENTS: Mutex<Vec<TraceEvent>> = Mutex::new(Vec::new());

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// One buffered `trace_event`.
struct TraceEvent {
    name: String,
    cat: &'static str,
    ph: char,
    ts_us: f64,
    /// only meaningful for `X` events
    dur_us: f64,
    pid: u64,
    tid: u64,
    args: Vec<(&'static str, f64)>,
}

/// Is the tracer recording?  One relaxed load — the guard every
/// instrumentation site checks first.
#[inline]
pub fn active() -> bool {
    ACTIVE.load(Ordering::Relaxed)
}

/// Start recording (idempotent).  Also pins the wall-clock epoch and
/// turns the scheduler phase timers on.
pub fn enable() {
    let _ = epoch();
    super::registry::set_timers_enabled(true);
    ACTIVE.store(true, Ordering::SeqCst);
}

/// Stop recording (buffered events stay until [`write_to`] drains them).
pub fn disable() {
    ACTIVE.store(false, Ordering::SeqCst);
}

/// Microseconds since the process trace epoch.
pub fn wall_ts_us() -> f64 {
    epoch().elapsed().as_secs_f64() * 1e6
}

fn push(ev: TraceEvent) {
    let mut buf = EVENTS.lock().unwrap();
    if buf.len() >= MAX_EVENTS {
        DROPPED.fetch_add(1, Ordering::Relaxed);
        return;
    }
    buf.push(ev);
}

/// Wall-time span open (`B`) on the wall pid.
pub fn wall_begin(name: &str, cat: &'static str, tid: u64) {
    if !active() {
        return;
    }
    push(TraceEvent {
        name: name.to_string(),
        cat,
        ph: 'B',
        ts_us: wall_ts_us(),
        dur_us: 0.0,
        pid: PID_WALL,
        tid,
        args: Vec::new(),
    });
}

/// Wall-time span close (`E`), pairing the innermost open `B` on the
/// same track.
pub fn wall_end(name: &str, cat: &'static str, tid: u64) {
    if !active() {
        return;
    }
    push(TraceEvent {
        name: name.to_string(),
        cat,
        ph: 'E',
        ts_us: wall_ts_us(),
        dur_us: 0.0,
        pid: PID_WALL,
        tid,
        args: Vec::new(),
    });
}

/// Simulated-time complete span (`X`) on cell track `cell`,
/// `[start_s, end_s]` in virtual seconds.
pub fn sim_span(
    name: &str,
    cat: &'static str,
    cell: usize,
    start_s: f64,
    end_s: f64,
    args: Vec<(&'static str, f64)>,
) {
    if !active() {
        return;
    }
    push(TraceEvent {
        name: name.to_string(),
        cat,
        ph: 'X',
        ts_us: start_s * 1e6,
        dur_us: (end_s - start_s).max(0.0) * 1e6,
        pid: PID_SIM,
        tid: cell as u64,
        args,
    });
}

/// Simulated-time instant (`i`, thread scope) on cell track `cell`.
pub fn sim_instant(
    name: &str,
    cat: &'static str,
    cell: usize,
    at_s: f64,
    args: Vec<(&'static str, f64)>,
) {
    if !active() {
        return;
    }
    push(TraceEvent {
        name: name.to_string(),
        cat,
        ph: 'i',
        ts_us: at_s * 1e6,
        dur_us: 0.0,
        pid: PID_SIM,
        tid: cell as u64,
        args,
    });
}

/// Buffered event count (tests/diagnostics).
pub fn len() -> usize {
    EVENTS.lock().unwrap().len()
}

/// Whether the buffer holds no events.
pub fn is_empty() -> bool {
    EVENTS.lock().unwrap().is_empty()
}

/// Drop everything buffered so far (tests).
pub fn clear() {
    EVENTS.lock().unwrap().clear();
    DROPPED.store(0, Ordering::Relaxed);
}

fn event_json(ev: &TraceEvent) -> Json {
    let mut fields: Vec<(&str, Json)> = vec![
        ("name", Json::Str(ev.name.clone())),
        ("cat", Json::Str(ev.cat.to_string())),
        ("ph", Json::Str(ev.ph.to_string())),
        ("ts", Json::Num(ev.ts_us)),
        ("pid", Json::Num(ev.pid as f64)),
        ("tid", Json::Num(ev.tid as f64)),
    ];
    if ev.ph == 'X' {
        fields.push(("dur", Json::Num(ev.dur_us)));
    }
    if ev.ph == 'i' {
        fields.push(("s", Json::Str("t".to_string())));
    }
    if !ev.args.is_empty() {
        fields.push((
            "args",
            json::obj(ev.args.iter().map(|&(k, v)| (k, Json::Num(v))).collect()),
        ));
    }
    json::obj(fields)
}

/// Drain the buffer, sort by `(pid, tid, ts)` (stable, so `B` stays
/// ahead of its `E` at equal timestamps), and write the Chrome
/// `{"traceEvents": [...]}` document to `path`.
pub fn write_to(path: &str) -> anyhow::Result<()> {
    let mut events = std::mem::take(&mut *EVENTS.lock().unwrap());
    events.sort_by(|a, b| {
        (a.pid, a.tid)
            .cmp(&(b.pid, b.tid))
            .then(a.ts_us.total_cmp(&b.ts_us))
    });
    let dropped = DROPPED.swap(0, Ordering::Relaxed);
    let doc = json::obj(vec![
        ("traceEvents", Json::Arr(events.iter().map(event_json).collect())),
        ("displayTimeUnit", Json::Str("ms".to_string())),
    ]);
    std::fs::write(path, doc.to_string() + "\n")
        .map_err(|e| anyhow::anyhow!("writing trace {path}: {e}"))?;
    if dropped > 0 {
        crate::log_warn!("trace buffer overflowed: {dropped} events dropped (cap {MAX_EVENTS})");
    }
    Ok(())
}
