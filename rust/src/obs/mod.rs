//! Observability layer (DESIGN.md §16): a process-wide, lock-free
//! **metrics registry**, a Chrome-`trace_event` **trace emitter**, and
//! the **telemetry snapshot** every `BENCH_*.json` report envelope
//! carries under `data.telemetry`.
//!
//! ```text
//! instrumented sites                registry (always on)   snapshot
//!  scheduler cache hit/miss   ──►   Counter  ─┐
//!  pool claims / idle parks   ──►   PerWorker ├─► Snapshot::collect()
//!  DES queue depth / waits    ──►   Gauge     │      └─► Report data.telemetry
//!  phase timers (opt-in)      ──►   Histogram ┘          └─► `obs-report`
//!
//!  DES virtual-time activity  ──►   trace (opt-in, --trace <path>)
//!  engine wall-time phases    ──►     └─► Chrome trace_event JSON
//! ```
//!
//! **Zero-perturbation contract.**  No instrumentation site touches an
//! RNG stream, reorders work, or feeds back into a decision — records
//! are bitwise identical with telemetry/tracing on or off, which the
//! `exp::verify` gates plus `rust/tests/obs_telemetry.rs` enforce
//! across both engines, every preset, and serial vs. pooled threads.

pub mod registry;
pub mod snapshot;
pub mod trace;

pub use registry::{metrics, set_enabled, timer_record, timer_start, Counter, Gauge, Histogram};
pub use snapshot::{HistogramSnapshot, Snapshot};
