//! [`Snapshot`]: a point-in-time copy of the metrics registry, plus
//! its JSON form — the `data.telemetry` block every `BENCH_*.json`
//! envelope carries and the `obs-report` subcommand renders.

use crate::util::json::{self, Json};
use crate::util::table::Table;

use super::registry::{self, metrics, STRATEGY_KEYS};

/// One histogram, frozen: `buckets[i]` counts observations
/// `<= bounds[i]`, with one overflow bucket past the end.
#[derive(Clone, Debug)]
pub struct HistogramSnapshot {
    pub bounds: &'static [f64],
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum: f64,
}

/// Point-in-time copy of every registry metric, under the static
/// string keys the registry assigns.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub enabled: bool,
    pub counters: Vec<(&'static str, u64)>,
    /// key → (last, max)
    pub gauges: Vec<(&'static str, u64, u64)>,
    pub histograms: Vec<(&'static str, HistogramSnapshot)>,
    /// pool tasks claimed per worker slot (0 = caller), trailing zero
    /// slots trimmed
    pub pool_claimed: Vec<u64>,
    pub pool_idle_parks: u64,
}

fn hist_snap(h: &registry::Histogram) -> HistogramSnapshot {
    HistogramSnapshot {
        bounds: h.bounds(),
        buckets: h.bucket_counts(),
        count: h.count(),
        sum: h.sum(),
    }
}

impl Snapshot {
    /// Read the whole registry (relaxed loads — counters racing with
    /// live workers are torn only across *different* metrics, never
    /// within one word).
    pub fn collect() -> Snapshot {
        let m = metrics();
        let mut counters: Vec<(&'static str, u64)> = Vec::new();
        // per-strategy cache counters under static compound keys
        const HIT_KEYS: [&str; 8] = [
            "decision_cache.hit.card",
            "decision_cache.hit.server-only",
            "decision_cache.hit.device-only",
            "decision_cache.hit.static-cut",
            "decision_cache.hit.random-cut",
            "decision_cache.hit.eps-greedy",
            "decision_cache.hit.ucb1",
            "decision_cache.hit.thompson",
        ];
        const MISS_KEYS: [&str; 8] = [
            "decision_cache.miss.card",
            "decision_cache.miss.server-only",
            "decision_cache.miss.device-only",
            "decision_cache.miss.static-cut",
            "decision_cache.miss.random-cut",
            "decision_cache.miss.eps-greedy",
            "decision_cache.miss.ucb1",
            "decision_cache.miss.thompson",
        ];
        for (i, _) in STRATEGY_KEYS.iter().enumerate() {
            counters.push((HIT_KEYS[i], m.cache_hit[i].value()));
            counters.push((MISS_KEYS[i], m.cache_miss[i].value()));
        }
        counters.push(("pool.idle_parks", m.pool_parks.value()));
        counters.push(("round.soa.chunks", m.soa_chunks.value()));
        counters.push(("des.events", m.des_events.value()));
        counters.push(("des.merges", m.des_merges.value()));
        counters.push(("des.drops.straggler", m.des_drops_straggler.value()));
        counters.push(("des.drops.churn", m.des_drops_churn.value()));
        counters.push(("des.handovers", m.des_handovers.value()));
        counters.push(("des.faults.retries", m.des_fault_retries.value()));
        counters.push(("des.faults.timeouts", m.des_fault_timeouts.value()));
        counters.push(("des.faults.failovers", m.des_fault_failovers.value()));
        counters.push(("des.faults.slot_failures", m.des_fault_slot_failures.value()));
        counters.push(("des.faults.slot_repairs", m.des_fault_slot_repairs.value()));
        counters.push(("policy.explore", m.policy_explore.value()));
        counters.push(("policy.exploit", m.policy_exploit.value()));

        let gauges = vec![
            (
                "des.event_queue_depth",
                m.des_queue_depth.last(),
                m.des_queue_depth.max(),
            ),
            (
                "policy.regret_milli",
                m.policy_regret_milli.last(),
                m.policy_regret_milli.max(),
            ),
        ];

        let histograms = vec![
            ("des.queue_wait_s", hist_snap(&m.des_queue_wait_s)),
            ("des.faults.backoff_s", hist_snap(&m.des_fault_backoff_s)),
            ("des.server_utilization", hist_snap(&m.des_server_utilization)),
            ("sched.realize_link_s", hist_snap(&m.sched_realize_link_s)),
            ("sched.decide_s", hist_snap(&m.sched_decide_s)),
            ("round.soa.fill_s", hist_snap(&m.soa_fill_s)),
        ];

        let mut pool_claimed = m.pool_claimed.values();
        while pool_claimed.len() > 1 && *pool_claimed.last().unwrap() == 0 {
            pool_claimed.pop();
        }

        Snapshot {
            enabled: registry::enabled(),
            counters,
            gauges,
            histograms,
            pool_claimed,
            pool_idle_parks: m.pool_parks.value(),
        }
    }

    /// The `data.telemetry` JSON block (`edgesplit/telemetry/v1`).
    pub fn to_json(&self) -> Json {
        let counters = json::obj(
            self.counters
                .iter()
                .map(|&(k, v)| (k, Json::Num(v as f64)))
                .collect(),
        );
        let gauges = json::obj(
            self.gauges
                .iter()
                .map(|&(k, last, max)| {
                    (
                        k,
                        json::obj(vec![
                            ("last", Json::Num(last as f64)),
                            ("max", Json::Num(max as f64)),
                        ]),
                    )
                })
                .collect(),
        );
        let histograms = json::obj(
            self.histograms
                .iter()
                .map(|(k, h)| {
                    (
                        *k,
                        json::obj(vec![
                            ("count", Json::Num(h.count as f64)),
                            ("sum", Json::Num(h.sum)),
                            (
                                "buckets",
                                Json::Arr(
                                    h.buckets
                                        .iter()
                                        .enumerate()
                                        .map(|(i, &c)| {
                                            json::obj(vec![
                                                (
                                                    "le",
                                                    h.bounds
                                                        .get(i)
                                                        .map(|&b| Json::Num(b))
                                                        .unwrap_or_else(|| {
                                                            Json::Str("inf".into())
                                                        }),
                                                ),
                                                ("count", Json::Num(c as f64)),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                        ]),
                    )
                })
                .collect(),
        );
        json::obj(vec![
            ("schema", Json::Str("edgesplit/telemetry/v1".into())),
            ("enabled", Json::Bool(self.enabled)),
            ("counters", counters),
            ("gauges", gauges),
            ("histograms", histograms),
            (
                "pool",
                json::obj(vec![
                    (
                        "tasks_claimed_per_worker",
                        Json::Arr(
                            self.pool_claimed
                                .iter()
                                .map(|&v| Json::Num(v as f64))
                                .collect(),
                        ),
                    ),
                    ("idle_parks", Json::Num(self.pool_idle_parks as f64)),
                ]),
            ),
        ])
    }

    /// ASCII rendering (the `obs-report` subcommand's output).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let mut t = Table::new("telemetry — counters", &["key", "value"]);
        for &(k, v) in &self.counters {
            t.row(vec![k.to_string(), v.to_string()]);
        }
        out.push_str(&t.render());
        out.push('\n');

        let mut t = Table::new("telemetry — gauges", &["key", "last", "max"]);
        for &(k, last, max) in &self.gauges {
            t.row(vec![k.to_string(), last.to_string(), max.to_string()]);
        }
        out.push_str(&t.render());
        out.push('\n');

        let mut t = Table::new("telemetry — histograms", &["key", "count", "sum", "mean"]);
        for (k, h) in &self.histograms {
            let mean = if h.count > 0 { h.sum / h.count as f64 } else { 0.0 };
            t.row(vec![
                k.to_string(),
                h.count.to_string(),
                format!("{:.6}", h.sum),
                format!("{mean:.6}"),
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');

        let mut t = Table::new("telemetry — worker pool", &["slot", "tasks claimed"]);
        for (i, &v) in self.pool_claimed.iter().enumerate() {
            let who = if i == 0 { "caller".to_string() } else { format!("worker {}", i - 1) };
            t.row(vec![who, v.to_string()]);
        }
        t.row(vec!["idle parks".into(), self.pool_idle_parks.to_string()]);
        out.push_str(&t.render());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_json_carries_every_section() {
        let s = Snapshot::collect();
        let j = s.to_json();
        assert_eq!(
            j.get("schema").and_then(Json::as_str),
            Some("edgesplit/telemetry/v1")
        );
        for key in ["counters", "gauges", "histograms", "pool"] {
            assert!(j.get(key).is_some(), "missing section {key}");
        }
        assert!(j
            .at(&["counters", "decision_cache.hit.card"])
            .and_then(Json::as_f64)
            .is_some());
        assert!(j
            .at(&["histograms", "des.queue_wait_s", "count"])
            .and_then(Json::as_f64)
            .is_some());
        assert!(j.at(&["pool", "idle_parks"]).is_some());
        // round-trips through the parser
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn render_mentions_every_section() {
        let out = Snapshot::collect().render();
        for needle in ["counters", "gauges", "histograms", "worker pool", "idle parks"] {
            assert!(out.contains(needle), "render missing {needle}");
        }
    }
}
