//! Process-wide, lock-free metrics registry.
//!
//! Three primitive types, all wait-free on the hot path:
//!
//! * [`Counter`] — monotonic, sharded over cache-line-padded atomic
//!   words exactly like the decision cache's hit/miss counters
//!   (DESIGN.md §12): the caller passes a shard *hint* (device index,
//!   worker index) so concurrent increments from the pool land on
//!   different cache lines; [`Counter::value`] sums the shards.
//! * [`Gauge`] — last-observed + running-max of a `u64` level (event
//!   queue depth).
//! * [`Histogram`] — fixed, static bucket bounds with `le` semantics
//!   (bucket *i* counts `v <= bounds[i]`; one overflow bucket past the
//!   end), plus a CAS-folded `f64` sum.  Bounds are compile-time
//!   constants, so observation is a `partition_point` + one
//!   `fetch_add`.
//!
//! Every metric the crate instruments lives in the one static
//! [`Metrics`] struct behind [`metrics()`], registered under the
//! static string keys [`Snapshot`](super::Snapshot) reports.
//!
//! **Zero-perturbation contract** (DESIGN.md §16): nothing in this
//! module touches an RNG stream or reorders work — instrumentation is
//! observation only, and the bit-compat gates run with it enabled.
//! The master switch [`set_enabled`] exists for the property test that
//! proves records are bitwise identical either way, not for
//! performance: a disabled metric still costs one relaxed load.
//! Wall-clock *phase timers* are the exception — they cost two
//! `Instant::now()` calls per observation, so they default **off**
//! ([`set_timers_enabled`]; `--trace` and `obs-report` turn them on).

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Shard count for [`Counter`] — matches the decision cache's
/// `COUNTER_SHARDS` (enough to spread a pool's worth of writers).
pub const COUNTER_SHARDS: usize = 8;

/// Fixed per-worker slot count for [`PerWorker`]: slot 0 is the
/// calling thread (it participates in pool jobs), slots `1..` are the
/// pool workers.  Indexes past the end clamp into the last slot.
pub const MAX_WORKER_SLOTS: usize = 65;

/// Strategy key order for the per-strategy cache counters — the
/// coordinator maps its `Strategy` enum onto these slots (the learned
/// family is uncacheable, so its slots only ever count misses of 0,
/// but keeping the key space total means `obs_slot` never clamps).
pub const STRATEGY_KEYS: [&str; 8] = [
    "card",
    "server-only",
    "device-only",
    "static-cut",
    "random-cut",
    "eps-greedy",
    "ucb1",
    "thompson",
];

/// Wall/sim duration bucket bounds [s] (log-ish spacing, µs → 10 min).
pub const TIME_BUCKETS_S: [f64; 12] = [
    1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 0.1, 0.5, 1.0, 5.0, 30.0, 120.0, 600.0,
];

/// Ratio bucket bounds (utilization ∈ [0, 1]).
pub const RATIO_BUCKETS: [f64; 10] = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0];

static ENABLED: AtomicBool = AtomicBool::new(true);
static TIMERS_ENABLED: AtomicBool = AtomicBool::new(false);

/// Is metric collection on?  (Default: yes.)
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Master switch — exists so the zero-perturbation property test can
/// prove records are bitwise identical with telemetry on vs. off.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::SeqCst);
}

/// Are the wall-clock phase timers on?  (Default: no — two
/// `Instant::now()` calls per device-round are not free.)
#[inline]
pub fn timers_enabled() -> bool {
    TIMERS_ENABLED.load(Ordering::Relaxed)
}

/// Enable the scheduler phase timers (`--trace` / `obs-report` do).
pub fn set_timers_enabled(on: bool) {
    TIMERS_ENABLED.store(on, Ordering::SeqCst);
}

/// Start a phase timer — `None` (and no clock read) unless
/// [`timers_enabled`].
#[inline]
pub fn timer_start() -> Option<Instant> {
    if timers_enabled() {
        Some(Instant::now())
    } else {
        None
    }
}

/// Fold a started phase timer into `h` (no-op for `None`).
#[inline]
pub fn timer_record(h: &Histogram, t0: Option<Instant>) {
    if let Some(t0) = t0 {
        h.observe(t0.elapsed().as_secs_f64());
    }
}

thread_local! {
    /// Which [`PerWorker`] slot this thread charges: 0 for ordinary
    /// (caller) threads, `w + 1` for pool worker `w`.
    static WORKER_SLOT: Cell<usize> = const { Cell::new(0) };
}

/// Pin the current thread's per-worker slot (the pool does this once
/// per worker at spawn).
pub fn set_worker_slot(slot: usize) {
    WORKER_SLOT.with(|s| s.set(slot));
}

/// The current thread's per-worker slot (0 unless pinned).
pub fn worker_slot() -> usize {
    WORKER_SLOT.with(|s| s.get())
}

/// One cache line per atomic word so sharded writers never false-share.
#[repr(align(64))]
struct Padded(AtomicU64);

impl Padded {
    fn new() -> Padded {
        Padded(AtomicU64::new(0))
    }
}

/// Monotonic counter, sharded like the decision cache's hit/miss
/// counters: `hint` (device/worker index) picks the shard.
pub struct Counter {
    shards: [Padded; COUNTER_SHARDS],
}

impl Counter {
    pub fn new() -> Counter {
        Counter {
            shards: std::array::from_fn(|_| Padded::new()),
        }
    }

    #[inline]
    pub fn inc(&self, hint: usize) {
        self.add(hint, 1);
    }

    #[inline]
    pub fn add(&self, hint: usize, n: u64) {
        if !enabled() {
            return;
        }
        self.shards[hint % COUNTER_SHARDS]
            .0
            .fetch_add(n, Ordering::Relaxed);
    }

    /// Sum across shards.
    pub fn value(&self) -> u64 {
        self.shards.iter().map(|s| s.0.load(Ordering::Relaxed)).sum()
    }
}

impl Default for Counter {
    fn default() -> Counter {
        Counter::new()
    }
}

/// Last-observed + running-max level.
pub struct Gauge {
    last: AtomicU64,
    max: AtomicU64,
}

impl Gauge {
    pub fn new() -> Gauge {
        Gauge {
            last: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn observe(&self, v: u64) {
        if !enabled() {
            return;
        }
        self.last.store(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn last(&self) -> u64 {
        self.last.load(Ordering::Relaxed)
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }
}

impl Default for Gauge {
    fn default() -> Gauge {
        Gauge::new()
    }
}

/// Fixed-bucket histogram: `counts[i]` tallies `v <= bounds[i]`
/// (`counts[bounds.len()]` is the overflow bucket), plus an exact
/// observation count and CAS-folded `f64` sum.
pub struct Histogram {
    bounds: &'static [f64],
    counts: Vec<AtomicU64>,
    sum_bits: AtomicU64,
}

impl Histogram {
    /// `bounds` must be sorted ascending (static, checked once here).
    pub fn new(bounds: &'static [f64]) -> Histogram {
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly ascending"
        );
        Histogram {
            bounds,
            counts: (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect(),
            sum_bits: AtomicU64::new(0f64.to_bits()),
        }
    }

    #[inline]
    pub fn observe(&self, v: f64) {
        if !enabled() {
            return;
        }
        // first bound >= v, i.e. the `le` bucket; past-the-end ⇒ overflow
        let i = self.bounds.partition_point(|&b| b < v);
        self.counts[i].fetch_add(1, Ordering::Relaxed);
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self
                .sum_bits
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn bounds(&self) -> &'static [f64] {
        self.bounds
    }

    /// Per-bucket tallies (`bounds.len() + 1` entries, overflow last).
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    pub fn count(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }
}

/// Fixed per-slot counters for the worker pool: tasks claimed per
/// worker (slot 0 = the participating caller thread).
pub struct PerWorker {
    slots: Vec<Padded>,
}

impl PerWorker {
    pub fn new() -> PerWorker {
        PerWorker {
            slots: (0..MAX_WORKER_SLOTS).map(|_| Padded::new()).collect(),
        }
    }

    #[inline]
    pub fn add(&self, slot: usize, n: u64) {
        if !enabled() {
            return;
        }
        self.slots[slot.min(MAX_WORKER_SLOTS - 1)]
            .0
            .fetch_add(n, Ordering::Relaxed);
    }

    /// All slot values (fixed length [`MAX_WORKER_SLOTS`]).
    pub fn values(&self) -> Vec<u64> {
        self.slots.iter().map(|s| s.0.load(Ordering::Relaxed)).collect()
    }
}

impl Default for PerWorker {
    fn default() -> PerWorker {
        PerWorker::new()
    }
}

/// Every metric the crate instruments, as one process-wide struct —
/// the "registry".  Field order is the report order.
pub struct Metrics {
    /// decision-cache hits, one counter per [`STRATEGY_KEYS`] slot
    pub cache_hit: [Counter; 8],
    /// decision-cache misses, same slots
    pub cache_miss: [Counter; 8],
    /// pool tasks claimed, per worker slot (0 = caller)
    pub pool_claimed: PerWorker,
    /// pool idle parks (worker found no work and blocked on the condvar)
    pub pool_parks: Counter,
    /// DES events popped off the virtual-time queue
    pub des_events: Counter,
    /// DES device-round merges (cell + cloud aggregation)
    pub des_merges: Counter,
    /// DES semi-sync straggler drops
    pub des_drops_straggler: Counter,
    /// DES churn cancellations
    pub des_drops_churn: Counter,
    /// DES cell re-associations observed at launch
    pub des_handovers: Counter,
    /// DES fault retransmissions scheduled (link outage + backoff)
    pub des_fault_retries: Counter,
    /// DES sync-policy timeout demotions to the straggler path
    pub des_fault_timeouts: Counter,
    /// DES burst-failovers (second-cell reroutes + degraded cuts)
    pub des_fault_failovers: Counter,
    /// DES server capacity-slot failures at batch dispatch
    pub des_fault_slot_failures: Counter,
    /// DES slot repairs completed (pairs 1:1 with the failures)
    pub des_fault_slot_repairs: Counter,
    /// DES event-queue depth (level at each pop)
    pub des_queue_depth: Gauge,
    /// per-job server queue wait [sim s]
    pub des_queue_wait_s: Histogram,
    /// per-retry backoff wait [sim s]
    pub des_fault_backoff_s: Histogram,
    /// per-cell end-of-run server utilization
    pub des_server_utilization: Histogram,
    /// wall time of `Scheduler::realize_link` (timers only)
    pub sched_realize_link_s: Histogram,
    /// wall time of the decision scan / cache path (timers only)
    pub sched_decide_s: Histogram,
    /// SoA chunks filled by the round engine's streaming path
    pub soa_chunks: Counter,
    /// wall time per SoA chunk fill (timers only)
    pub soa_fill_s: Histogram,
    /// learned-policy decisions that explored (off the greedy arm)
    pub policy_explore: Counter,
    /// learned-policy decisions that exploited the greedy arm
    pub policy_exploit: Counter,
    /// latest cumulative regret vs CARD [milli-units of cost U] —
    /// written by the policy sweep as each curve finishes
    pub policy_regret_milli: Gauge,
}

impl Metrics {
    fn new() -> Metrics {
        Metrics {
            cache_hit: std::array::from_fn(|_| Counter::new()),
            cache_miss: std::array::from_fn(|_| Counter::new()),
            pool_claimed: PerWorker::new(),
            pool_parks: Counter::new(),
            des_events: Counter::new(),
            des_merges: Counter::new(),
            des_drops_straggler: Counter::new(),
            des_drops_churn: Counter::new(),
            des_handovers: Counter::new(),
            des_fault_retries: Counter::new(),
            des_fault_timeouts: Counter::new(),
            des_fault_failovers: Counter::new(),
            des_fault_slot_failures: Counter::new(),
            des_fault_slot_repairs: Counter::new(),
            des_queue_depth: Gauge::new(),
            des_queue_wait_s: Histogram::new(&TIME_BUCKETS_S),
            des_fault_backoff_s: Histogram::new(&TIME_BUCKETS_S),
            des_server_utilization: Histogram::new(&RATIO_BUCKETS),
            sched_realize_link_s: Histogram::new(&TIME_BUCKETS_S),
            sched_decide_s: Histogram::new(&TIME_BUCKETS_S),
            soa_chunks: Counter::new(),
            soa_fill_s: Histogram::new(&TIME_BUCKETS_S),
            policy_explore: Counter::new(),
            policy_exploit: Counter::new(),
            policy_regret_milli: Gauge::new(),
        }
    }
}

/// The process-wide registry (created on first touch).
pub fn metrics() -> &'static Metrics {
    static REGISTRY: OnceLock<Metrics> = OnceLock::new();
    REGISTRY.get_or_init(Metrics::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_merges_across_shards() {
        let c = Counter::new();
        // hit every shard, including wraparound hints
        for hint in 0..COUNTER_SHARDS * 3 {
            c.inc(hint);
        }
        c.add(5, 10);
        assert_eq!(c.value(), (COUNTER_SHARDS * 3) as u64 + 10);
    }

    #[test]
    fn counter_shards_spread_by_hint() {
        let c = Counter::new();
        c.inc(0);
        c.inc(1);
        c.inc(COUNTER_SHARDS); // same shard as hint 0
        let shard0 = c.shards[0].0.load(Ordering::Relaxed);
        let shard1 = c.shards[1].0.load(Ordering::Relaxed);
        assert_eq!(shard0, 2);
        assert_eq!(shard1, 1);
        assert_eq!(c.value(), 3);
    }

    #[test]
    fn gauge_tracks_last_and_max() {
        let g = Gauge::new();
        g.observe(3);
        g.observe(17);
        g.observe(5);
        assert_eq!(g.last(), 5);
        assert_eq!(g.max(), 17);
    }

    #[test]
    fn histogram_bucket_boundaries_are_le() {
        static BOUNDS: [f64; 3] = [1.0, 2.0, 4.0];
        let h = Histogram::new(&BOUNDS);
        h.observe(0.5); // <= 1.0            -> bucket 0
        h.observe(1.0); // == bound, le      -> bucket 0
        h.observe(1.5); //                   -> bucket 1
        h.observe(2.0); // == bound, le      -> bucket 1
        h.observe(4.0); // == last bound     -> bucket 2
        h.observe(9.0); // past the end      -> overflow
        assert_eq!(h.bucket_counts(), vec![2, 2, 1, 1]);
        assert_eq!(h.count(), 6);
        assert!((h.sum() - 18.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "ascending")]
    fn histogram_rejects_unsorted_bounds() {
        static BAD: [f64; 2] = [2.0, 1.0];
        let _ = Histogram::new(&BAD);
    }

    #[test]
    fn per_worker_clamps_out_of_range_slots() {
        let p = PerWorker::new();
        p.add(0, 2);
        p.add(3, 1);
        p.add(MAX_WORKER_SLOTS + 100, 5); // clamps into the last slot
        let v = p.values();
        assert_eq!(v.len(), MAX_WORKER_SLOTS);
        assert_eq!(v[0], 2);
        assert_eq!(v[3], 1);
        assert_eq!(v[MAX_WORKER_SLOTS - 1], 5);
    }

    #[test]
    fn registry_is_process_wide() {
        let a = metrics() as *const Metrics;
        let b = metrics() as *const Metrics;
        assert_eq!(a, b);
    }

    #[test]
    fn worker_slot_defaults_to_caller() {
        assert_eq!(worker_slot(), 0);
        std::thread::spawn(|| {
            set_worker_slot(7);
            assert_eq!(worker_slot(), 7);
        })
        .join()
        .unwrap();
        // pinning in the spawned thread must not leak here
        assert_eq!(worker_slot(), 0);
    }
}
