//! # edgesplit
//!
//! Production-grade reproduction of **"Energy-Efficient Split Learning
//! for Fine-Tuning Large Language Models in Edge Networks"** (Li, Wu,
//! Li, Zhang — IEEE Networking Letters 2024) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the split-learning coordinator: the CARD
//!   cut-layer/frequency algorithm, the parallel fleet-scale round
//!   engine (Stages 1–5, bit-deterministic at any thread count), the
//!   discrete-event fleet engine (`des`: server queueing, device
//!   churn, sync/semi-sync/async aggregation), wireless-channel and
//!   device-fleet simulators, the TOML-driven scenario registry, cost
//!   models (Eqs. 7–12, 16), and a PJRT runtime that executes the real
//!   split LoRA transformer from AOT-compiled HLO artifacts.
//! * **L2 (python/compile)** — JAX split-segment model, lowered once to
//!   HLO text (`make artifacts`); never on the request path.
//! * **L1 (python/compile/kernels)** — fused LoRA-linear + RMSNorm
//!   Pallas kernels inside those segments.
//!
//! Experiments are constructed and reported through the unified
//! [`exp`] API: `exp::ExperimentBuilder` → `exp::Engine` (round or
//! discrete-event) → `exp::MetricsSink` → `exp::Report` (DESIGN.md §14).
//! Every engine is instrumented through the [`obs`] telemetry layer
//! (metrics registry + Chrome-trace emitter, DESIGN.md §16); reports
//! carry an `obs::Snapshot` under `data.telemetry`.
//!
//! See `DESIGN.md` (repo root) for the architecture and
//! `EXPERIMENTS.md` for the paper-vs-measured figures; `README.md`
//! covers build/quickstart and the `fleet-sweep` scenario engine.

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod des;
pub mod devices;
pub mod exp;
pub mod model;
pub mod net;
pub mod obs;
pub mod policy;
pub mod runtime;
pub mod sim;
pub mod util;
