//! The [`Engine`] trait: one `run(sink)` entry point for every
//! execution regime, replacing the seven overlapping `Scheduler::run*`
//! variants at the public surface.
//!
//! Two implementations:
//!
//! * [`RoundEngine`] — the per-round parallel fleet engine.  Its
//!   [`ExecMode`] selects the production path (`Cached`) or one of the
//!   two retained oracles (`Uncached`: kernel scan without the decision
//!   cache; `Ref`: the pre-kernel full-recompute path).  All three emit
//!   bit-identical record streams (`rust/tests/decision_kernel.rs`).
//! * [`EventEngine`] — the discrete-event fleet engine (`des::DesEngine`):
//!   server queueing, churn, sync/semi-sync/async aggregation.
//!
//! Both stream records into a [`MetricsSink`] in round-major order; the
//! round engine holds at most one round of records in memory at a time.

use std::sync::Arc;

use crate::coordinator::{RoundBatch, Scheduler, SOA_WINDOW};
use crate::des::{CellStats, DesEngine, DesOutcome, RunState, ServerStats, SimSnapshot};
use crate::obs::trace;
use crate::policy::PolicyObs;

use super::sink::MetricsSink;

/// How the round engine evaluates cells.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Production path: decision kernel + CQI-keyed cache, streamed as
    /// bounded SoA windows whose chunks fan out across the worker pool
    /// (serial when `threads <= 1`) — no `Vec<RoundRecord>` is ever
    /// materialized by the engine.
    Cached,
    /// Oracle: kernel scan with the decision cache bypassed (serial).
    Uncached,
    /// Oracle: pre-kernel full model re-evaluation per cost call
    /// (serial) — the legacy bit-compat reference.
    Ref,
}

impl ExecMode {
    pub const ALL: [ExecMode; 3] = [ExecMode::Cached, ExecMode::Uncached, ExecMode::Ref];

    pub fn name(&self) -> &'static str {
        match self {
            ExecMode::Cached => "cached",
            ExecMode::Uncached => "uncached",
            ExecMode::Ref => "ref",
        }
    }
}

/// Engine-level observables of a DES run (per-record data goes through
/// the sink; these are the run-wide aggregates).
#[derive(Clone, Debug)]
pub struct DesRunStats {
    pub makespan_s: f64,
    pub server: ServerStats,
    /// cells abandoned to churn or the straggler deadline
    pub dropped: u64,
    /// cells launched (== records + dropped)
    pub launched: u64,
    pub departures: u64,
    pub arrivals: u64,
    pub peak_staleness: usize,
    /// Eq.-11 server energy booked at job dispatch [J] — counts work
    /// later wasted on cancelled stragglers, which merged records omit.
    /// Always the exact sum of the `per_cell` energy accumulators.
    pub energy_spent_j: f64,
    /// consistency of the cloud-level aggregator (DESIGN.md §15)
    pub aggregator_consistent: bool,
    /// per-cell queue/energy/handover observables — length
    /// `cfg.cells.count` (a single entry for the default single cell)
    pub per_cell: Vec<CellStats>,
    /// total device→cell re-associations over the run (0 when
    /// `cells.count == 1` or the fleet is static)
    pub handovers: u64,
    /// link retransmission attempts scheduled by the fault plane
    /// (DESIGN.md §17; 0 when `[faults]` is dormant)
    pub retries: u64,
    /// sync-policy stragglers demoted by the fault timeout
    pub timeout_demotions: u64,
    /// burst-struck launches rerouted or degraded
    pub failovers: u64,
    /// server capacity-slot failures hit at batch dispatch
    pub slot_failures: u64,
    /// slot repairs completed
    pub slot_repairs: u64,
    /// energy wasted in interrupted partial transfers [J] — extra on
    /// top of `energy_spent_j` (which is Eq.-11 server compute)
    pub retry_energy_j: f64,
}

/// What a completed engine run reports back, beyond the record stream.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    /// records pushed into the sink
    pub cells: usize,
    /// DES observables — `Some` iff the [`EventEngine`] ran
    pub des: Option<DesRunStats>,
}

/// One entry point for every execution regime.  Implementations must
/// emit records in round-major `(round, device)` order and be pure
/// functions of `(config, seed)` — thread counts and event
/// interleavings may change wall-clock, never a record.
pub trait Engine {
    fn run(&self, sink: &mut dyn MetricsSink) -> anyhow::Result<RunOutcome>;

    /// Run until the first event past virtual time `t_s` and freeze the
    /// simulation there (DESIGN.md §17).  Only engines with a virtual
    /// clock can pause; the round engine bails.
    fn checkpoint_at(&self, t_s: f64) -> anyhow::Result<RunState> {
        let _ = t_s;
        anyhow::bail!("this engine has no virtual clock to checkpoint — use the event engine")
    }

    /// Continue a checkpointed run to completion, streaming the *full*
    /// record stream (pre- and post-checkpoint cells) into `sink`.
    /// Bit-identical to an uninterrupted `run`.
    fn resume_from(
        &self,
        snap: &SimSnapshot,
        sink: &mut dyn MetricsSink,
    ) -> anyhow::Result<RunOutcome> {
        let _ = (snap, sink);
        anyhow::bail!("this engine cannot resume a checkpoint — use the event engine")
    }
}

/// The per-round parallel fleet engine over a shared [`Scheduler`].
pub struct RoundEngine {
    sched: Arc<Scheduler>,
    mode: ExecMode,
    threads: usize,
}

impl RoundEngine {
    pub fn new(sched: Arc<Scheduler>, mode: ExecMode, threads: usize) -> Self {
        RoundEngine {
            sched,
            mode,
            threads,
        }
    }
}

impl Engine for RoundEngine {
    fn run(&self, sink: &mut dyn MetricsSink) -> anyhow::Result<RunOutcome> {
        let rounds = self.sched.cfg.workload.rounds;
        let devices = self.sched.cfg.devices.len();
        let mut cells = 0usize;
        // wall-time phase spans (DESIGN.md §16) — one relaxed load when
        // tracing is off, never any effect on the record stream.  The
        // trace tid is this thread's pool slot: sweeps fan experiments
        // out on workers, and per-slot tracks keep concurrent spans
        // properly nested (one engine at a time per worker).
        let traced = trace::active();
        let tid = crate::obs::registry::worker_slot() as u64;
        if traced {
            trace::wall_begin("round_engine.run", "engine", tid);
        }
        // one reusable SoA window for the whole run: the streaming
        // path's memory is O(SOA_WINDOW), not O(devices × rounds)
        let mut batch = RoundBatch::new();
        // learned strategies: start from a blank bank and buffer one
        // round of (context, cut, cost) rewards to fold at each round
        // boundary — decisions within a round read frozen state, so the
        // window/thread fan-out stays bit-deterministic (DESIGN.md §19)
        let learned = self.sched.policy_enabled();
        self.sched.policy_reset();
        let mut rewards: Vec<PolicyObs> = Vec::new();
        for round in 0..rounds {
            if traced {
                trace::wall_begin("round", "engine", tid);
            }
            match self.mode {
                ExecMode::Cached => {
                    // bounded SoA windows in device order — bit-
                    // identical to the per-record serial stream at any
                    // window/thread count (every cell is pure)
                    let mut start = 0;
                    while start < devices {
                        let len = SOA_WINDOW.min(devices - start);
                        batch.fill(&self.sched, round, start, len, self.threads);
                        if learned {
                            rewards.extend((0..batch.len()).map(|i| PolicyObs {
                                device_idx: batch.device_idx(i),
                                snr_up_db: batch.snr_up_db[i],
                                cut: batch.cut[i],
                                cost: batch.cost[i],
                            }));
                        }
                        sink.on_batch(&batch);
                        cells += len;
                        start += len;
                    }
                }
                ExecMode::Uncached => {
                    for i in 0..devices {
                        let rec = self.sched.device_round_uncached(round, i);
                        if learned {
                            rewards.push(PolicyObs {
                                device_idx: rec.device_idx,
                                snr_up_db: rec.snr_up_db,
                                cut: rec.cut,
                                cost: rec.cost,
                            });
                        }
                        sink.on_record_owned(rec);
                        cells += 1;
                    }
                }
                ExecMode::Ref => {
                    for i in 0..devices {
                        let rec = self.sched.device_round_ref(round, i);
                        if learned {
                            rewards.push(PolicyObs {
                                device_idx: rec.device_idx,
                                snr_up_db: rec.snr_up_db,
                                cut: rec.cut,
                                cost: rec.cost,
                            });
                        }
                        sink.on_record_owned(rec);
                        cells += 1;
                    }
                }
            }
            if learned {
                self.sched.policy_observe(&rewards);
                rewards.clear();
            }
            if traced {
                trace::wall_end("round", "engine", tid);
            }
        }
        if traced {
            trace::wall_end("round_engine.run", "engine", tid);
        }
        Ok(RunOutcome { cells, des: None })
    }
}

/// The discrete-event fleet engine behind the unified trait.
pub struct EventEngine {
    des: DesEngine,
}

impl EventEngine {
    pub fn new(des: DesEngine) -> Self {
        EventEngine { des }
    }
}

/// Drain a finished DES outcome into `sink` and fold it into the
/// unified [`RunOutcome`] shape — shared by `run` and `resume_from`.
fn drain_des_outcome(mut out: DesOutcome, sink: &mut dyn MetricsSink) -> RunOutcome {
    // hand the records over by value: sinks that materialize them
    // (CollectSink) move the payload instead of cloning two Arc names
    // per cell
    let records = std::mem::take(&mut out.records);
    let cells = records.len();
    for rec in records {
        sink.on_des_record_owned(rec);
    }
    RunOutcome {
        cells,
        des: Some(DesRunStats {
            makespan_s: out.makespan_s,
            server: out.server,
            dropped: out.dropped,
            launched: out.launched,
            departures: out.departures,
            arrivals: out.arrivals,
            peak_staleness: out.peak_staleness,
            energy_spent_j: out.energy_spent_j,
            aggregator_consistent: out.aggregator.is_consistent(),
            per_cell: out.per_cell.clone(),
            handovers: out.handovers,
            retries: out.retries,
            timeout_demotions: out.timeout_demotions,
            failovers: out.failovers,
            slot_failures: out.slot_failures,
            slot_repairs: out.slot_repairs,
            retry_energy_j: out.retry_energy_j,
        }),
    }
}

impl Engine for EventEngine {
    fn run(&self, sink: &mut dyn MetricsSink) -> anyhow::Result<RunOutcome> {
        let traced = trace::active();
        let tid = crate::obs::registry::worker_slot() as u64;
        if traced {
            trace::wall_begin("event_engine.run", "engine", tid);
        }
        let out = self.des.run();
        if traced {
            trace::wall_end("event_engine.run", "engine", tid);
            trace::wall_begin("event_engine.drain", "engine", tid);
        }
        let outcome = drain_des_outcome(out, sink);
        if traced {
            trace::wall_end("event_engine.drain", "engine", tid);
        }
        Ok(outcome)
    }

    fn checkpoint_at(&self, t_s: f64) -> anyhow::Result<RunState> {
        anyhow::ensure!(
            t_s.is_finite() && t_s >= 0.0,
            "checkpoint instant must be finite and >= 0, got {t_s}"
        );
        Ok(self.des.run_until(t_s))
    }

    fn resume_from(
        &self,
        snap: &SimSnapshot,
        sink: &mut dyn MetricsSink,
    ) -> anyhow::Result<RunOutcome> {
        let traced = trace::active();
        let tid = crate::obs::registry::worker_slot() as u64;
        if traced {
            trace::wall_begin("event_engine.resume", "engine", tid);
        }
        let out = self.des.resume(snap);
        if traced {
            trace::wall_end("event_engine.resume", "engine", tid);
        }
        Ok(drain_des_outcome(out, sink))
    }
}
