//! Shared determinism gates — the bit-level comparators both sweeps
//! (and every bit-compat test suite) run, hoisted here so the CLI
//! gates and the property tests can never drift apart.

use std::sync::Arc;

use crate::config::{ChannelState, ExpConfig};
use crate::coordinator::{RoundRecord, Scheduler, Strategy};
use crate::des::{DesConfig, DesEngine, DesOutcome, Policy, RunState, ServerStats};

use super::builder::Experiment;

/// Require two record streams to agree **bit for bit** on every field
/// the experiments report.
pub fn verify_bit_identical(a: &[RoundRecord], b: &[RoundRecord]) -> anyhow::Result<()> {
    anyhow::ensure!(
        a.len() == b.len(),
        "record count mismatch: {} vs {}",
        a.len(),
        b.len()
    );
    for (x, y) in a.iter().zip(b) {
        anyhow::ensure!(
            x.round == y.round
                && x.device_idx == y.device_idx
                && x.cut == y.cut
                && x.freq_hz.to_bits() == y.freq_hz.to_bits()
                && x.cost.to_bits() == y.cost.to_bits()
                && x.delay_s.to_bits() == y.delay_s.to_bits()
                && x.energy_j.to_bits() == y.energy_j.to_bits()
                && x.rate_up_bps.to_bits() == y.rate_up_bps.to_bits()
                && x.rate_down_bps.to_bits() == y.rate_down_bps.to_bits()
                && x.snr_up_db.to_bits() == y.snr_up_db.to_bits()
                && x.snr_down_db.to_bits() == y.snr_down_db.to_bits()
                && x.device_compute_s.to_bits() == y.device_compute_s.to_bits()
                && x.server_compute_s.to_bits() == y.server_compute_s.to_bits()
                && x.transmission_s.to_bits() == y.transmission_s.to_bits(),
            "parallel/serial divergence at round {} device {}",
            x.round,
            x.device_idx
        );
    }
    Ok(())
}

/// The fleet-sweep gate: the experiment's configured (parallel, cached)
/// round engine must reproduce the serial reference path bit for bit.
pub fn verify_round_determinism(exp: &Experiment) -> anyhow::Result<()> {
    let parallel = exp.run_collect()?;
    verify_records_match_serial(exp, &parallel)
}

/// Gate variant for callers that already hold the experiment's record
/// stream (e.g. a sweep's gated grid point): compares it against a
/// fresh serial reference run without re-running the parallel engine.
pub fn verify_records_match_serial(
    exp: &Experiment,
    parallel: &[RoundRecord],
) -> anyhow::Result<()> {
    anyhow::ensure!(
        !exp.is_event_engine(),
        "the round-determinism gate applies to the round engine"
    );
    let serial = exp.scheduler().run_analytic()?;
    verify_bit_identical(&serial, parallel)
}

/// The SoA-path gate (DESIGN.md §18): the streaming `ExecMode::Cached`
/// engine — SoA windows, pool-chunked fills, lazy name resolution —
/// must reproduce both retained AoS oracles bit for bit on the same
/// scheduler: `run_uncached` (kernel scan, no decision cache) and
/// `run_ref` (pre-kernel full re-evaluation, the paper-equation
/// reference).  `exp::Experiment::run_collect` drives the real
/// engine + sink stack, so this covers the whole streaming path, not
/// just the scheduler.
pub fn verify_soa_matches_oracles(exp: &Experiment) -> anyhow::Result<()> {
    anyhow::ensure!(
        !exp.is_event_engine(),
        "the SoA gate applies to the round engine"
    );
    let streamed = exp.run_collect()?;
    let sched = exp.scheduler();
    verify_bit_identical(&streamed, &sched.run_uncached())
        .map_err(|e| e.context("SoA stream vs uncached oracle"))?;
    verify_bit_identical(&streamed, &sched.run_ref())
        .map_err(|e| e.context("SoA stream vs ref oracle"))?;
    Ok(())
}

/// Gate variant for callers that already hold a churn-free sync-policy
/// DES record stream (e.g. a des-sweep grid point at the gate
/// configuration): compares it against a fresh serial round-engine run
/// of `cfg` without re-running the simulation.  Runs CARD, the
/// strategy every sweep point uses.
pub fn verify_des_records_match_round_engine(
    cfg: &ExpConfig,
    state: ChannelState,
    records: &[RoundRecord],
) -> anyhow::Result<()> {
    let sched = Scheduler::new(cfg.clone(), state, Strategy::Card);
    let serial = sched.run_analytic()?;
    verify_bit_identical(&serial, records)
}

/// The des-sweep gate: on a churn-free copy of `cfg`, the sync-policy
/// discrete-event engine must reproduce the serial round engine's
/// record stream bit for bit (the DES bit-compat contract,
/// DESIGN.md §11).  Runs CARD, the strategy every sweep point uses.
pub fn verify_des_sync_matches_round_engine(
    cfg: &ExpConfig,
    state: ChannelState,
    capacity: usize,
    batch: usize,
) -> anyhow::Result<()> {
    let mut cfg = cfg.clone();
    // with churn enabled, departing devices legitimately drop cells the
    // barrier engine would still run — gate on the churn-free contract
    cfg.churn = Default::default();
    let sched = Arc::new(Scheduler::new(cfg, state, Strategy::Card));
    let out = DesEngine::new(
        sched.clone(),
        DesConfig {
            policy: Policy::Sync,
            capacity,
            batch,
        },
    )
    .run();
    let des_records: Vec<RoundRecord> = out.records.iter().map(|r| r.record.clone()).collect();
    let serial = sched.run_analytic()?;
    verify_bit_identical(&serial, &des_records)
}

/// The cell-tier anchor (DESIGN.md §15): a single-cell copy of `cfg`
/// (churn zeroed, `[cells]` forced back to its one-cell default) run
/// through the sync-policy discrete-event engine must reproduce the
/// serial round engine bit for bit.  This is the gate `cell-sweep`
/// runs per scenario, pinning the multi-cell machinery to the
/// pre-cell engines: with one cell there is one queue, one aggregator
/// level, and one energy accumulator, so every multi-cell code path
/// must collapse to the original arithmetic.
pub fn verify_single_cell_bit_identity(
    cfg: &ExpConfig,
    state: ChannelState,
    capacity: usize,
    batch: usize,
) -> anyhow::Result<()> {
    let mut cfg = cfg.clone();
    cfg.cells = Default::default();
    verify_des_sync_matches_round_engine(&cfg, state, capacity, batch)
}

fn ensure_server_stats_bits(a: &ServerStats, b: &ServerStats, what: &str) -> anyhow::Result<()> {
    anyhow::ensure!(
        a.served_jobs == b.served_jobs
            && a.abandoned_jobs == b.abandoned_jobs
            && a.busy_slot_s.to_bits() == b.busy_slot_s.to_bits()
            && a.mean_wait_s.to_bits() == b.mean_wait_s.to_bits()
            && a.peak_depth == b.peak_depth
            && a.mean_depth.to_bits() == b.mean_depth.to_bits()
            && a.utilization.to_bits() == b.utilization.to_bits(),
        "{what}: server queue statistics diverge"
    );
    Ok(())
}

/// Require two full DES outcomes to agree bit for bit — analytic
/// records, DES observables, queue statistics, aggregator state, and
/// every fault counter.  The comparator behind both fault-plane gates.
pub fn verify_des_outcome_bit_identical(a: &DesOutcome, b: &DesOutcome) -> anyhow::Result<()> {
    anyhow::ensure!(
        a.records.len() == b.records.len(),
        "record count mismatch: {} vs {}",
        a.records.len(),
        b.records.len()
    );
    for (x, y) in a.records.iter().zip(&b.records) {
        verify_bit_identical(std::slice::from_ref(&x.record), std::slice::from_ref(&y.record))?;
        anyhow::ensure!(
            x.start_s.to_bits() == y.start_s.to_bits()
                && x.finish_s.to_bits() == y.finish_s.to_bits()
                && x.wait_s.to_bits() == y.wait_s.to_bits()
                && x.staleness == y.staleness
                && x.weight.to_bits() == y.weight.to_bits()
                && x.degraded == y.degraded,
            "DES observables diverge at round {} device {}",
            x.record.round,
            x.record.device_idx
        );
    }
    anyhow::ensure!(
        a.makespan_s.to_bits() == b.makespan_s.to_bits(),
        "makespan diverges: {} vs {}",
        a.makespan_s,
        b.makespan_s
    );
    ensure_server_stats_bits(&a.server, &b.server, "fleet")?;
    anyhow::ensure!(
        a.per_cell.len() == b.per_cell.len(),
        "per-cell breakdown length mismatch"
    );
    for (i, (x, y)) in a.per_cell.iter().zip(&b.per_cell).enumerate() {
        ensure_server_stats_bits(&x.server, &y.server, "cell")?;
        anyhow::ensure!(
            x.position_m.0.to_bits() == y.position_m.0.to_bits()
                && x.position_m.1.to_bits() == y.position_m.1.to_bits()
                && x.energy_spent_j.to_bits() == y.energy_spent_j.to_bits()
                && x.handovers_in == y.handovers_in
                && x.aggregator_consistent == y.aggregator_consistent,
            "cell {i} observables diverge"
        );
    }
    anyhow::ensure!(
        a.handovers == b.handovers
            && a.dropped == b.dropped
            && a.launched == b.launched
            && a.departures == b.departures
            && a.arrivals == b.arrivals
            && a.peak_staleness == b.peak_staleness
            && a.energy_spent_j.to_bits() == b.energy_spent_j.to_bits(),
        "run-level counters diverge"
    );
    anyhow::ensure!(
        a.aggregator.merges() == b.aggregator.merges()
            && a.aggregator.bytes_distributed.to_bits() == b.aggregator.bytes_distributed.to_bits()
            && a.aggregator.bytes_collected.to_bits() == b.aggregator.bytes_collected.to_bits()
            && a.aggregator.layers.len() == b.aggregator.layers.len()
            && a
                .aggregator
                .layers
                .iter()
                .zip(&b.aggregator.layers)
                .all(|(x, y)| x.owner == y.owner && x.round == y.round && x.updates == y.updates),
        "aggregator state diverges"
    );
    anyhow::ensure!(
        a.retries == b.retries
            && a.timeout_demotions == b.timeout_demotions
            && a.failovers == b.failovers
            && a.slot_failures == b.slot_failures
            && a.slot_repairs == b.slot_repairs
            && a.retry_energy_j.to_bits() == b.retry_energy_j.to_bits(),
        "fault counters diverge: retries {} vs {}, demotions {} vs {}, \
         failovers {} vs {}, slot failures {} vs {}, repairs {} vs {}, \
         retry energy {} vs {} J",
        a.retries,
        b.retries,
        a.timeout_demotions,
        b.timeout_demotions,
        a.failovers,
        b.failovers,
        a.slot_failures,
        b.slot_failures,
        a.slot_repairs,
        b.slot_repairs,
        a.retry_energy_j,
        b.retry_energy_j
    );
    Ok(())
}

/// The zero-perturbation anchor (DESIGN.md §17): a `[faults]` table
/// whose injection rates are all zero must be **bitwise invisible** —
/// the run must equal one with the fault plane entirely absent, on
/// every record, queue statistic, and counter.  The chaos sweep runs
/// this gate per scenario before any faulted point is trusted.
pub fn verify_zero_fault_rate_is_noop(
    cfg: &ExpConfig,
    state: ChannelState,
    des: DesConfig,
) -> anyhow::Result<()> {
    // keep the recovery knobs (retries, backoff, timeout factor) from
    // the caller's table: only the *rates* are zeroed, so this proves
    // the dormant plane never touches the timeline
    let mut dormant = cfg.clone();
    dormant.faults.link_outage_rate_hz = 0.0;
    dormant.faults.slot_fail_prob = 0.0;
    dormant.faults.burst_rate_per_round = 0.0;
    let mut absent = cfg.clone();
    absent.faults = Default::default();
    let run = |c: &ExpConfig| {
        DesEngine::new(
            Arc::new(Scheduler::new(c.clone(), state, Strategy::Card)),
            des,
        )
        .run()
    };
    verify_des_outcome_bit_identical(&run(&dormant), &run(&absent))
}

/// The checkpoint/resume gate (DESIGN.md §17): freezing the event
/// engine at virtual time `t_s`, round-tripping the snapshot through
/// the `edgesplit/checkpoint/v1` text envelope, and resuming must
/// reproduce the uninterrupted run bit for bit — including mid-burst
/// and mid-retry checkpoints, since `t_s` may land inside either.
/// The chaos sweep runs this gate per scenario, which doubles as the
/// CI round-trip smoke for the envelope codec.
pub fn verify_checkpoint_resume_bit_identity(
    cfg: &ExpConfig,
    state: ChannelState,
    des: DesConfig,
    t_s: f64,
) -> anyhow::Result<()> {
    verify_checkpoint_resume_bit_identity_with(cfg, state, des, t_s, Strategy::Card)
}

/// Strategy-parameterized checkpoint/resume gate: learned strategies
/// carry their bandit bank through the envelope's policy section, so a
/// mid-run freeze must restore the exact Welford table the
/// uninterrupted run had at that instant (DESIGN.md §19).
pub fn verify_checkpoint_resume_bit_identity_with(
    cfg: &ExpConfig,
    state: ChannelState,
    des: DesConfig,
    t_s: f64,
    strategy: Strategy,
) -> anyhow::Result<()> {
    let engine = DesEngine::new(Arc::new(Scheduler::new(cfg.clone(), state, strategy)), des);
    let full = engine.run();
    let resumed = match engine.run_until(t_s) {
        RunState::Checkpoint(snap) => {
            let decoded = super::checkpoint::decode(&super::checkpoint::encode(&snap))?;
            engine.resume(&decoded)
        }
        // the horizon drained before t_s — the "resume" is the run itself
        RunState::Done(out) => *out,
    };
    verify_des_outcome_bit_identical(&full, &resumed)
}

/// The learned-policy determinism gate (DESIGN.md §19): a bandit
/// strategy's record stream must be a pure function of
/// `(config, seed)` — bit-identical from the serial reference path and
/// the round-barriered parallel engine at any thread count.  The
/// policy sweep runs this gate per (strategy, scenario) before any
/// regret curve is trusted.
pub fn verify_learned_thread_determinism(
    cfg: &ExpConfig,
    state: ChannelState,
    strategy: Strategy,
) -> anyhow::Result<()> {
    anyhow::ensure!(
        strategy.is_learned(),
        "the learned-determinism gate applies to bandit strategies, not {}",
        strategy.name()
    );
    let sched = Scheduler::new(cfg.clone(), state, strategy);
    let serial = sched.run_analytic()?;
    for threads in [2usize, 8] {
        let par = sched.run_parallel(threads);
        verify_bit_identical(&serial, &par)
            .map_err(|e| e.context(format!("{} at {threads} threads", strategy.name())))?;
    }
    Ok(())
}

/// The channel-isolation gate (DESIGN.md §19): learned decisions draw
/// exploration noise from their own salted stream, never the cell RNG,
/// so every link realization (SNRs, rates) under a bandit strategy
/// must equal the CARD baseline's bit for bit — and CARD itself stays
/// bitwise untouched by the policy subsystem's existence.
pub fn verify_learned_channel_isolation(
    cfg: &ExpConfig,
    state: ChannelState,
    strategy: Strategy,
) -> anyhow::Result<()> {
    anyhow::ensure!(
        strategy.is_learned(),
        "the channel-isolation gate applies to bandit strategies, not {}",
        strategy.name()
    );
    let card = Scheduler::new(cfg.clone(), state, Strategy::Card).run_analytic()?;
    let learned = Scheduler::new(cfg.clone(), state, strategy).run_analytic()?;
    anyhow::ensure!(
        card.len() == learned.len(),
        "record count mismatch: {} vs {}",
        card.len(),
        learned.len()
    );
    for (c, l) in card.iter().zip(&learned) {
        anyhow::ensure!(
            c.snr_up_db.to_bits() == l.snr_up_db.to_bits()
                && c.snr_down_db.to_bits() == l.snr_down_db.to_bits()
                && c.rate_up_bps.to_bits() == l.rate_up_bps.to_bits()
                && c.rate_down_bps.to_bits() == l.rate_down_bps.to_bits(),
            "{} perturbed the channel stream at round {} device {}",
            strategy.name(),
            c.round,
            c.device_idx
        );
    }
    Ok(())
}
