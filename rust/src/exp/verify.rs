//! Shared determinism gates — the bit-level comparators both sweeps
//! (and every bit-compat test suite) run, hoisted here so the CLI
//! gates and the property tests can never drift apart.

use std::sync::Arc;

use crate::config::{ChannelState, ExpConfig};
use crate::coordinator::{RoundRecord, Scheduler, Strategy};
use crate::des::{DesConfig, DesEngine, Policy};

use super::builder::Experiment;

/// Require two record streams to agree **bit for bit** on every field
/// the experiments report.
pub fn verify_bit_identical(a: &[RoundRecord], b: &[RoundRecord]) -> anyhow::Result<()> {
    anyhow::ensure!(
        a.len() == b.len(),
        "record count mismatch: {} vs {}",
        a.len(),
        b.len()
    );
    for (x, y) in a.iter().zip(b) {
        anyhow::ensure!(
            x.round == y.round
                && x.device_idx == y.device_idx
                && x.cut == y.cut
                && x.freq_hz.to_bits() == y.freq_hz.to_bits()
                && x.cost.to_bits() == y.cost.to_bits()
                && x.delay_s.to_bits() == y.delay_s.to_bits()
                && x.energy_j.to_bits() == y.energy_j.to_bits()
                && x.rate_up_bps.to_bits() == y.rate_up_bps.to_bits()
                && x.rate_down_bps.to_bits() == y.rate_down_bps.to_bits()
                && x.snr_up_db.to_bits() == y.snr_up_db.to_bits()
                && x.snr_down_db.to_bits() == y.snr_down_db.to_bits()
                && x.device_compute_s.to_bits() == y.device_compute_s.to_bits()
                && x.server_compute_s.to_bits() == y.server_compute_s.to_bits()
                && x.transmission_s.to_bits() == y.transmission_s.to_bits(),
            "parallel/serial divergence at round {} device {}",
            x.round,
            x.device_idx
        );
    }
    Ok(())
}

/// The fleet-sweep gate: the experiment's configured (parallel, cached)
/// round engine must reproduce the serial reference path bit for bit.
pub fn verify_round_determinism(exp: &Experiment) -> anyhow::Result<()> {
    let parallel = exp.run_collect()?;
    verify_records_match_serial(exp, &parallel)
}

/// Gate variant for callers that already hold the experiment's record
/// stream (e.g. a sweep's gated grid point): compares it against a
/// fresh serial reference run without re-running the parallel engine.
pub fn verify_records_match_serial(
    exp: &Experiment,
    parallel: &[RoundRecord],
) -> anyhow::Result<()> {
    anyhow::ensure!(
        !exp.is_event_engine(),
        "the round-determinism gate applies to the round engine"
    );
    let serial = exp.scheduler().run_analytic()?;
    verify_bit_identical(&serial, parallel)
}

/// Gate variant for callers that already hold a churn-free sync-policy
/// DES record stream (e.g. a des-sweep grid point at the gate
/// configuration): compares it against a fresh serial round-engine run
/// of `cfg` without re-running the simulation.  Runs CARD, the
/// strategy every sweep point uses.
pub fn verify_des_records_match_round_engine(
    cfg: &ExpConfig,
    state: ChannelState,
    records: &[RoundRecord],
) -> anyhow::Result<()> {
    let sched = Scheduler::new(cfg.clone(), state, Strategy::Card);
    let serial = sched.run_analytic()?;
    verify_bit_identical(&serial, records)
}

/// The des-sweep gate: on a churn-free copy of `cfg`, the sync-policy
/// discrete-event engine must reproduce the serial round engine's
/// record stream bit for bit (the DES bit-compat contract,
/// DESIGN.md §11).  Runs CARD, the strategy every sweep point uses.
pub fn verify_des_sync_matches_round_engine(
    cfg: &ExpConfig,
    state: ChannelState,
    capacity: usize,
    batch: usize,
) -> anyhow::Result<()> {
    let mut cfg = cfg.clone();
    // with churn enabled, departing devices legitimately drop cells the
    // barrier engine would still run — gate on the churn-free contract
    cfg.churn = Default::default();
    let sched = Arc::new(Scheduler::new(cfg, state, Strategy::Card));
    let out = DesEngine::new(
        sched.clone(),
        DesConfig {
            policy: Policy::Sync,
            capacity,
            batch,
        },
    )
    .run();
    let des_records: Vec<RoundRecord> = out.records.iter().map(|r| r.record.clone()).collect();
    let serial = sched.run_analytic()?;
    verify_bit_identical(&serial, &des_records)
}

/// The cell-tier anchor (DESIGN.md §15): a single-cell copy of `cfg`
/// (churn zeroed, `[cells]` forced back to its one-cell default) run
/// through the sync-policy discrete-event engine must reproduce the
/// serial round engine bit for bit.  This is the gate `cell-sweep`
/// runs per scenario, pinning the multi-cell machinery to the
/// pre-cell engines: with one cell there is one queue, one aggregator
/// level, and one energy accumulator, so every multi-cell code path
/// must collapse to the original arithmetic.
pub fn verify_single_cell_bit_identity(
    cfg: &ExpConfig,
    state: ChannelState,
    capacity: usize,
    batch: usize,
) -> anyhow::Result<()> {
    let mut cfg = cfg.clone();
    cfg.cells = Default::default();
    verify_des_sync_matches_round_engine(&cfg, state, capacity, batch)
}
