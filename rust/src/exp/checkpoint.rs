//! Checkpoint envelope (DESIGN.md §17): serialize a paused DES run's
//! [`SimSnapshot`] to a versioned, line-oriented text format and back.
//!
//! Format `edgesplit/checkpoint/v2`: the first line is the magic, each
//! following line is a space-separated record with a leading tag.
//! (v2 added the decided cut to `i`/`r` lines and the trailing policy
//! section carrying a learned strategy's bandit state; v1 envelopes
//! are refused rather than silently half-restored.)
//! Every `f64` travels as the decimal rendering of its IEEE-754 **bit
//! pattern** (`to_bits`), never as a decimal float — the whole point of
//! a checkpoint is that `resume(decode(encode(checkpoint(t))))` is
//! bitwise identical to the uninterrupted run, and a round-trip through
//! decimal floats would quietly break that.  The envelope is canonical:
//! encoding a decoded snapshot reproduces the exact input text (the
//! round-trip property tested below), so checkpoints diff and hash
//! cleanly.
//!
//! The envelope stores only the *mutable* simulation state; everything
//! derivable from `(config, seed)` — cell grid, association traces,
//! analytic records, phase timings — is recomputed on resume.  The
//! `fingerprint` line carries the config/strategy/DES-knob hash that
//! `DesEngine::resume` checks, so a checkpoint can never silently
//! resume under a different experiment.

use std::fmt::Write as _;
use std::str::SplitWhitespace;

use anyhow::{bail, Context};

use crate::des::engine::{AggSnap, DeviceSnap, InflightSnap, RecordSnap};
use crate::des::{EventKind, SimSnapshot};
use crate::des::server::{Job, ServerQueueState};
use crate::des::SimTime;
use crate::policy::PolicyBankSnap;

/// First line of every checkpoint envelope.
pub const MAGIC: &str = "edgesplit/checkpoint/v2";

/// Serialize a snapshot to the versioned text envelope.
pub fn encode(snap: &SimSnapshot) -> String {
    let mut out = String::new();
    let w = &mut out;
    let _ = writeln!(w, "{MAGIC}");
    let _ = writeln!(w, "fingerprint {}", snap.fingerprint);
    let _ = writeln!(
        w,
        "clock {} {} {}",
        snap.now_s.to_bits(),
        snap.seq,
        snap.processed
    );
    let _ = writeln!(
        w,
        "counters {} {} {} {} {} {}",
        snap.retries,
        snap.timeout_demotions,
        snap.failovers,
        snap.slot_failures,
        snap.slot_repairs,
        snap.retry_energy_j.to_bits()
    );
    let _ = writeln!(
        w,
        "run {} {} {} {} {} {} {}",
        snap.launched,
        snap.dropped,
        snap.departures,
        snap.arrivals,
        snap.peak_staleness,
        snap.makespan_s.to_bits(),
        snap.version
    );
    let _ = writeln!(
        w,
        "barrier {} {} {} {}",
        snap.barrier_round,
        snap.barrier_outstanding,
        u8::from(snap.barrier_open),
        snap.remaining_budget
    );
    let _ = write!(w, "energy {}", snap.energy_by_cell.len());
    for e in &snap.energy_by_cell {
        let _ = write!(w, " {}", e.to_bits());
    }
    let _ = writeln!(w);
    let _ = write!(w, "dispatch {}", snap.dispatch_seqs.len());
    for s in &snap.dispatch_seqs {
        let _ = write!(w, " {s}");
    }
    let _ = writeln!(w);
    let _ = write!(w, "actives {}", snap.actives.len());
    for a in &snap.actives {
        // u64::MAX marks an idle device (a round index cannot reach it)
        let _ = write!(w, " {}", a.map(|r| r as u64).unwrap_or(u64::MAX));
    }
    let _ = writeln!(w);
    let _ = writeln!(w, "events {}", snap.events.len());
    for (t, seq, kind) in &snap.events {
        let _ = write!(w, "e {} {seq}", t.to_bits());
        encode_event(w, kind);
        let _ = writeln!(w);
    }
    let _ = writeln!(w, "servers {}", snap.servers.len());
    for s in &snap.servers {
        let (wn, wmean, wm2, wmin, wmax) = s.wait;
        let _ = write!(
            w,
            "s {} {} {} {} {} {} {} {wn} {} {} {} {} {}",
            s.busy_slots,
            s.busy_slot_s.to_bits(),
            s.served,
            s.abandoned,
            s.peak_depth,
            s.depth_area.to_bits(),
            s.depth_since_s.to_bits(),
            wmean.to_bits(),
            wm2.to_bits(),
            wmin.to_bits(),
            wmax.to_bits(),
            s.waiting.len()
        );
        for j in &s.waiting {
            let _ = write!(
                w,
                " {} {} {} {}",
                j.device,
                j.round,
                j.service_s.to_bits(),
                j.enqueued_at.secs().to_bits()
            );
        }
        let _ = writeln!(w);
    }
    let _ = writeln!(w, "devices {}", snap.devices.len());
    for d in &snap.devices {
        let (flag, bits) = match d.gauss_spare {
            Some(g) => (1u8, g.to_bits()),
            None => (0u8, 0u64),
        };
        let _ = writeln!(
            w,
            "d {} {} {} {} {} {} {flag} {bits}",
            u8::from(d.present),
            d.next_round,
            d.rng[0],
            d.rng[1],
            d.rng[2],
            d.rng[3]
        );
    }
    let _ = writeln!(w, "inflight {}", snap.inflight.len());
    for i in &snap.inflight {
        let _ = writeln!(
            w,
            "i {} {} {} {} {} {} {} {}",
            i.device,
            i.round,
            u8::from(i.degraded),
            i.cut,
            i.cell,
            i.start_s.to_bits(),
            i.wait_s.to_bits(),
            i.base_version
        );
    }
    encode_agg(w, &snap.agg);
    let _ = writeln!(w, "cellaggs {}", snap.cell_aggs.len());
    for a in &snap.cell_aggs {
        encode_agg(w, a);
    }
    let _ = writeln!(w, "records {}", snap.records.len());
    for r in &snap.records {
        let _ = writeln!(
            w,
            "r {} {} {} {} {} {} {} {} {}",
            r.device,
            r.round,
            u8::from(r.degraded),
            r.cut,
            r.start_s.to_bits(),
            r.finish_s.to_bits(),
            r.wait_s.to_bits(),
            r.staleness,
            r.weight.to_bits()
        );
    }
    match &snap.policy {
        None => {
            let _ = writeln!(w, "policy 0");
        }
        Some(p) => {
            let _ = writeln!(
                w,
                "policy 1 {} {} {} {}",
                p.n_ctx, p.n_arms, p.explore, p.exploit
            );
            let _ = write!(w, "pp");
            for pulls in &p.pulls {
                let _ = write!(w, " {pulls}");
            }
            let _ = writeln!(w);
            for i in 0..p.count.len() {
                let _ = writeln!(
                    w,
                    "pa {} {} {}",
                    p.count[i],
                    p.mean[i].to_bits(),
                    p.m2[i].to_bits()
                );
            }
        }
    }
    out
}

fn encode_agg(w: &mut String, a: &AggSnap) {
    let _ = write!(
        w,
        "agg {} {} {} {}",
        a.layers.len(),
        a.bytes_distributed.to_bits(),
        a.bytes_collected.to_bits(),
        a.merges
    );
    for &(owner, round, updates) in &a.layers {
        let _ = write!(w, " {owner} {round} {updates}");
    }
    let _ = writeln!(w);
}

fn encode_event(w: &mut String, kind: &EventKind) {
    let _ = match kind {
        EventKind::Arrive { device } => write!(w, " arrive {device}"),
        EventKind::Depart { device } => write!(w, " depart {device}"),
        EventKind::UplinkDone { device, round } => write!(w, " up {device} {round}"),
        EventKind::ServerBatchDone { cell, jobs } => {
            let _ = write!(w, " batch {cell} {}", jobs.len());
            for (d, r) in jobs {
                let _ = write!(w, " {d} {r}");
            }
            Ok(())
        }
        EventKind::MergeReady { device, round } => write!(w, " merge {device} {round}"),
        EventKind::Deadline { round } => write!(w, " deadline {round}"),
        EventKind::RetryUplink {
            device,
            round,
            attempt,
        } => write!(w, " retryup {device} {round} {attempt}"),
        EventKind::RetryDownlink {
            device,
            round,
            attempt,
        } => write!(w, " retrydown {device} {round} {attempt}"),
    };
}

/// Line cursor with 1-based positions for error context.
struct Cursor<'a> {
    lines: std::str::Lines<'a>,
    line_no: usize,
}

impl<'a> Cursor<'a> {
    fn next(&mut self, what: &str) -> anyhow::Result<Toks<'a>> {
        let line = self
            .lines
            .next()
            .with_context(|| format!("checkpoint truncated: expected {what}"))?;
        self.line_no += 1;
        Ok(Toks {
            it: line.split_whitespace(),
            line_no: self.line_no,
        })
    }

    /// Read a line and check its leading tag.
    fn tagged(&mut self, tag: &str) -> anyhow::Result<Toks<'a>> {
        let mut t = self.next(tag)?;
        let got = t.str("tag")?;
        if got != tag {
            bail!("checkpoint line {}: expected '{tag}', got '{got}'", t.line_no);
        }
        Ok(t)
    }
}

/// Whitespace-token cursor over one line.
struct Toks<'a> {
    it: SplitWhitespace<'a>,
    line_no: usize,
}

impl<'a> Toks<'a> {
    fn str(&mut self, what: &str) -> anyhow::Result<&'a str> {
        self.it
            .next()
            .with_context(|| format!("checkpoint line {}: missing {what}", self.line_no))
    }

    fn u64(&mut self, what: &str) -> anyhow::Result<u64> {
        let s = self.str(what)?;
        s.parse::<u64>()
            .with_context(|| format!("checkpoint line {}: bad {what} '{s}'", self.line_no))
    }

    fn usize(&mut self, what: &str) -> anyhow::Result<usize> {
        Ok(self.u64(what)? as usize)
    }

    fn f64_bits(&mut self, what: &str) -> anyhow::Result<f64> {
        Ok(f64::from_bits(self.u64(what)?))
    }

    fn bool01(&mut self, what: &str) -> anyhow::Result<bool> {
        match self.u64(what)? {
            0 => Ok(false),
            1 => Ok(true),
            v => bail!("checkpoint line {}: {what} must be 0/1, got {v}", self.line_no),
        }
    }
}

/// Parse a text envelope back into a [`SimSnapshot`].
pub fn decode(text: &str) -> anyhow::Result<SimSnapshot> {
    let mut cur = Cursor {
        lines: text.lines(),
        line_no: 0,
    };
    let magic = cur.next("magic line")?.str("magic")?;
    if magic != MAGIC {
        bail!("not a checkpoint envelope: expected '{MAGIC}', got '{magic}'");
    }
    let fingerprint = cur.tagged("fingerprint")?.u64("fingerprint")?;
    let mut t = cur.tagged("clock")?;
    let now_s = t.f64_bits("now bits")?;
    let seq = t.u64("seq")?;
    let processed = t.u64("processed")?;
    let mut t = cur.tagged("counters")?;
    let retries = t.u64("retries")?;
    let timeout_demotions = t.u64("timeout_demotions")?;
    let failovers = t.u64("failovers")?;
    let slot_failures = t.u64("slot_failures")?;
    let slot_repairs = t.u64("slot_repairs")?;
    let retry_energy_j = t.f64_bits("retry_energy bits")?;
    let mut t = cur.tagged("run")?;
    let launched = t.u64("launched")?;
    let dropped = t.u64("dropped")?;
    let departures = t.u64("departures")?;
    let arrivals = t.u64("arrivals")?;
    let peak_staleness = t.usize("peak_staleness")?;
    let makespan_s = t.f64_bits("makespan bits")?;
    let version = t.usize("version")?;
    let mut t = cur.tagged("barrier")?;
    let barrier_round = t.usize("barrier round")?;
    let barrier_outstanding = t.usize("barrier outstanding")?;
    let barrier_open = t.bool01("barrier open")?;
    let remaining_budget = t.usize("remaining budget")?;

    let mut t = cur.tagged("energy")?;
    let n = t.usize("energy count")?;
    let mut energy_by_cell = Vec::with_capacity(n);
    for _ in 0..n {
        energy_by_cell.push(t.f64_bits("energy bits")?);
    }
    let mut t = cur.tagged("dispatch")?;
    let n = t.usize("dispatch count")?;
    let mut dispatch_seqs = Vec::with_capacity(n);
    for _ in 0..n {
        dispatch_seqs.push(t.u64("dispatch seq")?);
    }
    let mut t = cur.tagged("actives")?;
    let n = t.usize("actives count")?;
    let mut actives = Vec::with_capacity(n);
    for _ in 0..n {
        let v = t.u64("active round")?;
        actives.push((v != u64::MAX).then_some(v as usize));
    }

    let n = cur.tagged("events")?.usize("event count")?;
    let mut events = Vec::with_capacity(n);
    for _ in 0..n {
        let mut t = cur.tagged("e")?;
        let at = t.f64_bits("event time bits")?;
        let eseq = t.u64("event seq")?;
        events.push((at, eseq, decode_event(&mut t)?));
    }

    let n = cur.tagged("servers")?.usize("server count")?;
    let mut servers = Vec::with_capacity(n);
    for _ in 0..n {
        let mut t = cur.tagged("s")?;
        let busy_slots = t.usize("busy slots")?;
        let busy_slot_s = t.f64_bits("busy slot seconds")?;
        let served = t.u64("served")?;
        let abandoned = t.u64("abandoned")?;
        let peak_depth = t.usize("peak depth")?;
        let depth_area = t.f64_bits("depth area")?;
        let depth_since_s = t.f64_bits("depth since")?;
        let wait = (
            t.u64("wait n")?,
            t.f64_bits("wait mean")?,
            t.f64_bits("wait m2")?,
            t.f64_bits("wait min")?,
            t.f64_bits("wait max")?,
        );
        let jn = t.usize("waiting count")?;
        let mut waiting = Vec::with_capacity(jn);
        for _ in 0..jn {
            waiting.push(Job {
                device: t.usize("job device")?,
                round: t.usize("job round")?,
                service_s: t.f64_bits("job service")?,
                enqueued_at: SimTime::new(t.f64_bits("job enqueued")?),
            });
        }
        servers.push(ServerQueueState {
            busy_slots,
            waiting,
            busy_slot_s,
            wait,
            served,
            abandoned,
            peak_depth,
            depth_area,
            depth_since_s,
        });
    }

    let n = cur.tagged("devices")?.usize("device count")?;
    let mut devices = Vec::with_capacity(n);
    for _ in 0..n {
        let mut t = cur.tagged("d")?;
        let present = t.bool01("present")?;
        let next_round = t.usize("next round")?;
        let rng = [
            t.u64("rng s0")?,
            t.u64("rng s1")?,
            t.u64("rng s2")?,
            t.u64("rng s3")?,
        ];
        let has_spare = t.bool01("gauss flag")?;
        let bits = t.u64("gauss bits")?;
        devices.push(DeviceSnap {
            present,
            next_round,
            rng,
            gauss_spare: has_spare.then(|| f64::from_bits(bits)),
        });
    }

    let n = cur.tagged("inflight")?.usize("inflight count")?;
    let mut inflight = Vec::with_capacity(n);
    for _ in 0..n {
        let mut t = cur.tagged("i")?;
        inflight.push(InflightSnap {
            device: t.usize("inflight device")?,
            round: t.usize("inflight round")?,
            degraded: t.bool01("inflight degraded")?,
            cut: t.usize("inflight cut")?,
            cell: t.usize("inflight cell")?,
            start_s: t.f64_bits("inflight start")?,
            wait_s: t.f64_bits("inflight wait")?,
            base_version: t.usize("inflight base version")?,
        });
    }

    let agg = decode_agg(&mut cur.tagged("agg")?)?;
    let n = cur.tagged("cellaggs")?.usize("cell agg count")?;
    let mut cell_aggs = Vec::with_capacity(n);
    for _ in 0..n {
        cell_aggs.push(decode_agg(&mut cur.tagged("agg")?)?);
    }

    let n = cur.tagged("records")?.usize("record count")?;
    let mut records = Vec::with_capacity(n);
    for _ in 0..n {
        let mut t = cur.tagged("r")?;
        records.push(RecordSnap {
            device: t.usize("record device")?,
            round: t.usize("record round")?,
            degraded: t.bool01("record degraded")?,
            cut: t.usize("record cut")?,
            start_s: t.f64_bits("record start")?,
            finish_s: t.f64_bits("record finish")?,
            wait_s: t.f64_bits("record wait")?,
            staleness: t.usize("record staleness")?,
            weight: t.f64_bits("record weight")?,
        });
    }

    let mut t = cur.tagged("policy")?;
    let policy = if t.bool01("policy present")? {
        let n_ctx = t.usize("policy contexts")?;
        let n_arms = t.usize("policy arms")?;
        let explore = t.u64("policy explore")?;
        let exploit = t.u64("policy exploit")?;
        let mut p = cur.tagged("pp")?;
        let mut pulls = Vec::with_capacity(n_ctx);
        for _ in 0..n_ctx {
            pulls.push(p.u64("policy pulls")?);
        }
        let cells = n_ctx
            .checked_mul(n_arms)
            .ok_or_else(|| anyhow::anyhow!("policy table dimensions overflow"))?;
        let mut count = Vec::with_capacity(cells);
        let mut mean = Vec::with_capacity(cells);
        let mut m2 = Vec::with_capacity(cells);
        for _ in 0..cells {
            let mut a = cur.tagged("pa")?;
            count.push(a.u64("arm count")?);
            mean.push(a.f64_bits("arm mean")?);
            m2.push(a.f64_bits("arm m2")?);
        }
        Some(PolicyBankSnap {
            n_ctx,
            n_arms,
            count,
            mean,
            m2,
            pulls,
            explore,
            exploit,
        })
    } else {
        None
    };

    Ok(SimSnapshot {
        fingerprint,
        now_s,
        seq,
        events,
        processed,
        servers,
        devices,
        actives,
        inflight,
        agg,
        cell_aggs,
        version,
        records,
        barrier_round,
        barrier_outstanding,
        barrier_open,
        remaining_budget,
        launched,
        dropped,
        departures,
        arrivals,
        peak_staleness,
        makespan_s,
        energy_by_cell,
        dispatch_seqs,
        retries,
        timeout_demotions,
        failovers,
        slot_failures,
        slot_repairs,
        retry_energy_j,
        policy,
    })
}

fn decode_agg(t: &mut Toks<'_>) -> anyhow::Result<AggSnap> {
    let n = t.usize("layer count")?;
    let bytes_distributed = t.f64_bits("bytes distributed")?;
    let bytes_collected = t.f64_bits("bytes collected")?;
    let merges = t.u64("merges")?;
    let mut layers = Vec::with_capacity(n);
    for _ in 0..n {
        layers.push((
            t.u64("layer owner")?,
            t.usize("layer round")?,
            t.u64("layer updates")?,
        ));
    }
    Ok(AggSnap {
        layers,
        bytes_distributed,
        bytes_collected,
        merges,
    })
}

fn decode_event(t: &mut Toks<'_>) -> anyhow::Result<EventKind> {
    let kind = t.str("event kind")?;
    Ok(match kind {
        "arrive" => EventKind::Arrive {
            device: t.usize("device")?,
        },
        "depart" => EventKind::Depart {
            device: t.usize("device")?,
        },
        "up" => EventKind::UplinkDone {
            device: t.usize("device")?,
            round: t.usize("round")?,
        },
        "batch" => {
            let cell = t.usize("cell")?;
            let n = t.usize("job count")?;
            let mut jobs = Vec::with_capacity(n);
            for _ in 0..n {
                jobs.push((t.usize("job device")?, t.usize("job round")?));
            }
            EventKind::ServerBatchDone { cell, jobs }
        }
        "merge" => EventKind::MergeReady {
            device: t.usize("device")?,
            round: t.usize("round")?,
        },
        "deadline" => EventKind::Deadline {
            round: t.usize("round")?,
        },
        "retryup" => EventKind::RetryUplink {
            device: t.usize("device")?,
            round: t.usize("round")?,
            attempt: t.usize("attempt")?,
        },
        "retrydown" => EventKind::RetryDownlink {
            device: t.usize("device")?,
            round: t.usize("round")?,
            attempt: t.usize("attempt")?,
        },
        other => bail!(
            "checkpoint line {}: unknown event kind '{other}'",
            t.line_no
        ),
    })
}

/// Write an envelope to a file.
pub fn write_to(path: &str, snap: &SimSnapshot) -> anyhow::Result<()> {
    std::fs::write(path, encode(snap))
        .with_context(|| format!("writing checkpoint to {path}"))
}

/// Read an envelope from a file.
pub fn read_from(path: &str) -> anyhow::Result<SimSnapshot> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading checkpoint from {path}"))?;
    decode(&text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::{DesConfig, Policy, RunState};
    use crate::exp::ExperimentBuilder;

    fn mid_run_snapshot() -> SimSnapshot {
        let spec = crate::config::FaultsSpec {
            link_outage_rate_hz: 0.4,
            slot_fail_prob: 0.2,
            burst_rate_per_round: 0.5,
            ..Default::default()
        };
        let exp = ExperimentBuilder::preset("dense-urban")
            .devices(6)
            .rounds(3)
            .seed(11)
            .faults(spec)
            .des(DesConfig {
                policy: Policy::Sync,
                capacity: 2,
                batch: 1,
            })
            .build()
            .unwrap();
        // far enough in to have in-flight cells, queue state, and
        // (with these rates) a fault counter or two
        let mut t = 0.0;
        loop {
            match exp.checkpoint_at(t).unwrap() {
                RunState::Checkpoint(snap) => {
                    if !snap.inflight.is_empty() || !snap.events.is_empty() {
                        return *snap;
                    }
                    t += 1.0;
                }
                RunState::Done(_) => panic!("run drained before producing a checkpoint"),
            }
        }
    }

    #[test]
    fn envelope_round_trips_canonically() {
        let snap = mid_run_snapshot();
        let text = encode(&snap);
        assert!(text.starts_with(MAGIC));
        let decoded = decode(&text).unwrap();
        // canonical: re-encoding the decoded snapshot reproduces the
        // exact envelope, which covers every field bitwise
        assert_eq!(encode(&decoded), text);
        assert_eq!(decoded.fingerprint, snap.fingerprint);
        assert_eq!(decoded.now_s.to_bits(), snap.now_s.to_bits());
        assert_eq!(decoded.events.len(), snap.events.len());
    }

    #[test]
    fn rejects_foreign_and_truncated_envelopes() {
        assert!(decode("not a checkpoint").is_err());
        assert!(decode("").is_err());
        let snap = mid_run_snapshot();
        let text = encode(&snap);
        // drop the last line: the parser must notice the truncation
        let cut = &text[..text.trim_end().rfind('\n').unwrap()];
        assert!(decode(cut).is_err());
        // corrupt the magic
        let bad = text.replacen("v2", "v9", 1);
        assert!(decode(&bad).is_err());
    }

    #[test]
    fn learned_policy_section_round_trips() {
        let exp = ExperimentBuilder::preset("dense-urban")
            .devices(6)
            .rounds(4)
            .seed(11)
            .strategy(crate::coordinator::Strategy::Ucb1)
            .des(DesConfig {
                policy: Policy::Sync,
                capacity: 2,
                batch: 1,
            })
            .build()
            .unwrap();
        // checkpoint late enough that the bank has folded rewards, so
        // the envelope exercises a non-trivial policy section
        let mut t = 0.5;
        let snap = loop {
            match exp.checkpoint_at(t).unwrap() {
                RunState::Checkpoint(snap) => {
                    let fed = snap
                        .policy
                        .as_ref()
                        .is_some_and(|p| p.pulls.iter().sum::<u64>() > 0);
                    if fed {
                        break *snap;
                    }
                    t += 0.5;
                }
                RunState::Done(_) => panic!("run drained before the bank saw a reward"),
            }
        };
        let text = encode(&snap);
        assert!(text.contains("\npolicy 1 "));
        let decoded = decode(&text).unwrap();
        assert_eq!(encode(&decoded), text);
        assert_eq!(decoded.policy, snap.policy);
        // oracle snapshots keep an empty section
        let plain = mid_run_snapshot();
        assert!(plain.policy.is_none());
        assert!(encode(&plain).contains("\npolicy 0\n"));
    }

    #[test]
    fn file_round_trip() {
        let snap = mid_run_snapshot();
        let dir = std::env::temp_dir().join("edgesplit-checkpoint-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("snap.ckpt");
        let path = path.to_str().unwrap();
        write_to(path, &snap).unwrap();
        let back = read_from(path).unwrap();
        assert_eq!(encode(&back), encode(&snap));
        let _ = std::fs::remove_file(path);
    }
}
