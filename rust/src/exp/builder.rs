//! [`ExperimentBuilder`]: the one way experiments are constructed —
//! scenario preset or explicit config, strategy, channel/mobility/cell
//! overrides, seed, threads, rounds, engine choice — with typed
//! [`BuildError`] validation instead of ad-hoc flag plumbing.
//!
//! Every sweep, figure, and CLI subcommand funnels through this module:
//! parse knobs, call [`ExperimentBuilder::build`], stream the resulting
//! [`Experiment`] into a [`MetricsSink`].  The builder owns *all*
//! cross-knob validation (engine/mode compatibility, DES parameter
//! sanity, the multi-cell tier requiring the event engine), so a
//! successfully built `Experiment` can always run.

use std::fmt;
use std::sync::Arc;

use crate::config::scenario::{self, Scenario};
use crate::config::{
    CellLayout, CellsSpec, ChannelState, ConfigError, ExpConfig, FadingModel, FaultsSpec,
    MobilitySpec,
};
use crate::coordinator::{RoundRecord, Scheduler, Strategy, TrainBackend};
use crate::des::{DesConfig, DesEngine, Policy};
use crate::sim::metrics::Summary;
use crate::util::pool;

use super::engine::{Engine, EventEngine, ExecMode, RoundEngine, RunOutcome};
use super::sink::{CollectSink, MetricsSink, SummarySink};

/// Which engine executes the experiment.
#[derive(Clone, Copy, Debug)]
pub enum EngineChoice {
    /// Per-round parallel fleet engine (the default).
    Round,
    /// Discrete-event fleet engine: server queue, churn, aggregation
    /// policy.
    Des(DesConfig),
}

/// Typed validation errors from [`ExperimentBuilder::build`].
#[derive(Debug)]
pub enum BuildError {
    /// Preset name not in the scenario registry.
    UnknownPreset(String),
    /// Strategy name not in the strategy family (see
    /// [`parse_strategy`]).
    UnknownStrategy(String),
    /// The named preset base needs an explicit fleet size (`devices(n)`).
    MissingFleetSize(String),
    /// `devices(n)` only applies to preset bases — an explicit config
    /// already carries its fleet.
    FleetSizeWithoutPreset,
    /// `devices(0)`.
    ZeroDevices,
    /// `rounds(0)` (or a config with no rounds).
    ZeroRounds,
    /// The named `Uncached`/`Ref` oracle exists only on the round engine.
    OracleOnEventEngine(&'static str),
    /// Degenerate DES knobs (capacity/batch/deadline factor).
    InvalidDes(String),
    /// `[cells] count > 1` needs per-cell server queues, which only the
    /// discrete-event engine models — the round engine's closed-form
    /// timeline has no queueing tier to partition.  Carries the
    /// offending cell count.
    CellsOnRoundEngine(usize),
    /// Config-level validation failed (`ExpConfig::validate` et al.).
    Config(ConfigError),
}

impl fmt::Display for BuildError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BuildError::UnknownPreset(name) => {
                let known: Vec<&str> = scenario::ALL.iter().map(|s| s.name).collect();
                write!(f, "unknown preset '{name}' (have: {})", known.join(", "))
            }
            BuildError::UnknownStrategy(name) => write!(
                f,
                "unknown strategy '{name}' (have: {})",
                STRATEGY_NAMES.join(", ")
            ),
            BuildError::MissingFleetSize(preset) => {
                write!(f, "preset '{preset}' needs an explicit fleet size — call .devices(n)")
            }
            BuildError::FleetSizeWithoutPreset => write!(
                f,
                ".devices(n) only applies to preset bases; an explicit config already carries its fleet"
            ),
            BuildError::ZeroDevices => write!(f, "fleet size must be >= 1"),
            BuildError::ZeroRounds => write!(f, "rounds must be >= 1"),
            BuildError::OracleOnEventEngine(mode) => write!(
                f,
                "ExecMode::{mode} is a round-engine oracle — the event engine only runs ExecMode::Cached"
            ),
            BuildError::InvalidDes(msg) => write!(f, "invalid DES config: {msg}"),
            BuildError::CellsOnRoundEngine(count) => write!(
                f,
                "a multi-cell tier ({count} cells) needs per-cell server queues — \
                 run the event engine (.des(...)), the round engine is single-cell"
            ),
            BuildError::Config(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for BuildError {}

impl From<ConfigError> for BuildError {
    fn from(e: ConfigError) -> Self {
        BuildError::Config(e)
    }
}

/// Every accepted `--strategy` spelling family, for error messages and
/// help text (aliases like `ucb`/`epsilon-greedy` parse too).
pub const STRATEGY_NAMES: [&str; 8] = [
    "card",
    "server-only",
    "device-only",
    "static:<cut>",
    "random",
    "eps-greedy",
    "ucb1",
    "thompson",
];

/// Parse a `--strategy` argument with a typed error that lists the
/// valid names — the strategy-family mirror of
/// [`BuildError::UnknownPreset`].
pub fn parse_strategy(s: &str) -> Result<Strategy, BuildError> {
    Strategy::parse(s).ok_or_else(|| BuildError::UnknownStrategy(s.to_string()))
}

enum Base {
    Preset(String),
    Config(Box<ExpConfig>),
}

/// Builder for a validated, runnable [`Experiment`].
///
/// The doctest below actually runs (a 6-device, 2-round fleet is
/// cheap): build from a preset, execute, read the outcome.
///
/// ```
/// # fn main() -> anyhow::Result<()> {
/// use edgesplit::exp::ExperimentBuilder;
///
/// let exp = ExperimentBuilder::preset("dense-urban")
///     .devices(6)
///     .rounds(2)
///     .seed(7)
///     .build()?;
/// let (summary, outcome) = exp.run_summary()?;
/// assert_eq!(outcome.cells, 6 * 2);
/// assert!(summary.delay.mean() > 0.0);
/// # Ok(())
/// # }
/// ```
///
/// A multi-cell experiment needs the event engine (see
/// [`BuildError::CellsOnRoundEngine`]):
///
/// ```
/// # fn main() -> anyhow::Result<()> {
/// use edgesplit::des::{DesConfig, Policy};
/// use edgesplit::exp::ExperimentBuilder;
///
/// let exp = ExperimentBuilder::preset("dense-urban")
///     .devices(6)
///     .rounds(2)
///     .cells(3)
///     .des(DesConfig { policy: Policy::Sync, capacity: 2, batch: 1 })
///     .build()?;
/// let (_, outcome) = exp.run_summary()?;
/// let des = outcome.des.expect("event engine ran");
/// assert_eq!(des.per_cell.len(), 3);
/// # Ok(())
/// # }
/// ```
pub struct ExperimentBuilder {
    base: Base,
    n_devices: Option<usize>,
    state: Option<ChannelState>,
    strategy: Strategy,
    seed: Option<u64>,
    rounds: Option<usize>,
    threads: Option<usize>,
    mode: ExecMode,
    engine: EngineChoice,
    channel_model: Option<FadingModel>,
    mobility: Option<MobilitySpec>,
    cells_spec: Option<CellsSpec>,
    cells_count: Option<usize>,
    cells_layout: Option<CellLayout>,
    faults: Option<FaultsSpec>,
    trace: Option<String>,
}

impl ExperimentBuilder {
    /// Start from a scenario-registry preset (see `show scenarios`).
    /// The preset supplies the channel state, channel process, and
    /// workload; `devices(n)` is required to size the synthetic fleet.
    pub fn preset(name: &str) -> Self {
        Self::with_base(Base::Preset(name.to_string()))
    }

    /// Start from the paper's testbed (Tables I + II).
    pub fn paper() -> Self {
        Self::with_base(Base::Config(Box::new(ExpConfig::paper())))
    }

    /// Start from an explicit, caller-assembled config.
    pub fn from_config(cfg: ExpConfig) -> Self {
        Self::with_base(Base::Config(Box::new(cfg)))
    }

    fn with_base(base: Base) -> Self {
        ExperimentBuilder {
            base,
            n_devices: None,
            state: None,
            strategy: Strategy::Card,
            seed: None,
            rounds: None,
            threads: None,
            mode: ExecMode::Cached,
            engine: EngineChoice::Round,
            channel_model: None,
            mobility: None,
            cells_spec: None,
            cells_count: None,
            cells_layout: None,
            faults: None,
            trace: None,
        }
    }

    /// Synthetic fleet size (preset bases only).
    pub fn devices(mut self, n: usize) -> Self {
        self.n_devices = Some(n);
        self
    }

    /// Channel state (pathloss regime) override; presets default to
    /// their registered state, config bases to `Normal`.
    pub fn channel_state(mut self, state: ChannelState) -> Self {
        self.state = Some(state);
        self
    }

    /// Decision strategy (default: CARD).
    pub fn strategy(mut self, strategy: Strategy) -> Self {
        self.strategy = strategy;
        self
    }

    /// Root RNG seed (presets default to 0, configs to their own
    /// seed).  On a preset base the seed also drives synthetic fleet
    /// placement; on a config base the fleet was already assembled by
    /// the caller, so the override reaches only the RNG streams —
    /// reseed at `Scenario::config`/fleet-construction time if the
    /// placement itself must move.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    /// Training-round override.
    pub fn rounds(mut self, rounds: usize) -> Self {
        self.rounds = Some(rounds);
        self
    }

    /// Worker-pool participants for the round engine's `Cached` mode
    /// (default: all cores; `0` means default).  Results are
    /// bit-identical at any value.
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads);
        self
    }

    /// Execution mode of the round engine (default: `Cached`).
    pub fn mode(mut self, mode: ExecMode) -> Self {
        self.mode = mode;
        self
    }

    /// Engine choice (default: the round engine).
    pub fn engine(mut self, engine: EngineChoice) -> Self {
        self.engine = engine;
        self
    }

    /// Shorthand for `engine(EngineChoice::Des(des))`.
    pub fn des(self, des: DesConfig) -> Self {
        self.engine(EngineChoice::Des(des))
    }

    /// Fading-process override (`[channel.process]` / `--channel-model`).
    pub fn channel_model(mut self, model: FadingModel) -> Self {
        self.channel_model = Some(model);
        self
    }

    /// Mobility override (`[mobility]`).
    pub fn mobility(mut self, mobility: MobilitySpec) -> Self {
        self.mobility = Some(mobility);
        self
    }

    /// Full cell-tier override (`[cells]`): count, layout, spacing,
    /// hysteresis.  `.cells(n)` / `.cell_layout(l)` applied afterwards
    /// refine this spec.
    pub fn cells_spec(mut self, spec: CellsSpec) -> Self {
        self.cells_spec = Some(spec);
        self
    }

    /// Number of edge-server cells.  Counts above 1 require the event
    /// engine ([`BuildError::CellsOnRoundEngine`]).
    pub fn cells(mut self, count: usize) -> Self {
        self.cells_count = Some(count);
        self
    }

    /// Cell placement layout (`line` / `ring` / `grid`).
    pub fn cell_layout(mut self, layout: CellLayout) -> Self {
        self.cells_layout = Some(layout);
        self
    }

    /// Fault-injection override (`[faults]`, DESIGN.md §17): link
    /// outages, server slot failures, correlated bursts, retry budget,
    /// sync timeout demotion.  Only the event engine injects; with
    /// every rate zero the plane stays off (the zero-perturbation
    /// anchor).
    pub fn faults(mut self, spec: FaultsSpec) -> Self {
        self.faults = Some(spec);
        self
    }

    /// Record a Chrome `trace_event` timeline of the run and write it
    /// to `path` when the run completes (the `--trace <path>` CLI flag;
    /// DESIGN.md §16).  Zero-perturbation: records stay bitwise
    /// identical with tracing on or off.
    pub fn trace(mut self, path: &str) -> Self {
        self.trace = Some(path.to_string());
        self
    }

    /// Validate and assemble the experiment.
    pub fn build(self) -> Result<Experiment, BuildError> {
        let (mut cfg, preset_state, preset_name) = match &self.base {
            Base::Preset(name) => {
                let sc: Scenario = Scenario::by_name(name)
                    .ok_or_else(|| BuildError::UnknownPreset(name.clone()))?;
                let n = self
                    .n_devices
                    .ok_or_else(|| BuildError::MissingFleetSize(sc.name.to_string()))?;
                if n == 0 {
                    return Err(BuildError::ZeroDevices);
                }
                let cfg = sc.config(n, self.seed.unwrap_or(0))?;
                (cfg, Some(sc.state), Some(sc.name.to_string()))
            }
            Base::Config(cfg) => {
                if self.n_devices.is_some() {
                    return Err(BuildError::FleetSizeWithoutPreset);
                }
                ((**cfg).clone(), None, None)
            }
        };
        if let Some(s) = self.seed {
            cfg.seed = s;
        }
        if let Some(r) = self.rounds {
            cfg.workload.rounds = r;
        }
        if let Some(m) = self.channel_model {
            cfg.channel.process.model = m;
        }
        if let Some(mb) = self.mobility {
            cfg.mobility = mb;
        }
        if let Some(spec) = self.cells_spec {
            cfg.cells = spec;
        }
        if let Some(count) = self.cells_count {
            cfg.cells.count = count;
        }
        if let Some(layout) = self.cells_layout {
            cfg.cells.layout = layout;
        }
        if let Some(faults) = self.faults {
            cfg.faults = faults;
        }
        if cfg.workload.rounds == 0 {
            return Err(BuildError::ZeroRounds);
        }
        if cfg.cells.enabled() && matches!(self.engine, EngineChoice::Round) {
            return Err(BuildError::CellsOnRoundEngine(cfg.cells.count));
        }
        if let EngineChoice::Des(des) = &self.engine {
            if self.mode != ExecMode::Cached {
                return Err(BuildError::OracleOnEventEngine(self.mode.name()));
            }
            if des.capacity == 0 {
                return Err(BuildError::InvalidDes("server capacity must be >= 1".into()));
            }
            if des.batch == 0 {
                return Err(BuildError::InvalidDes("server batch must be >= 1".into()));
            }
            if let Policy::SemiSync { deadline_factor } = des.policy {
                if !deadline_factor.is_finite() || deadline_factor <= 0.0 {
                    return Err(BuildError::InvalidDes(format!(
                        "semi-sync deadline factor must lie in the open range (0, +inf) \
                         — finite and strictly positive — got {deadline_factor}"
                    )));
                }
            }
        }
        cfg.validate()?;

        let state = self.state.or(preset_state).unwrap_or(ChannelState::Normal);
        let threads = match self.threads {
            Some(t) if t > 0 => t,
            _ => pool::default_parallelism(),
        };
        let sched = Arc::new(Scheduler::new(cfg, state, self.strategy));
        let (engine, is_event): (Box<dyn Engine>, bool) = match self.engine {
            EngineChoice::Round => (
                Box::new(RoundEngine::new(sched.clone(), self.mode, threads)),
                false,
            ),
            EngineChoice::Des(des) => (
                Box::new(EventEngine::new(DesEngine::new(sched.clone(), des))),
                true,
            ),
        };
        Ok(Experiment {
            sched,
            engine,
            is_event,
            mode: self.mode,
            threads,
            preset: preset_name,
            trace: self.trace,
        })
    }
}

/// A validated, runnable experiment: a [`Scheduler`] plus the boxed
/// [`Engine`] that executes it.
pub struct Experiment {
    sched: Arc<Scheduler>,
    engine: Box<dyn Engine>,
    is_event: bool,
    mode: ExecMode,
    threads: usize,
    preset: Option<String>,
    /// Chrome-trace output path, when timeline recording was requested.
    trace: Option<String>,
}

impl fmt::Debug for Experiment {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Experiment")
            .field("preset", &self.preset)
            .field("mode", &self.mode)
            .field("threads", &self.threads)
            .field("is_event", &self.is_event)
            .finish_non_exhaustive()
    }
}

impl Experiment {
    /// Read-only view of the underlying scheduler (cost model, cut
    /// tables, cache statistics, config).
    pub fn scheduler(&self) -> &Scheduler {
        &self.sched
    }

    pub fn config(&self) -> &ExpConfig {
        &self.sched.cfg
    }

    pub fn mode(&self) -> ExecMode {
        self.mode
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The preset this experiment was built from, if any.
    pub fn preset(&self) -> Option<&str> {
        self.preset.as_deref()
    }

    /// `true` when the discrete-event engine backs this experiment.
    pub fn is_event_engine(&self) -> bool {
        self.is_event
    }

    /// Stream the run into `sink` — the generic entry point.  When the
    /// builder asked for a trace, recording starts here and the
    /// timeline is written once the engine returns.
    pub fn run_into(&self, sink: &mut dyn MetricsSink) -> anyhow::Result<RunOutcome> {
        match &self.trace {
            None => self.engine.run(sink),
            Some(path) => {
                crate::obs::trace::enable();
                let out = self.engine.run(sink)?;
                crate::obs::trace::write_to(path)?;
                Ok(out)
            }
        }
    }

    /// Run and materialize every record (figures, bit-compat gates).
    pub fn run_collect(&self) -> anyhow::Result<Vec<RoundRecord>> {
        let mut sink = CollectSink::default();
        self.run_into(&mut sink)?;
        Ok(sink.records)
    }

    /// Run and aggregate online into a [`Summary`].
    pub fn run_summary(&self) -> anyhow::Result<(Summary, RunOutcome)> {
        let mut sink = SummarySink::default();
        let outcome = self.run_into(&mut sink)?;
        Ok((sink.summary, outcome))
    }

    /// Run the event engine until the first event past virtual time
    /// `t_s` and freeze there (DESIGN.md §17).  Returns the paused
    /// state — serialize it with [`crate::exp::checkpoint::encode`] —
    /// or the finished outcome when the timeline drained first.
    /// Errors on the round engine, which has no virtual clock.
    pub fn checkpoint_at(&self, t_s: f64) -> anyhow::Result<crate::des::RunState> {
        self.engine.checkpoint_at(t_s)
    }

    /// Continue a checkpointed run to completion, streaming the full
    /// record stream into `sink`.  `resume_into(checkpoint_at(t))` is
    /// bitwise identical to `run_into` for any `t` — the property
    /// `exp::verify::verify_checkpoint_resume_bit_identity` gates.
    pub fn resume_into(
        &self,
        snap: &crate::des::SimSnapshot,
        sink: &mut dyn MetricsSink,
    ) -> anyhow::Result<RunOutcome> {
        self.engine.resume_from(snap, sink)
    }

    /// Run with a real-training backend riding along (the PJRT split
    /// executor): serial, round engine + `Cached` mode only.
    pub fn run_trained<B: TrainBackend + ?Sized>(
        &self,
        backend: &mut B,
    ) -> anyhow::Result<Vec<RoundRecord>> {
        anyhow::ensure!(
            !self.is_event,
            "run_trained: the event engine has no backend hook — use the round engine"
        );
        anyhow::ensure!(
            self.mode == ExecMode::Cached,
            "run_trained: oracle modes ({}) do not drive backends",
            self.mode.name()
        );
        self.sched.run(Some(backend))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_strategy_is_a_typed_error_listing_the_family() {
        let err = parse_strategy("bogus").unwrap_err();
        assert!(matches!(err, BuildError::UnknownStrategy(ref n) if n == "bogus"));
        let msg = err.to_string();
        for name in STRATEGY_NAMES {
            assert!(msg.contains(name), "error should list {name}: {msg}");
        }
    }

    #[test]
    fn parse_strategy_accepts_every_family_member() {
        assert_eq!(parse_strategy("card").unwrap(), Strategy::Card);
        assert_eq!(parse_strategy("ucb").unwrap(), Strategy::Ucb1);
        assert_eq!(parse_strategy("eps-greedy").unwrap(), Strategy::EpsGreedy);
        assert_eq!(parse_strategy("thompson").unwrap(), Strategy::Thompson);
        assert_eq!(
            parse_strategy("static:12").unwrap(),
            Strategy::StaticCut(12)
        );
    }
}
