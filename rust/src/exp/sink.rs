//! Streaming metric sinks: the engine pushes each record as it is
//! produced, so aggregating consumers never have to hold a full
//! `rounds × devices` record vector per grid point.

use crate::coordinator::{RoundBatch, RoundRecord};
use crate::des::DesRecord;
use crate::sim::metrics::Summary;
use crate::util::stats::ReservoirSampler;

/// Receives the record stream an [`super::Engine`] produces, in the
/// engine's canonical (round-major) order.
///
/// The DES engine calls [`MetricsSink::on_des_record`] with its timed
/// observables; the default implementation forwards the embedded
/// analytic record, so sinks that only care about `RoundRecord`s work
/// unchanged under both engines.
pub trait MetricsSink {
    fn on_record(&mut self, rec: &RoundRecord);

    /// Owned-record fast path: engines that own the records they
    /// stream (the round engine's oracle modes) hand them over without
    /// a clone.  Sinks that materialize records override this; the
    /// default forwards by reference.
    fn on_record_owned(&mut self, rec: RoundRecord) {
        self.on_record(&rec);
    }

    /// One SoA window from the round engine's streaming path
    /// (DESIGN.md §18).  The default materializes each cell through
    /// [`RoundBatch::record`] and forwards it, so record-oriented sinks
    /// work unchanged; column-oriented sinks ([`SummarySink`],
    /// [`NullSink`]) override this to fold without building a single
    /// `RoundRecord`.
    fn on_batch(&mut self, batch: &RoundBatch) {
        for i in 0..batch.len() {
            self.on_record_owned(batch.record(i));
        }
    }

    fn on_des_record(&mut self, rec: &DesRecord) {
        self.on_record(&rec.record);
    }

    /// Owned DES-record fast path, mirroring `on_record_owned`: the
    /// event engine owns its outcome records and hands them over
    /// without refcount traffic.  The default forwards by reference.
    fn on_des_record_owned(&mut self, rec: DesRecord) {
        self.on_des_record(&rec);
    }
}

/// Discards everything (engine side effects only — e.g. warming the
/// decision cache to read its hit rate afterwards).
pub struct NullSink;

impl MetricsSink for NullSink {
    fn on_record(&mut self, _rec: &RoundRecord) {}

    fn on_batch(&mut self, _batch: &RoundBatch) {}
}

/// Materializes the full record stream (figures and bit-compat gates
/// that genuinely need every record).
#[derive(Default)]
pub struct CollectSink {
    pub records: Vec<RoundRecord>,
}

impl MetricsSink for CollectSink {
    fn on_record(&mut self, rec: &RoundRecord) {
        self.records.push(rec.clone());
    }

    fn on_record_owned(&mut self, rec: RoundRecord) {
        self.records.push(rec);
    }

    /// By-value end-to-end: moving the embedded record out of an owned
    /// `DesRecord` costs zero `Arc` refcount bumps per cell (the
    /// by-reference default would clone both interned names).
    fn on_des_record_owned(&mut self, rec: DesRecord) {
        self.records.push(rec.record);
    }
}

/// Aggregates the stream into a [`Summary`] online — what the sweeps
/// use instead of materializing records per grid point.
#[derive(Default)]
pub struct SummarySink {
    pub summary: Summary,
}

impl MetricsSink for SummarySink {
    fn on_record(&mut self, rec: &RoundRecord) {
        self.summary.push(rec);
    }

    /// Column-wise fold, no record materialization — bit-identical to
    /// the per-record path (see `Summary::push_batch`).
    fn on_batch(&mut self, batch: &RoundBatch) {
        self.summary.push_batch(batch);
    }
}

/// DES observables the `des-sweep` reports: per-cell end-to-end latency
/// samples (for percentiles; reservoir-bounded so memory stays fixed at
/// any fleet size) and the energy of merged rounds only
/// (`energy_merged_j` — the dispatch-time bill lives in
/// [`super::DesRunStats::energy_spent_j`]).
#[derive(Default)]
pub struct DesSink {
    pub latencies: ReservoirSampler,
    pub energy_merged_j: f64,
}

impl MetricsSink for DesSink {
    fn on_record(&mut self, _rec: &RoundRecord) {}

    fn on_des_record(&mut self, rec: &DesRecord) {
        self.latencies.push(rec.latency_s());
        self.energy_merged_j += rec.record.energy_j;
    }
}
