//! Unified experiment API (DESIGN.md §14): **one builder, one engine
//! trait, one report schema** for every run mode.
//!
//! The paper's contribution is a single decision loop (CARD, Eqs. 7–16)
//! evaluated under many execution regimes — per-round parallel fleet,
//! discrete-event queueing, strategy baselines, parameter ablations.
//! Before this module each regime had its own ad-hoc surface; now every
//! experiment flows through the same four stages:
//!
//! ```text
//! ExperimentBuilder ──build()──► Experiment ──run──► Engine ──► MetricsSink
//!   preset/config,                holds the           round      streams
//!   strategy, seed,               Scheduler +         or DES     records;
//!   rounds, threads,              a boxed Engine      engine     aggregates
//!   ExecMode, engine                                             online
//!                                        │
//!                                        ▼
//!                                   RunOutcome (+ Report envelope for
//!                                   every BENCH_*.json emitter)
//! ```
//!
//! * [`ExperimentBuilder`] replaces direct `Scheduler::new` + flag
//!   plumbing, with typed [`BuildError`] validation.
//! * The [`Engine`] trait collapses `Scheduler::{run, run_parallel,
//!   run_uncached, run_ref, run_analytic}` into one entry point; the
//!   `_ref`/`_uncached` oracles survive as [`ExecMode`] variants, so
//!   the bit-compat property suites keep their teeth.
//! * [`MetricsSink`] streams records as the engine produces them, so
//!   sweeps aggregate [`crate::sim::Summary`]/percentiles online
//!   instead of materializing every `RoundRecord` per grid point.
//! * [`Report`] gives every `BENCH_*.json` emitter one versioned
//!   envelope (`schema_version` + `meta`).
//! * [`verify`] hosts the shared serial-vs-parallel (and DES-sync-vs-
//!   round-engine) determinism gates all sweeps run, including the
//!   single-cell bit-identity anchor the multi-cell tier is pinned to,
//!   plus the fault-plane gates (zero-rate no-op, checkpoint/resume
//!   bit-identity) the chaos sweep runs per scenario (DESIGN.md §17).
//! * [`checkpoint`] serializes a paused event-engine run to the
//!   versioned `edgesplit/checkpoint/v2` text envelope and back
//!   (v2 carries the learned-policy bandit bank, DESIGN.md §19).
//!
//! Not sure which engine a new experiment should use?  See the
//! decision table in `rust/src/exp/README.md`.

pub mod builder;
pub mod checkpoint;
pub mod engine;
pub mod report;
pub mod sink;
pub mod verify;

pub use builder::{
    parse_strategy, BuildError, EngineChoice, Experiment, ExperimentBuilder, STRATEGY_NAMES,
};
pub use engine::{DesRunStats, Engine, ExecMode, RunOutcome};
pub use report::{Report, ReportMeta, SCHEMA_VERSION};
pub use sink::{CollectSink, DesSink, MetricsSink, NullSink, SummarySink};
