//! [`Report`]: the one versioned envelope every `BENCH_*.json` emitter
//! goes through.
//!
//! ```json
//! {
//!   "schema_version": 1,
//!   "kind": "fleet-sweep",
//!   "meta": { "preset": "dense-urban", "seed": "7", "threads": 8, "rounds": 2 },
//!   "data": { ...emitter-specific payload (unchanged shapes)... }
//! }
//! ```
//!
//! CI checks that every uploaded bench artifact parses and carries
//! `schema_version` + `meta.preset`; downstream tooling keys on
//! `schema_version` instead of sniffing per-emitter `data.schema`
//! strings.

use crate::util::json::{self, Json};

/// Version of the shared envelope (not of the per-kind `data` payload —
/// those keep their own `schema` strings inside `data`).
pub const SCHEMA_VERSION: u64 = 1;

/// Envelope metadata common to every emitter.
#[derive(Clone, Debug)]
pub struct ReportMeta {
    /// emitter kind: `fleet-sweep` | `des-sweep` | `cell-sweep` |
    /// `chaos-sweep` | `card-bench`
    pub kind: &'static str,
    /// scenario selector the run used (`all`, or a registry name)
    pub preset: String,
    pub seed: u64,
    pub threads: usize,
    /// round-count override, when one applied
    pub rounds: Option<usize>,
}

/// A rendered + machine-readable experiment report.
pub struct Report {
    pub meta: ReportMeta,
    /// emitter-specific payload (the pre-envelope JSON shape)
    pub body: Json,
    rendered: String,
}

impl Report {
    pub fn new(meta: ReportMeta, body: Json, rendered: String) -> Self {
        Report {
            meta,
            body,
            rendered,
        }
    }

    /// Human-readable summary (what the CLI prints).
    pub fn render(&self) -> &str {
        &self.rendered
    }

    /// The versioned envelope around the emitter payload.  Consumes
    /// the report: the payload is moved into the envelope, not cloned.
    ///
    /// Every envelope carries the process-wide telemetry snapshot under
    /// `data.telemetry` (DESIGN.md §16) — injected here, the single
    /// choke point, so all emitters get it without knowing about it.
    pub fn to_json(self) -> Json {
        let mut body = self.body;
        if let Json::Obj(ref mut map) = body {
            map.insert(
                "telemetry".to_string(),
                crate::obs::Snapshot::collect().to_json(),
            );
        }
        json::obj(vec![
            ("schema_version", Json::Num(SCHEMA_VERSION as f64)),
            ("kind", Json::Str(self.meta.kind.to_string())),
            (
                "meta",
                json::obj(vec![
                    ("preset", Json::Str(self.meta.preset)),
                    // string, not number: u64 seeds above 2^53 would
                    // lose precision through the f64-backed Json::Num
                    ("seed", Json::Str(self.meta.seed.to_string())),
                    ("threads", Json::Num(self.meta.threads as f64)),
                    (
                        "rounds",
                        match self.meta.rounds {
                            Some(r) => Json::Num(r as f64),
                            None => Json::Null,
                        },
                    ),
                ]),
            ),
            ("data", body),
        ])
    }

    /// Write the envelope (newline-terminated) to `path`, consuming
    /// the report.
    pub fn write(self, path: &str) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string() + "\n")
            .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> Report {
        Report::new(
            ReportMeta {
                kind: "fleet-sweep",
                preset: "dense-urban".into(),
                seed: u64::MAX,
                threads: 8,
                rounds: Some(2),
            },
            json::obj(vec![("points", Json::Arr(vec![]))]),
            "rendered table".into(),
        )
    }

    #[test]
    fn envelope_carries_version_kind_meta_and_data() {
        let j = report().to_json();
        assert_eq!(j.get("schema_version").and_then(Json::as_f64), Some(1.0));
        assert_eq!(j.get("kind").and_then(Json::as_str), Some("fleet-sweep"));
        assert_eq!(j.at(&["meta", "preset"]).and_then(Json::as_str), Some("dense-urban"));
        // u64::MAX survives as a string
        assert_eq!(
            j.at(&["meta", "seed"]).and_then(Json::as_str),
            Some(u64::MAX.to_string().as_str())
        );
        assert_eq!(j.at(&["meta", "rounds"]).and_then(Json::as_f64), Some(2.0));
        assert!(j.at(&["data", "points"]).is_some());
    }

    #[test]
    fn envelope_injects_the_telemetry_snapshot() {
        let j = report().to_json();
        assert_eq!(
            j.at(&["data", "telemetry", "schema"]).and_then(Json::as_str),
            Some("edgesplit/telemetry/v1")
        );
        assert!(j.at(&["data", "telemetry", "counters"]).is_some());
        assert!(j.at(&["data", "telemetry", "pool"]).is_some());
    }

    #[test]
    fn envelope_round_trips_through_the_parser() {
        let s = report().to_json().to_string();
        let parsed = Json::parse(&s).unwrap();
        assert_eq!(parsed.get("schema_version").and_then(Json::as_usize), Some(1));
    }

    #[test]
    fn render_is_the_human_summary() {
        assert_eq!(report().render(), "rendered table");
    }
}
