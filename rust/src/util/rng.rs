//! Deterministic PRNG substrate (no `rand` crate in the offline set).
//!
//! `SplitMix64` seeds `Xoshiro256PlusPlus` (Blackman & Vigna), which is
//! the workhorse for every stochastic piece of the simulator: Rayleigh
//! fading draws, device placement, synthetic corpora, property tests.
//! Everything in the repo seeds explicitly, so figures reproduce
//! bit-identically (DESIGN.md §8).

/// SplitMix64: tiny, solid seeder / stream-splitter.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Counter-based stream derivation: the seed of the sub-stream
    /// identified by `tags` under `root`.
    ///
    /// Unlike [`Rng::fork`], this touches **no shared mutable state** —
    /// the result is a pure function of `(root, tags)` — so streams for
    /// different `(round, device)` cells can be materialized in any
    /// order, on any thread, and a parallel fleet round reproduces the
    /// serial one bit for bit.  Each tag is folded through a full
    /// SplitMix64 avalanche, making the derivation order-sensitive
    /// (`[a, b]` and `[b, a]` land in unrelated streams).
    pub fn stream_seed(root: u64, tags: &[u64]) -> u64 {
        let mut state = SplitMix64::new(root).next_u64();
        for &tag in tags {
            let mut sm = SplitMix64::new(state ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15));
            state = sm.next_u64();
        }
        state
    }
}

/// Xoshiro256++ — fast, high-quality 64-bit generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller variate
    gauss_spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_spare: None,
        }
    }

    /// Independent child stream (for per-device / per-round substreams).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = SplitMix64::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        Rng {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_spare: None,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's multiply-shift rejection-free-enough reduction; the
        // modulo bias for our n (<= thousands) is < 2^-50 — irrelevant
        // for simulation workloads.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box–Muller (cached spare).
    pub fn gauss(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // u1 in (0,1] to keep ln() finite
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Exponential with rate `lambda`.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Full generator state — `(xoshiro words, cached Box-Muller
    /// spare)` — for checkpoint serialization.  [`Rng::from_state`]
    /// rebuilds a generator whose stream continues bit-identically.
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.gauss_spare)
    }

    /// Inverse of [`Rng::state`].
    pub fn from_state(s: [u64; 4], gauss_spare: Option<f64>) -> Self {
        Self { s, gauss_spare }
    }

    /// |CN(0, 1)|² — Rayleigh-fading power gain (unit mean).
    pub fn rayleigh_power(&mut self) -> f64 {
        let re = self.gauss() * std::f64::consts::FRAC_1_SQRT_2;
        let im = self.gauss() * std::f64::consts::FRAC_1_SQRT_2;
        re * re + im * im
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Zipf-distributed integer in [0, n) with exponent `s` (synthetic
    /// token corpora — natural-language-ish frequency profile).
    pub fn zipf(&mut self, n: usize, s: f64, harmonic: &[f64]) -> usize {
        debug_assert_eq!(harmonic.len(), n);
        let total = harmonic[n - 1];
        let target = self.f64() * total;
        // binary search the cumulative harmonic table
        let mut lo = 0usize;
        let mut hi = n - 1;
        while lo < hi {
            let mid = (lo + hi) / 2;
            if harmonic[mid] < target {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        let _ = s;
        lo
    }
}

/// Precomputed cumulative weights for `Rng::zipf`.
pub fn zipf_table(n: usize, s: f64) -> Vec<f64> {
    let mut acc = 0.0;
    (1..=n)
        .map(|k| {
            acc += (k as f64).powf(-s);
            acc
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_seed_pure_and_tag_sensitive() {
        let a = SplitMix64::stream_seed(1, &[2, 3]);
        assert_eq!(a, SplitMix64::stream_seed(1, &[2, 3]));
        assert_ne!(a, SplitMix64::stream_seed(1, &[3, 2]), "order must matter");
        assert_ne!(a, SplitMix64::stream_seed(2, &[2, 3]));
        assert_ne!(a, SplitMix64::stream_seed(1, &[2, 4]));
        assert_ne!(a, SplitMix64::stream_seed(1, &[2]));
    }

    #[test]
    fn stream_seeds_decorrelated_across_adjacent_tags() {
        let mut r1 = Rng::new(SplitMix64::stream_seed(9, &[0, 0]));
        let mut r2 = Rng::new(SplitMix64::stream_seed(9, &[0, 1]));
        let hits = (0..1000).filter(|_| r1.next_u64() == r2.next_u64()).count();
        assert_eq!(hits, 0);
    }

    #[test]
    fn stream_seeds_unique_over_grid() {
        // every (round, device) cell of a large grid gets its own stream
        let mut seen = std::collections::HashSet::new();
        for round in 0..64u64 {
            for dev in 0..64u64 {
                assert!(seen.insert(SplitMix64::stream_seed(7, &[round, dev])));
            }
        }
    }

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn forked_streams_are_decorrelated() {
        let mut root = Rng::new(7);
        let mut c1 = root.fork(1);
        let mut c2 = root.fork(2);
        let matches = (0..1000).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(matches, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(4);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn rayleigh_power_unit_mean() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let mean = (0..n).map(|_| r.rayleigh_power()).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(6);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10) as usize;
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn zipf_is_head_heavy() {
        let mut r = Rng::new(8);
        let table = zipf_table(100, 1.1);
        let mut counts = [0usize; 100];
        for _ in 0..20_000 {
            counts[r.zipf(100, 1.1, &table)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > counts[99] * 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }

    #[test]
    fn state_round_trip_continues_stream() {
        let mut a = Rng::new(11);
        let _ = a.gauss(); // park a Box-Muller spare in the state
        let (s, spare) = a.state();
        let mut b = Rng::from_state(s, spare);
        assert_eq!(a.gauss().to_bits(), b.gauss().to_bits());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(10);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }
}
