//! Dependency-free fork-join worker pool (no `rayon` in the offline
//! crate set): a scoped-thread `par_map` with work stealing via an
//! atomic cursor.
//!
//! Output order is always the input order, regardless of which worker
//! finishes first, so callers that pair this with order-independent
//! per-item RNG streams (see `rng::SplitMix64::stream_seed`) get
//! bit-identical results at any thread count — the invariant the fleet
//! round engine is built on.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Worker count to use when the caller has no preference: one per core.
pub fn default_parallelism() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Map `f` over `items` on up to `threads` workers, returning results in
/// input order.  `f` receives `(index, &item)`.  Falls back to a plain
/// serial map for trivial inputs (0/1 items or 1 thread).
///
/// Degenerate worker counts are clamped, never a panic: `threads == 0`
/// runs serially, and `threads > items.len()` spawns one worker per
/// item at most (spawning idle workers would only pay thread-start
/// cost for nothing).
pub fn par_map_indexed<T, R, F>(threads: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let threads = threads.clamp(1, items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        return items.iter().enumerate().map(|(i, x)| f(i, x)).collect();
    }

    let cursor = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> = (0..items.len()).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(i, &items[i]);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap()
                .expect("pool invariant: every slot filled before join")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_serial_map_in_order() {
        let xs: Vec<u64> = (0..500).collect();
        let serial: Vec<u64> = xs.iter().enumerate().map(|(i, &x)| x * 3 + i as u64).collect();
        for threads in [1, 2, 4, 8, 17] {
            let par = par_map_indexed(threads, &xs, |i, &x| x * 3 + i as u64);
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn empty_and_singleton_inputs() {
        let empty: [u64; 0] = [];
        assert!(par_map_indexed(4, &empty, |_, &x| x).is_empty());
        assert_eq!(par_map_indexed(4, &[41u64], |_, &x| x + 1), vec![42]);
    }

    #[test]
    fn more_threads_than_items() {
        let xs = [1u64, 2, 3];
        assert_eq!(par_map_indexed(64, &xs, |_, &x| x * x), vec![1, 4, 9]);
    }

    #[test]
    fn zero_threads_clamps_to_serial() {
        // regression: threads == 0 must clamp to 1 worker, not panic
        // or deadlock
        let xs: Vec<u64> = (0..20).collect();
        let expect: Vec<u64> = xs.iter().map(|&x| x + 7).collect();
        assert_eq!(par_map_indexed(0, &xs, |_, &x| x + 7), expect);
    }

    #[test]
    fn degenerate_combinations_never_panic() {
        // every (threads, items) corner: 0/1/many threads × 0/1/many cells
        for threads in [0usize, 1, 2, 100] {
            for n in [0usize, 1, 2, 33] {
                let xs: Vec<u64> = (0..n as u64).collect();
                let got = par_map_indexed(threads, &xs, |i, &x| x * 2 + i as u64);
                let expect: Vec<u64> =
                    xs.iter().enumerate().map(|(i, &x)| x * 2 + i as u64).collect();
                assert_eq!(got, expect, "threads={threads} n={n}");
            }
        }
    }

    #[test]
    fn default_parallelism_positive() {
        assert!(default_parallelism() >= 1);
    }
}
