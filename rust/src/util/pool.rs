//! Dependency-free **persistent** fork-join worker pool (no `rayon` in
//! the offline crate set).
//!
//! Workers are spawned once per [`WorkerPool`] (the process-wide
//! [`global`] pool lives for the whole run) and sleep on a condvar
//! between jobs, so fleet engines pay thread-start cost once — not once
//! per round, as the previous `std::thread::scope` implementation did.
//! Items are claimed in chunks off an atomic cursor and results are
//! written **lock-free** straight into their final slot (the previous
//! per-item `Mutex<Option<R>>` is gone).
//!
//! Output order is always the input order (`results[i]` comes from
//! `items[i]`, whichever worker computed it), so callers that pair this
//! with order-independent per-item RNG streams (see
//! `rng::SplitMix64::stream_seed`) get bit-identical results at any
//! worker count — the invariant the fleet round engine is built on.
//!
//! ## Job protocol (what makes the borrowed closures sound)
//!
//! [`WorkerPool::run_map`] publishes a type-erased pointer to a stack
//! `JobCtx` that borrows `items`, `f`, and the result buffer.  The
//! publishing thread participates in the claim loop itself and does not
//! return until, under the pool mutex, every helper that joined the job
//! has left it (`active == 0`) and the job slot is cleared — so no
//! worker can observe the context after `run_map` returns.  A worker
//! that wakes late sees either a cleared slot (sleeps) or joins while
//! the publisher is still blocked (counted in `active`).  If the pool
//! is already busy (nested or concurrent call) the job runs inline on
//! the caller — bit-identical by the ordering invariant.
//!
//! A panicking task is caught on the worker, recorded, and re-raised on
//! the caller after the job drains; results computed before the panic
//! are leaked (never dropped), which is safe, just not tidy.

use std::any::Any;
use std::mem::{ManuallyDrop, MaybeUninit};
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// Worker count to use when the caller has no preference: one per core.
pub fn default_parallelism() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Type-erased handle to a caller-owned `JobCtx`.
#[derive(Clone, Copy)]
struct Job {
    ctx: *const (),
    run: unsafe fn(*const ()),
}

// SAFETY: `ctx` points at a `JobCtx` whose borrowed contents are `Sync`
// and which the publishing thread keeps alive until every participant
// has left `run` (see the module docs' job protocol).
unsafe impl Send for Job {}

struct Slot {
    job: Option<Job>,
    /// bumped per published job so a worker never joins one twice
    generation: u64,
    /// helpers still allowed to join the current job
    tickets: usize,
    /// helpers currently inside `run`
    active: usize,
    shutdown: bool,
}

struct Shared {
    slot: Mutex<Slot>,
    work_cv: Condvar,
    done_cv: Condvar,
}

/// Persistent fork-join pool; see the module docs.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

/// Everything one `run_map` job shares with its participants.
struct JobCtx<'a, T, R, F> {
    items: &'a [T],
    /// write-only result slots; index i is claimed by exactly one
    /// participant via `cursor`, so writes never race
    results: *mut MaybeUninit<R>,
    f: &'a F,
    cursor: AtomicUsize,
    chunk: usize,
    /// fast-path flag: participants stop claiming once a task panicked
    panicked: AtomicBool,
    /// first panic's payload, re-raised on the caller (cold path)
    panic_payload: Mutex<Option<Box<dyn Any + Send + 'static>>>,
}

/// The claim loop every participant (workers and the caller) runs.
///
/// SAFETY: `ctx` must point at a live `JobCtx<'_, T, R, F>` whose
/// `results` buffer has space for `items.len()` slots.
unsafe fn run_job<T, R, F>(ctx: *const ())
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let ctx = &*(ctx as *const JobCtx<'_, T, R, F>);
    let len = ctx.items.len();
    loop {
        // once any task panicked the job's results are doomed — stop
        // claiming instead of computing the rest of the input
        if ctx.panicked.load(Ordering::Relaxed) {
            return;
        }
        let start = ctx.cursor.fetch_add(ctx.chunk, Ordering::Relaxed);
        if start >= len {
            return;
        }
        let end = (start + ctx.chunk).min(len);
        // per-worker claim attribution (slot 0 = the caller thread)
        crate::obs::metrics()
            .pool_claimed
            .add(crate::obs::registry::worker_slot(), (end - start) as u64);
        for i in start..end {
            match catch_unwind(AssertUnwindSafe(|| (ctx.f)(i, &ctx.items[i]))) {
                Ok(r) => ctx.results.add(i).write(MaybeUninit::new(r)),
                Err(payload) => {
                    // payload first, flag second: whoever sees the flag
                    // finds a payload to re-raise
                    let mut slot = ctx.panic_payload.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(payload);
                    }
                    drop(slot);
                    ctx.panicked.store(true, Ordering::Release);
                    return;
                }
            }
        }
    }
}

fn worker_loop(shared: Arc<Shared>, worker_idx: usize) {
    // pin this thread's telemetry slot: worker w charges slot w + 1
    // (slot 0 is the participating caller)
    crate::obs::registry::set_worker_slot(worker_idx + 1);
    let mut seen_gen = 0u64;
    loop {
        let job = {
            let mut s = shared.slot.lock().unwrap();
            loop {
                if s.shutdown {
                    return;
                }
                if let Some(job) = s.job {
                    if s.generation != seen_gen {
                        seen_gen = s.generation;
                        if s.tickets > 0 {
                            s.tickets -= 1;
                            s.active += 1;
                            break job;
                        }
                        // over the caller's thread budget: sit this
                        // one out (generation marked seen)
                    }
                }
                crate::obs::metrics().pool_parks.inc(worker_idx);
                s = shared.work_cv.wait(s).unwrap();
            }
        };
        // SAFETY: the publisher keeps the ctx alive until `active`
        // returns to 0, which cannot happen before the decrement below.
        unsafe { (job.run)(job.ctx) };
        let mut s = shared.slot.lock().unwrap();
        s.active -= 1;
        if s.active == 0 {
            shared.done_cv.notify_all();
        }
    }
}

impl WorkerPool {
    /// Spawn `workers` persistent worker threads.
    pub fn new(workers: usize) -> Self {
        let shared = Arc::new(Shared {
            slot: Mutex::new(Slot {
                job: None,
                generation: 0,
                tickets: 0,
                active: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|w| {
                let shared = shared.clone();
                std::thread::spawn(move || worker_loop(shared, w))
            })
            .collect();
        WorkerPool { shared, handles }
    }

    /// Number of persistent workers (the caller participates too).
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Map `f` over `items` with up to `threads` participants (this
    /// thread plus at most `threads − 1` pool workers), returning
    /// results in input order.  Serial for trivial inputs, when
    /// `threads <= 1`, or when the pool is busy with another job.
    pub fn run_map<T, R, F>(&self, threads: usize, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        let len = items.len();
        let threads = threads.clamp(1, len.max(1));
        if threads <= 1 || len <= 1 {
            return items.iter().enumerate().map(|(i, x)| f(i, x)).collect();
        }

        let mut results: Vec<MaybeUninit<R>> = Vec::with_capacity(len);
        // SAFETY: MaybeUninit slots need no initialization; each is
        // written exactly once (claim protocol) before being read, and
        // never read as `R` on the panic path.
        unsafe { results.set_len(len) };

        let ctx = JobCtx {
            items,
            results: results.as_mut_ptr(),
            f: &f,
            cursor: AtomicUsize::new(0),
            // ~8 claims per participant amortizes the cursor without
            // starving the tail
            chunk: (len / (threads * 8)).max(1),
            panicked: AtomicBool::new(false),
            panic_payload: Mutex::new(None),
        };

        let published = {
            let mut s = self.shared.slot.lock().unwrap();
            if s.job.is_none() && !self.handles.is_empty() {
                s.job = Some(Job {
                    ctx: &ctx as *const JobCtx<'_, T, R, F> as *const (),
                    run: run_job::<T, R, F>,
                });
                s.generation = s.generation.wrapping_add(1);
                s.tickets = (threads - 1).min(self.handles.len());
                self.shared.work_cv.notify_all();
                true
            } else {
                false // busy pool (nested/concurrent job): run inline
            }
        };

        // the caller always participates in its own job
        // SAFETY: ctx is live for this whole call; see module docs.
        unsafe { run_job::<T, R, F>(&ctx as *const JobCtx<'_, T, R, F> as *const ()) };

        if published {
            let mut s = self.shared.slot.lock().unwrap();
            while s.active > 0 {
                s = self.shared.done_cv.wait(s).unwrap();
            }
            // same critical section as the last active observation: a
            // late-waking worker now sees the cleared slot and sleeps
            s.job = None;
            s.tickets = 0;
        }

        if ctx.panicked.load(Ordering::Acquire) {
            // Vec<MaybeUninit<R>> drops only the buffer — written
            // results leak rather than risking a drop of an
            // uninitialized slot
            let payload = ctx.panic_payload.lock().unwrap().take();
            drop(results);
            match payload {
                Some(p) => resume_unwind(p),
                None => panic!("worker pool: a parallel task panicked"),
            }
        }

        // SAFETY: every slot 0..len was written exactly once;
        // MaybeUninit<R> has the same layout as R.
        let mut results = ManuallyDrop::new(results);
        unsafe { Vec::from_raw_parts(results.as_mut_ptr() as *mut R, len, results.capacity()) }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut s = self.shared.slot.lock().unwrap();
            s.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// The process-wide pool every `par_map_indexed` call shares — spawned
/// on first use with one worker per core, alive until process exit.
pub fn global() -> &'static WorkerPool {
    static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();
    GLOBAL.get_or_init(|| WorkerPool::new(default_parallelism()))
}

/// Map `f` over `items` on up to `threads` participants of the
/// [`global`] persistent pool, returning results in input order.  `f`
/// receives `(index, &item)`.  Falls back to a plain serial map for
/// trivial inputs (0/1 items or 1 thread).  Unlike the old scoped-
/// thread pool, `threads` beyond the pool's worker count + 1 (the
/// caller) gain nothing — participants cap at the core count; results
/// are bit-identical at any value.
///
/// Degenerate worker counts are clamped, never a panic: `threads == 0`
/// runs serially, and `threads > items.len()` uses at most one
/// participant per item.
pub fn par_map_indexed<T, R, F>(threads: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    global().run_map(threads, items, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_serial_map_in_order() {
        let xs: Vec<u64> = (0..500).collect();
        let serial: Vec<u64> = xs.iter().enumerate().map(|(i, &x)| x * 3 + i as u64).collect();
        for threads in [1, 2, 4, 8, 17] {
            let par = par_map_indexed(threads, &xs, |i, &x| x * 3 + i as u64);
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn empty_and_singleton_inputs() {
        let empty: [u64; 0] = [];
        assert!(par_map_indexed(4, &empty, |_, &x| x).is_empty());
        assert_eq!(par_map_indexed(4, &[41u64], |_, &x| x + 1), vec![42]);
    }

    #[test]
    fn more_threads_than_items() {
        let xs = [1u64, 2, 3];
        assert_eq!(par_map_indexed(64, &xs, |_, &x| x * x), vec![1, 4, 9]);
    }

    #[test]
    fn zero_threads_clamps_to_serial() {
        // regression: threads == 0 must clamp to 1 worker, not panic
        // or deadlock
        let xs: Vec<u64> = (0..20).collect();
        let expect: Vec<u64> = xs.iter().map(|&x| x + 7).collect();
        assert_eq!(par_map_indexed(0, &xs, |_, &x| x + 7), expect);
    }

    #[test]
    fn degenerate_combinations_never_panic() {
        // every (threads, items) corner: 0/1/many threads × 0/1/many cells
        for threads in [0usize, 1, 2, 100] {
            for n in [0usize, 1, 2, 33] {
                let xs: Vec<u64> = (0..n as u64).collect();
                let got = par_map_indexed(threads, &xs, |i, &x| x * 2 + i as u64);
                let expect: Vec<u64> =
                    xs.iter().enumerate().map(|(i, &x)| x * 2 + i as u64).collect();
                assert_eq!(got, expect, "threads={threads} n={n}");
            }
        }
    }

    #[test]
    fn pool_persists_across_many_jobs() {
        // the whole point: repeated rounds reuse the same workers
        let pool = WorkerPool::new(4);
        let xs: Vec<u64> = (0..256).collect();
        for round in 0..50u64 {
            // x == i for this input, so the expected value is 2i + round
            let got = pool.run_map(4, &xs, |i, &x| x + round + i as u64);
            let expect: Vec<u64> = (0..256u64).map(|i| 2 * i + round).collect();
            assert_eq!(got, expect, "round={round}");
        }
        assert_eq!(pool.workers(), 4);
    }

    #[test]
    fn nested_calls_fall_back_to_serial_without_deadlock() {
        let xs: Vec<u64> = (0..64).collect();
        let got = par_map_indexed(4, &xs, |_, &x| {
            let inner: Vec<u64> = par_map_indexed(4, &[x, x + 1], |_, &y| y * 2);
            inner[0] + inner[1]
        });
        let expect: Vec<u64> = xs.iter().map(|&x| x * 2 + (x + 1) * 2).collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn zero_worker_pool_runs_inline() {
        let pool = WorkerPool::new(0);
        let xs: Vec<u64> = (0..10).collect();
        assert_eq!(pool.run_map(8, &xs, |_, &x| x + 1), (1..=10).collect::<Vec<u64>>());
    }

    #[test]
    fn concurrent_top_level_callers_are_safe() {
        // threads race the global pool; the loser of the publish runs
        // inline — every caller must produce correct, ordered output
        let xs: Vec<u64> = (0..300).collect();
        // x == i for this input, so x * 5 + i == 6i
        let expect: Vec<u64> = (0..300u64).map(|i| i * 6).collect();
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let (xs, expect) = (&xs, &expect);
                scope.spawn(move || {
                    for _ in 0..20 {
                        let got = par_map_indexed(4, xs, |i, &x| x * 5 + i as u64);
                        assert_eq!(&got, expect);
                    }
                });
            }
        });
    }

    #[test]
    fn panicking_task_propagates_to_caller() {
        let pool = WorkerPool::new(2);
        let xs: Vec<u64> = (0..64).collect();
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.run_map(2, &xs, |_, &x| {
                if x == 13 {
                    panic!("boom");
                }
                x
            })
        }));
        // the ORIGINAL payload propagates, not a generic wrapper
        let payload = result.expect_err("panic must propagate");
        assert_eq!(payload.downcast_ref::<&str>(), Some(&"boom"));
        // the pool survives and keeps serving jobs
        assert_eq!(pool.run_map(2, &xs[..4], |_, &x| x + 1), vec![1, 2, 3, 4]);
    }

    #[test]
    fn default_parallelism_positive() {
        assert!(default_parallelism() >= 1);
    }

    #[test]
    fn heavier_payload_types_round_trip() {
        // non-Copy results exercise the MaybeUninit hand-off
        let xs: Vec<u64> = (0..100).collect();
        let got: Vec<String> = par_map_indexed(4, &xs, |i, &x| format!("{i}:{x}"));
        for (i, s) in got.iter().enumerate() {
            assert_eq!(s, &format!("{i}:{i}"));
        }
    }
}
