//! Micro/meso benchmark harness (no `criterion` in the offline set).
//!
//! Warmup + timed iterations with adaptive iteration counts, reporting
//! mean/median/p95/min and ns-per-op.  Used by every `benches/*.rs`
//! target (declared `harness = false` in Cargo.toml).

use std::hint::black_box;
use std::time::{Duration, Instant};

use super::stats;
use super::table::{fmt_secs, Table};

pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    pub throughput: Option<(f64, &'static str)>,
}

pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub max_iters: u64,
    results: Vec<BenchResult>,
    suite: String,
}

impl Bencher {
    pub fn new(suite: &str) -> Self {
        // Keep wall time sane on the 1-core CI box; EDGESPLIT_BENCH_FAST=1
        // (used by `cargo test`-driven smoke checks) shrinks everything.
        let fast = std::env::var("EDGESPLIT_BENCH_FAST").is_ok();
        Self {
            warmup: if fast {
                Duration::from_millis(10)
            } else {
                Duration::from_millis(200)
            },
            measure: if fast {
                Duration::from_millis(50)
            } else {
                Duration::from_millis(1000)
            },
            max_iters: if fast { 1_000 } else { 1_000_000 },
            results: Vec::new(),
            suite: suite.to_string(),
        }
    }

    /// Time `f` adaptively; returns (and records) the result.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // warmup + calibration
        let wstart = Instant::now();
        let mut calib_iters = 0u64;
        while wstart.elapsed() < self.warmup {
            f();
            calib_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / calib_iters.max(1) as f64;
        // choose a batch size so each sample is ≥ ~50 µs (timer noise floor)
        let batch = ((5e-5 / per_iter).ceil() as u64).clamp(1, self.max_iters);
        let target_samples = ((self.measure.as_secs_f64() / (per_iter * batch as f64))
            .ceil() as u64)
            .clamp(5, 200);

        let mut samples = Vec::with_capacity(target_samples as usize);
        let mut total_iters = 0u64;
        for _ in 0..target_samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(t0.elapsed().as_secs_f64() / batch as f64);
            total_iters += batch;
            if total_iters >= self.max_iters {
                break;
            }
        }

        let res = BenchResult {
            name: name.to_string(),
            iters: total_iters,
            mean_s: stats::mean(&samples),
            median_s: stats::median(&samples),
            p95_s: stats::percentile(&samples, 95.0),
            min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
            throughput: None,
        };
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Like `bench` but annotates items/sec given `items` per call.
    pub fn bench_throughput<F: FnMut()>(
        &mut self,
        name: &str,
        items: f64,
        unit: &'static str,
        f: F,
    ) -> &BenchResult {
        self.bench(name, f);
        let last = self.results.last_mut().unwrap();
        last.throughput = Some((items / last.mean_s, unit));
        self.results.last().unwrap()
    }

    /// Record an externally-timed single-shot measurement — for
    /// meso-benchmarks (whole fleet rounds, figure regenerations) that
    /// are too heavy for adaptive repetition.  `throughput` is an
    /// optional `(rate, unit)` annotation, already per-second.
    pub fn record_once(
        &mut self,
        name: &str,
        seconds: f64,
        throughput: Option<(f64, &'static str)>,
    ) -> &BenchResult {
        self.results.push(BenchResult {
            name: name.to_string(),
            iters: 1,
            mean_s: seconds,
            median_s: seconds,
            p95_s: seconds,
            min_s: seconds,
            throughput,
        });
        self.results.last().unwrap()
    }

    /// Time exactly one invocation of `f` and record it.
    pub fn bench_once<F: FnOnce()>(&mut self, name: &str, f: F) -> &BenchResult {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_secs_f64();
        self.record_once(name, dt, None)
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    pub fn report(&self) {
        let mut t = Table::new(
            &format!("bench suite: {}", self.suite),
            &["benchmark", "mean", "median", "p95", "min", "throughput"],
        );
        for r in &self.results {
            t.row(vec![
                r.name.clone(),
                fmt_secs(r.mean_s),
                fmt_secs(r.median_s),
                fmt_secs(r.p95_s),
                fmt_secs(r.min_s),
                match r.throughput {
                    Some((v, u)) => format!("{v:.1} {u}/s"),
                    None => "-".to_string(),
                },
            ]);
        }
        t.print();
    }
}

/// Re-export of `std::hint::black_box` for bench bodies.
pub fn bb<T>(x: T) -> T {
    black_box(x)
}

/// Peak resident set size of this process in bytes, from the `VmHWM`
/// high-water mark in `/proc/self/status`.  `None` off Linux (or if
/// procfs is unreadable) — callers report the probe as unavailable
/// rather than guessing.  The kernel reports kB; monotonic over the
/// process lifetime, so probe *after* the workload under test.
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("EDGESPLIT_BENCH_FAST", "1");
        let mut b = Bencher::new("test");
        let mut acc = 0u64;
        let r = b.bench("noop-ish", || {
            acc = bb(acc.wrapping_add(1));
        });
        assert!(r.mean_s > 0.0);
        assert!(r.iters > 0);
    }

    #[test]
    fn throughput_annotation() {
        std::env::set_var("EDGESPLIT_BENCH_FAST", "1");
        let mut b = Bencher::new("test");
        let r = b.bench_throughput("items", 100.0, "item", || {
            bb(0u64);
        });
        assert!(r.throughput.unwrap().0 > 0.0);
    }

    #[test]
    fn single_shot_recording() {
        let mut b = Bencher::new("once");
        let r = b.bench_once("one-call", || {
            bb(7u64);
        });
        assert_eq!(r.iters, 1);
        assert!(r.mean_s >= 0.0);
        let r = b.record_once("external", 0.25, Some((400.0, "device-round")));
        assert_eq!(r.mean_s, 0.25);
        assert_eq!(r.throughput, Some((400.0, "device-round")));
        assert_eq!(b.results().len(), 2);
        b.report(); // must not panic
    }

    #[test]
    fn peak_rss_probe_is_sane_on_linux() {
        match peak_rss_bytes() {
            // this very test's allocations put the floor well above a page
            Some(b) => assert!(b > 4096, "VmHWM {b} bytes is implausibly small"),
            // non-Linux (or exotic procfs): the probe must decline, not lie
            None => assert!(!cfg!(target_os = "linux") || !std::path::Path::new("/proc/self/status").exists()),
        }
    }

    #[test]
    fn report_renders() {
        std::env::set_var("EDGESPLIT_BENCH_FAST", "1");
        let mut b = Bencher::new("render");
        b.bench("x", || {
            bb(1u32);
        });
        b.report(); // must not panic
    }
}
