//! Tooling substrates built from scratch for the offline environment
//! (no serde/rand/criterion/proptest available): deterministic RNG,
//! statistics, JSON, table rendering, logging, bench harness, property
//! testing.

pub mod benchkit;
pub mod json;
pub mod logging;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;
