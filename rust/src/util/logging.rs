//! Leveled stderr logger (no `log`/`env_logger` runtime wiring needed).
//!
//! Controlled by `EDGESPLIT_LOG` (error|warn|info|debug|trace) or the
//! `--log-level` CLI flag; defaults to `info`.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn init() {
    START.get_or_init(Instant::now);
    if let Ok(v) = std::env::var("EDGESPLIT_LOG") {
        if let Some(l) = Level::parse(&v) {
            set_level(l);
        }
    }
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Serializes whole log lines: pool workers logging concurrently used
/// to interleave fragments through independent `eprintln!` handles.
static WRITER: Mutex<()> = Mutex::new(());

pub fn log(l: Level, module: &str, msg: &str) {
    if !enabled(l) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    // format first, then hold the writer lock only for the single write
    let line = format!("[{t:9.3}s {} {module}] {msg}\n", l.tag());
    let guard = WRITER.lock().unwrap_or_else(|e| e.into_inner());
    let _ = std::io::stderr().write_all(line.as_bytes());
    drop(guard);
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Trace, module_path!(), &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn level_ordering_gates() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }

    #[test]
    fn trace_macro_rounds_out_the_level_set() {
        // no test in this binary raises the level to Trace, so this is
        // a gated no-op — the point is that the macro expands at all
        crate::log_trace!("suppressed at level {:?}", level());
        assert!(!enabled(Level::Trace));
    }
}
