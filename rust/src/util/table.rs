//! ASCII table printer for figure/table reproduction output.
//!
//! Every bench prints the same rows the paper reports; this keeps the
//! rendering consistent (and diff-able in EXPERIMENTS.md).

pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push(' ');
                s.push_str(&cells[i]);
                s.push_str(&" ".repeat(widths[i] - cells[i].len() + 1));
                s.push('|');
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format seconds with an adaptive unit.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.2} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Format Joules with an adaptive unit.
pub fn fmt_joules(j: f64) -> String {
    if j >= 1000.0 {
        format!("{:.2} kJ", j / 1e3)
    } else if j >= 1.0 {
        format!("{j:.2} J")
    } else {
        format!("{:.2} mJ", j * 1e3)
    }
}

/// Format bytes with an adaptive unit.
pub fn fmt_bytes(b: f64) -> String {
    if b >= 1e9 {
        format!("{:.2} GB", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.2} MB", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.2} KB", b / 1e3)
    } else {
        format!("{b:.0} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["k", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-key".into(), "2".into()]);
        let out = t.render();
        assert!(out.contains("| long-key | 2     |"));
        assert!(out.lines().all(|l| l.len() == out.lines().nth(1).unwrap().len() || l.starts_with("==")));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x".into()]);
    }

    #[test]
    fn unit_formatting() {
        assert_eq!(fmt_secs(2.5), "2.50 s");
        assert_eq!(fmt_secs(0.0025), "2.50 ms");
        assert_eq!(fmt_joules(1500.0), "1.50 kJ");
        assert_eq!(fmt_bytes(3.2e6), "3.20 MB");
    }
}
