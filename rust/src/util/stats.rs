//! Descriptive statistics for metrics/bench reporting (no external deps).

use super::rng::SplitMix64;

/// Online accumulator (Welford) — used by the round metrics and benchkit.
#[derive(Clone, Debug)]
pub struct Accum {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

/// `default()` must equal `new()`: a derived (zeroed) impl would start
/// `min`/`max` at 0.0 instead of the ±∞ sentinels and corrupt the
/// extrema of any accumulator built via `..Default::default()`.
impl Default for Accum {
    fn default() -> Self {
        Self::new()
    }
}

impl Accum {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Sample variance (n-1).
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Full internal state `(n, mean, m2, min, max)` for checkpoint
    /// serialization; `min`/`max` may be the ±∞ sentinels of an empty
    /// accumulator, so serialize them as raw bit patterns.
    pub fn state(&self) -> (u64, f64, f64, f64, f64) {
        (self.n, self.mean, self.m2, self.min, self.max)
    }

    /// Inverse of [`Accum::state`].
    pub fn from_state(n: u64, mean: f64, m2: f64, min: f64, max: f64) -> Self {
        Self { n, mean, m2, min, max }
    }
}

/// Default retained-sample ceiling for [`ReservoirSampler`] — big
/// enough that every pre-mega workload (≤ 10k devices × a few rounds)
/// stays in the exact regime.
pub const RESERVOIR_CAP: usize = 65_536;

/// Bounded uniform sample of an unbounded stream (Vitter's Algorithm R)
/// — the fixed memory ceiling behind percentile reporting at fleet
/// scale.
///
/// Below `cap` every observation is retained in push order, so
/// percentiles over [`ReservoirSampler::as_slice`] are **exact** and
/// bit-identical to the unbounded vector this replaces.  Past `cap`,
/// observation k replaces a uniformly random slot with probability
/// `cap / k`, driven by a private fixed-seed [`SplitMix64`] that
/// advances once per overflow push.  The replacement sequence is a pure
/// function of the push *count*, never of any experiment RNG stream or
/// thread schedule — two consumers folding the same stream hold
/// bit-identical samples.
#[derive(Clone, Debug)]
pub struct ReservoirSampler {
    cap: usize,
    seen: u64,
    rng: SplitMix64,
    samples: Vec<f64>,
}

impl Default for ReservoirSampler {
    fn default() -> Self {
        Self::new(RESERVOIR_CAP)
    }
}

impl ReservoirSampler {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "reservoir capacity must be positive");
        Self {
            cap,
            seen: 0,
            // arbitrary fixed constant: the sampler is deterministic
            // given the push sequence, independent of all other seeds
            rng: SplitMix64::new(0x0DDB_1A5E_55AA_C3D5),
            samples: Vec::new(),
        }
    }

    pub fn push(&mut self, x: f64) {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(x);
            return;
        }
        let j = self.rng.next_u64() % self.seen;
        if (j as usize) < self.cap {
            self.samples[j as usize] = x;
        }
    }

    /// Total observations pushed (not the retained count).
    pub fn len(&self) -> usize {
        self.seen as usize
    }

    pub fn is_empty(&self) -> bool {
        self.seen == 0
    }

    /// The retained samples (push order below `cap`; arbitrary above).
    pub fn as_slice(&self) -> &[f64] {
        &self.samples
    }

    /// `true` while every pushed observation is still retained —
    /// percentiles over [`ReservoirSampler::as_slice`] are exact.
    pub fn is_exact(&self) -> bool {
        self.seen as usize <= self.cap
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// Linear-interpolated percentile of an **already sorted** slice —
/// lets callers taking several percentiles sort once.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (rank - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Least-squares slope & intercept — used by the O(I) scaling bench (A3).
pub fn linreg(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = mean(xs);
    let my = mean(ys);
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    if sxx == 0.0 || n < 2.0 {
        return (0.0, my);
    }
    let slope = sxy / sxx;
    (slope, my - slope * mx)
}

/// Pearson correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    let mx = mean(xs);
    let my = mean(ys);
    let num: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let dx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum::<f64>().sqrt();
    let dy: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum::<f64>().sqrt();
    if dx == 0.0 || dy == 0.0 {
        return 0.0;
    }
    num / (dx * dy)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accum_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let mut a = Accum::new();
        for &x in &xs {
            a.push(x);
        }
        assert!((a.mean() - 3.0).abs() < 1e-12);
        assert!((a.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(a.min(), 1.0);
        assert_eq!(a.max(), 5.0);
        assert_eq!(a.count(), 5);
    }

    #[test]
    fn accum_state_round_trip() {
        let mut a = Accum::new();
        a.push(2.0);
        a.push(5.0);
        let (n, mean, m2, min, max) = a.state();
        let b = Accum::from_state(n, mean, m2, min, max);
        assert_eq!(a.count(), b.count());
        assert_eq!(a.mean().to_bits(), b.mean().to_bits());
        assert_eq!(a.var().to_bits(), b.var().to_bits());
        // the empty sentinels survive a round trip too
        let (n, mean, m2, min, max) = Accum::new().state();
        let e = Accum::from_state(n, mean, m2, min, max);
        assert_eq!(e.min(), f64::INFINITY);
        assert_eq!(e.max(), f64::NEG_INFINITY);
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [3.0, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 3.0);
        assert_eq!(median(&xs), 2.0);
        // pre-sorted fast path agrees with the sorting wrapper
        assert_eq!(percentile_sorted(&[1.0, 2.0, 3.0], 50.0), 2.0);
        assert!(percentile_sorted(&[], 50.0).is_nan());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 25.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn linreg_exact_line() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let (m, b) = linreg(&xs, &ys);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((b - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_signs() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let up = [0.0, 1.0, 2.0, 3.0];
        let down = [3.0, 2.0, 1.0, 0.0];
        assert!((pearson(&xs, &up) - 1.0).abs() < 1e-12);
        assert!((pearson(&xs, &down) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs_are_nan_not_panic() {
        assert!(mean(&[]).is_nan());
        assert!(percentile(&[], 50.0).is_nan());
    }

    #[test]
    fn reservoir_is_exact_below_cap() {
        let mut r = ReservoirSampler::new(8);
        for i in 0..8 {
            r.push(i as f64);
        }
        assert!(r.is_exact());
        assert_eq!(r.len(), 8);
        assert_eq!(r.as_slice(), &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
        // one more tips it into the sampled regime
        r.push(8.0);
        assert!(!r.is_exact());
        assert_eq!(r.len(), 9);
        assert_eq!(r.as_slice().len(), 8);
    }

    #[test]
    fn reservoir_replacement_is_deterministic() {
        let fold = || {
            let mut r = ReservoirSampler::new(16);
            for i in 0..10_000 {
                r.push((i as f64).sin());
            }
            r
        };
        let (a, b) = (fold(), fold());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.as_slice().iter().zip(b.as_slice()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // retained memory stays at the cap no matter the stream length
        assert_eq!(a.as_slice().len(), 16);
        // and the sample is not degenerate: several distinct survivors
        let distinct: std::collections::HashSet<u64> =
            a.as_slice().iter().map(|x| x.to_bits()).collect();
        assert!(distinct.len() > 4);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn reservoir_rejects_zero_cap() {
        let _ = ReservoirSampler::new(0);
    }
}
