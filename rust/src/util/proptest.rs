//! Tiny property-testing kit (no `proptest`/`quickcheck` offline).
//!
//! Deterministic: each case derives from a root seed, so failures print
//! a reproducible case index + the generated value (via `Debug`).
//! No shrinking — generators are kept small/structured instead.

use super::rng::Rng;

pub struct PropConfig {
    pub seed: u64,
    pub cases: u32,
}

impl Default for PropConfig {
    fn default() -> Self {
        Self {
            seed: 0xED6E_5712,
            cases: 256,
        }
    }
}

/// Check `prop` on `cases` values drawn by `gen`.  Panics with the case
/// index, seed, and a debug dump of the failing input.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    cfg: PropConfig,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut root = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let mut r = root.fork(case as u64);
        let value = gen(&mut r);
        if let Err(msg) = prop(&value) {
            panic!(
                "property '{name}' failed at case {case}/{} (seed {:#x}):\n  input: {value:?}\n  reason: {msg}",
                cfg.cases, cfg.seed
            );
        }
    }
}

/// Convenience: assert with a formatted reason.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err(format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        forall(
            "u64 parity",
            PropConfig::default(),
            |r| r.next_u64(),
            |&x| {
                if (x % 2 == 0) == (x & 1 == 0) {
                    Ok(())
                } else {
                    Err("parity mismatch".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_reports() {
        forall(
            "always-fails",
            PropConfig {
                seed: 1,
                cases: 10,
            },
            |r| r.below(100),
            |_| Err("nope".into()),
        );
    }
}
