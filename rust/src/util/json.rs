//! Minimal JSON substrate (parser + writer) — no `serde` in the offline
//! crate set.  Parses the AOT `manifest.json` and writes experiment
//! metric dumps.  Full JSON grammar except: no `\u` surrogate pairs
//! beyond the BMP, numbers parsed as f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors ------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path accessor: `j.at(&["config", "d_model"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for key in path {
            cur = cur.get(key)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---- writer ---------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders for metric dumps.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, ch: u8) -> Result<(), JsonError> {
        if self.peek() == Some(ch) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", ch as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos..self.pos + 4]).unwrap();
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": {} }"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.at(&["a"]).unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,null,true],"name":"x\"y","obj":{"k":-7}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} trailing").is_err());
    }

    #[test]
    fn unicode_escape_and_utf8() {
        let j = Json::parse("\"\\u00e9é\"").unwrap();
        assert_eq!(j.as_str(), Some("éé"));
    }

    #[test]
    fn manifest_shaped_document() {
        let src = r#"{"artifacts":{"layer_fwd":{"file":"layer_fwd.hlo.txt",
            "inputs":[{"dtype":"f32","name":"h","shape":[4,64,128]}]}},
            "config":{"d_model":128}}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.at(&["config", "d_model"]).unwrap().as_usize(), Some(128));
        let shape = j
            .at(&["artifacts", "layer_fwd", "inputs"])
            .unwrap()
            .as_arr()
            .unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape.len(), 3);
    }
}
