//! Device fleet: the set of edge devices participating in split
//! fine-tuning, with helpers to synthesize larger heterogeneous fleets
//! (used by examples/fleet_simulation.rs and the ablation benches).

use crate::config::{DeviceSpec, ServerSpec};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Fleet {
    pub devices: Vec<DeviceSpec>,
}

impl Fleet {
    pub fn new(devices: Vec<DeviceSpec>) -> Self {
        Self { devices }
    }

    /// The paper's 5-device testbed (Table I).
    pub fn paper() -> Self {
        Self::new(crate::config::schema::default_devices())
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Synthesize `n` heterogeneous devices by sampling capability tiers
    /// around the Table I range (0.4–1.4 GHz, 512–2048 cores) and
    /// placements in [5, 45] m.
    pub fn synthetic(n: usize, rng: &mut Rng) -> Self {
        Self::synthetic_within(n, (5.0, 45.0), rng)
    }

    /// Like [`Fleet::synthetic`], but places devices in `dist_range` [m]
    /// — scenario presets position their fleets differently (dense-urban
    /// close-in, sparse-rural far out) while keeping the Table I tiers.
    pub fn synthetic_within(n: usize, dist_range: (f64, f64), rng: &mut Rng) -> Self {
        let tiers: [(&str, f64, f64); 4] = [
            ("AGX Orin", 1.3, 2048.0),
            ("AGX Orin", 1.0, 2048.0),
            ("Orin NX", 0.7, 1024.0),
            ("AGX Nano", 0.5, 512.0),
        ];
        let devices = (0..n)
            .map(|i| {
                let (plat, ghz, cores) = tiers[rng.below(tiers.len() as u64) as usize];
                DeviceSpec {
                    name: format!("Device {}", i + 1),
                    platform: plat.to_string(),
                    // ±10% silicon lottery around the tier clock
                    freq_hz: ghz * 1e9 * rng.range(0.9, 1.1),
                    cores,
                    flops_per_cycle: 2.0,
                    distance_m: rng.range(dist_range.0, dist_range.1),
                }
            })
            .collect();
        Self::new(devices)
    }

    /// Largest server-frequency floor over the fleet — the binding
    /// F^{m,S}_min constraint when serving every device.
    pub fn max_freq_floor(&self, server: &ServerSpec) -> f64 {
        self.devices
            .iter()
            .map(|d| d.server_freq_floor(server))
            .fold(0.0, f64::max)
    }

    /// Devices sorted by compute capability (descending) — Fig. 3 is
    /// indexed this way ("capabilities gradually decrease from Device 1
    /// to Device 5").
    pub fn by_capability(&self) -> Vec<&DeviceSpec> {
        let mut v: Vec<&DeviceSpec> = self.devices.iter().collect();
        v.sort_by(|a, b| b.throughput().partial_cmp(&a.throughput()).unwrap());
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_fleet_matches_table1() {
        let f = Fleet::paper();
        assert_eq!(f.len(), 5);
        assert_eq!(f.devices[0].platform, "Jetson AGX Orin");
        assert_eq!(f.devices[4].platform, "Jetson AGX Nano");
        // capability strictly decreasing (Table I ordering)
        let caps: Vec<f64> = f.devices.iter().map(|d| d.throughput()).collect();
        for w in caps.windows(2) {
            assert!(w[0] > w[1]);
        }
    }

    #[test]
    fn synthetic_fleet_properties() {
        let mut rng = Rng::new(11);
        let f = Fleet::synthetic(50, &mut rng);
        assert_eq!(f.len(), 50);
        for d in &f.devices {
            assert!(d.freq_hz > 0.3e9 && d.freq_hz < 1.5e9);
            assert!(d.distance_m >= 5.0 && d.distance_m < 45.0);
        }
        // heterogeneity: more than one distinct core count
        let mut cores: Vec<u64> = f.devices.iter().map(|d| d.cores as u64).collect();
        cores.sort_unstable();
        cores.dedup();
        assert!(cores.len() > 1);
    }

    #[test]
    fn synthetic_within_respects_placement_band() {
        let mut rng = Rng::new(13);
        let f = Fleet::synthetic_within(40, (50.0, 120.0), &mut rng);
        for d in &f.devices {
            assert!(d.distance_m >= 50.0 && d.distance_m < 120.0, "{}", d.distance_m);
        }
        // capability tiers unchanged by placement band
        assert!(f.devices.iter().all(|d| d.freq_hz > 0.3e9 && d.freq_hz < 1.5e9));
    }

    #[test]
    fn freq_floor_is_max_over_fleet() {
        let f = Fleet::paper();
        let s = ServerSpec::default();
        let floor = f.max_freq_floor(&s);
        assert!((floor - f.devices[0].server_freq_floor(&s)).abs() < 1.0);
        assert!(floor < s.max_freq_hz);
    }

    #[test]
    fn by_capability_sorted() {
        let mut rng = Rng::new(12);
        let f = Fleet::synthetic(20, &mut rng);
        let sorted = f.by_capability();
        for w in sorted.windows(2) {
            assert!(w[0].throughput() >= w[1].throughput());
        }
    }
}
