//! Device fleet management: Table I profiles plus synthetic
//! heterogeneous fleets for scaling experiments.

pub mod fleet;

pub use fleet::Fleet;
