//! Artifact store: loads the AOT manifest + HLO-text segments, compiles
//! them on the PJRT client (lazily, cached), and executes them with
//! shape/dtype validation.
//!
//! HLO *text* is the interchange format — see python/compile/aot.py and
//! /opt/xla-example/README.md (jax ≥ 0.5 emits 64-bit instruction ids
//! that xla_extension 0.5.1 rejects in proto form; the text parser
//! reassigns ids).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

use super::tensor::{DType, HostTensor};

/// One input/output slot of a segment, from the manifest.
#[derive(Clone, Debug)]
pub struct SlotMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// One compiled-segment description.
#[derive(Clone, Debug)]
pub struct SegmentMeta {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<SlotMeta>,
    pub outputs: Vec<SlotMeta>,
}

/// Model dims exported by the manifest (mirror of configs.py).
#[derive(Clone, Debug)]
pub struct ManifestConfig {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch_size: usize,
    pub lora_rank: usize,
    pub base_layer_len: usize,
    pub lora_layer_len: usize,
    pub head_len: usize,
}

/// One named slice of a flat parameter vector (from manifest layouts).
#[derive(Clone, Debug)]
pub struct LayoutEntry {
    pub name: String,
    pub offset: usize,
    pub shape: Vec<usize>,
}

impl LayoutEntry {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

impl std::fmt::Debug for ArtifactStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ArtifactStore")
            .field("dir", &self.dir)
            .field("config", &self.config)
            .field("segments", &self.segments.len())
            .field("compiled", &self.compiled.len())
            .finish()
    }
}

pub struct ArtifactStore {
    pub dir: PathBuf,
    pub config: ManifestConfig,
    pub segments: HashMap<String, SegmentMeta>,
    /// flat-vector layouts: "base_layer", "lora_layer", "head"
    pub layouts: HashMap<String, Vec<LayoutEntry>>,
    client: xla::PjRtClient,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
    /// executions per segment (perf accounting)
    exec_counts: HashMap<String, u64>,
}

impl ArtifactStore {
    /// Open `artifacts/<cfg>` and parse its manifest. The PJRT CPU client
    /// is created here; compilation happens lazily per segment.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts`"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let cfg = parse_config(&j)?;
        let mut segments = HashMap::new();
        let arts = j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?;
        for (name, meta) in arts {
            segments.insert(name.clone(), parse_segment(name, meta, &dir)?);
        }

        let mut layouts = HashMap::new();
        if let Some(ls) = j.get("layouts").and_then(Json::as_obj) {
            for (lname, entries) in ls {
                let v = entries
                    .as_arr()
                    .ok_or_else(|| anyhow!("layout '{lname}' not an array"))?
                    .iter()
                    .map(|e| -> Result<LayoutEntry> {
                        Ok(LayoutEntry {
                            name: e
                                .get("name")
                                .and_then(Json::as_str)
                                .ok_or_else(|| anyhow!("layout entry missing name"))?
                                .to_string(),
                            offset: e
                                .get("offset")
                                .and_then(Json::as_usize)
                                .ok_or_else(|| anyhow!("layout entry missing offset"))?,
                            shape: e
                                .get("shape")
                                .and_then(Json::as_arr)
                                .ok_or_else(|| anyhow!("layout entry missing shape"))?
                                .iter()
                                .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad dim")))
                                .collect::<Result<_>>()?,
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                layouts.insert(lname.clone(), v);
            }
        }

        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            dir,
            config: cfg,
            segments,
            layouts,
            client,
            compiled: HashMap::new(),
            exec_counts: HashMap::new(),
        })
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    pub fn segment(&self, name: &str) -> Result<&SegmentMeta> {
        self.segments
            .get(name)
            .ok_or_else(|| anyhow!("unknown segment '{name}' (have: {:?})", self.segment_names()))
    }

    pub fn segment_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.segments.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    /// Compile (or fetch cached) a segment executable.
    pub fn compile(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.compiled.contains_key(name) {
            let meta = self.segment(name)?.clone();
            let proto = xla::HloModuleProto::from_text_file(&meta.file)
                .with_context(|| format!("parsing HLO text {:?}", meta.file))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling segment '{name}'"))?;
            self.compiled.insert(name.to_string(), exe);
        }
        Ok(&self.compiled[name])
    }

    /// Eagerly compile every segment (startup cost, measured by benches).
    pub fn compile_all(&mut self) -> Result<()> {
        let names: Vec<String> = self.segments.keys().cloned().collect();
        for n in names {
            self.compile(&n)?;
        }
        Ok(())
    }

    /// Execute a segment on host tensors, with full I/O validation.
    /// Outputs come back as host tensors in manifest order.
    pub fn execute(&mut self, name: &str, args: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let meta = self.segment(name)?.clone();
        if args.len() != meta.inputs.len() {
            bail!(
                "segment '{name}': expected {} inputs, got {}",
                meta.inputs.len(),
                args.len()
            );
        }
        for (slot, t) in meta.inputs.iter().zip(args) {
            if slot.shape != t.shape || slot.dtype != t.dtype {
                bail!(
                    "segment '{name}' input '{}': manifest wants {:?} {:?}, got {:?} {:?}",
                    slot.name,
                    slot.dtype,
                    slot.shape,
                    t.dtype,
                    t.shape
                );
            }
        }
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let exe = self.compile(name)?;
        let out_bufs = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing segment '{name}'"))?;
        *self.exec_counts.entry(name.to_string()).or_insert(0) += 1;

        // single-device: outputs[0][0] is the result tuple
        let lit = out_bufs[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let parts = lit.to_tuple().context("untupling result")?;
        if parts.len() != meta.outputs.len() {
            bail!(
                "segment '{name}': manifest declares {} outputs, got {}",
                meta.outputs.len(),
                parts.len()
            );
        }
        let mut outs = Vec::with_capacity(parts.len());
        for (slot, part) in meta.outputs.iter().zip(&parts) {
            let t = HostTensor::from_literal(part)
                .with_context(|| format!("decoding output '{}'", slot.name))?;
            // scalars come back shape [] — accept against manifest []
            if t.shape != slot.shape {
                bail!(
                    "segment '{name}' output '{}': manifest {:?}, got {:?}",
                    slot.name,
                    slot.shape,
                    t.shape
                );
            }
            outs.push(t);
        }
        Ok(outs)
    }

    /// Upload a host tensor to the device once; the returned buffer can
    /// be passed to `execute_buffers` any number of times (perf path:
    /// parameters stay device-resident across steps — DESIGN.md §9 L3).
    pub fn upload(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        let lit = t.to_literal()?;
        self.client
            .buffer_from_host_literal(None, &lit)
            .context("uploading buffer")
    }

    /// Fetch a device buffer back to the host.
    pub fn buffer_to_host(&self, buf: &xla::PjRtBuffer) -> Result<HostTensor> {
        let lit = buf.to_literal_sync().context("downloading buffer")?;
        HostTensor::from_literal(&lit)
    }

    /// Device-resident execution: inputs and outputs are PJRT buffers;
    /// no host round-trip.  The forked `xla` crate's `execute_b` is
    /// patched to untuple results, so outputs arrive one buffer per
    /// manifest output, chainable into the next call.
    pub fn execute_buffers(
        &mut self,
        name: &str,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let n_inputs = self.segment(name)?.inputs.len();
        let n_outputs = self.segment(name)?.outputs.len();
        if args.len() != n_inputs {
            bail!(
                "segment '{name}': expected {} inputs, got {}",
                n_inputs,
                args.len()
            );
        }
        let exe = self.compile(name)?;
        let mut out = exe
            .execute_b::<&xla::PjRtBuffer>(args)
            .with_context(|| format!("executing segment '{name}' (buffers)"))?;
        *self.exec_counts.entry(name.to_string()).or_insert(0) += 1;
        let bufs = out.remove(0);
        if bufs.len() != n_outputs {
            bail!(
                "segment '{name}': manifest declares {} outputs, got {} buffers \
                 (is the forked xla crate's untuple patch active?)",
                n_outputs,
                bufs.len()
            );
        }
        Ok(bufs)
    }

    pub fn exec_count(&self, name: &str) -> u64 {
        self.exec_counts.get(name).copied().unwrap_or(0)
    }

    pub fn compiled_count(&self) -> usize {
        self.compiled.len()
    }
}

fn parse_config(j: &Json) -> Result<ManifestConfig> {
    let c = j
        .get("config")
        .ok_or_else(|| anyhow!("manifest missing 'config'"))?;
    let g = |k: &str| -> Result<usize> {
        c.get(k)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("config missing '{k}'"))
    };
    Ok(ManifestConfig {
        name: c
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or("unknown")
            .to_string(),
        vocab_size: g("vocab_size")?,
        d_model: g("d_model")?,
        n_layers: g("n_layers")?,
        d_ff: g("d_ff")?,
        seq_len: g("seq_len")?,
        batch_size: g("batch_size")?,
        lora_rank: g("lora_rank")?,
        base_layer_len: g("base_layer_len")?,
        lora_layer_len: g("lora_layer_len")?,
        head_len: g("head_len")?,
    })
}

fn parse_slot(v: &Json) -> Result<SlotMeta> {
    let name = v
        .get("name")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("slot missing name"))?;
    let shape = v
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("slot missing shape"))?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad shape dim")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = DType::parse(
        v.get("dtype")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("slot missing dtype"))?,
    )?;
    Ok(SlotMeta {
        name: name.to_string(),
        shape,
        dtype,
    })
}

fn parse_segment(name: &str, meta: &Json, dir: &Path) -> Result<SegmentMeta> {
    let file = dir.join(
        meta.get("file")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("segment '{name}' missing file"))?,
    );
    if !file.exists() {
        bail!("segment '{name}': artifact file {file:?} missing — run `make artifacts`");
    }
    let slots = |key: &str| -> Result<Vec<SlotMeta>> {
        meta.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("segment '{name}' missing {key}"))?
            .iter()
            .map(parse_slot)
            .collect()
    };
    Ok(SegmentMeta {
        name: name.to_string(),
        file,
        inputs: slots("inputs")?,
        outputs: slots("outputs")?,
    })
}
