//! Host tensors + conversions to/from PJRT `Literal`s.
//!
//! Only the two dtypes the artifacts use (f32, i32); shapes are
//! validated against the manifest before every upload so a drifted
//! artifact fails loudly instead of silently reinterpreting bytes.

use anyhow::{bail, Context, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unsupported dtype '{other}'"),
        }
    }

    pub fn element_type(self) -> xla::ElementType {
        match self {
            DType::F32 => xla::ElementType::F32,
            DType::I32 => xla::ElementType::S32,
        }
    }

    pub fn size(self) -> usize {
        4
    }
}

/// A host-side tensor (row-major).
#[derive(Clone, Debug)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub dtype: DType,
    data: Vec<u8>,
}

impl HostTensor {
    pub fn from_f32(shape: &[usize], values: &[f32]) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != values.len() {
            bail!("shape {shape:?} wants {n} elements, got {}", values.len());
        }
        let mut data = vec![0u8; n * 4];
        for (chunk, v) in data.chunks_exact_mut(4).zip(values) {
            chunk.copy_from_slice(&v.to_le_bytes());
        }
        Ok(Self {
            shape: shape.to_vec(),
            dtype: DType::F32,
            data,
        })
    }

    pub fn from_i32(shape: &[usize], values: &[i32]) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != values.len() {
            bail!("shape {shape:?} wants {n} elements, got {}", values.len());
        }
        let mut data = vec![0u8; n * 4];
        for (chunk, v) in data.chunks_exact_mut(4).zip(values) {
            chunk.copy_from_slice(&v.to_le_bytes());
        }
        Ok(Self {
            shape: shape.to_vec(),
            dtype: DType::I32,
            data,
        })
    }

    pub fn zeros(shape: &[usize], dtype: DType) -> Self {
        let n: usize = shape.iter().product();
        Self {
            shape: shape.to_vec(),
            dtype,
            data: vec![0u8; n * dtype.size()],
        }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            bail!("tensor is {:?}, not f32", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::I32 {
            bail!("tensor is {:?}, not i32", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Convert to a PJRT literal (copies).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::create_from_shape_and_untyped_data(
            self.dtype.element_type(),
            &self.shape,
            &self.data,
        )
        .context("literal_create_from_shape_and_data")?;
        Ok(lit)
    }

    /// Convert back from a PJRT literal.
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape().context("literal has no array shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let (dtype, data) = match shape.ty() {
            xla::ElementType::F32 => {
                let v: Vec<f32> = lit.to_vec().context("to_vec f32")?;
                let mut bytes = vec![0u8; v.len() * 4];
                for (c, x) in bytes.chunks_exact_mut(4).zip(&v) {
                    c.copy_from_slice(&x.to_le_bytes());
                }
                (DType::F32, bytes)
            }
            xla::ElementType::S32 => {
                let v: Vec<i32> = lit.to_vec().context("to_vec i32")?;
                let mut bytes = vec![0u8; v.len() * 4];
                for (c, x) in bytes.chunks_exact_mut(4).zip(&v) {
                    c.copy_from_slice(&x.to_le_bytes());
                }
                (DType::I32, bytes)
            }
            other => bail!("unsupported literal element type {other:?}"),
        };
        Ok(Self {
            shape: dims,
            dtype,
            data,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip_values() {
        let t = HostTensor::from_f32(&[2, 3], &[1.0, -2.5, 3.0, 0.0, 1e-8, 7.25]).unwrap();
        assert_eq!(t.numel(), 6);
        assert_eq!(t.as_f32().unwrap()[1], -2.5);
    }

    #[test]
    fn i32_roundtrip_values() {
        let t = HostTensor::from_i32(&[4], &[1, -2, 300000, 0]).unwrap();
        assert_eq!(t.as_i32().unwrap(), vec![1, -2, 300000, 0]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(HostTensor::from_f32(&[2, 2], &[1.0]).is_err());
    }

    #[test]
    fn dtype_confusion_rejected() {
        let t = HostTensor::from_i32(&[1], &[1]).unwrap();
        assert!(t.as_f32().is_err());
    }

    #[test]
    fn zeros_is_zero() {
        let t = HostTensor::zeros(&[3, 3], DType::F32);
        assert!(t.as_f32().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("f32").unwrap(), DType::F32);
        assert_eq!(DType::parse("i32").unwrap(), DType::I32);
        assert!(DType::parse("f64").is_err());
    }
}
