//! PJRT runtime (L3 ⇄ L2 boundary): loads the AOT HLO-text artifacts,
//! compiles them on the PJRT CPU client (`xla` crate), and chains them
//! into real split LoRA fine-tuning steps.  Python is never on this
//! path — the artifacts are self-contained after `make artifacts`.

pub mod artifact;
pub mod executor;
pub mod tensor;

pub use artifact::{ArtifactStore, LayoutEntry, ManifestConfig, SegmentMeta, SlotMeta};
pub use executor::{ModelState, SplitExecutor, StepTraffic};
pub use tensor::{DType, HostTensor};

/// Conventional artifact directory for a named config, resolved
/// relative to the workspace root (or `EDGESPLIT_ARTIFACTS` override).
pub fn artifact_dir(config: &str) -> std::path::PathBuf {
    let base = std::env::var("EDGESPLIT_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    std::path::Path::new(&base).join(config)
}
