//! The split execution engine: runs REAL LoRA fine-tuning by chaining
//! per-layer HLO artifacts, implementing Stages 2–5 of the paper's
//! protocol with actual numerics (DESIGN.md §3).
//!
//! For a cut layer c:
//!   device FP  = embed_fwd + layer_fwd × c          (stash layer inputs)
//!   server FP  = layer_fwd × (I−c) + head_loss_grad
//!   server BP  = layer_bwd × (I−c), adapter_sgd × (I−c)
//!   device BP  = layer_bwd × c,     adapter_sgd × c
//!
//! The cut does not change the math (the same ops run either way), so
//! loss curves are comparable across strategies — exactly the paper's
//! setting, where the split only moves delay/energy, not gradients.
//! The executor still tracks which side executed every op + the bytes
//! that crossed the "air gap" so integration tests can assert protocol
//! invariants against the Aggregator.

use anyhow::{bail, Context, Result};

use crate::coordinator::aggregator::Aggregator;
use crate::coordinator::scheduler::{BackendStats, TrainBackend};
use crate::data::Batcher;
use crate::util::rng::Rng;

use super::artifact::{ArtifactStore, LayoutEntry};
use super::tensor::HostTensor;

/// Full model state as flat f32 vectors (layouts from the manifest).
pub struct ModelState {
    pub embed: HostTensor,
    pub base: Vec<HostTensor>,
    pub lora: Vec<HostTensor>,
    pub head: HostTensor,
}

impl ModelState {
    /// Initialize mirroring python/compile/params.py: scaled-normal
    /// weights, unit RMS gains, LoRA A ~ N(0, 0.02²), B = 0.
    pub fn init(store: &ArtifactStore, seed: u64) -> Result<Self> {
        let cfg = &store.config;
        let mut rng = Rng::new(seed);

        let embed_vals: Vec<f32> = {
            let scale = (cfg.d_model as f64).powf(-0.5);
            (0..cfg.vocab_size * cfg.d_model)
                .map(|_| (rng.gauss() * scale) as f32)
                .collect()
        };
        let embed = HostTensor::from_f32(&[cfg.vocab_size, cfg.d_model], &embed_vals)?;

        let base_layout = store
            .layouts
            .get("base_layer")
            .context("manifest missing base_layer layout")?;
        let lora_layout = store
            .layouts
            .get("lora_layer")
            .context("manifest missing lora_layer layout")?;
        let head_layout = store
            .layouts
            .get("head")
            .context("manifest missing head layout")?;

        let mut base = Vec::with_capacity(cfg.n_layers);
        let mut lora = Vec::with_capacity(cfg.n_layers);
        for _ in 0..cfg.n_layers {
            base.push(HostTensor::from_f32(
                &[cfg.base_layer_len],
                &init_flat(base_layout, cfg.base_layer_len, &mut rng),
            )?);
            lora.push(HostTensor::from_f32(
                &[cfg.lora_layer_len],
                &init_flat(lora_layout, cfg.lora_layer_len, &mut rng),
            )?);
        }
        let head = HostTensor::from_f32(
            &[cfg.head_len],
            &init_flat(head_layout, cfg.head_len, &mut rng),
        )?;

        Ok(Self {
            embed,
            base,
            lora,
            head,
        })
    }

    /// Stacked (n_layers, len) views for the fused `train_step` artifact.
    pub fn stacked(&self) -> Result<(HostTensor, HostTensor)> {
        let n = self.base.len();
        let lb = self.base[0].numel();
        let ll = self.lora[0].numel();
        let mut bs = Vec::with_capacity(n * lb);
        let mut ls = Vec::with_capacity(n * ll);
        for t in &self.base {
            bs.extend(t.as_f32()?);
        }
        for t in &self.lora {
            ls.extend(t.as_f32()?);
        }
        Ok((
            HostTensor::from_f32(&[n, lb], &bs)?,
            HostTensor::from_f32(&[n, ll], &ls)?,
        ))
    }
}

/// Initialize one flat parameter vector per its layout semantics.
fn init_flat(layout: &[LayoutEntry], total: usize, rng: &mut Rng) -> Vec<f32> {
    let mut v = vec![0f32; total];
    for e in layout {
        let seg = &mut v[e.offset..e.offset + e.numel()];
        if e.name.starts_with("rms") {
            seg.fill(1.0);
        } else if e.name.starts_with("a_") {
            for x in seg.iter_mut() {
                *x = (rng.gauss() * 0.02) as f32;
            }
        } else if e.name.starts_with("b_") {
            // zeros: adapter starts as identity (B = 0)
        } else {
            // base / head weight matrices: N(0, fan_in^-1)
            let fan_in = e.shape[0].max(1) as f64;
            let scale = fan_in.powf(-0.5);
            for x in seg.iter_mut() {
                *x = (rng.gauss() * scale) as f32;
            }
        }
    }
    v
}

/// Wire-traffic ledger for one training step at cut c (what crossed the
/// device↔server boundary; mirrors the datasize model).
#[derive(Clone, Copy, Debug, Default)]
pub struct StepTraffic {
    pub smashed_up_bytes: f64,
    pub grad_down_bytes: f64,
    pub device_ops: usize,
    pub server_ops: usize,
}

impl std::fmt::Debug for SplitExecutor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SplitExecutor")
            .field("store", &self.store)
            .field("steps", &self.loss_log.len())
            .finish()
    }
}

/// Device-resident parameter set (perf path: uploaded once, reused
/// every step; only tokens go up and the loss scalar comes down).
struct DeviceParams {
    embed: xla::PjRtBuffer,
    head: xla::PjRtBuffer,
    base: Vec<xla::PjRtBuffer>,
    lora: Vec<xla::PjRtBuffer>,
    lr: xla::PjRtBuffer,
}

pub struct SplitExecutor {
    pub store: ArtifactStore,
    pub state: ModelState,
    batchers: Vec<Batcher>,
    pub lr: f32,
    pub aggregator: Aggregator,
    /// (device, loss) per executed step
    pub loss_log: Vec<(usize, f64)>,
    pub traffic_log: Vec<StepTraffic>,
    /// lazily-initialized device-resident parameters (fast path)
    dev_params: Option<DeviceParams>,
    /// true when `dev_params.lora` is newer than `state.lora`
    lora_host_stale: bool,
}

impl SplitExecutor {
    pub fn new(store: ArtifactStore, batchers: Vec<Batcher>, lr: f32, seed: u64) -> Result<Self> {
        let cfg = &store.config;
        for b in &batchers {
            if b.batch_size != cfg.batch_size || b.seq_len != cfg.seq_len {
                bail!(
                    "batcher ({},{}) does not match artifact config ({},{})",
                    b.batch_size,
                    b.seq_len,
                    cfg.batch_size,
                    cfg.seq_len
                );
            }
        }
        let n_layers = cfg.n_layers;
        let state = ModelState::init(&store, seed)?;
        Ok(Self {
            store,
            state,
            batchers,
            lr,
            aggregator: Aggregator::new(n_layers),
            loss_log: Vec::new(),
            traffic_log: Vec::new(),
            dev_params: None,
            lora_host_stale: false,
        })
    }

    /// Upload all parameters to the device (idempotent).
    fn ensure_device_params(&mut self) -> Result<()> {
        if self.dev_params.is_some() {
            return Ok(());
        }
        let embed = self.store.upload(&self.state.embed)?;
        let head = self.store.upload(&self.state.head)?;
        let base = self
            .state
            .base
            .iter()
            .map(|t| self.store.upload(t))
            .collect::<Result<Vec<_>>>()?;
        let lora = self
            .state
            .lora
            .iter()
            .map(|t| self.store.upload(t))
            .collect::<Result<Vec<_>>>()?;
        let lr = self.store.upload(&HostTensor::from_f32(&[1], &[self.lr])?)?;
        self.dev_params = Some(DeviceParams {
            embed,
            head,
            base,
            lora,
            lr,
        });
        Ok(())
    }

    /// Pull device-resident adapters back into `state.lora` (after fast
    /// steps; no-op when already in sync).
    pub fn sync_lora_to_host(&mut self) -> Result<()> {
        if !self.lora_host_stale {
            return Ok(());
        }
        if let Some(dp) = &self.dev_params {
            for (t, buf) in self.state.lora.iter_mut().zip(&dp.lora) {
                *t = self.store.buffer_to_host(buf)?;
            }
        }
        self.lora_host_stale = false;
        Ok(())
    }

    /// One split training step on the DEVICE-RESIDENT fast path: the
    /// same Stage 2–5 protocol as `train_step`, but parameters live on
    /// the device across steps and activations/gradients chain between
    /// segments as PJRT buffers.  Host boundary traffic per step: the
    /// token/label batch up, one f32 loss down.
    pub fn train_step_device(&mut self, device_idx: usize, cut: usize, round: usize) -> Result<f64> {
        let i_layers = self.n_layers();
        if cut > i_layers {
            bail!("cut {cut} exceeds model depth {i_layers}");
        }
        if device_idx >= self.batchers.len() {
            bail!("device {device_idx} has no batcher");
        }
        self.ensure_device_params()?;
        let cfg_b = self.store.config.batch_size;
        let cfg_s = self.store.config.seq_len;
        let d = self.store.config.d_model;

        let adapter_bytes = (cut * self.store.config.lora_layer_len * 4) as f64;
        self.aggregator.distribute(device_idx, cut, round, adapter_bytes);

        let (toks, labs) = self.batchers[device_idx].next_batch();
        let tokens = self
            .store
            .upload(&HostTensor::from_i32(&[cfg_b, cfg_s], &toks)?)?;
        let labels = self
            .store
            .upload(&HostTensor::from_i32(&[cfg_b, cfg_s], &labs)?)?;

        let mut traffic = StepTraffic {
            smashed_up_bytes: (cfg_b * cfg_s * d * 4 + cfg_b * cfg_s * 4) as f64,
            ..Default::default()
        };

        // Stage 3: forward chain, stashing layer inputs (buffers)
        let dp = self.dev_params.take().expect("ensured above");
        let step = (|| -> Result<(f64, Vec<xla::PjRtBuffer>)> {
            let mut h = self
                .store
                .execute_buffers("embed_fwd", &[&tokens, &dp.embed])?
                .remove(0);
            traffic.device_ops += 1;
            let mut acts: Vec<xla::PjRtBuffer> = Vec::with_capacity(i_layers);
            for l in 0..i_layers {
                let out = self
                    .store
                    .execute_buffers("layer_fwd", &[&h, &dp.base[l], &dp.lora[l]])?
                    .remove(0);
                acts.push(h);
                h = out;
                if l < cut {
                    traffic.device_ops += 1;
                } else {
                    traffic.server_ops += 1;
                }
            }
            let mut head_out = self
                .store
                .execute_buffers("head_loss_grad", &[&h, &dp.head, &labels])?;
            traffic.server_ops += 1;
            let g_h = head_out.remove(1);
            let loss = self
                .store
                .buffer_to_host(&head_out.remove(0))?
                .as_f32()?[0] as f64;

            // Stage 4: backward chain + in-place adapter updates
            let mut new_lora: Vec<(usize, xla::PjRtBuffer)> = Vec::with_capacity(i_layers);
            let mut g = g_h;
            for l in (0..i_layers).rev() {
                let mut outs = self.store.execute_buffers(
                    "layer_bwd",
                    &[&acts[l], &dp.base[l], &dp.lora[l], &g],
                )?;
                let g_lora = outs.remove(1);
                let g_in = outs.remove(0);
                let updated = self
                    .store
                    .execute_buffers("adapter_sgd", &[&dp.lora[l], &g_lora, &dp.lr])?
                    .remove(0);
                new_lora.push((l, updated));
                if l < cut {
                    traffic.device_ops += 2;
                } else {
                    traffic.server_ops += 2;
                }
                if l == cut && cut > 0 {
                    traffic.grad_down_bytes = (cfg_b * cfg_s * d * 4) as f64;
                }
                g = g_in;
            }
            let lora_bufs: Vec<xla::PjRtBuffer> = {
                new_lora.sort_by_key(|(l, _)| *l);
                new_lora.into_iter().map(|(_, b)| b).collect()
            };
            Ok((loss, lora_bufs))
        })();

        match step {
            Ok((loss, lora_bufs)) => {
                self.dev_params = Some(DeviceParams { lora: lora_bufs, ..dp });
                self.lora_host_stale = true;
                self.aggregator.server_update(cut, round);
                self.aggregator.merge(device_idx, cut, round, adapter_bytes);
                self.loss_log.push((device_idx, loss));
                self.traffic_log.push(traffic);
                Ok(loss)
            }
            Err(e) => {
                // restore params so the executor stays usable
                self.dev_params = Some(dp);
                Err(e)
            }
        }
    }

    pub fn n_layers(&self) -> usize {
        self.store.config.n_layers
    }

    /// One split training step for `device_idx` at cut `c`.
    /// Returns the step loss.
    pub fn train_step(&mut self, device_idx: usize, cut: usize, round: usize) -> Result<f64> {
        let i_layers = self.n_layers();
        if cut > i_layers {
            bail!("cut {cut} exceeds model depth {i_layers}");
        }
        if device_idx >= self.batchers.len() {
            bail!("device {device_idx} has no batcher");
        }
        self.sync_lora_to_host()?; // in case fast steps ran before
        let cfg_b = self.store.config.batch_size;
        let cfg_s = self.store.config.seq_len;
        let d = self.store.config.d_model;

        // ---- Stage 2: adapter distribution (control plane) ----
        let adapter_bytes = (cut * self.store.config.lora_layer_len * 4) as f64;
        self.aggregator.distribute(device_idx, cut, round, adapter_bytes);

        let (toks, labs) = self.batchers[device_idx].next_batch();
        let tokens = HostTensor::from_i32(&[cfg_b, cfg_s], &toks)?;
        let labels = HostTensor::from_i32(&[cfg_b, cfg_s], &labs)?;

        // ---- Stage 3: forward (device then server) ----
        let mut traffic = StepTraffic::default();
        let mut h = self
            .store
            .execute("embed_fwd", &[&tokens, &self.state.embed])?
            .remove(0);
        traffic.device_ops += 1;

        let mut acts: Vec<HostTensor> = Vec::with_capacity(i_layers);
        for l in 0..i_layers {
            acts.push(h.clone());
            let out = self
                .store
                .execute("layer_fwd", &[&h, &self.state.base[l], &self.state.lora[l]])?
                .remove(0);
            h = out;
            if l < cut {
                traffic.device_ops += 1;
            } else {
                traffic.server_ops += 1;
            }
        }
        // smashed data crosses up exactly once per step
        traffic.smashed_up_bytes = (cfg_b * cfg_s * d * 4 + cfg_b * cfg_s * 4) as f64;

        let mut head_out = self
            .store
            .execute("head_loss_grad", &[&h, &self.state.head, &labels])?;
        traffic.server_ops += 1;
        let g_h = head_out.remove(1);
        let loss = head_out.remove(0).as_f32()?[0] as f64;

        // ---- Stage 4: backward (server layers, then device layers) ----
        let lr = HostTensor::from_f32(&[1], &[self.lr])?;
        let mut g = g_h;
        for l in (0..i_layers).rev() {
            let mut outs = self.store.execute(
                "layer_bwd",
                &[&acts[l], &self.state.base[l], &self.state.lora[l], &g],
            )?;
            let g_lora = outs.remove(1);
            let g_in = outs.remove(0);
            let updated = self
                .store
                .execute("adapter_sgd", &[&self.state.lora[l], &g_lora, &lr])?
                .remove(0);
            self.state.lora[l] = updated;
            if l < cut {
                traffic.device_ops += 2;
            } else {
                traffic.server_ops += 2;
            }
            if l == cut && cut > 0 {
                // the smashed-data gradient crosses down here
                traffic.grad_down_bytes = (cfg_b * cfg_s * d * 4) as f64;
            }
            g = g_in;
        }
        self.aggregator.server_update(cut, round);

        // ---- Stage 5: adapter upload + merge (Eq. 6) ----
        self.aggregator.merge(device_idx, cut, round, adapter_bytes);

        self.loss_log.push((device_idx, loss));
        self.traffic_log.push(traffic);
        // host-side adapters changed: device copies (if any) are stale
        self.dev_params = None;
        self.lora_host_stale = false;
        Ok(loss)
    }

    /// Fused whole-model step via the `train_step` artifact (ablation
    /// A4 baseline).  Updates the LoRA state in place.
    pub fn fused_train_step(&mut self, device_idx: usize) -> Result<f64> {
        self.sync_lora_to_host()?;
        let cfg_b = self.store.config.batch_size;
        let cfg_s = self.store.config.seq_len;
        let (toks, labs) = self.batchers[device_idx].next_batch();
        let tokens = HostTensor::from_i32(&[cfg_b, cfg_s], &toks)?;
        let labels = HostTensor::from_i32(&[cfg_b, cfg_s], &labs)?;
        let (base_stack, lora_stack) = self.state.stacked()?;
        let lr = HostTensor::from_f32(&[1], &[self.lr])?;
        let mut outs = self.store.execute(
            "train_step",
            &[
                &tokens,
                &labels,
                &self.state.embed,
                &base_stack,
                &lora_stack,
                &self.state.head,
                &lr,
            ],
        )?;
        let new_stack = outs.remove(1);
        let loss = outs.remove(0).as_f32()?[0] as f64;
        // scatter the stacked result back into per-layer tensors
        let flat = new_stack.as_f32()?;
        let ll = self.store.config.lora_layer_len;
        for (l, t) in self.state.lora.iter_mut().enumerate() {
            *t = HostTensor::from_f32(&[ll], &flat[l * ll..(l + 1) * ll])?;
        }
        self.loss_log.push((device_idx, loss));
        // host-side adapters changed: device copies (if any) are stale
        self.dev_params = None;
        Ok(loss)
    }
}

impl TrainBackend for SplitExecutor {
    fn train_round(
        &mut self,
        device_idx: usize,
        cut: usize,
        epochs: usize,
    ) -> Result<BackendStats> {
        let t0 = std::time::Instant::now();
        let mut total = 0.0;
        let round = self.aggregator.merges() as usize;
        for _ in 0..epochs {
            // device-resident fast path (see train_step for the host
            // reference path the tests cross-check against)
            total += self.train_step_device(device_idx, cut, round)?;
        }
        Ok(BackendStats {
            mean_loss: total / epochs.max(1) as f64,
            wallclock_s: t0.elapsed().as_secs_f64(),
        })
    }
}
