//! `cell-sweep`: scenario × device-count × cell-count grid on the
//! multi-cell discrete-event engine (DESIGN.md §15), emitting global
//! *and* per-cell utilization/energy/handover figures into
//! `BENCH_cells.json` for CI trajectory tracking (EXPERIMENTS.md).
//!
//! The aggregation policy is pinned to `Sync` — the sweep studies how
//! the *cell tier* (association, hysteresis handover, per-cell
//! queueing, star-to-cloud aggregation) reshapes contention and
//! energy, so the timeline policy is held fixed.  Every grid point is
//! an independent [`crate::exp::ExperimentBuilder`]-built experiment,
//! fanned out on the worker pool: thread count changes wall-clock
//! only, never a reported metric.
//!
//! Two invariants are enforced on every run:
//!
//! * per scenario, the single-cell anchor gate
//!   ([`crate::exp::verify::verify_single_cell_bit_identity`]): with
//!   `[cells]` forced back to one cell, the sync DES timeline must
//!   reproduce the serial round engine bit for bit;
//! * per point, the per-cell energy accumulators must sum *exactly*
//!   (bitwise) to the global `energy_spent_j` figure.

use crate::config::scenario::Scenario;
use crate::config::{CellLayout, CellsSpec};
use crate::exp::{self, DesSink, ExperimentBuilder, Report, ReportMeta};
use crate::util::benchkit::Bencher;
use crate::util::json::{self, Json};
use crate::util::pool;
use crate::util::table::{fmt_joules, fmt_secs, Table};

use super::engine::{CellStats, DesConfig, Policy};

/// One (scenario, fleet size, cell count) measurement: the global
/// figures plus the per-cell breakdown.
#[derive(Clone, Debug)]
pub struct CellPoint {
    pub scenario: String,
    pub n_devices: usize,
    pub n_cells: usize,
    pub layout: String,
    pub spacing_m: f64,
    pub hysteresis_db: f64,
    pub rounds: usize,
    pub capacity: usize,
    pub batch: usize,
    pub wall_s: f64,
    pub makespan_s: f64,
    /// completed device-round merges
    pub completed: usize,
    pub dropped: u64,
    /// total device→cell re-associations over the run
    pub handovers: u64,
    /// across-cell fleet figures (see `des::engine::DesOutcome::server`)
    pub mean_wait_s: f64,
    pub server_utilization: f64,
    pub peak_queue_depth: usize,
    /// Eq.-11 dispatch-time energy, summed over cells [J]
    pub energy_j: f64,
    /// energy of merged rounds only (excludes wasted work) [J]
    pub energy_merged_j: f64,
    /// per-cell queue/energy/handover observables, indexed by cell
    pub per_cell: Vec<CellStats>,
}

/// Full cell-sweep result.
#[derive(Clone, Debug)]
pub struct CellSweep {
    pub points: Vec<CellPoint>,
    pub threads: usize,
    pub seed: u64,
}

/// Run the grid.  `rounds` overrides each preset's round count;
/// `layout`/`spacing_m`/`hysteresis_db` parameterize the cell tier for
/// every multi-cell point; `capacity`/`batch` size each cell's queue.
#[allow(clippy::too_many_arguments)]
pub fn sweep(
    scenarios: &[Scenario],
    counts: &[usize],
    cell_counts: &[usize],
    layout: CellLayout,
    spacing_m: f64,
    hysteresis_db: f64,
    rounds: Option<usize>,
    capacity: usize,
    batch: usize,
    threads: usize,
    seed: u64,
    bench: &mut Bencher,
) -> anyhow::Result<CellSweep> {
    anyhow::ensure!(!scenarios.is_empty(), "no scenarios selected");
    anyhow::ensure!(!counts.is_empty(), "no device counts selected");
    anyhow::ensure!(!cell_counts.is_empty(), "no cell counts selected");
    anyhow::ensure!(capacity >= 1, "server capacity must be >= 1");
    anyhow::ensure!(batch >= 1, "server batch must be >= 1");
    anyhow::ensure!(
        spacing_m.is_finite() && spacing_m > 0.0,
        "cell spacing must be finite and > 0, got {spacing_m}"
    );
    anyhow::ensure!(
        hysteresis_db.is_finite() && hysteresis_db >= 0.0,
        "hysteresis margin must be finite and >= 0, got {hysteresis_db}"
    );
    for &n in counts {
        anyhow::ensure!(n > 0, "device count must be >= 1");
    }
    for &c in cell_counts {
        anyhow::ensure!(c >= 1, "cell count must be >= 1");
    }

    let mut grid: Vec<(Scenario, usize, usize)> = Vec::new();
    for sc in scenarios {
        for &n in counts {
            for &cells in cell_counts {
                grid.push((*sc, n, cells));
            }
        }
    }

    let results: Vec<anyhow::Result<CellPoint>> =
        pool::par_map_indexed(threads, &grid, |_, &(sc, n, cells)| {
            run_point(
                sc,
                n,
                cells,
                layout,
                spacing_m,
                hysteresis_db,
                rounds,
                capacity,
                batch,
                seed,
            )
        });
    let mut points = Vec::with_capacity(results.len());
    for r in results {
        points.push(r?);
    }
    for p in &points {
        let rate = p.completed as f64 / p.wall_s.max(1e-9);
        bench.record_once(
            &format!("{}_c{}_n{}", p.scenario, p.n_cells, p.n_devices),
            p.wall_s,
            Some((rate, "device-round")),
        );
    }

    // the single-cell anchor (DESIGN.md §15): per scenario, at the
    // largest fleet, a cells=1 sync DES run must reproduce the serial
    // round engine bit for bit — pinning every multi-cell code path to
    // the pre-cell engines
    let gate_n = *counts.iter().max().unwrap();
    for sc in scenarios {
        let mut cfg = sc.config(gate_n, seed)?;
        if let Some(r) = rounds {
            cfg.workload.rounds = r;
        }
        exp::verify::verify_single_cell_bit_identity(&cfg, sc.state, capacity, batch)?;
    }

    Ok(CellSweep {
        points,
        threads,
        seed,
    })
}

#[allow(clippy::too_many_arguments)]
fn run_point(
    sc: Scenario,
    n: usize,
    cells: usize,
    layout: CellLayout,
    spacing_m: f64,
    hysteresis_db: f64,
    rounds: Option<usize>,
    capacity: usize,
    batch: usize,
    seed: u64,
) -> anyhow::Result<CellPoint> {
    let mut builder = ExperimentBuilder::preset(sc.name)
        .devices(n)
        .seed(seed)
        .cells_spec(CellsSpec {
            count: cells,
            layout,
            spacing_m,
            hysteresis_db,
        })
        .des(DesConfig {
            policy: Policy::Sync,
            capacity,
            batch,
        });
    if let Some(r) = rounds {
        builder = builder.rounds(r);
    }
    let experiment = builder.build()?;
    let n_rounds = experiment.config().workload.rounds;

    let mut sink = DesSink::default();
    let t0 = std::time::Instant::now();
    let outcome = experiment.run_into(&mut sink)?;
    let wall = t0.elapsed().as_secs_f64();
    let des = outcome
        .des
        .ok_or_else(|| anyhow::anyhow!("event engine must report DES stats"))?;

    anyhow::ensure!(
        des.per_cell.len() == cells,
        "expected {} per-cell entries, got {}",
        cells,
        des.per_cell.len()
    );
    // the energy-conservation invariant: global figure == exact sum of
    // the per-cell accumulators (same order, same additions)
    let cell_sum: f64 = des.per_cell.iter().map(|c| c.energy_spent_j).sum();
    anyhow::ensure!(
        cell_sum.to_bits() == des.energy_spent_j.to_bits(),
        "per-cell energy {cell_sum} J does not reproduce the global {} J",
        des.energy_spent_j
    );

    Ok(CellPoint {
        scenario: sc.name.to_string(),
        n_devices: n,
        n_cells: cells,
        layout: layout.name().to_string(),
        spacing_m,
        hysteresis_db,
        rounds: n_rounds,
        capacity,
        batch,
        wall_s: wall,
        makespan_s: des.makespan_s,
        completed: outcome.cells,
        dropped: des.dropped,
        handovers: des.handovers,
        mean_wait_s: des.server.mean_wait_s,
        server_utilization: des.server.utilization,
        peak_queue_depth: des.server.peak_depth,
        energy_j: des.energy_spent_j,
        energy_merged_j: sink.energy_merged_j,
        per_cell: des.per_cell,
    })
}

impl CellSweep {
    /// ASCII summary: one row per grid point, indented per-cell rows
    /// under every multi-cell point.
    pub fn render(&self) -> String {
        let mut t = Table::new(
            &format!(
                "cell-sweep — multi-cell DES engine ({} workers, seed {})",
                self.threads, self.seed
            ),
            &[
                "scenario",
                "devices",
                "cells",
                "layout",
                "merged",
                "handovers",
                "makespan",
                "util",
                "peak q",
                "energy",
            ],
        );
        for p in &self.points {
            t.row(vec![
                p.scenario.clone(),
                p.n_devices.to_string(),
                p.n_cells.to_string(),
                p.layout.clone(),
                p.completed.to_string(),
                p.handovers.to_string(),
                fmt_secs(p.makespan_s),
                format!("{:.0}%", 100.0 * p.server_utilization),
                p.peak_queue_depth.to_string(),
                fmt_joules(p.energy_j),
            ]);
            if p.n_cells > 1 {
                for (i, c) in p.per_cell.iter().enumerate() {
                    t.row(vec![
                        format!("  cell {i}"),
                        String::new(),
                        String::new(),
                        format!("({:.0},{:.0})m", c.position_m.0, c.position_m.1),
                        c.server.served_jobs.to_string(),
                        c.handovers_in.to_string(),
                        String::new(),
                        format!("{:.0}%", 100.0 * c.server.utilization),
                        c.server.peak_depth.to_string(),
                        fmt_joules(c.energy_spent_j),
                    ]);
                }
            }
        }
        t.render()
    }

    /// Emitter payload (the `data` member of the report envelope).
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("schema", Json::Str("edgesplit/cell-sweep/v1".into())),
            // string, not number: u64 seeds above 2^53 would lose
            // precision through the f64-backed Json::Num
            ("seed", Json::Str(self.seed.to_string())),
            ("threads", Json::Num(self.threads as f64)),
            (
                "points",
                Json::Arr(self.points.iter().map(point_json).collect()),
            ),
        ])
    }

    /// The enveloped report (`BENCH_cells.json`): shared
    /// `schema_version`/`meta` wrapper around [`CellSweep::to_json`].
    pub fn report(&self, scenario_sel: &str, rounds: Option<usize>) -> Report {
        Report::new(
            ReportMeta {
                kind: "cell-sweep",
                preset: scenario_sel.to_string(),
                seed: self.seed,
                threads: self.threads,
                rounds,
            },
            self.to_json(),
            self.render(),
        )
    }
}

fn point_json(p: &CellPoint) -> Json {
    json::obj(vec![
        ("scenario", Json::Str(p.scenario.clone())),
        ("n_devices", Json::Num(p.n_devices as f64)),
        ("n_cells", Json::Num(p.n_cells as f64)),
        ("layout", Json::Str(p.layout.clone())),
        ("spacing_m", Json::Num(p.spacing_m)),
        ("hysteresis_db", Json::Num(p.hysteresis_db)),
        ("rounds", Json::Num(p.rounds as f64)),
        ("capacity", Json::Num(p.capacity as f64)),
        ("batch", Json::Num(p.batch as f64)),
        ("wall_s", Json::Num(p.wall_s)),
        ("makespan_s", Json::Num(p.makespan_s)),
        ("completed", Json::Num(p.completed as f64)),
        ("dropped", Json::Num(p.dropped as f64)),
        ("handovers", Json::Num(p.handovers as f64)),
        ("mean_wait_s", Json::Num(p.mean_wait_s)),
        ("server_utilization", Json::Num(p.server_utilization)),
        ("peak_queue_depth", Json::Num(p.peak_queue_depth as f64)),
        ("energy_j", Json::Num(p.energy_j)),
        ("energy_merged_j", Json::Num(p.energy_merged_j)),
        (
            "per_cell",
            Json::Arr(
                p.per_cell
                    .iter()
                    .enumerate()
                    .map(|(i, c)| cell_json(i, c))
                    .collect(),
            ),
        ),
    ])
}

fn cell_json(i: usize, c: &CellStats) -> Json {
    json::obj(vec![
        ("cell", Json::Num(i as f64)),
        ("x_m", Json::Num(c.position_m.0)),
        ("y_m", Json::Num(c.position_m.1)),
        ("served_jobs", Json::Num(c.server.served_jobs as f64)),
        ("abandoned_jobs", Json::Num(c.server.abandoned_jobs as f64)),
        ("utilization", Json::Num(c.server.utilization)),
        ("mean_wait_s", Json::Num(c.server.mean_wait_s)),
        ("peak_queue_depth", Json::Num(c.server.peak_depth as f64)),
        ("energy_j", Json::Num(c.energy_spent_j)),
        ("handovers_in", Json::Num(c.handovers_in as f64)),
        ("aggregator_consistent", Json::Bool(c.aggregator_consistent)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::scenario;

    #[test]
    fn small_grid_produces_points_and_json() {
        let mut bench = Bencher::new("cell-sweep-test");
        let sweep = sweep(
            &[scenario::DENSE_URBAN],
            &[6],
            &[1, 3],
            CellLayout::Line,
            40.0,
            3.0,
            Some(2),
            2,
            1,
            4,
            7,
            &mut bench,
        )
        .unwrap();
        assert_eq!(sweep.points.len(), 2);
        assert_eq!(bench.results().len(), 2);
        for p in &sweep.points {
            assert!(p.makespan_s > 0.0 && p.makespan_s.is_finite());
            assert_eq!(p.per_cell.len(), p.n_cells);
            assert!(p.completed > 0);
            let cell_sum: f64 = p.per_cell.iter().map(|c| c.energy_spent_j).sum();
            assert_eq!(cell_sum.to_bits(), p.energy_j.to_bits());
        }
        let js = sweep.to_json().to_string();
        assert!(js.contains("cell-sweep/v1"));
        assert!(js.contains("per_cell"));
        assert!(js.contains("handovers_in"));
        assert!(Json::parse(&js).is_ok());
    }

    #[test]
    fn single_cell_points_match_the_des_sweep_globals() {
        // the cells=1 point must carry exactly the legacy figures the
        // des-sweep reports for the same (scenario, fleet, knobs)
        let mut bench = Bencher::new("cell-anchor");
        let cells = sweep(
            &[scenario::DENSE_URBAN],
            &[5],
            &[1],
            CellLayout::Line,
            60.0,
            3.0,
            Some(2),
            2,
            1,
            2,
            9,
            &mut bench,
        )
        .unwrap();
        let mut bench2 = Bencher::new("des-anchor");
        let des = super::super::sweep::sweep(
            &[scenario::DENSE_URBAN],
            &[5],
            &[Policy::Sync],
            Some(2),
            2,
            1,
            2,
            9,
            &mut bench2,
        )
        .unwrap();
        let (c, d) = (&cells.points[0], &des.points[0]);
        assert_eq!(c.makespan_s.to_bits(), d.makespan_s.to_bits());
        assert_eq!(c.energy_j.to_bits(), d.energy_j.to_bits());
        assert_eq!(c.server_utilization.to_bits(), d.server_utilization.to_bits());
        assert_eq!(c.completed, d.completed);
        assert_eq!(c.handovers, 0);
    }

    #[test]
    fn sweep_is_deterministic_across_thread_counts() {
        let run = |threads: usize| {
            let mut bench = Bencher::new("det");
            sweep(
                &[scenario::MOBILE_VEHICULAR],
                &[8],
                &[1, 4],
                CellLayout::Line,
                60.0,
                3.0,
                Some(3),
                2,
                1,
                threads,
                11,
                &mut bench,
            )
            .unwrap()
        };
        let a = run(1);
        let b = run(8);
        assert_eq!(a.points.len(), b.points.len());
        for (x, y) in a.points.iter().zip(&b.points) {
            assert_eq!(x.n_cells, y.n_cells);
            assert_eq!(x.makespan_s.to_bits(), y.makespan_s.to_bits());
            assert_eq!(x.completed, y.completed);
            assert_eq!(x.handovers, y.handovers);
            assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits());
            for (cx, cy) in x.per_cell.iter().zip(&y.per_cell) {
                assert_eq!(cx.energy_spent_j.to_bits(), cy.energy_spent_j.to_bits());
                assert_eq!(cx.handovers_in, cy.handovers_in);
                assert_eq!(cx.server.served_jobs, cy.server.served_jobs);
            }
        }
    }

    #[test]
    fn rejects_degenerate_input() {
        let mut bench = Bencher::new("bad");
        let sc = [scenario::DENSE_URBAN];
        let l = CellLayout::Line;
        assert!(sweep(&[], &[4], &[1], l, 60.0, 3.0, None, 1, 1, 1, 0, &mut bench).is_err());
        assert!(sweep(&sc, &[], &[1], l, 60.0, 3.0, None, 1, 1, 1, 0, &mut bench).is_err());
        assert!(sweep(&sc, &[4], &[], l, 60.0, 3.0, None, 1, 1, 1, 0, &mut bench).is_err());
        assert!(sweep(&sc, &[0], &[1], l, 60.0, 3.0, None, 1, 1, 1, 0, &mut bench).is_err());
        assert!(sweep(&sc, &[4], &[0], l, 60.0, 3.0, None, 1, 1, 1, 0, &mut bench).is_err());
        assert!(sweep(&sc, &[4], &[1], l, 0.0, 3.0, None, 1, 1, 1, 0, &mut bench).is_err());
        assert!(sweep(&sc, &[4], &[1], l, 60.0, -1.0, None, 1, 1, 1, 0, &mut bench).is_err());
        assert!(sweep(&sc, &[4], &[1], l, 60.0, 3.0, None, 0, 1, 1, 0, &mut bench).is_err());
    }

    #[test]
    fn render_lists_points_and_per_cell_rows() {
        let mut bench = Bencher::new("render");
        let sweep = sweep(
            &[scenario::SPARSE_RURAL],
            &[4],
            &[2],
            CellLayout::Ring,
            50.0,
            2.0,
            Some(1),
            2,
            1,
            2,
            1,
            &mut bench,
        )
        .unwrap();
        let out = sweep.render();
        assert!(out.contains("sparse-rural"));
        assert!(out.contains("handovers"));
        assert!(out.contains("cell 0"));
        assert!(out.contains("cell 1"));
    }
}
