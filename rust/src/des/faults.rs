//! Deterministic fault injection for the DES engine (DESIGN.md §17).
//!
//! Three failure channels, all driven by **counter-based SplitMix64
//! streams** under the same purity discipline as churn and fading —
//! every draw is a pure function of `(fault root, tags)`, never of
//! event-processing order:
//!
//! * **Link outages** — while an activation upload or gradient
//!   download is in flight, a transient outage interrupts it after a
//!   uniformly drawn fraction of the transfer.  The per-attempt stream
//!   is tagged `(LINK_TAG, dir, device, round, attempt)`; the outage
//!   indicator compares *the same uniform draw* against
//!   `p = 1 − exp(−rate · duration)`, so raising the injected rate only
//!   ever grows the outage set — retry counts and retransmission energy
//!   are pointwise monotone in the rate, which the `chaos-sweep` CI
//!   validator asserts.
//! * **Slot failures** — at each batch dispatch a server capacity slot
//!   fails with `slot_fail_prob` and repairs after an exponential
//!   `slot_repair_s` mean; the batch completes late by the repair time.
//!   Tagged `(SLOT_TAG, cell, dispatch_seq)`.
//! * **Regional bursts** — per round, with `burst_rate_per_round`, a
//!   correlated dropout region opens around a uniformly drawn center
//!   device's mobility position.  Devices launching inside the radius
//!   fail over to their second-nearest cell (or degrade to a
//!   device-heavy cut when there is no alternate cell).  Tagged
//!   `(BURST_TAG, round)`.
//!
//! Recovery semantics (bounded retry with exponential backoff + jitter,
//! timeout demotion, graceful degradation) live in `des::engine`; this
//! module only answers "does fault X strike, and with what parameters".

use crate::config::FaultsSpec;
use crate::util::rng::{Rng, SplitMix64};

/// Stream-tag domains (disjoint from `CHURN_TAG = 0xC4_52_4E`).
pub const LINK_TAG: u64 = 0xFA_17_71;
pub const SLOT_TAG: u64 = 0xFA_17_5C;
pub const BURST_TAG: u64 = 0xFA_17_B5;

/// Salt folding the experiment seed into the fault root, so fault
/// streams never collide with the churn root (`seed ^ 0xDE5C_4`).
const FAULT_SALT: u64 = 0xFA_017_0u64;

/// Transfer direction of a link-outage stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    /// device → server activation upload
    Up,
    /// server → device gradient download
    Down,
}

impl Dir {
    fn tag(self) -> u64 {
        match self {
            Dir::Up => 0,
            Dir::Down => 1,
        }
    }
}

/// A link outage that struck one transfer attempt.
#[derive(Clone, Copy, Debug)]
pub struct Outage {
    /// fraction of the transfer completed (and wasted) before the cut
    pub frac: f64,
    /// exponential backoff + jitter wait before the retransmission [s]
    pub backoff_s: f64,
}

/// Pure fault sampler over the experiment's fault knobs.
#[derive(Clone, Debug)]
pub struct FaultProcess {
    root: u64,
    spec: FaultsSpec,
    n_devices: usize,
}

impl FaultProcess {
    pub fn new(seed: u64, spec: &FaultsSpec, n_devices: usize) -> Self {
        Self {
            root: seed ^ FAULT_SALT,
            spec: spec.clone(),
            n_devices,
        }
    }

    pub fn spec(&self) -> &FaultsSpec {
        &self.spec
    }

    /// Retransmissions allowed per transfer before the cell is dropped.
    pub fn max_retries(&self) -> usize {
        self.spec.max_retries
    }

    /// Does `attempt` of the `(device, round)` transfer in direction
    /// `dir`, lasting `duration_s`, suffer a transient outage?
    ///
    /// The first draw of the attempt stream is the outage indicator;
    /// `frac` and the backoff jitter follow in fixed order, so the
    /// struck attempt replays identically whatever rate crossed its
    /// threshold.
    pub fn link_outage(
        &self,
        dir: Dir,
        device: usize,
        round: usize,
        attempt: usize,
        duration_s: f64,
    ) -> Option<Outage> {
        let rate = self.spec.link_outage_rate_hz;
        if rate <= 0.0 || duration_s <= 0.0 {
            return None;
        }
        let mut rng = Rng::new(SplitMix64::stream_seed(
            self.root,
            &[LINK_TAG, dir.tag(), device as u64, round as u64, attempt as u64],
        ));
        let u = rng.f64();
        let p = 1.0 - (-rate * duration_s).exp();
        if u >= p {
            return None;
        }
        let frac = rng.f64();
        let jitter = 1.0 + self.spec.backoff_jitter * rng.f64();
        let backoff_s = self.spec.backoff_base_s * (1u64 << attempt.min(16)) as f64 * jitter;
        Some(Outage { frac, backoff_s })
    }

    /// Force an outage on a burst-struck single-cell uplink attempt:
    /// same stream as [`FaultProcess::link_outage`] but unconditional,
    /// so the retry parameters stay pure in the attempt coordinates.
    pub fn forced_outage(&self, dir: Dir, device: usize, round: usize, attempt: usize) -> Outage {
        let mut rng = Rng::new(SplitMix64::stream_seed(
            self.root,
            &[LINK_TAG, dir.tag(), device as u64, round as u64, attempt as u64],
        ));
        let _u = rng.f64();
        let frac = rng.f64();
        let jitter = 1.0 + self.spec.backoff_jitter * rng.f64();
        let backoff_s = self.spec.backoff_base_s * (1u64 << attempt.min(16)) as f64 * jitter;
        Outage { frac, backoff_s }
    }

    /// Does the `seq`-th batch dispatch on `cell` hit a failed capacity
    /// slot?  Returns the exponential repair time that delays the batch.
    pub fn slot_failure(&self, cell: usize, seq: u64) -> Option<f64> {
        let p = self.spec.slot_fail_prob;
        if p <= 0.0 {
            return None;
        }
        let mut rng = Rng::new(SplitMix64::stream_seed(
            self.root,
            &[SLOT_TAG, cell as u64, seq],
        ));
        if rng.f64() >= p {
            return None;
        }
        Some(rng.exp(1.0 / self.spec.slot_repair_s))
    }

    /// Is a correlated dropout burst open during `round`, and which
    /// device anchors its region?  Pure in `(seed, round)` — async
    /// devices on personal round clocks sample the same burst calendar.
    pub fn burst_center(&self, round: usize) -> Option<usize> {
        let p = self.spec.burst_rate_per_round;
        if p <= 0.0 || self.n_devices == 0 {
            return None;
        }
        let mut rng = Rng::new(SplitMix64::stream_seed(self.root, &[BURST_TAG, round as u64]));
        if rng.f64() >= p {
            return None;
        }
        Some(rng.below(self.n_devices as u64) as usize)
    }

    /// Is `pos` inside the burst region centered at `center`?
    pub fn in_burst(&self, pos: (f64, f64), center: (f64, f64)) -> bool {
        let (dx, dy) = (pos.0 - center.0, pos.1 - center.1);
        (dx * dx + dy * dy).sqrt() <= self.spec.burst_radius_m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(rate: f64) -> FaultsSpec {
        FaultsSpec {
            link_outage_rate_hz: rate,
            slot_fail_prob: 0.3,
            burst_rate_per_round: 0.5,
            ..FaultsSpec::default()
        }
    }

    #[test]
    fn draws_are_pure_in_their_coordinates() {
        let f = FaultProcess::new(7, &spec(2.0), 8);
        let g = FaultProcess::new(7, &spec(2.0), 8);
        for attempt in 0..4 {
            let a = f.link_outage(Dir::Up, 3, 5, attempt, 0.8);
            let b = g.link_outage(Dir::Up, 3, 5, attempt, 0.8);
            assert_eq!(a.is_some(), b.is_some());
            if let (Some(a), Some(b)) = (a, b) {
                assert_eq!(a.frac.to_bits(), b.frac.to_bits());
                assert_eq!(a.backoff_s.to_bits(), b.backoff_s.to_bits());
            }
        }
        assert_eq!(f.burst_center(4), g.burst_center(4));
        assert_eq!(f.slot_failure(1, 9), g.slot_failure(1, 9));
    }

    #[test]
    fn outage_set_grows_monotonically_with_rate() {
        // the same uniform draw against a larger threshold: any attempt
        // struck at rate r is struck at every r' > r
        let lo = FaultProcess::new(11, &spec(0.05), 16);
        let hi = FaultProcess::new(11, &spec(1.5), 16);
        let mut struck_lo = 0;
        let mut struck_hi = 0;
        for dev in 0..16 {
            for round in 0..8 {
                let a = lo.link_outage(Dir::Up, dev, round, 0, 1.0);
                let b = hi.link_outage(Dir::Up, dev, round, 0, 1.0);
                if a.is_some() {
                    struck_lo += 1;
                    assert!(b.is_some(), "outage at low rate vanished at high rate");
                }
                struck_hi += usize::from(b.is_some());
            }
        }
        assert!(struck_hi > struck_lo, "{struck_hi} vs {struck_lo}");
    }

    #[test]
    fn zero_rates_never_strike() {
        let f = FaultProcess::new(3, &FaultsSpec::default(), 8);
        assert!(f.link_outage(Dir::Up, 0, 0, 0, 10.0).is_none());
        assert!(f.link_outage(Dir::Down, 1, 2, 3, 10.0).is_none());
        assert!(f.slot_failure(0, 0).is_none());
        assert!(f.burst_center(0).is_none());
    }

    #[test]
    fn backoff_grows_exponentially_with_attempt() {
        let s = FaultsSpec {
            link_outage_rate_hz: 1.0,
            backoff_jitter: 0.0,
            ..FaultsSpec::default()
        };
        let f = FaultProcess::new(5, &s, 4);
        let b0 = f.forced_outage(Dir::Up, 2, 1, 0).backoff_s;
        let b1 = f.forced_outage(Dir::Up, 2, 1, 1).backoff_s;
        let b2 = f.forced_outage(Dir::Up, 2, 1, 2).backoff_s;
        assert_eq!(b0, s.backoff_base_s);
        assert_eq!(b1, 2.0 * s.backoff_base_s);
        assert_eq!(b2, 4.0 * s.backoff_base_s);
    }

    #[test]
    fn burst_region_is_a_disk() {
        let f = FaultProcess::new(9, &spec(0.0), 4);
        // default radius 25 m
        assert!(f.in_burst((10.0, 0.0), (0.0, 0.0)));
        assert!(f.in_burst((0.0, 25.0), (0.0, 0.0)));
        assert!(!f.in_burst((30.0, 0.0), (0.0, 0.0)));
    }
}
