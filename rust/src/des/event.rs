//! Virtual clock + event queue — the substrate of the discrete-event
//! fleet engine (DESIGN.md §11).
//!
//! Time is a non-negative finite `f64` of virtual seconds wrapped in
//! [`SimTime`] so it can live in a `BinaryHeap` with a *total* order.
//! Ties are broken deterministically by insertion sequence number: two
//! events scheduled for the same instant pop in the order they were
//! pushed.  Because the event loop is single-threaded and every
//! stochastic input comes from counter-based RNG streams, a DES run is
//! a pure function of `(config, seed)` — thread counts, wall-clock, and
//! host load can never reorder it.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Virtual simulation time [s].  Non-negative and finite by
/// construction, which makes the raw IEEE-754 bit pattern order-
/// preserving — that is what `Ord` compares.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SimTime(f64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0.0);

    /// Wrap a timestamp; panics on NaN/negative/infinite input because
    /// a corrupt clock would silently scramble the heap order.
    pub fn new(t: f64) -> SimTime {
        assert!(t.is_finite() && t >= 0.0, "SimTime must be finite and >= 0, got {t}");
        SimTime(t)
    }

    pub fn secs(self) -> f64 {
        self.0
    }

    /// This instant shifted `dt` seconds into the future.
    pub fn after(self, dt: f64) -> SimTime {
        assert!(dt.is_finite() && dt >= 0.0, "event delay must be finite and >= 0, got {dt}");
        SimTime::new(self.0 + dt)
    }
}

impl Eq for SimTime {}

impl PartialOrd for SimTime {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for SimTime {
    fn cmp(&self, other: &Self) -> Ordering {
        // valid because both sides are non-negative finite
        self.0.to_bits().cmp(&other.0.to_bits())
    }
}

/// Everything that can happen in the fleet timeline.  `device` indexes
/// `cfg.devices`; `round` is the cell's round coordinate (global round
/// for sync/semi-sync, the device's personal round for async).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Churn: the device (re)joins the fleet.
    Arrive { device: usize },
    /// Churn: the device leaves; its in-flight work is abandoned.
    Depart { device: usize },
    /// Device-side FP + smashed/adapter uplink finished — the job is
    /// ready for the server compute queue.
    UplinkDone { device: usize, round: usize },
    /// One server slot of `cell`'s queue finished a fused batch of
    /// jobs; each job's gradient downlink starts now.
    ServerBatchDone { cell: usize, jobs: Vec<(usize, usize)> },
    /// Gradient/adapter downlink + device BP finished — merge happens.
    MergeReady { device: usize, round: usize },
    /// Semi-sync: the straggler deadline for a global round.  Doubles
    /// as the fault-timeout demotion deadline for the sync policy when
    /// `[faults]` sets `timeout_factor > 0` (DESIGN.md §17).
    Deadline { round: usize },
    /// Faults: the backoff wait after an interrupted uplink expired —
    /// retransmit attempt `attempt` of the activation upload.
    RetryUplink { device: usize, round: usize, attempt: usize },
    /// Faults: retransmit attempt `attempt` of the gradient downlink.
    RetryDownlink { device: usize, round: usize, attempt: usize },
}

struct Entry {
    t: SimTime,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}

impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert so the earliest (t, seq)
        // pops first.  seq breaks time ties FIFO — the determinism rule.
        (other.t, other.seq).cmp(&(self.t, self.seq))
    }
}

/// Min-heap of timed events with a monotone virtual clock.
pub struct EventQueue {
    heap: BinaryHeap<Entry>,
    seq: u64,
    now: SimTime,
}

impl Default for EventQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
            now: SimTime::ZERO,
        }
    }

    /// Current virtual time — advances only in [`EventQueue::pop`].
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `kind` at absolute time `t` (must not be in the past).
    pub fn push_at(&mut self, t: SimTime, kind: EventKind) {
        assert!(t >= self.now, "cannot schedule into the past: {t:?} < {:?}", self.now);
        self.heap.push(Entry {
            t,
            seq: self.seq,
            kind,
        });
        self.seq += 1;
    }

    /// Schedule `kind` `dt` seconds after the current instant.
    pub fn push_after(&mut self, dt: f64, kind: EventKind) {
        self.push_at(self.now.after(dt), kind);
    }

    /// Pop the earliest event, advancing the clock to it.
    pub fn pop(&mut self) -> Option<(SimTime, EventKind)> {
        let e = self.heap.pop()?;
        debug_assert!(e.t >= self.now, "clock went backwards");
        self.now = e.t;
        Some((e.t, e.kind))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Timestamp of the earliest pending event without popping it —
    /// how `run_until` decides a checkpoint instant has been reached.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.t)
    }

    /// Checkpoint view: `(now, next seq, pending events)` with the
    /// pending set sorted by `(t, seq)` so the serialized envelope is
    /// canonical (heap iteration order is arbitrary).
    pub fn snapshot(&self) -> (SimTime, u64, Vec<(SimTime, u64, EventKind)>) {
        let mut entries: Vec<_> = self
            .heap
            .iter()
            .map(|e| (e.t, e.seq, e.kind.clone()))
            .collect();
        entries.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        (self.now, self.seq, entries)
    }

    /// Inverse of [`EventQueue::snapshot`]: rebuild the queue with the
    /// original insertion sequence numbers, so time ties keep breaking
    /// exactly as they would have in the uninterrupted run.
    pub fn restore(now: SimTime, seq: u64, entries: Vec<(SimTime, u64, EventKind)>) -> EventQueue {
        let mut heap = BinaryHeap::with_capacity(entries.len());
        for (t, entry_seq, kind) in entries {
            assert!(t >= now, "checkpointed event predates the clock");
            assert!(entry_seq < seq, "checkpointed event seq beyond the counter");
            heap.push(Entry {
                t,
                seq: entry_seq,
                kind,
            });
        }
        EventQueue { heap, seq, now }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push_at(SimTime::new(3.0), EventKind::Arrive { device: 3 });
        q.push_at(SimTime::new(1.0), EventKind::Arrive { device: 1 });
        q.push_at(SimTime::new(2.0), EventKind::Arrive { device: 2 });
        let order: Vec<usize> = std::iter::from_fn(|| q.pop())
            .map(|(_, e)| match e {
                EventKind::Arrive { device } => device,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        for device in 0..10 {
            q.push_at(SimTime::new(5.0), EventKind::Depart { device });
        }
        let order: Vec<usize> = std::iter::from_fn(|| q.pop())
            .map(|(_, e)| match e {
                EventKind::Depart { device } => device,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn clock_is_monotone_and_relative_push_works() {
        let mut q = EventQueue::new();
        q.push_after(2.0, EventKind::Arrive { device: 0 });
        let (t, _) = q.pop().unwrap();
        assert_eq!(t.secs(), 2.0);
        assert_eq!(q.now().secs(), 2.0);
        q.push_after(1.5, EventKind::Arrive { device: 1 });
        let (t, _) = q.pop().unwrap();
        assert_eq!(t.secs(), 3.5);
        assert!(q.is_empty());
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn snapshot_restore_preserves_order_and_ties() {
        let mut q = EventQueue::new();
        q.push_at(SimTime::new(1.0), EventKind::Arrive { device: 0 });
        for device in 0..5 {
            q.push_at(SimTime::new(5.0), EventKind::Depart { device });
        }
        q.pop(); // advance the clock past the first event
        let (now, seq, entries) = q.snapshot();
        assert_eq!(now.secs(), 1.0);
        assert_eq!(entries.len(), 5);
        // entries are sorted canonically by (t, seq)
        assert!(entries.windows(2).all(|w| (w[0].0, w[0].1) < (w[1].0, w[1].1)));
        let mut r = EventQueue::restore(now, seq, entries);
        assert_eq!(r.now(), q.now());
        assert_eq!(r.len(), q.len());
        // the restored queue drains identically, ties still FIFO
        while let Some(a) = q.pop() {
            let b = r.pop().unwrap();
            assert_eq!(a.0, b.0);
            assert_eq!(a.1, b.1);
        }
        assert!(r.is_empty());
    }

    #[test]
    fn peek_time_matches_next_pop() {
        let mut q = EventQueue::new();
        assert!(q.peek_time().is_none());
        q.push_at(SimTime::new(4.0), EventKind::Arrive { device: 0 });
        q.push_at(SimTime::new(2.0), EventKind::Arrive { device: 1 });
        assert_eq!(q.peek_time(), Some(SimTime::new(2.0)));
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, SimTime::new(2.0));
    }

    #[test]
    #[should_panic(expected = "cannot schedule into the past")]
    fn rejects_past_events() {
        let mut q = EventQueue::new();
        q.push_at(SimTime::new(2.0), EventKind::Arrive { device: 0 });
        q.pop();
        q.push_at(SimTime::new(1.0), EventKind::Arrive { device: 1 });
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn rejects_nan_time() {
        SimTime::new(f64::NAN);
    }

    #[test]
    fn simtime_orders_like_f64() {
        let xs = [0.0, 1e-300, 0.5, 1.0, 1e9];
        for (i, &a) in xs.iter().enumerate() {
            for &b in &xs[i + 1..] {
                assert!(SimTime::new(a) < SimTime::new(b), "{a} !< {b}");
            }
        }
    }
}
