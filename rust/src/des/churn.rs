//! Poisson device churn for the DES engine (DESIGN.md §11).
//!
//! Every device alternates *present* and *away* periods with
//! exponential durations: present ~ Exp(`depart_rate_hz`), away ~
//! Exp(`arrive_rate_hz`).  Each device draws from its own counter-based
//! SplitMix64 stream (`stream_seed(root, [CHURN_TAG, device])`) and the
//! draws are consumed in device-local order only, so the realized trace
//! is a pure function of `(seed, scenario, device)` — event
//! interleaving, policies, and thread counts can never perturb it.

use crate::config::ChurnSpec;
use crate::util::rng::{Rng, SplitMix64};

/// Stream-tag namespace for churn draws — distinct from the
/// `(round, device)` cell tags used by the round engine.
const CHURN_TAG: u64 = 0xC4_52_4E; // "ChRN"

/// Lazily drawn presence trace for one device.  Devices start present
/// at t = 0.
#[derive(Clone, Debug)]
pub struct ChurnTrace {
    rng: Rng,
    depart_rate_hz: f64,
    arrive_rate_hz: f64,
}

impl ChurnTrace {
    pub fn new(root: u64, device: usize, spec: &ChurnSpec) -> ChurnTrace {
        ChurnTrace {
            rng: Rng::new(SplitMix64::stream_seed(root, &[CHURN_TAG, device as u64])),
            depart_rate_hz: spec.depart_rate_hz,
            arrive_rate_hz: spec.arrive_rate_hz,
        }
    }

    /// Does this trace ever generate a departure?
    pub fn churns(&self) -> bool {
        self.depart_rate_hz > 0.0
    }

    /// Duration of the next *present* period [s]; `None` when the
    /// device never departs (rate 0).
    pub fn next_present_s(&mut self) -> Option<f64> {
        if self.depart_rate_hz > 0.0 {
            Some(self.rng.exp(self.depart_rate_hz))
        } else {
            None
        }
    }

    /// Duration of the next *away* period [s]; `None` when the device
    /// never returns (rate 0 — a permanent departure).
    pub fn next_away_s(&mut self) -> Option<f64> {
        if self.arrive_rate_hz > 0.0 {
            Some(self.rng.exp(self.arrive_rate_hz))
        } else {
            None
        }
    }

    /// Generator state for checkpoint serialization (rates come back
    /// from the resuming run's config).
    pub fn rng_state(&self) -> ([u64; 4], Option<f64>) {
        self.rng.state()
    }

    /// Replace the generator state — the checkpoint/resume inverse of
    /// [`ChurnTrace::rng_state`]; the trace continues bit-identically.
    pub fn restore_rng(&mut self, s: [u64; 4], gauss_spare: Option<f64>) {
        self.rng = Rng::from_state(s, gauss_spare);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(depart: f64, arrive: f64) -> ChurnSpec {
        ChurnSpec {
            depart_rate_hz: depart,
            arrive_rate_hz: arrive,
        }
    }

    #[test]
    fn zero_rates_mean_no_churn() {
        let mut t = ChurnTrace::new(7, 0, &spec(0.0, 0.0));
        assert!(!t.churns());
        assert_eq!(t.next_present_s(), None);
        assert_eq!(t.next_away_s(), None);
    }

    #[test]
    fn traces_are_deterministic_per_device() {
        let draw = |device: usize| {
            let mut t = ChurnTrace::new(42, device, &spec(0.01, 0.1));
            (0..8).map(|_| t.next_present_s().unwrap()).collect::<Vec<f64>>()
        };
        assert_eq!(draw(3), draw(3));
        assert_ne!(draw(3), draw(4), "devices must get distinct streams");
    }

    #[test]
    fn exponential_means_roughly_match_rates() {
        let mut t = ChurnTrace::new(1, 0, &spec(0.5, 2.0));
        let n = 20_000;
        let up: f64 = (0..n).map(|_| t.next_present_s().unwrap()).sum::<f64>() / n as f64;
        let away: f64 = (0..n).map(|_| t.next_away_s().unwrap()).sum::<f64>() / n as f64;
        assert!((up - 2.0).abs() < 0.1, "mean uptime {up} != 1/0.5");
        assert!((away - 0.5).abs() < 0.05, "mean away {away} != 1/2.0");
    }

    #[test]
    fn permanent_departure_when_arrival_rate_zero() {
        let mut t = ChurnTrace::new(9, 2, &spec(1.0, 0.0));
        assert!(t.churns());
        assert!(t.next_present_s().is_some());
        assert_eq!(t.next_away_s(), None);
    }
}
