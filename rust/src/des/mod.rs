//! Discrete-event fleet engine (DESIGN.md §11 and §15): virtual clock,
//! deterministic event queue, per-cell server compute queues, Poisson
//! device churn, and sync / semi-sync / async aggregation policies —
//! the subsystem that replaces the implicit round barrier with
//! explicit timed events and makes the edge servers contended
//! resources.  With `[cells] count > 1` jobs route to the serving
//! cell's queue and merges climb a star-to-cloud aggregation topology.

pub mod cellsweep;
pub mod churn;
pub mod engine;
pub mod event;
pub mod server;
pub mod sweep;

pub use cellsweep::{CellPoint, CellSweep};
pub use churn::ChurnTrace;
pub use engine::{CellStats, DesConfig, DesEngine, DesOutcome, DesRecord, Policy};
pub use event::{EventKind, EventQueue, SimTime};
pub use server::{ServerQueue, ServerStats};
pub use sweep::{sweep, DesPoint, DesSweep};
