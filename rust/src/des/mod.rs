//! Discrete-event fleet engine (DESIGN.md §11): virtual clock,
//! deterministic event queue, server compute queue, Poisson device
//! churn, and sync / semi-sync / async aggregation policies — the
//! subsystem that replaces the implicit round barrier with explicit
//! timed events and makes the shared edge server a contended resource.

pub mod churn;
pub mod engine;
pub mod event;
pub mod server;
pub mod sweep;

pub use churn::ChurnTrace;
pub use engine::{DesConfig, DesEngine, DesOutcome, DesRecord, Policy};
pub use event::{EventKind, EventQueue, SimTime};
pub use server::{ServerQueue, ServerStats};
pub use sweep::{sweep, DesPoint, DesSweep};
