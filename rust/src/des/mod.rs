//! Discrete-event fleet engine (DESIGN.md §11 and §15): virtual clock,
//! deterministic event queue, per-cell server compute queues, Poisson
//! device churn, and sync / semi-sync / async aggregation policies —
//! the subsystem that replaces the implicit round barrier with
//! explicit timed events and makes the edge servers contended
//! resources.  With `[cells] count > 1` jobs route to the serving
//! cell's queue and merges climb a star-to-cloud aggregation topology.
//! The `[faults]` plane (DESIGN.md §17) injects link outages, server
//! slot failures, and correlated bursts on the same timeline, with
//! bounded-retry recovery and checkpoint/resume.

pub mod cellsweep;
pub mod chaossweep;
pub mod churn;
pub mod engine;
pub mod event;
pub mod faults;
pub mod server;
pub mod sweep;

pub use cellsweep::{CellPoint, CellSweep};
pub use chaossweep::{chaos_sweep, ChaosPoint, ChaosSweep};
pub use churn::ChurnTrace;
pub use engine::{
    CellStats, DesConfig, DesEngine, DesOutcome, DesRecord, Policy, RunState, SimSnapshot,
};
pub use event::{EventKind, EventQueue, SimTime};
pub use faults::{Dir, FaultProcess, Outage};
pub use server::{ServerQueue, ServerStats};
pub use sweep::{sweep, DesPoint, DesSweep};
