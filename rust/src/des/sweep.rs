//! `des-sweep`: policy × scenario × device-count grid on the
//! discrete-event fleet engine, emitting per-point makespan percentiles,
//! server utilization/queue depth, and energy into `BENCH_des.json`
//! for CI perf-trajectory tracking (EXPERIMENTS.md).
//!
//! Grid points are independent [`crate::exp::ExperimentBuilder`]-built
//! DES experiments (each strictly serial and deterministic), so the
//! sweep fans them out on the worker pool — thread count changes
//! wall-clock only, never a reported metric.  Per-cell latency samples
//! and merged energy stream through an `exp::DesSink`.  The sweep runs
//! the shared determinism gate
//! ([`crate::exp::verify::verify_des_sync_matches_round_engine`]) at
//! the largest fleet of every scenario: churn-free sync DES must
//! reproduce the serial round engine bit for bit.

use crate::config::scenario::Scenario;
use crate::coordinator::RoundRecord;
use crate::exp::{self, DesSink, ExperimentBuilder, MetricsSink, Report, ReportMeta};
use crate::sim::metrics::Percentiles;
use crate::util::benchkit::Bencher;
use crate::util::json::{self, Json};
use crate::util::pool;
use crate::util::table::{fmt_joules, fmt_secs, Table};

use super::engine::{DesConfig, DesRecord, Policy};

/// One (scenario, policy, fleet size) DES measurement.
#[derive(Clone, Debug)]
pub struct DesPoint {
    pub scenario: String,
    pub policy: String,
    pub n_devices: usize,
    pub rounds: usize,
    pub capacity: usize,
    pub batch: usize,
    pub wall_s: f64,
    pub makespan_s: f64,
    /// completed device-round merges
    pub completed: usize,
    pub dropped: u64,
    pub departures: u64,
    pub arrivals: u64,
    /// observed per-cell latency percentiles (0 when nothing completed)
    pub round_latency: Percentiles,
    pub mean_wait_s: f64,
    pub server_utilization: f64,
    pub peak_queue_depth: usize,
    pub mean_queue_depth: f64,
    /// Eq.-11 server energy booked at dispatch — includes work wasted
    /// on dropped stragglers, so policy comparisons see the real bill
    pub energy_j: f64,
    /// energy of merged rounds only (excludes wasted work)
    pub energy_merged_j: f64,
    pub peak_staleness: usize,
}

/// Full DES sweep result.
#[derive(Clone, Debug)]
pub struct DesSweep {
    pub points: Vec<DesPoint>,
    pub threads: usize,
    pub seed: u64,
}

/// Run the grid.  `rounds` overrides each preset's round count;
/// `capacity`/`batch` parameterize the server queue for every point.
#[allow(clippy::too_many_arguments)]
pub fn sweep(
    scenarios: &[Scenario],
    counts: &[usize],
    policies: &[Policy],
    rounds: Option<usize>,
    capacity: usize,
    batch: usize,
    threads: usize,
    seed: u64,
    bench: &mut Bencher,
) -> anyhow::Result<DesSweep> {
    anyhow::ensure!(!scenarios.is_empty(), "no scenarios selected");
    anyhow::ensure!(!counts.is_empty(), "no device counts selected");
    anyhow::ensure!(!policies.is_empty(), "no policies selected");
    anyhow::ensure!(capacity >= 1, "server capacity must be >= 1");
    anyhow::ensure!(batch >= 1, "server batch must be >= 1");
    for &n in counts {
        anyhow::ensure!(n > 0, "device count must be >= 1");
    }
    for p in policies {
        if let Policy::SemiSync { deadline_factor } = *p {
            anyhow::ensure!(
                deadline_factor > 0.0 && deadline_factor.is_finite(),
                "semi-sync deadline factor must be finite and > 0"
            );
        }
    }

    // a (sync, largest-fleet) grid point doubles as its scenario's
    // determinism-gate run when the preset is churn-free — its records
    // are collected so the gate never re-runs the simulation
    let gate_n = *counts.iter().max().unwrap();
    let mut grid: Vec<(Scenario, usize, Policy, bool)> = Vec::new();
    for sc in scenarios {
        for &n in counts {
            for &p in policies {
                let gate = n == gate_n && matches!(p, Policy::Sync);
                grid.push((*sc, n, p, gate));
            }
        }
    }

    let results: Vec<anyhow::Result<(DesPoint, Option<Vec<RoundRecord>>)>> =
        pool::par_map_indexed(threads, &grid, |_, &(sc, n, policy, gate)| {
            run_point(sc, n, policy, rounds, capacity, batch, seed, gate)
        });
    let mut points = Vec::with_capacity(results.len());
    let mut gate_records = Vec::with_capacity(results.len());
    for r in results {
        let (point, records) = r?;
        points.push(point);
        gate_records.push(records);
    }
    for p in &points {
        let rate = p.completed as f64 / p.wall_s.max(1e-9);
        bench.record_once(
            &format!("{}_{}_n{}", p.scenario, p.policy, p.n_devices),
            p.wall_s,
            Some((rate, "device-round")),
        );
    }

    // shared determinism gate at each scenario's largest fleet: the
    // churn-free sync-policy DES timeline must reproduce the serial
    // round engine's records bit for bit.  Reuse the gate point's own
    // records when the sweep produced them; otherwise (no sync policy
    // selected, or a churny preset) run the dedicated churn-free check.
    for sc in scenarios {
        let mut cfg = sc.config(gate_n, seed)?;
        if let Some(r) = rounds {
            cfg.workload.rounds = r;
        }
        let reused = grid
            .iter()
            .zip(&gate_records)
            .find_map(|((gsc, _, _, _), records)| {
                (gsc.name == sc.name).then_some(records.as_ref()).flatten()
            });
        match reused {
            Some(records) => {
                exp::verify::verify_des_records_match_round_engine(&cfg, sc.state, records)?
            }
            None => {
                exp::verify::verify_des_sync_matches_round_engine(&cfg, sc.state, capacity, batch)?
            }
        }
    }

    Ok(DesSweep {
        points,
        threads,
        seed,
    })
}

/// Sink for gated grid points: the standard [`DesSink`] observables
/// plus (when `collect` is set) the analytic records the determinism
/// gate verifies, so the gate never re-runs the simulation.
struct GateSink {
    des: DesSink,
    collect: bool,
    records: Vec<RoundRecord>,
}

impl MetricsSink for GateSink {
    fn on_record(&mut self, _rec: &RoundRecord) {}

    fn on_des_record(&mut self, rec: &DesRecord) {
        self.des.on_des_record(rec);
        if self.collect {
            self.records.push(rec.record.clone());
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn run_point(
    sc: Scenario,
    n: usize,
    policy: Policy,
    rounds: Option<usize>,
    capacity: usize,
    batch: usize,
    seed: u64,
    gate: bool,
) -> anyhow::Result<(DesPoint, Option<Vec<RoundRecord>>)> {
    let mut builder = ExperimentBuilder::preset(sc.name)
        .devices(n)
        .seed(seed)
        .des(DesConfig {
            policy,
            capacity,
            batch,
        });
    if let Some(r) = rounds {
        builder = builder.rounds(r);
    }
    let experiment = builder.build()?;
    let n_rounds = experiment.config().workload.rounds;
    // the gate contract is churn-free sync-vs-round-engine bit
    // identity, so a churny preset's records cannot serve as the gate
    let collect = gate && !experiment.config().churn.enabled();

    let mut sink = GateSink {
        des: DesSink::default(),
        collect,
        records: Vec::new(),
    };
    let t0 = std::time::Instant::now();
    let outcome = experiment.run_into(&mut sink)?;
    let wall = t0.elapsed().as_secs_f64();
    let des = outcome
        .des
        .ok_or_else(|| anyhow::anyhow!("event engine must report DES stats"))?;

    let round_latency = if sink.des.latencies.is_empty() {
        Percentiles::default()
    } else {
        Percentiles::of(sink.des.latencies.as_slice())
    };
    let point = DesPoint {
        scenario: sc.name.to_string(),
        policy: policy.name().to_string(),
        n_devices: n,
        rounds: n_rounds,
        capacity,
        batch,
        wall_s: wall,
        makespan_s: des.makespan_s,
        completed: outcome.cells,
        dropped: des.dropped,
        departures: des.departures,
        arrivals: des.arrivals,
        round_latency,
        mean_wait_s: des.server.mean_wait_s,
        server_utilization: des.server.utilization,
        peak_queue_depth: des.server.peak_depth,
        mean_queue_depth: des.server.mean_depth,
        energy_j: des.energy_spent_j,
        energy_merged_j: sink.des.energy_merged_j,
        peak_staleness: des.peak_staleness,
    };
    Ok((point, collect.then_some(sink.records)))
}

impl DesSweep {
    /// ASCII summary table (scenario × fleet size × policy).
    pub fn render(&self) -> String {
        let mut t = Table::new(
            &format!(
                "des-sweep — discrete-event fleet engine ({} workers, seed {})",
                self.threads, self.seed
            ),
            &[
                "scenario",
                "policy",
                "devices",
                "merged",
                "dropped",
                "makespan",
                "p50 rtt",
                "p95 rtt",
                "p99 rtt",
                "p99.9 rtt",
                "util",
                "peak q",
                "energy",
            ],
        );
        for p in &self.points {
            t.row(vec![
                p.scenario.clone(),
                p.policy.clone(),
                p.n_devices.to_string(),
                p.completed.to_string(),
                p.dropped.to_string(),
                fmt_secs(p.makespan_s),
                fmt_secs(p.round_latency.p50),
                fmt_secs(p.round_latency.p95),
                fmt_secs(p.round_latency.p99),
                fmt_secs(p.round_latency.p999),
                format!("{:.0}%", 100.0 * p.server_utilization),
                p.peak_queue_depth.to_string(),
                fmt_joules(p.energy_j),
            ]);
        }
        t.render()
    }

    /// Emitter payload (the `data` member of the report envelope).
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("schema", Json::Str("edgesplit/des-sweep/v1".into())),
            // string, not number: u64 seeds above 2^53 would lose
            // precision through the f64-backed Json::Num
            ("seed", Json::Str(self.seed.to_string())),
            ("threads", Json::Num(self.threads as f64)),
            (
                "points",
                Json::Arr(self.points.iter().map(point_json).collect()),
            ),
        ])
    }

    /// The enveloped report (`BENCH_des.json`): shared
    /// `schema_version`/`meta` wrapper around [`DesSweep::to_json`].
    pub fn report(&self, scenario_sel: &str, rounds: Option<usize>) -> Report {
        Report::new(
            ReportMeta {
                kind: "des-sweep",
                preset: scenario_sel.to_string(),
                seed: self.seed,
                threads: self.threads,
                rounds,
            },
            self.to_json(),
            self.render(),
        )
    }
}

fn point_json(p: &DesPoint) -> Json {
    json::obj(vec![
        ("scenario", Json::Str(p.scenario.clone())),
        ("policy", Json::Str(p.policy.clone())),
        ("n_devices", Json::Num(p.n_devices as f64)),
        ("rounds", Json::Num(p.rounds as f64)),
        ("capacity", Json::Num(p.capacity as f64)),
        ("batch", Json::Num(p.batch as f64)),
        ("wall_s", Json::Num(p.wall_s)),
        ("makespan_s", Json::Num(p.makespan_s)),
        ("completed", Json::Num(p.completed as f64)),
        ("dropped", Json::Num(p.dropped as f64)),
        ("departures", Json::Num(p.departures as f64)),
        ("arrivals", Json::Num(p.arrivals as f64)),
        ("p50_round_s", Json::Num(p.round_latency.p50)),
        ("p95_round_s", Json::Num(p.round_latency.p95)),
        ("p99_round_s", Json::Num(p.round_latency.p99)),
        ("p999_round_s", Json::Num(p.round_latency.p999)),
        ("mean_wait_s", Json::Num(p.mean_wait_s)),
        ("server_utilization", Json::Num(p.server_utilization)),
        ("peak_queue_depth", Json::Num(p.peak_queue_depth as f64)),
        ("mean_queue_depth", Json::Num(p.mean_queue_depth)),
        ("energy_j", Json::Num(p.energy_j)),
        ("energy_merged_j", Json::Num(p.energy_merged_j)),
        ("peak_staleness", Json::Num(p.peak_staleness as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::scenario;

    const ALL_POLICIES: [Policy; 3] = [
        Policy::Sync,
        Policy::SemiSync {
            deadline_factor: 1.5,
        },
        Policy::Async,
    ];

    #[test]
    fn small_grid_produces_points_and_json() {
        let mut bench = Bencher::new("des-sweep-test");
        let sweep = sweep(
            &[scenario::DENSE_URBAN],
            &[6],
            &ALL_POLICIES,
            Some(2),
            2,
            1,
            4,
            7,
            &mut bench,
        )
        .unwrap();
        assert_eq!(sweep.points.len(), 3);
        assert_eq!(bench.results().len(), 3);
        for p in &sweep.points {
            assert!(p.makespan_s > 0.0 && p.makespan_s.is_finite(), "{}", p.policy);
            assert!(p.server_utilization > 0.0 && p.server_utilization <= 1.0 + 1e-9);
            assert!(p.completed > 0, "{}", p.policy);
        }
        let js = sweep.to_json().to_string();
        assert!(js.contains("des-sweep/v1"));
        assert!(js.contains("\"policy\":\"async\""));
        assert!(js.contains("server_utilization"));
        assert!(js.contains("p999_round_s"));
        assert!(Json::parse(&js).is_ok());
    }

    #[test]
    fn report_wraps_payload_in_versioned_envelope() {
        let mut bench = Bencher::new("des-envelope");
        let sweep = sweep(
            &[scenario::DENSE_URBAN],
            &[4],
            &[Policy::Sync],
            Some(1),
            2,
            1,
            2,
            3,
            &mut bench,
        )
        .unwrap();
        let j = sweep.report("all", Some(1)).to_json();
        assert_eq!(j.get("schema_version").and_then(Json::as_usize), Some(1));
        assert_eq!(j.at(&["meta", "preset"]).and_then(Json::as_str), Some("all"));
        assert!(j.at(&["data", "points"]).is_some());
    }

    #[test]
    fn sweep_is_deterministic_across_thread_counts() {
        let run = |threads: usize| {
            let mut bench = Bencher::new("det");
            sweep(
                &[scenario::HETEROGENEOUS_FLEET],
                &[8],
                &ALL_POLICIES,
                Some(2),
                2,
                1,
                threads,
                11,
                &mut bench,
            )
            .unwrap()
        };
        let a = run(1);
        let b = run(8);
        assert_eq!(a.points.len(), b.points.len());
        for (x, y) in a.points.iter().zip(&b.points) {
            assert_eq!(x.policy, y.policy);
            assert_eq!(x.makespan_s.to_bits(), y.makespan_s.to_bits(), "{}", x.policy);
            assert_eq!(x.completed, y.completed);
            assert_eq!(x.dropped, y.dropped);
            assert_eq!(
                x.server_utilization.to_bits(),
                y.server_utilization.to_bits(),
                "{}",
                x.policy
            );
            assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits());
        }
    }

    #[test]
    fn rejects_degenerate_input() {
        let mut bench = Bencher::new("bad");
        let sc = [scenario::DENSE_URBAN];
        assert!(sweep(&[], &[4], &ALL_POLICIES, None, 1, 1, 1, 0, &mut bench).is_err());
        assert!(sweep(&sc, &[], &ALL_POLICIES, None, 1, 1, 1, 0, &mut bench).is_err());
        assert!(sweep(&sc, &[4], &[], None, 1, 1, 1, 0, &mut bench).is_err());
        assert!(sweep(&sc, &[0], &ALL_POLICIES, None, 1, 1, 1, 0, &mut bench).is_err());
        assert!(sweep(&sc, &[4], &ALL_POLICIES, None, 0, 1, 1, 0, &mut bench).is_err());
        let bad_deadline = [Policy::SemiSync {
            deadline_factor: 0.0,
        }];
        assert!(sweep(&sc, &[4], &bad_deadline, None, 1, 1, 1, 0, &mut bench).is_err());
    }

    #[test]
    fn render_lists_every_point() {
        let mut bench = Bencher::new("render");
        let sweep = sweep(
            &[scenario::SPARSE_RURAL],
            &[4],
            &[Policy::Sync, Policy::Async],
            Some(1),
            2,
            1,
            2,
            1,
            &mut bench,
        )
        .unwrap();
        let out = sweep.render();
        assert!(out.contains("sparse-rural"));
        assert!(out.contains("async"));
        assert!(out.contains("p95 rtt"));
        assert!(out.contains("p99.9 rtt"));
    }
}
