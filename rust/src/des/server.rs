//! The shared edge server as a contended resource: a FIFO compute
//! queue with `capacity` concurrent slots and optional job batching
//! (DESIGN.md §11).
//!
//! Each job runs at the frequency its Stage-1 decision chose, so the
//! instantaneous server power is the Eq.-11 cubic law summed over the
//! jobs in service, `P(t) = Σ_j ξ·f_j³`, and the integrated energy is
//! exactly the sum of the per-job analytic energies — concurrency
//! changes *when* energy is spent (and the peak power), never the
//! per-round totals, which is what keeps the `sync` policy
//! bit-compatible with the barrier engine.
//!
//! Batching fuses up to `batch` queued jobs into one slot dispatch;
//! the fused service time is the max over the batch (the slowest
//! kernel gates the fused execution).

use std::collections::VecDeque;

use crate::util::stats::Accum;

use super::event::SimTime;

/// One server-side FP/BP work item (a device-round's Stage-3/4 share).
/// Energy is not tracked here: the engine books each job's analytic
/// Eq.-11 energy at dispatch, which is exact per the module docs.
#[derive(Clone, Debug)]
pub struct Job {
    pub device: usize,
    pub round: usize,
    /// server compute time for the whole round (T epochs) [s]
    pub service_s: f64,
    pub enqueued_at: SimTime,
}

/// A fused dispatch: `jobs` run together on one slot for `service_s`.
#[derive(Clone, Debug)]
pub struct Batch {
    pub jobs: Vec<Job>,
    pub service_s: f64,
}

/// Aggregate queue/occupancy statistics for one DES run.
#[derive(Clone, Copy, Debug)]
pub struct ServerStats {
    pub served_jobs: u64,
    pub abandoned_jobs: u64,
    /// total slot-seconds spent serving
    pub busy_slot_s: f64,
    /// mean time jobs spent waiting in the queue [s]
    pub mean_wait_s: f64,
    pub peak_depth: usize,
    /// time-averaged queue depth
    pub mean_depth: f64,
    /// busy_slot_s / (capacity × makespan), in [0, 1]
    pub utilization: f64,
}

/// Serializable mutable state of a [`ServerQueue`] (checkpoint
/// envelope, DESIGN.md §17).  The `wait` accumulator travels as its
/// raw `(n, mean, m2, min, max)` Welford state.
#[derive(Clone, Debug)]
pub struct ServerQueueState {
    pub busy_slots: usize,
    pub waiting: Vec<Job>,
    pub busy_slot_s: f64,
    pub wait: (u64, f64, f64, f64, f64),
    pub served: u64,
    pub abandoned: u64,
    pub peak_depth: usize,
    pub depth_area: f64,
    pub depth_since_s: f64,
}

pub struct ServerQueue {
    capacity: usize,
    batch: usize,
    busy_slots: usize,
    waiting: VecDeque<Job>,
    // stats
    busy_slot_s: f64,
    wait: Accum,
    served: u64,
    abandoned: u64,
    peak_depth: usize,
    depth_area: f64,
    depth_since_s: f64,
}

impl ServerQueue {
    /// `capacity` = concurrent jobs the server can run; `batch` = max
    /// jobs fused per slot dispatch.  Both are clamped to >= 1.
    pub fn new(capacity: usize, batch: usize) -> ServerQueue {
        ServerQueue {
            capacity: capacity.max(1),
            batch: batch.max(1),
            busy_slots: 0,
            waiting: VecDeque::new(),
            busy_slot_s: 0.0,
            wait: Accum::new(),
            served: 0,
            abandoned: 0,
            peak_depth: 0,
            depth_area: 0.0,
            depth_since_s: 0.0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    fn note_depth(&mut self, now: SimTime) {
        let t = now.secs();
        self.depth_area += self.waiting.len() as f64 * (t - self.depth_since_s);
        self.depth_since_s = t;
    }

    /// Add a job to the queue and dispatch as far as capacity allows.
    /// `alive(device, round)` filters out cells cancelled (churn,
    /// straggler dropout) while the job sat in the queue.
    pub fn enqueue(
        &mut self,
        job: Job,
        now: SimTime,
        alive: impl Fn(usize, usize) -> bool,
    ) -> Vec<Batch> {
        self.note_depth(now);
        self.waiting.push_back(job);
        self.peak_depth = self.peak_depth.max(self.waiting.len());
        self.dispatch(now, alive)
    }

    /// A slot finished its batch: free it and refill from the queue.
    pub fn on_batch_done(
        &mut self,
        now: SimTime,
        alive: impl Fn(usize, usize) -> bool,
    ) -> Vec<Batch> {
        assert!(self.busy_slots > 0, "batch completion with no busy slot");
        self.busy_slots -= 1;
        self.dispatch(now, alive)
    }

    fn dispatch(&mut self, now: SimTime, alive: impl Fn(usize, usize) -> bool) -> Vec<Batch> {
        self.note_depth(now);
        let mut out = Vec::new();
        while self.busy_slots < self.capacity {
            let mut jobs: Vec<Job> = Vec::new();
            while jobs.len() < self.batch {
                match self.waiting.pop_front() {
                    Some(j) if alive(j.device, j.round) => jobs.push(j),
                    Some(_) => self.abandoned += 1,
                    None => break,
                }
            }
            if jobs.is_empty() {
                break;
            }
            let service_s = jobs.iter().fold(0.0f64, |m, j| m.max(j.service_s));
            for j in &jobs {
                self.wait.push(now.secs() - j.enqueued_at.secs());
            }
            self.served += jobs.len() as u64;
            self.busy_slots += 1;
            self.busy_slot_s += service_s;
            out.push(Batch { jobs, service_s });
        }
        self.note_depth(now);
        out
    }

    /// Purge cancelled jobs still sitting in the queue — no slot will
    /// ever pop them once the simulation has ended, so leaving them in
    /// would overstate `mean_depth` and undercount `abandoned_jobs`.
    pub fn flush_cancelled(&mut self, now: SimTime, alive: impl Fn(usize, usize) -> bool) {
        self.note_depth(now);
        let before = self.waiting.len();
        self.waiting.retain(|j| alive(j.device, j.round));
        self.abandoned += (before - self.waiting.len()) as u64;
    }

    /// Book extra slot-busy seconds outside normal service — the
    /// repair downtime of a failed capacity slot (DESIGN.md §17), which
    /// occupies the slot exactly like service does.
    pub fn add_busy_s(&mut self, dt: f64) {
        debug_assert!(dt.is_finite() && dt >= 0.0);
        self.busy_slot_s += dt;
    }

    /// Checkpoint view of the full mutable state.  `capacity`/`batch`
    /// are config-derived and not included; [`ServerQueue::restore`]
    /// takes them from the resuming run's `DesConfig`.
    pub fn snapshot(&self) -> ServerQueueState {
        ServerQueueState {
            busy_slots: self.busy_slots,
            waiting: self.waiting.iter().cloned().collect(),
            busy_slot_s: self.busy_slot_s,
            wait: self.wait.state(),
            served: self.served,
            abandoned: self.abandoned,
            peak_depth: self.peak_depth,
            depth_area: self.depth_area,
            depth_since_s: self.depth_since_s,
        }
    }

    /// Inverse of [`ServerQueue::snapshot`].
    pub fn restore(capacity: usize, batch: usize, st: ServerQueueState) -> ServerQueue {
        let (n, mean, m2, min, max) = st.wait;
        ServerQueue {
            capacity: capacity.max(1),
            batch: batch.max(1),
            busy_slots: st.busy_slots,
            waiting: st.waiting.into(),
            busy_slot_s: st.busy_slot_s,
            wait: Accum::from_state(n, mean, m2, min, max),
            served: st.served,
            abandoned: st.abandoned,
            peak_depth: st.peak_depth,
            depth_area: st.depth_area,
            depth_since_s: st.depth_since_s,
        }
    }

    /// Snapshot the run statistics given the realized makespan.
    pub fn stats(&self, makespan_s: f64) -> ServerStats {
        let span = makespan_s.max(f64::MIN_POSITIVE);
        let tail = self.waiting.len() as f64 * (makespan_s - self.depth_since_s).max(0.0);
        ServerStats {
            served_jobs: self.served,
            abandoned_jobs: self.abandoned,
            busy_slot_s: self.busy_slot_s,
            mean_wait_s: if self.wait.count() == 0 { 0.0 } else { self.wait.mean() },
            peak_depth: self.peak_depth,
            mean_depth: (self.depth_area + tail) / span,
            // clamp: a straggler batch still in service when the
            // simulation terminates can push the raw ratio a hair
            // past 1 (its full service was booked at dispatch)
            utilization: (self.busy_slot_s / (self.capacity as f64 * span)).min(1.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(device: usize, service_s: f64, at: f64) -> Job {
        Job {
            device,
            round: 0,
            service_s,
            enqueued_at: SimTime::new(at),
        }
    }

    const ALIVE: fn(usize, usize) -> bool = |_, _| true;

    #[test]
    fn capacity_limits_concurrency() {
        let mut q = ServerQueue::new(2, 1);
        let t0 = SimTime::ZERO;
        let b1 = q.enqueue(job(0, 1.0, 0.0), t0, ALIVE);
        let b2 = q.enqueue(job(1, 1.0, 0.0), t0, ALIVE);
        let b3 = q.enqueue(job(2, 1.0, 0.0), t0, ALIVE);
        assert_eq!(b1.len() + b2.len(), 2, "two slots dispatch immediately");
        assert!(b3.is_empty(), "third job must wait");
        // a completion frees the slot for the queued job
        let refill = q.on_batch_done(SimTime::new(1.0), ALIVE);
        assert_eq!(refill.len(), 1);
        assert_eq!(refill[0].jobs[0].device, 2);
        let s = q.stats(2.0);
        assert_eq!(s.served_jobs, 3);
        assert_eq!(s.peak_depth, 1);
    }

    #[test]
    fn batching_fuses_jobs_and_takes_max_service() {
        let mut q = ServerQueue::new(1, 4);
        let t0 = SimTime::ZERO;
        // first job grabs the only slot solo
        let b = q.enqueue(job(0, 1.0, 0.0), t0, ALIVE);
        assert_eq!(b[0].jobs.len(), 1);
        // three more queue up behind it
        for d in 1..4 {
            assert!(q.enqueue(job(d, d as f64, 0.0), t0, ALIVE).is_empty());
        }
        let refill = q.on_batch_done(SimTime::new(1.0), ALIVE);
        assert_eq!(refill.len(), 1);
        assert_eq!(refill[0].jobs.len(), 3, "queued jobs fuse into one batch");
        assert_eq!(refill[0].service_s, 3.0, "slowest job gates the batch");
    }

    #[test]
    fn cancelled_jobs_are_skipped_at_dispatch() {
        let mut q = ServerQueue::new(1, 1);
        let t0 = SimTime::ZERO;
        q.enqueue(job(0, 1.0, 0.0), t0, ALIVE);
        q.enqueue(job(1, 1.0, 0.0), t0, ALIVE);
        q.enqueue(job(2, 1.0, 0.0), t0, ALIVE);
        // device 1 departs while queued
        let refill = q.on_batch_done(SimTime::new(1.0), |d, _| d != 1);
        assert_eq!(refill[0].jobs[0].device, 2);
        assert_eq!(q.stats(2.0).abandoned_jobs, 1);
    }

    #[test]
    fn utilization_and_wait_accounting() {
        let mut q = ServerQueue::new(1, 1);
        q.enqueue(job(0, 2.0, 0.0), SimTime::ZERO, ALIVE);
        q.enqueue(job(1, 2.0, 0.0), SimTime::ZERO, ALIVE);
        q.on_batch_done(SimTime::new(2.0), ALIVE);
        q.on_batch_done(SimTime::new(4.0), ALIVE);
        let s = q.stats(4.0);
        assert!((s.utilization - 1.0).abs() < 1e-12, "{}", s.utilization);
        assert!((s.mean_wait_s - 1.0).abs() < 1e-12, "{}", s.mean_wait_s);
        assert!(s.mean_depth > 0.0 && s.mean_depth < 1.0);
    }

    #[test]
    fn flush_purges_dead_waiters_from_depth_stats() {
        let mut q = ServerQueue::new(1, 1);
        q.enqueue(job(0, 1.0, 0.0), SimTime::ZERO, ALIVE);
        q.enqueue(job(1, 1.0, 0.0), SimTime::ZERO, ALIVE);
        q.enqueue(job(2, 1.0, 0.0), SimTime::ZERO, ALIVE);
        // devices 1 and 2 cancelled; the run ends at t = 1
        q.flush_cancelled(SimTime::new(1.0), |d, _| d == 0);
        let s = q.stats(1.0);
        assert_eq!(s.abandoned_jobs, 2);
        // no phantom waiters charged past the flush point
        assert!((s.mean_depth - 2.0).abs() < 1e-12, "{}", s.mean_depth);
    }

    #[test]
    fn snapshot_restore_round_trips_mid_service() {
        let mut q = ServerQueue::new(1, 1);
        q.enqueue(job(0, 2.0, 0.0), SimTime::ZERO, ALIVE);
        q.enqueue(job(1, 2.0, 0.0), SimTime::ZERO, ALIVE);
        // one job in service, one waiting — checkpoint here
        let mut r = ServerQueue::restore(1, 1, q.snapshot());
        let a = q.on_batch_done(SimTime::new(2.0), ALIVE);
        let b = r.on_batch_done(SimTime::new(2.0), ALIVE);
        assert_eq!(a.len(), b.len());
        assert_eq!(a[0].jobs[0].device, b[0].jobs[0].device);
        q.on_batch_done(SimTime::new(4.0), ALIVE);
        r.on_batch_done(SimTime::new(4.0), ALIVE);
        let (sa, sb) = (q.stats(4.0), r.stats(4.0));
        assert_eq!(sa.served_jobs, sb.served_jobs);
        assert_eq!(sa.busy_slot_s.to_bits(), sb.busy_slot_s.to_bits());
        assert_eq!(sa.mean_wait_s.to_bits(), sb.mean_wait_s.to_bits());
        assert_eq!(sa.mean_depth.to_bits(), sb.mean_depth.to_bits());
    }

    #[test]
    fn repair_downtime_counts_as_busy() {
        let mut q = ServerQueue::new(1, 1);
        q.enqueue(job(0, 1.0, 0.0), SimTime::ZERO, ALIVE);
        q.add_busy_s(1.0);
        q.on_batch_done(SimTime::new(2.0), ALIVE);
        let s = q.stats(2.0);
        assert!((s.utilization - 1.0).abs() < 1e-12, "{}", s.utilization);
    }

    #[test]
    fn degenerate_capacity_clamped() {
        let mut q = ServerQueue::new(0, 0);
        assert_eq!(q.capacity(), 1);
        let b = q.enqueue(job(0, 1.0, 0.0), SimTime::ZERO, ALIVE);
        assert_eq!(b.len(), 1);
    }
}
