//! `chaos-sweep`: scenario × fault-rate grid on the discrete-event
//! fleet engine with the `[faults]` plane armed (DESIGN.md §17),
//! emitting per-point retry/demotion/failover counts and the retry
//! energy overhead into `BENCH_faults.json` for CI robustness-trend
//! tracking (EXPERIMENTS.md).
//!
//! One knob drives all three injection planes: ladder value `r` sets
//! the link-outage rate to `r` Hz, the slot-failure probability to
//! `min(r, 0.95)`, and the burst rate to `r` per round, so a single
//! `--rates` axis sweeps the whole fault surface.  Every scenario runs
//! two variants per rate — `timeout-off` (stragglers ride the barrier)
//! and `timeout-on` (sync demotion at [`TIMEOUT_FACTOR`]× the nominal
//! round span) — and the `r = 0` point doubles as the fault-free
//! baseline the CI validator compares energy against.
//!
//! Before any faulted point is trusted, the sweep runs both §17 gates
//! per scenario: [`crate::exp::verify::verify_zero_fault_rate_is_noop`]
//! (a dormant `[faults]` table is bitwise invisible) and
//! [`crate::exp::verify::verify_checkpoint_resume_bit_identity`]
//! (freeze mid-storm, round-trip the envelope, resume, compare bit for
//! bit) — the latter doubling as the CI checkpoint/resume smoke.

use crate::config::scenario::Scenario;
use crate::config::FaultsSpec;
use crate::coordinator::RoundRecord;
use crate::exp::{self, DesSink, ExperimentBuilder, MetricsSink, Report, ReportMeta};
use crate::util::benchkit::Bencher;
use crate::util::json::{self, Json};
use crate::util::pool;
use crate::util::table::{fmt_joules, fmt_secs, Table};

use super::engine::{DesConfig, DesRecord, Policy};

/// Sync-demotion deadline factor used by the `timeout-on` variant.
pub const TIMEOUT_FACTOR: f64 = 1.5;

/// One (scenario, fault rate, timeout variant) chaos measurement.
#[derive(Clone, Debug)]
pub struct ChaosPoint {
    pub scenario: String,
    /// ladder value: link-outage rate [1/s]; the slot-failure
    /// probability and burst rate derive from it (module docs)
    pub rate_hz: f64,
    pub slot_fail_prob: f64,
    pub burst_rate_per_round: f64,
    /// 0 = timeout-off variant, [`TIMEOUT_FACTOR`] = timeout-on
    pub timeout_factor: f64,
    pub n_devices: usize,
    pub rounds: usize,
    pub capacity: usize,
    pub batch: usize,
    pub wall_s: f64,
    pub makespan_s: f64,
    /// completed device-round merges
    pub completed: usize,
    pub dropped: u64,
    /// merged cells that ran the degraded device-heavy cut
    pub degraded: u64,
    pub retries: u64,
    pub timeout_demotions: u64,
    pub failovers: u64,
    pub slot_failures: u64,
    pub slot_repairs: u64,
    /// Eq.-11 server energy booked at dispatch [J]
    pub energy_j: f64,
    /// energy wasted in interrupted partial transfers [J] — the
    /// robustness bill, on top of `energy_j`
    pub retry_energy_j: f64,
}

/// Full chaos sweep result.
#[derive(Clone, Debug)]
pub struct ChaosSweep {
    pub points: Vec<ChaosPoint>,
    pub threads: usize,
    pub seed: u64,
}

/// Ladder value → full `[faults]` table (module docs).
fn spec_for(rate: f64, timeout_factor: f64) -> FaultsSpec {
    FaultsSpec {
        link_outage_rate_hz: rate,
        slot_fail_prob: rate.min(0.95),
        burst_rate_per_round: rate,
        timeout_factor,
        ..Default::default()
    }
}

/// Run the grid.  `rates` is the fault-rate ladder (a `0` entry gives
/// the fault-free baseline); `rounds` overrides each preset's round
/// count; `capacity`/`batch` parameterize the server queues.
#[allow(clippy::too_many_arguments)]
pub fn chaos_sweep(
    scenarios: &[Scenario],
    rates: &[f64],
    n_devices: usize,
    rounds: Option<usize>,
    capacity: usize,
    batch: usize,
    threads: usize,
    seed: u64,
    bench: &mut Bencher,
) -> anyhow::Result<ChaosSweep> {
    anyhow::ensure!(!scenarios.is_empty(), "no scenarios selected");
    anyhow::ensure!(!rates.is_empty(), "no fault rates selected");
    anyhow::ensure!(n_devices > 0, "device count must be >= 1");
    anyhow::ensure!(capacity >= 1, "server capacity must be >= 1");
    anyhow::ensure!(batch >= 1, "server batch must be >= 1");
    for &r in rates {
        anyhow::ensure!(
            r.is_finite() && r >= 0.0,
            "fault rate must be finite and >= 0, got {r}"
        );
    }

    let mut grid: Vec<(Scenario, f64, f64)> = Vec::new();
    for sc in scenarios {
        for &rate in rates {
            for tf in [0.0, TIMEOUT_FACTOR] {
                grid.push((*sc, rate, tf));
            }
        }
    }

    let results: Vec<anyhow::Result<ChaosPoint>> =
        pool::par_map_indexed(threads, &grid, |_, &(sc, rate, tf)| {
            run_point(sc, rate, tf, n_devices, rounds, capacity, batch, seed)
        });
    let mut points = Vec::with_capacity(results.len());
    for r in results {
        points.push(r?);
    }
    for p in &points {
        let rate = p.completed as f64 / p.wall_s.max(1e-9);
        bench.record_once(
            &format!(
                "{}_r{}_t{}",
                p.scenario,
                p.rate_hz,
                if p.timeout_factor > 0.0 { "on" } else { "off" }
            ),
            p.wall_s,
            Some((rate, "device-round")),
        );
    }

    // §17 gates, per scenario, at the ladder's harshest point: the
    // dormant plane must be bitwise invisible, and a checkpoint taken
    // mid-storm must resume to the uninterrupted run bit for bit
    let max_rate = rates.iter().cloned().fold(0.0_f64, f64::max);
    let des = DesConfig {
        policy: Policy::Sync,
        capacity,
        batch,
    };
    for sc in scenarios {
        let mut cfg = sc.config(n_devices, seed)?;
        if let Some(r) = rounds {
            cfg.workload.rounds = r;
        }
        cfg.faults = spec_for(max_rate, TIMEOUT_FACTOR);
        exp::verify::verify_zero_fault_rate_is_noop(&cfg, sc.state, des)?;
        // freeze halfway through this scenario's shortest observed
        // makespan — deterministic, and guaranteed mid-run
        let t_s = 0.5
            * points
                .iter()
                .filter(|p| p.scenario == sc.name)
                .map(|p| p.makespan_s)
                .fold(f64::INFINITY, f64::min);
        exp::verify::verify_checkpoint_resume_bit_identity(&cfg, sc.state, des, t_s)?;
    }

    Ok(ChaosSweep {
        points,
        threads,
        seed,
    })
}

/// [`DesSink`] plus a degraded-cut tally (not in the run-level stats).
struct ChaosSink {
    des: DesSink,
    degraded: u64,
}

impl MetricsSink for ChaosSink {
    fn on_record(&mut self, _rec: &RoundRecord) {}

    fn on_des_record(&mut self, rec: &DesRecord) {
        self.des.on_des_record(rec);
        if rec.degraded {
            self.degraded += 1;
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn run_point(
    sc: Scenario,
    rate: f64,
    timeout_factor: f64,
    n: usize,
    rounds: Option<usize>,
    capacity: usize,
    batch: usize,
    seed: u64,
) -> anyhow::Result<ChaosPoint> {
    let spec = spec_for(rate, timeout_factor);
    let mut builder = ExperimentBuilder::preset(sc.name)
        .devices(n)
        .seed(seed)
        .faults(spec)
        .des(DesConfig {
            policy: Policy::Sync,
            capacity,
            batch,
        });
    if let Some(r) = rounds {
        builder = builder.rounds(r);
    }
    let experiment = builder.build()?;
    let n_rounds = experiment.config().workload.rounds;
    let spec = experiment.config().faults.clone();

    let mut sink = ChaosSink {
        des: DesSink::default(),
        degraded: 0,
    };
    let t0 = std::time::Instant::now();
    let outcome = experiment.run_into(&mut sink)?;
    let wall = t0.elapsed().as_secs_f64();
    let des = outcome
        .des
        .ok_or_else(|| anyhow::anyhow!("event engine must report DES stats"))?;

    Ok(ChaosPoint {
        scenario: sc.name.to_string(),
        rate_hz: rate,
        slot_fail_prob: spec.slot_fail_prob,
        burst_rate_per_round: spec.burst_rate_per_round,
        timeout_factor,
        n_devices: n,
        rounds: n_rounds,
        capacity,
        batch,
        wall_s: wall,
        makespan_s: des.makespan_s,
        completed: outcome.cells,
        dropped: des.dropped,
        degraded: sink.degraded,
        retries: des.retries,
        timeout_demotions: des.timeout_demotions,
        failovers: des.failovers,
        slot_failures: des.slot_failures,
        slot_repairs: des.slot_repairs,
        energy_j: des.energy_spent_j,
        retry_energy_j: des.retry_energy_j,
    })
}

impl ChaosSweep {
    /// ASCII summary table (scenario × rate × timeout variant).
    pub fn render(&self) -> String {
        let mut t = Table::new(
            &format!(
                "chaos-sweep — fault-injection grid ({} workers, seed {})",
                self.threads, self.seed
            ),
            &[
                "scenario",
                "rate",
                "timeout",
                "merged",
                "dropped",
                "degraded",
                "retries",
                "demoted",
                "failover",
                "slotfail",
                "makespan",
                "energy",
                "retry E",
            ],
        );
        for p in &self.points {
            t.row(vec![
                p.scenario.clone(),
                format!("{}", p.rate_hz),
                if p.timeout_factor > 0.0 { "on" } else { "off" }.to_string(),
                p.completed.to_string(),
                p.dropped.to_string(),
                p.degraded.to_string(),
                p.retries.to_string(),
                p.timeout_demotions.to_string(),
                p.failovers.to_string(),
                p.slot_failures.to_string(),
                fmt_secs(p.makespan_s),
                fmt_joules(p.energy_j),
                fmt_joules(p.retry_energy_j),
            ]);
        }
        t.render()
    }

    /// Emitter payload (the `data` member of the report envelope).
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("schema", Json::Str("edgesplit/chaos-sweep/v1".into())),
            // string, not number: u64 seeds above 2^53 would lose
            // precision through the f64-backed Json::Num
            ("seed", Json::Str(self.seed.to_string())),
            ("threads", Json::Num(self.threads as f64)),
            (
                "points",
                Json::Arr(self.points.iter().map(point_json).collect()),
            ),
        ])
    }

    /// The enveloped report (`BENCH_faults.json`): shared
    /// `schema_version`/`meta` wrapper around [`ChaosSweep::to_json`].
    pub fn report(&self, scenario_sel: &str, rounds: Option<usize>) -> Report {
        Report::new(
            ReportMeta {
                kind: "chaos-sweep",
                preset: scenario_sel.to_string(),
                seed: self.seed,
                threads: self.threads,
                rounds,
            },
            self.to_json(),
            self.render(),
        )
    }
}

fn point_json(p: &ChaosPoint) -> Json {
    json::obj(vec![
        ("scenario", Json::Str(p.scenario.clone())),
        ("rate_hz", Json::Num(p.rate_hz)),
        ("slot_fail_prob", Json::Num(p.slot_fail_prob)),
        ("burst_rate_per_round", Json::Num(p.burst_rate_per_round)),
        ("timeout_factor", Json::Num(p.timeout_factor)),
        ("n_devices", Json::Num(p.n_devices as f64)),
        ("rounds", Json::Num(p.rounds as f64)),
        ("capacity", Json::Num(p.capacity as f64)),
        ("batch", Json::Num(p.batch as f64)),
        ("wall_s", Json::Num(p.wall_s)),
        ("makespan_s", Json::Num(p.makespan_s)),
        ("completed", Json::Num(p.completed as f64)),
        ("dropped", Json::Num(p.dropped as f64)),
        ("degraded", Json::Num(p.degraded as f64)),
        ("retries", Json::Num(p.retries as f64)),
        ("timeout_demotions", Json::Num(p.timeout_demotions as f64)),
        ("failovers", Json::Num(p.failovers as f64)),
        ("slot_failures", Json::Num(p.slot_failures as f64)),
        ("slot_repairs", Json::Num(p.slot_repairs as f64)),
        ("energy_j", Json::Num(p.energy_j)),
        ("retry_energy_j", Json::Num(p.retry_energy_j)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::scenario;

    #[test]
    fn ladder_produces_points_gates_pass_and_json_parses() {
        let mut bench = Bencher::new("chaos-sweep-test");
        let sweep = chaos_sweep(
            &[scenario::DENSE_URBAN],
            &[0.0, 0.5],
            6,
            Some(2),
            2,
            1,
            4,
            7,
            &mut bench,
        )
        .unwrap();
        // 2 rates × 2 timeout variants
        assert_eq!(sweep.points.len(), 4);
        assert_eq!(bench.results().len(), 4);
        let baseline = sweep
            .points
            .iter()
            .find(|p| p.rate_hz == 0.0 && p.timeout_factor == 0.0)
            .unwrap();
        assert_eq!(baseline.retries, 0);
        assert_eq!(baseline.retry_energy_j, 0.0);
        let storm = sweep
            .points
            .iter()
            .find(|p| p.rate_hz == 0.5 && p.timeout_factor == 0.0)
            .unwrap();
        assert!(storm.retries > 0, "rate 0.5 must trigger retransmissions");
        assert!(storm.retry_energy_j > 0.0);
        let js = sweep.to_json().to_string();
        assert!(js.contains("chaos-sweep/v1"));
        assert!(js.contains("retry_energy_j"));
        assert!(js.contains("timeout_demotions"));
        assert!(Json::parse(&js).is_ok());
    }

    #[test]
    fn sweep_is_deterministic_across_thread_counts() {
        let run = |threads: usize| {
            let mut bench = Bencher::new("chaos-det");
            chaos_sweep(
                &[scenario::MOBILE_VEHICULAR],
                &[0.0, 0.1],
                6,
                Some(2),
                2,
                1,
                threads,
                11,
                &mut bench,
            )
            .unwrap()
        };
        let a = run(1);
        let b = run(8);
        assert_eq!(a.points.len(), b.points.len());
        for (x, y) in a.points.iter().zip(&b.points) {
            assert_eq!(x.rate_hz.to_bits(), y.rate_hz.to_bits());
            assert_eq!(x.makespan_s.to_bits(), y.makespan_s.to_bits());
            assert_eq!(x.retries, y.retries);
            assert_eq!(x.timeout_demotions, y.timeout_demotions);
            assert_eq!(x.retry_energy_j.to_bits(), y.retry_energy_j.to_bits());
            assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits());
        }
    }

    #[test]
    fn report_wraps_payload_in_versioned_envelope() {
        let mut bench = Bencher::new("chaos-envelope");
        let sweep = chaos_sweep(
            &[scenario::DENSE_URBAN],
            &[0.1],
            4,
            Some(1),
            2,
            1,
            2,
            3,
            &mut bench,
        )
        .unwrap();
        let j = sweep.report("dense-urban", Some(1)).to_json();
        assert_eq!(j.get("schema_version").and_then(Json::as_usize), Some(1));
        assert_eq!(j.get("kind").and_then(Json::as_str), Some("chaos-sweep"));
        assert!(j.at(&["data", "points"]).is_some());
    }

    #[test]
    fn rejects_degenerate_input() {
        let mut bench = Bencher::new("chaos-bad");
        let sc = [scenario::DENSE_URBAN];
        assert!(chaos_sweep(&[], &[0.1], 4, None, 1, 1, 1, 0, &mut bench).is_err());
        assert!(chaos_sweep(&sc, &[], 4, None, 1, 1, 1, 0, &mut bench).is_err());
        assert!(chaos_sweep(&sc, &[0.1], 0, None, 1, 1, 1, 0, &mut bench).is_err());
        assert!(chaos_sweep(&sc, &[-0.1], 4, None, 1, 1, 1, 0, &mut bench).is_err());
        assert!(chaos_sweep(&sc, &[f64::NAN], 4, None, 1, 1, 1, 0, &mut bench).is_err());
        assert!(chaos_sweep(&sc, &[0.1], 4, None, 0, 1, 1, 0, &mut bench).is_err());
    }
}
