//! The discrete-event fleet engine (DESIGN.md §11): replaces the round
//! engine's implicit barrier with explicit timed events over a virtual
//! clock — device FP → smashed uplink → **server compute queue** →
//! gradient downlink → device BP → merge — under three aggregation
//! policies and Poisson device churn.
//!
//! Every `(round, device)` cell still evaluates through
//! [`Scheduler::device_round`], the same pure counter-based-RNG
//! function the synchronous engine uses, so on churn-free configs the
//! `sync` policy reproduces `Scheduler::run_parallel` **bit for bit**
//! (asserted by `rust/tests/des_engine.rs` on dense-urban; with churn
//! enabled, departing devices drop cells the barrier engine would
//! still run).  `semi-sync`/`async` runs are pure functions of
//! `(config, seed)` — independent of thread count and wall-clock.
//!
//! Control-plane adapter bookkeeping applies atomically at each merge
//! instant through the [`Aggregator`]'s unordered (monotone) paths;
//! async merges carry the version they were *based on*, so
//! `Aggregator::staleness` reports real lag when stale merges land.
//!
//! ## Channel-process semantics on the virtual timeline
//!
//! The pluggable channel (`LinkProcess`, DESIGN.md §13) is sampled by
//! **round index**, not by virtual time: fading is block fading — one
//! realization per `(device, round)` cell, frozen for that cell's
//! whole timeline — and mobility advances one `round_s` tick per
//! round.  Under `sync`/`semi-sync` the round index is the global
//! round; under `async` it is the device's *personal* round counter,
//! so a fast device walks its correlated fading trace (and its
//! trajectory) faster in virtual time than a slow one.  The process
//! clock and the virtual clock are deliberately distinct: keeping
//! channel sampling round-indexed is what preserves the sync policy's
//! bit-identity with the barrier engine and keeps every cell a pure
//! function of `(config, seed, round, device)` regardless of event
//! interleaving.
//!
//! ## The multi-cell tier (DESIGN.md §15)
//!
//! With `[cells] count > 1` the single server queue becomes one
//! [`ServerQueue`] **per cell site**: a device-round's server job
//! routes to the serving cell of that `(device, round)` from the
//! precomputed [`CellGrid`] association traces, so contention, batch
//! fusion, and dispatched energy are tracked per cell.  Merges apply
//! to the cell's own [`Aggregator`] *and* to the cloud aggregator — a
//! star-to-cloud topology where the cloud sees exactly the legacy
//! unordered merge stream.  With `count = 1` every job routes to queue
//! 0 and the event timeline is bit-identical to the pre-cell engine
//! (the correctness anchor, property-tested across every preset by
//! `exp::verify::verify_single_cell_bit_identity`).

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::coordinator::{Aggregator, RoundRecord, Scheduler};
use crate::net::CellGrid;
use crate::obs::{self, trace};
use crate::util::stats;

use super::churn::ChurnTrace;
use super::event::{EventKind, EventQueue};
use super::server::{Batch, Job, ServerQueue, ServerStats};

/// Aggregation policy for the fleet timeline.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Policy {
    /// Global round barrier — reproduces the synchronous engine's
    /// records bit-identically.
    Sync,
    /// Barrier with a straggler deadline: participants that have not
    /// merged by `deadline_factor` × (median analytic round delay +
    /// estimated queue drain) are dropped for the round.
    SemiSync { deadline_factor: f64 },
    /// No barrier: each device loops its own rounds; merges are
    /// staleness-weighted.
    Async,
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Sync => "sync",
            Policy::SemiSync { .. } => "semi-sync",
            Policy::Async => "async",
        }
    }

    /// Parse a CLI policy name; `deadline_factor` parameterizes
    /// `semi-sync` (ignored by the other policies).
    pub fn parse(s: &str, deadline_factor: f64) -> Option<Policy> {
        match s.to_ascii_lowercase().as_str() {
            "sync" => Some(Policy::Sync),
            "semi-sync" | "semisync" => Some(Policy::SemiSync { deadline_factor }),
            "async" => Some(Policy::Async),
            _ => None,
        }
    }
}

/// DES knobs on top of the experiment config.
#[derive(Clone, Copy, Debug)]
pub struct DesConfig {
    pub policy: Policy,
    /// concurrent jobs the server sustains (queue slots)
    pub capacity: usize,
    /// max jobs fused per slot dispatch
    pub batch: usize,
}

/// One completed device-round, with its DES observables alongside the
/// analytic record.
#[derive(Clone, Debug)]
pub struct DesRecord {
    pub record: RoundRecord,
    /// virtual time the cell started [s]
    pub start_s: f64,
    /// virtual time the merge landed [s]
    pub finish_s: f64,
    /// time spent queued at the server [s]
    pub wait_s: f64,
    /// merges that landed while this cell was in flight (async lag)
    pub staleness: usize,
    /// staleness weight applied at merge (1 under sync/semi-sync)
    pub weight: f64,
}

impl DesRecord {
    /// Observed end-to-end latency of the cell (analytic delay + queueing).
    pub fn latency_s(&self) -> f64 {
        self.finish_s - self.start_s
    }
}

/// Per-cell observables of one DES run (DESIGN.md §15).  With
/// `[cells] count = 1` the single entry carries exactly the legacy
/// global figures.
#[derive(Clone, Debug)]
pub struct CellStats {
    /// site position [m]
    pub position_m: (f64, f64),
    /// this cell's queue/occupancy statistics
    pub server: ServerStats,
    /// Eq.-11 energy dispatched on this cell's queue [J]; summing over
    /// cells reproduces the global `energy_spent_j` exactly
    pub energy_spent_j: f64,
    /// handovers that landed on this cell (inbound re-associations)
    pub handovers_in: u64,
    /// whether this cell's own aggregation level converged
    pub aggregator_consistent: bool,
}

/// Everything a DES run produces.
#[derive(Clone, Debug)]
pub struct DesOutcome {
    /// completed cells, sorted round-major like the synchronous engine
    pub records: Vec<DesRecord>,
    pub makespan_s: f64,
    /// fleet-level queue statistics: the single cell's own stats when
    /// `count = 1` (bit-identical to the pre-cell engine), otherwise
    /// the across-cell merge (sums for counts/slot-seconds, served-
    /// weighted mean wait, max peak depth, mean utilization)
    pub server: ServerStats,
    /// per-cell queue/energy/handover breakdown (length = `[cells] count`)
    pub per_cell: Vec<CellStats>,
    /// total device→cell re-associations over the run's round horizon
    pub handovers: u64,
    /// cells abandoned to churn or the straggler deadline
    pub dropped: u64,
    /// cells launched (== records + dropped)
    pub launched: u64,
    pub departures: u64,
    pub arrivals: u64,
    /// max `Aggregator::staleness` observed across merges
    pub peak_staleness: usize,
    /// Eq.-11 server energy booked at job dispatch [J] — counts work
    /// later wasted on cancelled stragglers, which merged records omit.
    /// Always the exact sum of the per-cell accumulators.
    pub energy_spent_j: f64,
    /// the cloud (inter-server) aggregation level — sees every merge
    pub aggregator: Aggregator,
}

/// Fleet-level [`ServerStats`] across per-cell queues.  The
/// single-queue case returns the entry untouched so `count = 1`
/// stays bit-identical to the pre-cell engine.
fn merged_server_stats(per: &[ServerStats]) -> ServerStats {
    if per.len() == 1 {
        return per[0];
    }
    let served: u64 = per.iter().map(|s| s.served_jobs).sum();
    let wait_sum: f64 = per.iter().map(|s| s.mean_wait_s * s.served_jobs as f64).sum();
    ServerStats {
        served_jobs: served,
        abandoned_jobs: per.iter().map(|s| s.abandoned_jobs).sum(),
        busy_slot_s: per.iter().map(|s| s.busy_slot_s).sum(),
        mean_wait_s: if served == 0 { 0.0 } else { wait_sum / served as f64 },
        peak_depth: per.iter().map(|s| s.peak_depth).max().unwrap_or(0),
        // time-averages sum across queues: the fleet's mean total
        // backlog is the sum of per-cell mean depths
        mean_depth: per.iter().map(|s| s.mean_depth).sum(),
        // equal per-cell capacity, so the fleet utilization is the
        // plain mean of the per-cell ratios
        utilization: per.iter().map(|s| s.utilization).sum::<f64>() / per.len() as f64,
    }
}

/// Discrete-event engine over a [`Scheduler`]'s config and cost model.
/// Owns the scheduler through an `Arc` (shared with the caller and the
/// `exp::Engine` wrapper) — no borrowed lifetime, so the engine can
/// live inside trait objects.
pub struct DesEngine {
    sched: Arc<Scheduler>,
    des: DesConfig,
}

impl DesEngine {
    pub fn new(sched: Arc<Scheduler>, des: DesConfig) -> DesEngine {
        DesEngine { sched, des }
    }

    /// The scheduler this engine evaluates cells through.
    pub fn scheduler(&self) -> &Scheduler {
        &self.sched
    }

    /// Run the simulation to completion.  Strictly serial and
    /// deterministic; see the module docs for why.
    pub fn run(&self) -> DesOutcome {
        Sim::new(&self.sched, self.des).run()
    }
}

/// Phase durations of one cell on the DES timeline.  The decomposition
/// refines Eqs. (7)–(10) — the phase sums match the analytic round
/// delay up to floating-point association, while `record.delay_s`
/// itself stays bit-identical to the synchronous engine.
struct CellTiming {
    fp_s: f64,
    up_s: f64,
    down_s: f64,
    bp_s: f64,
}

struct Inflight {
    record: RoundRecord,
    start_s: f64,
    wait_s: f64,
    /// global merge version when the cell started (async staleness base)
    base_version: usize,
    down_s: f64,
    bp_s: f64,
}

struct DeviceState {
    present: bool,
    /// next personal round index (async cell coordinate)
    next_round: usize,
    churn: ChurnTrace,
}

struct Sim<'a> {
    sched: &'a Scheduler,
    des: DesConfig,
    q: EventQueue,
    /// cell sites + precomputed device→cell association (read-only)
    cells: CellGrid,
    /// one compute queue per cell site (index = cell)
    servers: Vec<ServerQueue>,
    devices: Vec<DeviceState>,
    /// round coordinate of each device's in-flight cell, if any — the
    /// single source of truth for cell liveness (also read by the
    /// server queue's cancellation filter without any per-event copy)
    actives: Vec<Option<usize>>,
    inflight: BTreeMap<(usize, usize), Inflight>,
    /// the cloud aggregation level — receives every merge
    agg: Aggregator,
    /// per-cell aggregation levels of the star-to-cloud topology
    cell_aggs: Vec<Aggregator>,
    /// global merge version (counts applied merges)
    version: usize,
    records: Vec<DesRecord>,
    /// global rounds (sync/semi-sync)
    rounds: usize,
    // barrier state (sync/semi-sync)
    barrier_round: usize,
    barrier_outstanding: usize,
    barrier_open: bool,
    /// async: device-round completions still owed
    remaining_budget: usize,
    done: bool,
    launched: u64,
    dropped: u64,
    departures: u64,
    arrivals: u64,
    peak_staleness: usize,
    makespan_s: f64,
    /// Eq.-11 server energy booked when jobs dispatch, per cell —
    /// includes work later wasted on cancelled stragglers, unlike the
    /// merged records.  The global figure is the exact sum.
    energy_by_cell: Vec<f64>,
}

impl<'a> Sim<'a> {
    fn new(sched: &'a Scheduler, des: DesConfig) -> Sim<'a> {
        let n = sched.cfg.devices.len();
        let rounds = sched.cfg.workload.rounds;
        let churn_root = sched.cfg.seed ^ 0xDE5C_4u64;
        let devices = (0..n)
            .map(|i| DeviceState {
                present: true,
                next_round: 0,
                churn: ChurnTrace::new(churn_root, i, &sched.cfg.churn),
            })
            .collect();
        // Association traces precompute over the configured round
        // horizon; async personal rounds past it keep the horizon's
        // last assignment (CellGrid::cell_of clamps).
        let cells = CellGrid::new(
            &sched.cfg.cells,
            &sched.cfg.server,
            sched.link.mobility(),
            n,
            rounds,
            sched.link.channel.state.pathloss_exp(),
        );
        let servers = (0..cells.count())
            .map(|_| ServerQueue::new(des.capacity, des.batch))
            .collect();
        let cell_aggs = (0..cells.count())
            .map(|_| Aggregator::new(sched.cost_model.n_layers()))
            .collect();
        let energy_by_cell = vec![0.0; cells.count()];
        Sim {
            sched,
            des,
            q: EventQueue::new(),
            cells,
            servers,
            devices,
            actives: vec![None; n],
            inflight: BTreeMap::new(),
            agg: Aggregator::new(sched.cost_model.n_layers()),
            cell_aggs,
            version: 0,
            records: Vec::new(),
            rounds,
            barrier_round: 0,
            barrier_outstanding: 0,
            barrier_open: false,
            remaining_budget: rounds * n,
            done: false,
            launched: 0,
            dropped: 0,
            departures: 0,
            arrivals: 0,
            peak_staleness: 0,
            makespan_s: 0.0,
            energy_by_cell,
        }
    }

    fn run(mut self) -> DesOutcome {
        // seed churn: every device starts present; its first departure
        // (if it churns at all) comes from its private stream
        for i in 0..self.devices.len() {
            if let Some(dt) = self.devices[i].churn.next_present_s() {
                self.q.push_after(dt, EventKind::Depart { device: i });
            }
        }
        match self.des.policy {
            Policy::Sync | Policy::SemiSync { .. } => self.start_global_round(0),
            Policy::Async => {
                for i in 0..self.devices.len() {
                    self.launch_async(i);
                }
            }
        }

        let mut processed: u64 = 0;
        while let Some((t, ev)) = self.q.pop() {
            processed += 1;
            assert!(
                processed < 50_000_000,
                "DES event budget exceeded — runaway simulation"
            );
            self.makespan_s = t.secs();
            // observation only (DESIGN.md §16): the pop already
            // happened, the queue depth is whatever remains
            obs::metrics().des_events.inc(processed as usize);
            obs::metrics().des_queue_depth.observe(self.q.len() as u64);
            match ev {
                EventKind::Arrive { device } => self.on_arrive(device),
                EventKind::Depart { device } => self.on_depart(device),
                EventKind::UplinkDone { device, round } => self.on_uplink_done(device, round),
                EventKind::ServerBatchDone { cell, jobs } => {
                    self.on_server_batch_done(cell, jobs)
                }
                EventKind::MergeReady { device, round } => self.on_merge_ready(device, round),
                EventKind::Deadline { round } => self.on_deadline(round),
            }
            if let Policy::Async = self.des.policy {
                if self.remaining_budget == 0 && self.inflight.is_empty() {
                    self.done = true;
                }
            }
            if self.done {
                break;
            }
        }

        // purge cancelled jobs still queued so the depth/abandonment
        // stats describe real waiters, not dead entries
        let now = self.q.now();
        let actives = &self.actives;
        for server in &mut self.servers {
            server.flush_cancelled(now, |d, k| actives[d] == Some(k));
        }

        // round-major record stream, like the synchronous engine's
        self.records
            .sort_by_key(|r| (r.record.round, r.record.device_idx));
        let per_cell: Vec<CellStats> = (0..self.cells.count())
            .map(|c| {
                let server = self.servers[c].stats(self.makespan_s);
                obs::metrics().des_server_utilization.observe(server.utilization);
                CellStats {
                    position_m: self.cells.position(c),
                    server,
                    energy_spent_j: self.energy_by_cell[c],
                    handovers_in: self.cells.handovers_into(c),
                    aggregator_consistent: self.cell_aggs[c].is_consistent(),
                }
            })
            .collect();
        let server = merged_server_stats(
            &per_cell.iter().map(|c| c.server).collect::<Vec<_>>(),
        );
        DesOutcome {
            records: self.records,
            makespan_s: self.makespan_s,
            server,
            handovers: self.cells.total_handovers(),
            per_cell,
            dropped: self.dropped,
            launched: self.launched,
            departures: self.departures,
            arrivals: self.arrivals,
            peak_staleness: self.peak_staleness,
            // the global figure is defined as the per-cell sum, so the
            // two can never drift apart (and the single-cell sum is the
            // lone accumulator, bit-identical to the pre-cell engine)
            energy_spent_j: self.energy_by_cell.iter().sum(),
            aggregator: self.agg,
        }
    }

    /// Phase decomposition for one cell (see `CellTiming`).
    fn timing(&self, rec: &RoundRecord) -> CellTiming {
        let dm = &self.sched.cost_model.delay;
        let t = dm.epochs;
        // FP share of device compute from the FLOP model's per-layer
        // forward vs total-train cost (BP is the remainder)
        let frac = dm.flops.layer_fwd() / dm.flops.layer_train().max(f64::MIN_POSITIVE);
        let fp_s = rec.device_compute_s * frac;
        let up_s = 8.0
            * (t * dm.sizes.smashed_wire_bytes(rec.cut) + dm.sizes.adapter_bytes(rec.cut))
            / rec.rate_up_bps;
        let down_s = 8.0
            * (t * dm.sizes.grad_wire_bytes(rec.cut) + dm.sizes.adapter_bytes(rec.cut))
            / rec.rate_down_bps;
        CellTiming {
            fp_s,
            up_s,
            down_s,
            bp_s: rec.device_compute_s - fp_s,
        }
    }

    fn is_active(&self, device: usize, round: usize) -> bool {
        self.actives[device] == Some(round)
    }

    fn schedule_batches(&mut self, cell: usize, batches: Vec<Batch>) {
        let now = self.q.now();
        for b in batches {
            for j in &b.jobs {
                if let Some(inf) = self.inflight.get_mut(&(j.device, j.round)) {
                    inf.wait_s = now.secs() - j.enqueued_at.secs();
                    // Eq.-11 energy is committed once the job runs,
                    // whether or not its merge survives — booked on the
                    // cell whose queue dispatched it
                    self.energy_by_cell[cell] += inf.record.energy_j;
                    obs::metrics().des_queue_wait_s.observe(inf.wait_s);
                    if trace::active() && inf.wait_s > 0.0 {
                        trace::sim_span(
                            "queue_wait",
                            "des.server",
                            cell,
                            j.enqueued_at.secs(),
                            now.secs(),
                            vec![("device", j.device as f64), ("round", j.round as f64)],
                        );
                    }
                }
            }
            if trace::active() {
                trace::sim_span(
                    "batch_service",
                    "des.server",
                    cell,
                    now.secs(),
                    now.secs() + b.service_s,
                    vec![("jobs", b.jobs.len() as f64)],
                );
            }
            let ids: Vec<(usize, usize)> = b.jobs.iter().map(|j| (j.device, j.round)).collect();
            self.q
                .push_after(b.service_s, EventKind::ServerBatchDone { cell, jobs: ids });
        }
    }

    fn launch_cell(&mut self, device: usize, round: usize, rec: RoundRecord) {
        let timing = self.timing(&rec);
        self.actives[device] = Some(round);
        self.launched += 1;
        if self.cells.count() > 1 && round > 0 {
            let serving = self.cells.cell_of(device, round);
            if serving != self.cells.cell_of(device, round - 1) {
                obs::metrics().des_handovers.inc(device);
                if trace::active() {
                    trace::sim_instant(
                        "handover",
                        "des.cells",
                        serving,
                        self.q.now().secs(),
                        vec![("device", device as f64), ("round", round as f64)],
                    );
                }
            }
        }
        self.inflight.insert(
            (device, round),
            Inflight {
                record: rec,
                start_s: self.q.now().secs(),
                wait_s: 0.0,
                base_version: self.version,
                down_s: timing.down_s,
                bp_s: timing.bp_s,
            },
        );
        self.q
            .push_after(timing.fp_s + timing.up_s, EventKind::UplinkDone { device, round });
    }

    /// Async: start the device's next personal round, if budget remains.
    fn launch_async(&mut self, device: usize) {
        if self.remaining_budget == 0
            || !self.devices[device].present
            || self.actives[device].is_some()
        {
            return;
        }
        self.remaining_budget -= 1;
        let round = self.devices[device].next_round;
        self.devices[device].next_round += 1;
        let rec = self.sched.device_round(round, device);
        self.launch_cell(device, round, rec);
    }

    /// Sync/semi-sync: open global round `round` with every present
    /// device; defer if the fleet is momentarily empty (churn).
    fn start_global_round(&mut self, round: usize) {
        self.barrier_round = round;
        self.barrier_open = false;
        let present: Vec<usize> = (0..self.devices.len())
            .filter(|&i| self.devices[i].present)
            .collect();
        if present.is_empty() {
            return; // the next Arrive restarts us
        }
        self.barrier_outstanding = present.len();
        self.barrier_open = true;
        let mut delays = Vec::with_capacity(present.len());
        let mut services = Vec::with_capacity(present.len());
        for &i in &present {
            let rec = self.sched.device_round(round, i);
            delays.push(rec.delay_s);
            services.push(rec.server_compute_s);
            self.launch_cell(i, round, rec);
        }
        if let Policy::SemiSync { deadline_factor } = self.des.policy {
            // deadline = factor × (median analytic round delay + the
            // serialization the *most loaded cell's* queue adds when
            // its participants share C slots).  With one cell the max
            // load is the whole barrier — the legacy formula exactly.
            let mut per_cell_load = vec![0usize; self.cells.count()];
            for &i in &present {
                per_cell_load[self.cells.cell_of(i, round)] += 1;
            }
            let max_load = per_cell_load.iter().copied().max().unwrap_or(0);
            let drain_batches =
                (max_load as f64 / self.servers[0].capacity() as f64).ceil() - 1.0;
            let deadline = deadline_factor
                * (stats::median(&delays) + drain_batches.max(0.0) * stats::median(&services));
            self.q.push_after(deadline, EventKind::Deadline { round });
        }
    }

    fn close_global_round(&mut self) {
        self.barrier_open = false;
        let next = self.barrier_round + 1;
        if next >= self.rounds {
            self.done = true;
        } else {
            self.start_global_round(next);
        }
    }

    /// A barrier participant resolved (merge or cancel).
    fn resolve_barrier_slot(&mut self) {
        debug_assert!(self.barrier_open && self.barrier_outstanding > 0);
        self.barrier_outstanding -= 1;
        if self.barrier_outstanding == 0 {
            self.close_global_round();
        }
    }

    /// Abandon the device's in-flight cell (churn departure).
    fn cancel_active(&mut self, device: usize) {
        if let Some(round) = self.actives[device].take() {
            self.inflight.remove(&(device, round));
            self.dropped += 1;
            obs::metrics().des_drops_churn.inc(device);
            if trace::active() {
                trace::sim_instant(
                    "churn_cancel",
                    "des.churn",
                    self.cells.cell_of(device, round),
                    self.q.now().secs(),
                    vec![("device", device as f64), ("round", round as f64)],
                );
            }
            match self.des.policy {
                Policy::Sync | Policy::SemiSync { .. } => self.resolve_barrier_slot(),
                Policy::Async => {
                    // the freed budget goes to any idle present device
                    // (a device that merged while the budget was
                    // exhausted has no other wake-up)
                    self.remaining_budget += 1;
                    self.relaunch_idle();
                }
            }
        }
    }

    /// Async: hand available budget to idle present devices.
    fn relaunch_idle(&mut self) {
        for i in 0..self.devices.len() {
            if self.remaining_budget == 0 {
                break;
            }
            self.launch_async(i);
        }
    }

    fn on_arrive(&mut self, device: usize) {
        if self.devices[device].present {
            return;
        }
        self.devices[device].present = true;
        self.arrivals += 1;
        if let Some(up) = self.devices[device].churn.next_present_s() {
            self.q.push_after(up, EventKind::Depart { device });
        }
        match self.des.policy {
            Policy::Async => self.launch_async(device),
            Policy::Sync | Policy::SemiSync { .. } => {
                // join at the next barrier; if the round start was
                // deferred because the fleet emptied, start it now
                if !self.barrier_open && !self.done {
                    self.start_global_round(self.barrier_round);
                }
            }
        }
    }

    fn on_depart(&mut self, device: usize) {
        if !self.devices[device].present {
            return;
        }
        self.devices[device].present = false;
        self.departures += 1;
        self.cancel_active(device);
        if let Some(away) = self.devices[device].churn.next_away_s() {
            self.q.push_after(away, EventKind::Arrive { device });
        }
    }

    fn on_uplink_done(&mut self, device: usize, round: usize) {
        if !self.is_active(device, round) {
            return;
        }
        let rec = &self.inflight[&(device, round)].record;
        let job = Job {
            device,
            round,
            service_s: rec.server_compute_s,
            enqueued_at: self.q.now(),
        };
        // route to the serving cell's queue — the precomputed
        // association of this (device, round)
        let cell = self.cells.cell_of(device, round);
        let now = self.q.now();
        let actives = &self.actives;
        let batches = self.servers[cell].enqueue(job, now, |d, k| actives[d] == Some(k));
        self.schedule_batches(cell, batches);
    }

    fn on_server_batch_done(&mut self, cell: usize, jobs: Vec<(usize, usize)>) {
        let now = self.q.now();
        for (device, round) in jobs {
            if !self.is_active(device, round) {
                continue; // cancelled while in service — wasted work
            }
            let inf = &self.inflight[&(device, round)];
            self.q
                .push_after(inf.down_s + inf.bp_s, EventKind::MergeReady { device, round });
        }
        let actives = &self.actives;
        let refills = self.servers[cell].on_batch_done(now, |d, k| actives[d] == Some(k));
        self.schedule_batches(cell, refills);
    }

    fn on_merge_ready(&mut self, device: usize, round: usize) {
        if !self.is_active(device, round) {
            return;
        }
        let inf = self.inflight.remove(&(device, round)).unwrap();
        self.actives[device] = None;

        // Stage 2/4/5 control-plane effects, applied atomically at the
        // merge instant.  The merge carries the version it was *based
        // on* + 1, so concurrent fresher merges are never regressed and
        // `Aggregator::staleness` reports real lag.
        self.version += 1;
        let v = self.version;
        let based = inf.base_version + 1;
        let cut = inf.record.cut;
        let bytes = inf.record.adapter_bytes;
        // star-to-cloud: the serving cell's aggregation level absorbs
        // the merge, then forwards it to the cloud level — both through
        // the unordered (monotone) paths, so event order cannot matter
        let cell = self.cells.cell_of(device, round);
        let ca = &mut self.cell_aggs[cell];
        ca.bytes_distributed += bytes;
        ca.server_update_unordered(cut, based);
        ca.merge_unordered(device, cut, based, bytes);
        self.agg.bytes_distributed += bytes;
        self.agg.server_update_unordered(cut, based);
        self.agg.merge_unordered(device, cut, based, bytes);
        let staleness = v - based;
        let weight = match self.des.policy {
            Policy::Async => 1.0 / (1.0 + staleness as f64),
            _ => 1.0,
        };
        self.peak_staleness = self
            .peak_staleness
            .max(self.agg.staleness(v))
            .max(staleness);

        obs::metrics().des_merges.inc(device);
        let now_s = self.q.now().secs();
        if trace::active() {
            trace::sim_span(
                "device_round",
                "des.round",
                cell,
                inf.start_s,
                now_s,
                vec![
                    ("device", device as f64),
                    ("round", round as f64),
                    ("staleness", staleness as f64),
                ],
            );
        }
        self.records.push(DesRecord {
            start_s: inf.start_s,
            finish_s: now_s,
            wait_s: inf.wait_s,
            staleness,
            weight,
            record: inf.record,
        });

        match self.des.policy {
            Policy::Sync | Policy::SemiSync { .. } => self.resolve_barrier_slot(),
            Policy::Async => self.launch_async(device),
        }
    }

    /// Semi-sync: the straggler deadline fired for `round`.
    fn on_deadline(&mut self, round: usize) {
        if !self.barrier_open || self.barrier_round != round {
            return; // stale — the round already closed
        }
        for device in 0..self.devices.len() {
            if self.actives[device] == Some(round) {
                self.actives[device] = None;
                self.inflight.remove(&(device, round));
                self.dropped += 1;
                self.barrier_outstanding -= 1;
                obs::metrics().des_drops_straggler.inc(device);
                if trace::active() {
                    trace::sim_instant(
                        "straggler_drop",
                        "des.deadline",
                        self.cells.cell_of(device, round),
                        self.q.now().secs(),
                        vec![("device", device as f64), ("round", round as f64)],
                    );
                }
            }
        }
        debug_assert_eq!(self.barrier_outstanding, 0);
        self.close_global_round();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChannelState, ExpConfig};
    use crate::coordinator::Strategy;
    use crate::exp::verify::verify_bit_identical;

    fn quick_cfg(rounds: usize) -> ExpConfig {
        let mut cfg = ExpConfig::paper();
        cfg.workload.rounds = rounds;
        cfg
    }

    fn engine_outcome(cfg: ExpConfig, policy: Policy, capacity: usize) -> DesOutcome {
        let sched = Arc::new(Scheduler::new(cfg, ChannelState::Normal, Strategy::Card));
        DesEngine::new(
            sched,
            DesConfig {
                policy,
                capacity,
                batch: 1,
            },
        )
        .run()
    }

    #[test]
    fn sync_policy_reproduces_round_engine_bitwise() {
        let cfg = quick_cfg(3);
        let sched = Scheduler::new(cfg.clone(), ChannelState::Normal, Strategy::Card);
        let reference = sched.run_parallel(4);
        let out = engine_outcome(cfg, Policy::Sync, 64);
        let des_records: Vec<RoundRecord> =
            out.records.iter().map(|r| r.record.clone()).collect();
        if let Err(e) = verify_bit_identical(&reference, &des_records) {
            panic!("{e:#}");
        }
        assert!(out.aggregator.is_consistent());
        assert_eq!(out.launched as usize, out.records.len());
        assert_eq!(out.dropped, 0);
    }

    #[test]
    fn sync_policy_reproduces_round_engine_under_correlated_mobile_channels() {
        use crate::config::{FadingModel, MobilityModel};
        let mut cfg = quick_cfg(3);
        cfg.channel.process.model = FadingModel::Markov;
        cfg.mobility.model = MobilityModel::Linear;
        cfg.mobility.speed_mps = 3.0;
        cfg.mobility.round_s = 10.0;
        let sched = Scheduler::new(cfg.clone(), ChannelState::Normal, Strategy::Card);
        let reference = sched.run_parallel(4);
        let out = engine_outcome(cfg, Policy::Sync, 64);
        let des_records: Vec<RoundRecord> =
            out.records.iter().map(|r| r.record.clone()).collect();
        if let Err(e) = verify_bit_identical(&reference, &des_records) {
            panic!("{e:#}");
        }
    }

    #[test]
    fn runs_are_deterministic() {
        for policy in [
            Policy::Sync,
            Policy::SemiSync {
                deadline_factor: 1.2,
            },
            Policy::Async,
        ] {
            let a = engine_outcome(quick_cfg(3), policy, 2);
            let b = engine_outcome(quick_cfg(3), policy, 2);
            assert_eq!(a.records.len(), b.records.len(), "{}", policy.name());
            assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits(), "{}", policy.name());
            assert_eq!(
                a.server.utilization.to_bits(),
                b.server.utilization.to_bits(),
                "{}",
                policy.name()
            );
            for (x, y) in a.records.iter().zip(&b.records) {
                assert_eq!(x.finish_s.to_bits(), y.finish_s.to_bits());
                assert_eq!(x.wait_s.to_bits(), y.wait_s.to_bits());
            }
        }
    }

    #[test]
    fn async_completes_full_budget_without_churn() {
        let out = engine_outcome(quick_cfg(4), Policy::Async, 2);
        assert_eq!(out.records.len(), 4 * 5, "rounds × devices merges");
        assert_eq!(out.dropped, 0);
        assert!(out.aggregator.is_consistent());
        assert!(out.makespan_s > 0.0);
        // capacity 2 with 5 devices in flight must queue somebody
        assert!(out.server.peak_depth >= 1);
        assert!(out.server.utilization > 0.0 && out.server.utilization <= 1.0);
        // every record's observed latency covers its analytic delay
        // phases at least approximately (queueing only adds)
        for r in &out.records {
            assert!(r.latency_s() > 0.0 && r.latency_s().is_finite());
            assert!(r.wait_s >= 0.0);
        }
        // nothing dropped ⇒ dispatched energy equals merged energy
        // (up to summation order)
        let merged: f64 = out.records.iter().map(|r| r.record.energy_j).sum();
        assert!(
            (out.energy_spent_j - merged).abs() <= merged.abs() * 1e-9,
            "spent {} vs merged {merged}",
            out.energy_spent_j
        );
    }

    #[test]
    fn async_staleness_observed_and_weighted() {
        let out = engine_outcome(quick_cfg(4), Policy::Async, 2);
        // with 5 concurrent devices, some merge must land while others
        // are in flight
        assert!(out.peak_staleness > 0, "no staleness in a concurrent run");
        let any_downweighted = out.records.iter().any(|r| r.weight < 1.0);
        assert!(any_downweighted, "staleness never weighted a merge");
        for r in &out.records {
            let expect = 1.0 / (1.0 + r.staleness as f64);
            assert!((r.weight - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn semi_sync_tight_deadline_drops_stragglers() {
        let out = engine_outcome(
            quick_cfg(3),
            Policy::SemiSync {
                deadline_factor: 0.25,
            },
            64,
        );
        assert!(out.dropped > 0, "a 0.25× deadline must drop the tail");
        assert_eq!(out.launched, out.records.len() as u64 + out.dropped);
        assert!(out.aggregator.is_consistent());
        // dispatched energy can only exceed merged energy (wasted work)
        let merged: f64 = out.records.iter().map(|r| r.record.energy_j).sum();
        assert!(out.energy_spent_j >= merged - merged.abs() * 1e-9);
    }

    #[test]
    fn churn_preserves_cell_accounting() {
        let mut cfg = quick_cfg(3);
        cfg.churn.depart_rate_hz = 0.002;
        cfg.churn.arrive_rate_hz = 0.02;
        for policy in [Policy::Sync, Policy::Async] {
            let out = engine_outcome(cfg.clone(), policy, 4);
            // every launched cell either merged or dropped — no leaks
            assert_eq!(
                out.launched,
                out.records.len() as u64 + out.dropped,
                "{}",
                policy.name()
            );
            // a device must depart before it can return
            assert!(out.departures >= out.arrivals, "{}", policy.name());
            assert!(out.aggregator.is_consistent(), "{}", policy.name());
            // determinism under churn
            let again = engine_outcome(cfg.clone(), policy, 4);
            assert_eq!(out.records.len(), again.records.len());
            assert_eq!(out.departures, again.departures);
            assert_eq!(out.makespan_s.to_bits(), again.makespan_s.to_bits());
        }
    }

    #[test]
    fn single_cell_per_cell_stats_mirror_the_globals() {
        let out = engine_outcome(quick_cfg(3), Policy::Sync, 4);
        assert_eq!(out.per_cell.len(), 1);
        assert_eq!(out.handovers, 0);
        let c = &out.per_cell[0];
        assert_eq!(c.position_m, (0.0, 0.0));
        assert_eq!(c.handovers_in, 0);
        assert!(c.aggregator_consistent);
        // one cell: the per-cell entry IS the global figure, bitwise
        assert_eq!(c.energy_spent_j.to_bits(), out.energy_spent_j.to_bits());
        assert_eq!(c.server.utilization.to_bits(), out.server.utilization.to_bits());
        assert_eq!(c.server.served_jobs, out.server.served_jobs);
        assert_eq!(c.server.mean_wait_s.to_bits(), out.server.mean_wait_s.to_bits());
    }

    #[test]
    fn multi_cell_partitions_queues_and_conserves_totals() {
        // paper fleet at 10–30 m + line cells at 0 and 40 m: the 20 m
        // midline splits the static fleet 3/2, no handovers possible
        let mut cfg = quick_cfg(3);
        cfg.cells.count = 2;
        cfg.cells.spacing_m = 40.0;
        let out = engine_outcome(cfg.clone(), Policy::Sync, 4);
        assert_eq!(out.per_cell.len(), 2);
        for c in &out.per_cell {
            assert!(c.server.served_jobs > 0, "both cells must see work");
            assert!(c.aggregator_consistent);
        }
        assert_eq!(out.handovers, 0, "static fleet cannot hand over");
        // per-cell totals reproduce the global figures exactly
        let e: f64 = out.per_cell.iter().map(|c| c.energy_spent_j).sum();
        assert_eq!(e.to_bits(), out.energy_spent_j.to_bits());
        let served: u64 = out.per_cell.iter().map(|c| c.server.served_jobs).sum();
        assert_eq!(served, out.server.served_jobs);
        assert_eq!(served as usize, out.records.len());
        assert!(out.aggregator.is_consistent());
        // the radio plane is cell-independent: the record stream (cut
        // decisions, delays, energies) matches the single-cell run bit
        // for bit — only queueing is routed differently
        let mut single = cfg;
        single.cells.count = 1;
        let base = engine_outcome(single, Policy::Sync, 4);
        assert_eq!(base.records.len(), out.records.len());
        for (a, b) in base.records.iter().zip(&out.records) {
            assert_eq!(a.record.delay_s.to_bits(), b.record.delay_s.to_bits());
            assert_eq!(a.record.energy_j.to_bits(), b.record.energy_j.to_bits());
            assert_eq!(a.record.cut, b.record.cut);
        }
    }

    #[test]
    fn multi_cell_runs_are_deterministic_across_policies() {
        let mut cfg = quick_cfg(3);
        cfg.cells.count = 3;
        cfg.cells.spacing_m = 15.0;
        cfg.mobility.model = crate::config::MobilityModel::Waypoint;
        cfg.mobility.speed_mps = 8.0;
        cfg.mobility.round_s = 5.0;
        cfg.mobility.range_m = 30.0;
        for policy in [
            Policy::Sync,
            Policy::SemiSync { deadline_factor: 1.2 },
            Policy::Async,
        ] {
            let a = engine_outcome(cfg.clone(), policy, 2);
            let b = engine_outcome(cfg.clone(), policy, 2);
            assert_eq!(a.handovers, b.handovers, "{}", policy.name());
            assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits(), "{}", policy.name());
            for (x, y) in a.per_cell.iter().zip(&b.per_cell) {
                assert_eq!(x.energy_spent_j.to_bits(), y.energy_spent_j.to_bits());
                assert_eq!(x.server.served_jobs, y.server.served_jobs);
                assert_eq!(x.handovers_in, y.handovers_in);
            }
            // handover bookkeeping is internally consistent
            let inbound: u64 = a.per_cell.iter().map(|c| c.handovers_in).sum();
            assert_eq!(inbound, a.handovers, "{}", policy.name());
            // and the energy ledger still sums exactly
            let e: f64 = a.per_cell.iter().map(|c| c.energy_spent_j).sum();
            assert_eq!(e.to_bits(), a.energy_spent_j.to_bits(), "{}", policy.name());
        }
    }

    #[test]
    fn policy_parse_roundtrip() {
        assert_eq!(Policy::parse("sync", 1.5), Some(Policy::Sync));
        assert_eq!(
            Policy::parse("SEMI-SYNC", 2.0),
            Some(Policy::SemiSync {
                deadline_factor: 2.0
            })
        );
        assert_eq!(Policy::parse("async", 1.5), Some(Policy::Async));
        assert_eq!(Policy::parse("bogus", 1.5), None);
        assert_eq!(Policy::Sync.name(), "sync");
        assert_eq!(Policy::Async.name(), "async");
    }
}
