//! The discrete-event fleet engine (DESIGN.md §11): replaces the round
//! engine's implicit barrier with explicit timed events over a virtual
//! clock — device FP → smashed uplink → **server compute queue** →
//! gradient downlink → device BP → merge — under three aggregation
//! policies and Poisson device churn.
//!
//! Every `(round, device)` cell still evaluates through
//! [`Scheduler::device_round`], the same pure counter-based-RNG
//! function the synchronous engine uses, so on churn-free configs the
//! `sync` policy reproduces `Scheduler::run_parallel` **bit for bit**
//! (asserted by `rust/tests/des_engine.rs` on dense-urban; with churn
//! enabled, departing devices drop cells the barrier engine would
//! still run).  `semi-sync`/`async` runs are pure functions of
//! `(config, seed)` — independent of thread count and wall-clock.
//!
//! Control-plane adapter bookkeeping applies atomically at each merge
//! instant through the [`Aggregator`]'s unordered (monotone) paths;
//! async merges carry the version they were *based on*, so
//! `Aggregator::staleness` reports real lag when stale merges land.
//!
//! ## Channel-process semantics on the virtual timeline
//!
//! The pluggable channel (`LinkProcess`, DESIGN.md §13) is sampled by
//! **round index**, not by virtual time: fading is block fading — one
//! realization per `(device, round)` cell, frozen for that cell's
//! whole timeline — and mobility advances one `round_s` tick per
//! round.  Under `sync`/`semi-sync` the round index is the global
//! round; under `async` it is the device's *personal* round counter,
//! so a fast device walks its correlated fading trace (and its
//! trajectory) faster in virtual time than a slow one.  The process
//! clock and the virtual clock are deliberately distinct: keeping
//! channel sampling round-indexed is what preserves the sync policy's
//! bit-identity with the barrier engine and keeps every cell a pure
//! function of `(config, seed, round, device)` regardless of event
//! interleaving.
//!
//! ## The multi-cell tier (DESIGN.md §15)
//!
//! With `[cells] count > 1` the single server queue becomes one
//! [`ServerQueue`] **per cell site**: a device-round's server job
//! routes to the serving cell of that `(device, round)` from the
//! precomputed [`CellGrid`] association traces, so contention, batch
//! fusion, and dispatched energy are tracked per cell.  Merges apply
//! to the cell's own [`Aggregator`] *and* to the cloud aggregator — a
//! star-to-cloud topology where the cloud sees exactly the legacy
//! unordered merge stream.  With `count = 1` every job routes to queue
//! 0 and the event timeline is bit-identical to the pre-cell engine
//! (the correctness anchor, property-tested across every preset by
//! `exp::verify::verify_single_cell_bit_identity`).
//!
//! ## Faults & recovery (DESIGN.md §17)
//!
//! With `[faults]` enabled a [`FaultProcess`] injects link outages,
//! server slot failures, and correlated regional bursts, all from
//! counter-based streams pure in their `(device, round, attempt)` /
//! `(cell, seq)` / `(round)` coordinates.  Recovery runs on the event
//! loop: interrupted transfers retry with exponential backoff + jitter
//! (the wasted partial's energy lands in `retry_energy_j`), exhausted
//! retry budgets drop the cell, sync rounds optionally demote
//! stragglers at `timeout_factor ×` the semi-sync deadline formula,
//! and burst-struck launches fail over to the hysteresis runner-up
//! cell — or degrade to the device-heavy cut when no alternate site
//! exists.  When `[faults]` is absent or all rates are zero the fault
//! plane is never constructed and the event stream is bit-identical
//! to a build without this module (the zero-perturbation anchor,
//! property-tested by `exp::verify::verify_zero_fault_rate_is_noop`).
//!
//! ## Checkpoint / resume (DESIGN.md §17)
//!
//! [`DesEngine::run_until`] stops at the first event past a virtual
//! instant and returns a [`SimSnapshot`] — the full mutable simulation
//! state (event queue, per-cell queues and aggregators, churn RNG
//! cursors, fault counters) in a serializable form.  Analytic
//! [`RoundRecord`]s are *not* stored: they are recomputed on resume
//! through the same pure `Scheduler::device_round`, which is what
//! keeps the envelope small and `resume(checkpoint(t))` bitwise
//! identical to the uninterrupted run (the gate in
//! `exp::verify::verify_checkpoint_resume_bit_identity`).

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::coordinator::aggregator::{LayerVersion, Owner};
use crate::coordinator::{Aggregator, RoundRecord, Scheduler, Strategy};
use crate::net::CellGrid;
use crate::obs::{self, trace};
use crate::policy::{PolicyBankSnap, PolicyObs};
use crate::util::stats;

use super::churn::ChurnTrace;
use super::event::{EventKind, EventQueue, SimTime};
use super::faults::{Dir, FaultProcess, Outage};
use super::server::{Batch, Job, ServerQueue, ServerQueueState, ServerStats};

/// dBm → watts, for pricing wasted partial retransmissions.
fn dbm_to_w(dbm: f64) -> f64 {
    10f64.powf((dbm - 30.0) / 10.0)
}

/// Aggregation policy for the fleet timeline.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Policy {
    /// Global round barrier — reproduces the synchronous engine's
    /// records bit-identically.
    Sync,
    /// Barrier with a straggler deadline: participants that have not
    /// merged by `deadline_factor` × (median analytic round delay +
    /// estimated queue drain) are dropped for the round.
    SemiSync { deadline_factor: f64 },
    /// No barrier: each device loops its own rounds; merges are
    /// staleness-weighted.
    Async,
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Sync => "sync",
            Policy::SemiSync { .. } => "semi-sync",
            Policy::Async => "async",
        }
    }

    /// Parse a CLI policy name; `deadline_factor` parameterizes
    /// `semi-sync` (ignored by the other policies).
    pub fn parse(s: &str, deadline_factor: f64) -> Option<Policy> {
        match s.to_ascii_lowercase().as_str() {
            "sync" => Some(Policy::Sync),
            "semi-sync" | "semisync" => Some(Policy::SemiSync { deadline_factor }),
            "async" => Some(Policy::Async),
            _ => None,
        }
    }
}

/// DES knobs on top of the experiment config.
#[derive(Clone, Copy, Debug)]
pub struct DesConfig {
    pub policy: Policy,
    /// concurrent jobs the server sustains (queue slots)
    pub capacity: usize,
    /// max jobs fused per slot dispatch
    pub batch: usize,
}

/// One completed device-round, with its DES observables alongside the
/// analytic record.
#[derive(Clone, Debug)]
pub struct DesRecord {
    pub record: RoundRecord,
    /// virtual time the cell started [s]
    pub start_s: f64,
    /// virtual time the merge landed [s]
    pub finish_s: f64,
    /// time spent queued at the server [s]
    pub wait_s: f64,
    /// merges that landed while this cell was in flight (async lag)
    pub staleness: usize,
    /// staleness weight applied at merge (1 under sync/semi-sync)
    pub weight: f64,
    /// the cell ran the degraded device-heavy cut (burst failover with
    /// no alternate cell site, DESIGN.md §17)
    pub degraded: bool,
}

impl DesRecord {
    /// Observed end-to-end latency of the cell (analytic delay + queueing).
    pub fn latency_s(&self) -> f64 {
        self.finish_s - self.start_s
    }
}

/// Per-cell observables of one DES run (DESIGN.md §15).  With
/// `[cells] count = 1` the single entry carries exactly the legacy
/// global figures.
#[derive(Clone, Debug)]
pub struct CellStats {
    /// site position [m]
    pub position_m: (f64, f64),
    /// this cell's queue/occupancy statistics
    pub server: ServerStats,
    /// Eq.-11 energy dispatched on this cell's queue [J]; summing over
    /// cells reproduces the global `energy_spent_j` exactly
    pub energy_spent_j: f64,
    /// handovers that landed on this cell (inbound re-associations)
    pub handovers_in: u64,
    /// whether this cell's own aggregation level converged
    pub aggregator_consistent: bool,
}

/// Everything a DES run produces.
#[derive(Clone, Debug)]
pub struct DesOutcome {
    /// completed cells, sorted round-major like the synchronous engine
    pub records: Vec<DesRecord>,
    pub makespan_s: f64,
    /// fleet-level queue statistics: the single cell's own stats when
    /// `count = 1` (bit-identical to the pre-cell engine), otherwise
    /// the across-cell merge (sums for counts/slot-seconds, served-
    /// weighted mean wait, max peak depth, mean utilization)
    pub server: ServerStats,
    /// per-cell queue/energy/handover breakdown (length = `[cells] count`)
    pub per_cell: Vec<CellStats>,
    /// total device→cell re-associations over the run's round horizon
    pub handovers: u64,
    /// cells abandoned to churn or the straggler deadline
    pub dropped: u64,
    /// cells launched (== records + dropped)
    pub launched: u64,
    pub departures: u64,
    pub arrivals: u64,
    /// max `Aggregator::staleness` observed across merges
    pub peak_staleness: usize,
    /// Eq.-11 server energy booked at job dispatch [J] — counts work
    /// later wasted on cancelled stragglers, which merged records omit.
    /// Always the exact sum of the per-cell accumulators.
    pub energy_spent_j: f64,
    /// the cloud (inter-server) aggregation level — sees every merge
    pub aggregator: Aggregator,
    /// link retransmission attempts scheduled (uplink + downlink)
    pub retries: u64,
    /// sync-policy stragglers demoted by the fault timeout
    pub timeout_demotions: u64,
    /// burst-struck launches rerouted to the runner-up cell or
    /// degraded to the device-heavy cut
    pub failovers: u64,
    /// capacity-slot failures hit at batch dispatch
    pub slot_failures: u64,
    /// slot repairs completed (== failures today; kept separate so the
    /// telemetry schema survives a future partial-repair model)
    pub slot_repairs: u64,
    /// energy wasted in interrupted partial transfers [J] — *extra* on
    /// top of the analytic records' one full transmission each, kept
    /// out of `energy_spent_j` (which is Eq.-11 server compute)
    pub retry_energy_j: f64,
}

/// Fleet-level [`ServerStats`] across per-cell queues.  The
/// single-queue case returns the entry untouched so `count = 1`
/// stays bit-identical to the pre-cell engine.
fn merged_server_stats(per: &[ServerStats]) -> ServerStats {
    if per.len() == 1 {
        return per[0];
    }
    let served: u64 = per.iter().map(|s| s.served_jobs).sum();
    let wait_sum: f64 = per.iter().map(|s| s.mean_wait_s * s.served_jobs as f64).sum();
    ServerStats {
        served_jobs: served,
        abandoned_jobs: per.iter().map(|s| s.abandoned_jobs).sum(),
        busy_slot_s: per.iter().map(|s| s.busy_slot_s).sum(),
        mean_wait_s: if served == 0 { 0.0 } else { wait_sum / served as f64 },
        peak_depth: per.iter().map(|s| s.peak_depth).max().unwrap_or(0),
        // time-averages sum across queues: the fleet's mean total
        // backlog is the sum of per-cell mean depths
        mean_depth: per.iter().map(|s| s.mean_depth).sum(),
        // equal per-cell capacity, so the fleet utilization is the
        // plain mean of the per-cell ratios
        utilization: per.iter().map(|s| s.utilization).sum::<f64>() / per.len() as f64,
    }
}

/// Checkpointed state of one device (presence + churn RNG cursor).
#[derive(Clone, Debug)]
pub struct DeviceSnap {
    pub present: bool,
    pub next_round: usize,
    pub rng: [u64; 4],
    pub gauss_spare: Option<f64>,
}

/// Checkpointed in-flight cell.  The analytic record and its phase
/// timing are recomputed on resume from the pure scheduler.
#[derive(Clone, Debug)]
pub struct InflightSnap {
    pub device: usize,
    pub round: usize,
    pub degraded: bool,
    /// the decided cut — lets resume rebuild a learned strategy's
    /// record without replaying bandit state (DESIGN.md §19)
    pub cut: usize,
    pub cell: usize,
    pub start_s: f64,
    pub wait_s: f64,
    pub base_version: usize,
}

/// Checkpointed completed record — only the DES observables; the
/// analytic [`RoundRecord`] is recomputed on resume.
#[derive(Clone, Debug)]
pub struct RecordSnap {
    pub device: usize,
    pub round: usize,
    pub degraded: bool,
    /// the decided cut (see [`InflightSnap::cut`])
    pub cut: usize,
    pub start_s: f64,
    pub finish_s: f64,
    pub wait_s: f64,
    pub staleness: usize,
    pub weight: f64,
}

/// Checkpointed [`Aggregator`] state.  Layer owners encode as `u64`
/// with `Owner::Server` = `u64::MAX` (a device index cannot reach it).
#[derive(Clone, Debug)]
pub struct AggSnap {
    /// per-layer `(owner, round, updates)`
    pub layers: Vec<(u64, usize, u64)>,
    pub bytes_distributed: f64,
    pub bytes_collected: f64,
    pub merges: u64,
}

fn agg_snapshot(a: &Aggregator) -> AggSnap {
    AggSnap {
        layers: a
            .layers
            .iter()
            .map(|l| {
                let owner = match l.owner {
                    Owner::Server => u64::MAX,
                    Owner::Device(d) => d as u64,
                };
                (owner, l.round, l.updates)
            })
            .collect(),
        bytes_distributed: a.bytes_distributed,
        bytes_collected: a.bytes_collected,
        merges: a.merges(),
    }
}

fn agg_restore(s: &AggSnap) -> Aggregator {
    Aggregator::from_parts(
        s.layers
            .iter()
            .map(|&(owner, round, updates)| LayerVersion {
                owner: if owner == u64::MAX {
                    Owner::Server
                } else {
                    Owner::Device(owner as usize)
                },
                round,
                updates,
            })
            .collect(),
        s.bytes_distributed,
        s.bytes_collected,
        s.merges,
    )
}

/// The full mutable state of a paused simulation (DESIGN.md §17) — in
/// concert with `(config, seed)` it determines the rest of the run
/// exactly.  Everything derivable from the config (cell grid,
/// association traces, analytic records, phase timings) is recomputed
/// on resume rather than stored.  `exp::checkpoint` serializes this to
/// the versioned text envelope.
#[derive(Clone, Debug)]
pub struct SimSnapshot {
    /// fingerprint of `(config, strategy, DES knobs)` — resume refuses
    /// a snapshot taken under a different experiment
    pub fingerprint: u64,
    pub now_s: f64,
    /// next event-queue insertion sequence number
    pub seq: u64,
    /// pending events as `(t, seq, kind)`, sorted by `(t, seq)`
    pub events: Vec<(f64, u64, EventKind)>,
    /// events processed so far (runaway-budget continuity)
    pub processed: u64,
    pub servers: Vec<ServerQueueState>,
    pub devices: Vec<DeviceSnap>,
    pub actives: Vec<Option<usize>>,
    pub inflight: Vec<InflightSnap>,
    pub agg: AggSnap,
    pub cell_aggs: Vec<AggSnap>,
    pub version: usize,
    pub records: Vec<RecordSnap>,
    pub barrier_round: usize,
    pub barrier_outstanding: usize,
    pub barrier_open: bool,
    pub remaining_budget: usize,
    pub launched: u64,
    pub dropped: u64,
    pub departures: u64,
    pub arrivals: u64,
    pub peak_staleness: usize,
    pub makespan_s: f64,
    pub energy_by_cell: Vec<f64>,
    pub dispatch_seqs: Vec<u64>,
    pub retries: u64,
    pub timeout_demotions: u64,
    pub failovers: u64,
    pub slot_failures: u64,
    pub slot_repairs: u64,
    pub retry_energy_j: f64,
    /// bandit state of a learned strategy (`None` for oracles) —
    /// restored verbatim, never replayed (DESIGN.md §19)
    pub policy: Option<PolicyBankSnap>,
}

/// Result of [`DesEngine::run_until`] / [`DesEngine::resume_until`].
pub enum RunState {
    /// the requested instant was reached with events still pending
    Checkpoint(Box<SimSnapshot>),
    /// the timeline drained before the requested instant
    Done(Box<DesOutcome>),
}

/// Fingerprint of everything that determines the event stream, so
/// resume can refuse a checkpoint from a different experiment.
/// FNV-1a over the `Debug` rendering — cheap, collision-safe enough
/// for a sanity gate, and stable for a given build.
fn config_fingerprint(sched: &Scheduler, des: DesConfig) -> u64 {
    let repr = format!("{:?}|{:?}|{:?}", sched.cfg, sched.strategy, des);
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in repr.as_bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

/// Discrete-event engine over a [`Scheduler`]'s config and cost model.
/// Owns the scheduler through an `Arc` (shared with the caller and the
/// `exp::Engine` wrapper) — no borrowed lifetime, so the engine can
/// live inside trait objects.
pub struct DesEngine {
    sched: Arc<Scheduler>,
    des: DesConfig,
}

impl DesEngine {
    pub fn new(sched: Arc<Scheduler>, des: DesConfig) -> DesEngine {
        DesEngine { sched, des }
    }

    /// The scheduler this engine evaluates cells through.
    pub fn scheduler(&self) -> &Scheduler {
        &self.sched
    }

    /// Run the simulation to completion.  Strictly serial and
    /// deterministic; see the module docs for why.
    pub fn run(&self) -> DesOutcome {
        let mut sim = Sim::new(&self.sched, self.des);
        sim.prologue();
        while sim.step() {}
        sim.finish()
    }

    /// Run until the first pending event *past* virtual time `t_s` and
    /// checkpoint there, or to completion if the timeline drains first.
    pub fn run_until(&self, t_s: f64) -> RunState {
        let mut sim = Sim::new(&self.sched, self.des);
        sim.prologue();
        sim.advance(t_s)
    }

    /// Continue a checkpointed run to completion.  Bit-identical to
    /// the uninterrupted run — the checkpoint/resume anchor.
    pub fn resume(&self, snap: &SimSnapshot) -> DesOutcome {
        let mut sim = Sim::restore(&self.sched, self.des, snap);
        while sim.step() {}
        sim.finish()
    }

    /// Continue a checkpointed run until `t_s`, re-checkpointing there
    /// (checkpoints compose: pausing twice equals pausing once).
    pub fn resume_until(&self, snap: &SimSnapshot, t_s: f64) -> RunState {
        Sim::restore(&self.sched, self.des, snap).advance(t_s)
    }
}

/// Phase durations of one cell on the DES timeline.  The decomposition
/// refines Eqs. (7)–(10) — the phase sums match the analytic round
/// delay up to floating-point association, while `record.delay_s`
/// itself stays bit-identical to the synchronous engine.
struct CellTiming {
    fp_s: f64,
    up_s: f64,
    down_s: f64,
    bp_s: f64,
}

struct Inflight {
    record: RoundRecord,
    start_s: f64,
    wait_s: f64,
    /// global merge version when the cell started (async staleness base)
    base_version: usize,
    up_s: f64,
    down_s: f64,
    bp_s: f64,
    /// the cell queue this job routes to — the serving cell, unless a
    /// burst failover rerouted the launch (DESIGN.md §17)
    cell: usize,
    /// running the degraded device-heavy cut (single-cell burst)
    degraded: bool,
}

struct DeviceState {
    present: bool,
    /// next personal round index (async cell coordinate)
    next_round: usize,
    churn: ChurnTrace,
}

struct Sim<'a> {
    sched: &'a Scheduler,
    des: DesConfig,
    q: EventQueue,
    /// cell sites + precomputed device→cell association (read-only)
    cells: CellGrid,
    /// one compute queue per cell site (index = cell)
    servers: Vec<ServerQueue>,
    devices: Vec<DeviceState>,
    /// round coordinate of each device's in-flight cell, if any — the
    /// single source of truth for cell liveness (also read by the
    /// server queue's cancellation filter without any per-event copy)
    actives: Vec<Option<usize>>,
    inflight: BTreeMap<(usize, usize), Inflight>,
    /// the cloud aggregation level — receives every merge
    agg: Aggregator,
    /// per-cell aggregation levels of the star-to-cloud topology
    cell_aggs: Vec<Aggregator>,
    /// global merge version (counts applied merges)
    version: usize,
    records: Vec<DesRecord>,
    /// global rounds (sync/semi-sync)
    rounds: usize,
    // barrier state (sync/semi-sync)
    barrier_round: usize,
    barrier_outstanding: usize,
    barrier_open: bool,
    /// async: device-round completions still owed
    remaining_budget: usize,
    done: bool,
    launched: u64,
    dropped: u64,
    departures: u64,
    arrivals: u64,
    peak_staleness: usize,
    makespan_s: f64,
    /// Eq.-11 server energy booked when jobs dispatch, per cell —
    /// includes work later wasted on cancelled stragglers, unlike the
    /// merged records.  The global figure is the exact sum.
    energy_by_cell: Vec<f64>,
    /// events processed (runaway budget + obs shard hint); checkpoint
    /// carries it so the budget is continuous across resume
    processed: u64,
    /// fault sampler — `None` whenever `[faults]` is absent or every
    /// injection rate is zero, in which case no fault branch below can
    /// perturb the timeline (the zero-perturbation contract)
    faults: Option<FaultProcess>,
    /// per-cell batch dispatch counter — the slot-failure stream's
    /// `seq` coordinate (only advanced when the fault plane is live)
    dispatch_seqs: Vec<u64>,
    /// lazily built `Strategy::DeviceOnly` scheduler for degraded cuts
    degraded_sched: Option<Scheduler>,
    retries: u64,
    timeout_demotions: u64,
    failovers: u64,
    slot_failures: u64,
    slot_repairs: u64,
    retry_energy_j: f64,
}

impl<'a> Sim<'a> {
    fn new(sched: &'a Scheduler, des: DesConfig) -> Sim<'a> {
        let n = sched.cfg.devices.len();
        let rounds = sched.cfg.workload.rounds;
        let churn_root = sched.cfg.seed ^ 0xDE5C_4u64;
        let devices = (0..n)
            .map(|i| DeviceState {
                present: true,
                next_round: 0,
                churn: ChurnTrace::new(churn_root, i, &sched.cfg.churn),
            })
            .collect();
        // Association traces precompute over the configured round
        // horizon; async personal rounds past it keep the horizon's
        // last assignment (CellGrid::cell_of clamps).
        let cells = CellGrid::new(
            &sched.cfg.cells,
            &sched.cfg.server,
            sched.link.mobility(),
            n,
            rounds,
            sched.link.channel.state.pathloss_exp(),
        );
        let servers = (0..cells.count())
            .map(|_| ServerQueue::new(des.capacity, des.batch))
            .collect();
        let cell_aggs = (0..cells.count())
            .map(|_| Aggregator::new(sched.cost_model.n_layers()))
            .collect();
        let energy_by_cell = vec![0.0; cells.count()];
        let faults = if sched.cfg.faults.enabled() {
            Some(FaultProcess::new(sched.cfg.seed, &sched.cfg.faults, n))
        } else {
            None
        };
        let dispatch_seqs = vec![0u64; cells.count()];
        Sim {
            sched,
            des,
            q: EventQueue::new(),
            cells,
            servers,
            devices,
            actives: vec![None; n],
            inflight: BTreeMap::new(),
            agg: Aggregator::new(sched.cost_model.n_layers()),
            cell_aggs,
            version: 0,
            records: Vec::new(),
            rounds,
            barrier_round: 0,
            barrier_outstanding: 0,
            barrier_open: false,
            remaining_budget: rounds * n,
            done: false,
            launched: 0,
            dropped: 0,
            departures: 0,
            arrivals: 0,
            peak_staleness: 0,
            makespan_s: 0.0,
            energy_by_cell,
            processed: 0,
            faults,
            dispatch_seqs,
            degraded_sched: None,
            retries: 0,
            timeout_demotions: 0,
            failovers: 0,
            slot_failures: 0,
            slot_repairs: 0,
            retry_energy_j: 0.0,
        }
    }

    /// Rebuild a paused simulation from a checkpoint.  Everything
    /// config-derived comes back through [`Sim::new`]; the snapshot
    /// overwrites the mutable state, and in-flight/completed analytic
    /// records are recomputed through the pure scheduler.
    fn restore(sched: &'a Scheduler, des: DesConfig, snap: &SimSnapshot) -> Sim<'a> {
        assert_eq!(
            snap.fingerprint,
            config_fingerprint(sched, des),
            "checkpoint was taken under a different experiment config"
        );
        let mut sim = Sim::new(sched, des);
        sim.q = EventQueue::restore(
            SimTime::new(snap.now_s),
            snap.seq,
            snap.events
                .iter()
                .map(|(t, s, k)| (SimTime::new(*t), *s, k.clone()))
                .collect(),
        );
        sim.processed = snap.processed;
        sim.servers = snap
            .servers
            .iter()
            .map(|st| ServerQueue::restore(des.capacity, des.batch, st.clone()))
            .collect();
        for (d, ds) in sim.devices.iter_mut().zip(&snap.devices) {
            d.present = ds.present;
            d.next_round = ds.next_round;
            d.churn.restore_rng(ds.rng, ds.gauss_spare);
        }
        sim.actives = snap.actives.clone();
        sim.agg = agg_restore(&snap.agg);
        sim.cell_aggs = snap.cell_aggs.iter().map(agg_restore).collect();
        sim.version = snap.version;
        sim.barrier_round = snap.barrier_round;
        sim.barrier_outstanding = snap.barrier_outstanding;
        sim.barrier_open = snap.barrier_open;
        sim.remaining_budget = snap.remaining_budget;
        sim.launched = snap.launched;
        sim.dropped = snap.dropped;
        sim.departures = snap.departures;
        sim.arrivals = snap.arrivals;
        sim.peak_staleness = snap.peak_staleness;
        sim.makespan_s = snap.makespan_s;
        sim.energy_by_cell = snap.energy_by_cell.clone();
        sim.dispatch_seqs = snap.dispatch_seqs.clone();
        sim.retries = snap.retries;
        sim.timeout_demotions = snap.timeout_demotions;
        sim.failovers = snap.failovers;
        sim.slot_failures = snap.slot_failures;
        sim.slot_repairs = snap.slot_repairs;
        sim.retry_energy_j = snap.retry_energy_j;
        if let Some(p) = &snap.policy {
            sim.sched
                .policy_restore(p)
                .expect("checkpoint policy state does not fit this strategy");
        } else {
            // oracle checkpoint: make sure no stale bank state from a
            // previous run on this scheduler leaks into the resume
            sim.sched.policy_reset();
        }
        for s in &snap.inflight {
            let rec = if s.degraded {
                sim.degraded_record(s.round, s.device)
            } else if sim.sched.policy_enabled() {
                // replay the decision by its recorded cut — the bank
                // has already advanced past this cell's launch state
                sim.sched.device_round_forced(s.round, s.device, s.cut)
            } else {
                sim.sched.device_round(s.round, s.device)
            };
            let timing = sim.timing(&rec);
            sim.inflight.insert(
                (s.device, s.round),
                Inflight {
                    record: rec,
                    start_s: s.start_s,
                    wait_s: s.wait_s,
                    base_version: s.base_version,
                    up_s: timing.up_s,
                    down_s: timing.down_s,
                    bp_s: timing.bp_s,
                    cell: s.cell,
                    degraded: s.degraded,
                },
            );
        }
        for s in &snap.records {
            let rec = if s.degraded {
                sim.degraded_record(s.round, s.device)
            } else if sim.sched.policy_enabled() {
                sim.sched.device_round_forced(s.round, s.device, s.cut)
            } else {
                sim.sched.device_round(s.round, s.device)
            };
            sim.records.push(DesRecord {
                record: rec,
                start_s: s.start_s,
                finish_s: s.finish_s,
                wait_s: s.wait_s,
                staleness: s.staleness,
                weight: s.weight,
                degraded: s.degraded,
            });
        }
        sim
    }

    /// Freeze the full mutable state (see [`SimSnapshot`]).
    fn snapshot(&self) -> SimSnapshot {
        let (now, seq, events) = self.q.snapshot();
        SimSnapshot {
            fingerprint: config_fingerprint(self.sched, self.des),
            now_s: now.secs(),
            seq,
            events: events.into_iter().map(|(t, s, k)| (t.secs(), s, k)).collect(),
            processed: self.processed,
            servers: self.servers.iter().map(|s| s.snapshot()).collect(),
            devices: self
                .devices
                .iter()
                .map(|d| {
                    let (rng, gauss_spare) = d.churn.rng_state();
                    DeviceSnap {
                        present: d.present,
                        next_round: d.next_round,
                        rng,
                        gauss_spare,
                    }
                })
                .collect(),
            actives: self.actives.clone(),
            inflight: self
                .inflight
                .iter()
                .map(|(&(device, round), inf)| InflightSnap {
                    device,
                    round,
                    degraded: inf.degraded,
                    cut: inf.record.cut,
                    cell: inf.cell,
                    start_s: inf.start_s,
                    wait_s: inf.wait_s,
                    base_version: inf.base_version,
                })
                .collect(),
            agg: agg_snapshot(&self.agg),
            cell_aggs: self.cell_aggs.iter().map(agg_snapshot).collect(),
            version: self.version,
            records: self
                .records
                .iter()
                .map(|r| RecordSnap {
                    device: r.record.device_idx,
                    round: r.record.round,
                    degraded: r.degraded,
                    cut: r.record.cut,
                    start_s: r.start_s,
                    finish_s: r.finish_s,
                    wait_s: r.wait_s,
                    staleness: r.staleness,
                    weight: r.weight,
                })
                .collect(),
            barrier_round: self.barrier_round,
            barrier_outstanding: self.barrier_outstanding,
            barrier_open: self.barrier_open,
            remaining_budget: self.remaining_budget,
            launched: self.launched,
            dropped: self.dropped,
            departures: self.departures,
            arrivals: self.arrivals,
            peak_staleness: self.peak_staleness,
            makespan_s: self.makespan_s,
            energy_by_cell: self.energy_by_cell.clone(),
            dispatch_seqs: self.dispatch_seqs.clone(),
            retries: self.retries,
            timeout_demotions: self.timeout_demotions,
            failovers: self.failovers,
            slot_failures: self.slot_failures,
            slot_repairs: self.slot_repairs,
            retry_energy_j: self.retry_energy_j,
            policy: self.sched.policy_snapshot(),
        }
    }

    /// Seed the timeline: churn departures + the first round/launches.
    fn prologue(&mut self) {
        // learned strategies start every run from a blank bandit bank
        // (resume skips the prologue and restores the bank instead)
        self.sched.policy_reset();
        // seed churn: every device starts present; its first departure
        // (if it churns at all) comes from its private stream
        for i in 0..self.devices.len() {
            if let Some(dt) = self.devices[i].churn.next_present_s() {
                self.q.push_after(dt, EventKind::Depart { device: i });
            }
        }
        match self.des.policy {
            Policy::Sync | Policy::SemiSync { .. } => self.start_global_round(0),
            Policy::Async => {
                for i in 0..self.devices.len() {
                    self.launch_async(i);
                }
            }
        }
    }

    /// Pop and process one event.  Returns `false` once the timeline
    /// is exhausted or the run completed.
    fn step(&mut self) -> bool {
        let Some((t, ev)) = self.q.pop() else {
            return false;
        };
        self.processed += 1;
        assert!(
            self.processed < 50_000_000,
            "DES event budget exceeded — runaway simulation"
        );
        self.makespan_s = t.secs();
        // observation only (DESIGN.md §16): the pop already
        // happened, the queue depth is whatever remains
        obs::metrics().des_events.inc(self.processed as usize);
        obs::metrics().des_queue_depth.observe(self.q.len() as u64);
        match ev {
            EventKind::Arrive { device } => self.on_arrive(device),
            EventKind::Depart { device } => self.on_depart(device),
            EventKind::UplinkDone { device, round } => self.on_uplink_done(device, round),
            EventKind::ServerBatchDone { cell, jobs } => self.on_server_batch_done(cell, jobs),
            EventKind::MergeReady { device, round } => self.on_merge_ready(device, round),
            EventKind::Deadline { round } => self.on_deadline(round),
            EventKind::RetryUplink {
                device,
                round,
                attempt,
            } => self.on_retry(Dir::Up, device, round, attempt),
            EventKind::RetryDownlink {
                device,
                round,
                attempt,
            } => self.on_retry(Dir::Down, device, round, attempt),
        }
        if let Policy::Async = self.des.policy {
            if self.remaining_budget == 0 && self.inflight.is_empty() {
                self.done = true;
            }
        }
        !self.done
    }

    /// Step until the first pending event strictly past `t_s`, then
    /// checkpoint; finish if the timeline drains first.
    fn advance(mut self, t_s: f64) -> RunState {
        loop {
            match self.q.peek_time() {
                Some(t) if t.secs() > t_s => {
                    return RunState::Checkpoint(Box::new(self.snapshot()))
                }
                Some(_) => {
                    if !self.step() {
                        break;
                    }
                }
                None => break,
            }
        }
        RunState::Done(Box::new(self.finish()))
    }

    fn finish(mut self) -> DesOutcome {
        // purge cancelled jobs still queued so the depth/abandonment
        // stats describe real waiters, not dead entries
        let now = self.q.now();
        let actives = &self.actives;
        for server in &mut self.servers {
            server.flush_cancelled(now, |d, k| actives[d] == Some(k));
        }

        // round-major record stream, like the synchronous engine's
        self.records
            .sort_by_key(|r| (r.record.round, r.record.device_idx));
        let per_cell: Vec<CellStats> = (0..self.cells.count())
            .map(|c| {
                let server = self.servers[c].stats(self.makespan_s);
                obs::metrics().des_server_utilization.observe(server.utilization);
                CellStats {
                    position_m: self.cells.position(c),
                    server,
                    energy_spent_j: self.energy_by_cell[c],
                    handovers_in: self.cells.handovers_into(c),
                    aggregator_consistent: self.cell_aggs[c].is_consistent(),
                }
            })
            .collect();
        let server = merged_server_stats(
            &per_cell.iter().map(|c| c.server).collect::<Vec<_>>(),
        );
        DesOutcome {
            records: self.records,
            makespan_s: self.makespan_s,
            server,
            handovers: self.cells.total_handovers(),
            per_cell,
            dropped: self.dropped,
            launched: self.launched,
            departures: self.departures,
            arrivals: self.arrivals,
            peak_staleness: self.peak_staleness,
            // the global figure is defined as the per-cell sum, so the
            // two can never drift apart (and the single-cell sum is the
            // lone accumulator, bit-identical to the pre-cell engine)
            energy_spent_j: self.energy_by_cell.iter().sum(),
            aggregator: self.agg,
            retries: self.retries,
            timeout_demotions: self.timeout_demotions,
            failovers: self.failovers,
            slot_failures: self.slot_failures,
            slot_repairs: self.slot_repairs,
            retry_energy_j: self.retry_energy_j,
        }
    }

    /// Phase decomposition for one cell (see `CellTiming`).
    fn timing(&self, rec: &RoundRecord) -> CellTiming {
        let dm = &self.sched.cost_model.delay;
        let t = dm.epochs;
        // FP share of device compute from the FLOP model's per-layer
        // forward vs total-train cost (BP is the remainder)
        let frac = dm.flops.layer_fwd() / dm.flops.layer_train().max(f64::MIN_POSITIVE);
        let fp_s = rec.device_compute_s * frac;
        let up_s = 8.0
            * (t * dm.sizes.smashed_wire_bytes(rec.cut) + dm.sizes.adapter_bytes(rec.cut))
            / rec.rate_up_bps;
        let down_s = 8.0
            * (t * dm.sizes.grad_wire_bytes(rec.cut) + dm.sizes.adapter_bytes(rec.cut))
            / rec.rate_down_bps;
        CellTiming {
            fp_s,
            up_s,
            down_s,
            bp_s: rec.device_compute_s - fp_s,
        }
    }

    fn is_active(&self, device: usize, round: usize) -> bool {
        self.actives[device] == Some(round)
    }

    fn schedule_batches(&mut self, cell: usize, batches: Vec<Batch>) {
        let now = self.q.now();
        for b in batches {
            // a failed capacity slot delays the whole fused dispatch by
            // its exponential repair time, occupying the slot meanwhile
            let repair_s = if self.faults.is_some() {
                let seq = self.dispatch_seqs[cell];
                self.dispatch_seqs[cell] += 1;
                self.faults
                    .as_ref()
                    .and_then(|f| f.slot_failure(cell, seq))
                    .unwrap_or(0.0)
            } else {
                0.0
            };
            if repair_s > 0.0 {
                self.slot_failures += 1;
                self.slot_repairs += 1;
                self.servers[cell].add_busy_s(repair_s);
                obs::metrics().des_fault_slot_failures.inc(cell);
                obs::metrics().des_fault_slot_repairs.inc(cell);
                if trace::active() {
                    trace::sim_span(
                        "slot_repair",
                        "des.faults",
                        cell,
                        now.secs(),
                        now.secs() + repair_s,
                        vec![("jobs", b.jobs.len() as f64)],
                    );
                }
            }
            for j in &b.jobs {
                if let Some(inf) = self.inflight.get_mut(&(j.device, j.round)) {
                    inf.wait_s = now.secs() - j.enqueued_at.secs();
                    // Eq.-11 energy is committed once the job runs,
                    // whether or not its merge survives — booked on the
                    // cell whose queue dispatched it
                    self.energy_by_cell[cell] += inf.record.energy_j;
                    obs::metrics().des_queue_wait_s.observe(inf.wait_s);
                    if trace::active() && inf.wait_s > 0.0 {
                        trace::sim_span(
                            "queue_wait",
                            "des.server",
                            cell,
                            j.enqueued_at.secs(),
                            now.secs(),
                            vec![("device", j.device as f64), ("round", j.round as f64)],
                        );
                    }
                }
            }
            if trace::active() {
                trace::sim_span(
                    "batch_service",
                    "des.server",
                    cell,
                    now.secs() + repair_s,
                    now.secs() + repair_s + b.service_s,
                    vec![("jobs", b.jobs.len() as f64)],
                );
            }
            let ids: Vec<(usize, usize)> = b.jobs.iter().map(|j| (j.device, j.round)).collect();
            self.q
                .push_after(repair_s + b.service_s, EventKind::ServerBatchDone { cell, jobs: ids });
        }
    }

    fn launch_cell(&mut self, device: usize, round: usize, rec: RoundRecord) {
        let mut rec = rec;
        let mut cell = self.cells.cell_of(device, round);
        let mut degraded = false;
        // correlated regional burst: launches inside the dropout disk
        // cannot use the serving cell's link this round
        let burst = match &self.faults {
            Some(f) => match f.burst_center(round) {
                Some(center) => {
                    let mob = self.sched.link.mobility();
                    f.in_burst(mob.position_at(device, round), mob.position_at(center, round))
                }
                None => false,
            },
            None => false,
        };
        if burst {
            self.failovers += 1;
            obs::metrics().des_fault_failovers.inc(device);
            let second = self.cells.second_cell_of(device, round);
            if second != cell {
                // graceful degradation, multi-cell: ride the hysteresis
                // runner-up site while the burst blankets the serving cell
                cell = second;
                if trace::active() {
                    trace::sim_instant(
                        "burst_failover",
                        "des.faults",
                        cell,
                        self.q.now().secs(),
                        vec![("device", device as f64), ("round", round as f64)],
                    );
                }
            } else {
                // single site: no alternate cell — fall back to the
                // device-heavy cut so the burst region's link carries
                // as little of the round as possible
                rec = self.degraded_record(round, device);
                degraded = true;
                if trace::active() {
                    trace::sim_instant(
                        "degraded_cut",
                        "des.faults",
                        cell,
                        self.q.now().secs(),
                        vec![
                            ("device", device as f64),
                            ("round", round as f64),
                            ("cut", rec.cut as f64),
                        ],
                    );
                }
            }
        }
        let timing = self.timing(&rec);
        self.actives[device] = Some(round);
        self.launched += 1;
        if self.cells.count() > 1 && round > 0 {
            let serving = self.cells.cell_of(device, round);
            if serving != self.cells.cell_of(device, round - 1) {
                obs::metrics().des_handovers.inc(device);
                if trace::active() {
                    trace::sim_instant(
                        "handover",
                        "des.cells",
                        serving,
                        self.q.now().secs(),
                        vec![("device", device as f64), ("round", round as f64)],
                    );
                }
            }
        }
        self.inflight.insert(
            (device, round),
            Inflight {
                record: rec,
                start_s: self.q.now().secs(),
                wait_s: 0.0,
                base_version: self.version,
                up_s: timing.up_s,
                down_s: timing.down_s,
                bp_s: timing.bp_s,
                cell,
                degraded,
            },
        );
        self.start_uplink(device, round, 0, timing.fp_s);
    }

    /// The degraded device-heavy record for a burst-struck launch with
    /// no alternate cell.  The `DeviceOnly` scheduler shares the exact
    /// config and channel state, so its records are the same pure
    /// function of `(round, device)` — resume recomputes them.
    fn degraded_record(&mut self, round: usize, device: usize) -> RoundRecord {
        if self.degraded_sched.is_none() {
            self.degraded_sched = Some(Scheduler::new(
                self.sched.cfg.clone(),
                self.sched.link.channel.state,
                Strategy::DeviceOnly,
            ));
        }
        self.degraded_sched.as_ref().unwrap().device_round(round, device)
    }

    /// Begin uplink attempt `attempt` of `(device, round)`.  `lead_s`
    /// is the device FP time preceding the transfer (attempt 0 only).
    fn start_uplink(&mut self, device: usize, round: usize, attempt: usize, lead_s: f64) {
        let (up_s, cell) = {
            let inf = &self.inflight[&(device, round)];
            (inf.up_s, inf.cell)
        };
        match self
            .faults
            .as_ref()
            .and_then(|f| f.link_outage(Dir::Up, device, round, attempt, up_s))
        {
            None => self
                .q
                .push_after(lead_s + up_s, EventKind::UplinkDone { device, round }),
            Some(o) => {
                let wasted_s = o.frac * up_s;
                // the interrupted partial is pure waste on top of the
                // analytic record's one full transmission
                self.retry_energy_j +=
                    dbm_to_w(self.sched.cfg.channel.tx_power_device_dbm) * wasted_s;
                self.after_outage(
                    Dir::Up,
                    device,
                    round,
                    attempt,
                    cell,
                    lead_s + wasted_s,
                    &o,
                );
            }
        }
    }

    /// Begin downlink attempt `attempt`; device BP follows on success.
    fn start_downlink(&mut self, device: usize, round: usize, attempt: usize) {
        let (down_s, bp_s, cell) = {
            let inf = &self.inflight[&(device, round)];
            (inf.down_s, inf.bp_s, inf.cell)
        };
        match self
            .faults
            .as_ref()
            .and_then(|f| f.link_outage(Dir::Down, device, round, attempt, down_s))
        {
            None => self
                .q
                .push_after(down_s + bp_s, EventKind::MergeReady { device, round }),
            Some(o) => {
                let wasted_s = o.frac * down_s;
                self.retry_energy_j +=
                    dbm_to_w(self.sched.cfg.channel.tx_power_ap_dbm) * wasted_s;
                self.after_outage(Dir::Down, device, round, attempt, cell, wasted_s, &o);
            }
        }
    }

    /// Common recovery path after an outage cut attempt `attempt`
    /// short: schedule the backed-off retransmission, or — when the
    /// retry budget is spent — the give-up event at the instant the
    /// final partial dies (`fail_dt` from now).
    fn after_outage(
        &mut self,
        dir: Dir,
        device: usize,
        round: usize,
        attempt: usize,
        cell: usize,
        fail_dt: f64,
        o: &Outage,
    ) {
        if trace::active() {
            trace::sim_instant(
                "link_outage",
                "des.faults",
                cell,
                self.q.now().secs() + fail_dt,
                vec![
                    ("device", device as f64),
                    ("round", round as f64),
                    ("attempt", attempt as f64),
                    ("dir", if dir == Dir::Up { 0.0 } else { 1.0 }),
                    ("frac", o.frac),
                ],
            );
        }
        let max = self.faults.as_ref().map(|f| f.max_retries()).unwrap_or(0);
        let next = match dir {
            Dir::Up => EventKind::RetryUplink {
                device,
                round,
                attempt: attempt + 1,
            },
            Dir::Down => EventKind::RetryDownlink {
                device,
                round,
                attempt: attempt + 1,
            },
        };
        if attempt < max {
            self.retries += 1;
            obs::metrics().des_fault_retries.inc(device);
            obs::metrics().des_fault_backoff_s.observe(o.backoff_s);
            self.q.push_after(fail_dt + o.backoff_s, next);
        } else {
            // the handler sees attempt > max_retries and drops the cell
            self.q.push_after(fail_dt, next);
        }
    }

    /// A retry event fired: retransmit, or give up if the budget is out.
    fn on_retry(&mut self, dir: Dir, device: usize, round: usize, attempt: usize) {
        if !self.is_active(device, round) {
            return; // cancelled (churn/timeout) while backing off
        }
        let max = self.faults.as_ref().map(|f| f.max_retries()).unwrap_or(0);
        if attempt > max {
            self.drop_exhausted(device, round);
            return;
        }
        match dir {
            Dir::Up => self.start_uplink(device, round, attempt, 0.0),
            Dir::Down => self.start_downlink(device, round, attempt),
        }
    }

    /// The retry budget ran out mid-transfer: abandon the cell.  Async
    /// budget is *not* refunded — the round was consumed and produced
    /// no merge, exactly like a semi-sync straggler drop.
    fn drop_exhausted(&mut self, device: usize, round: usize) {
        let Some(inf) = self.inflight.remove(&(device, round)) else {
            return;
        };
        self.actives[device] = None;
        self.dropped += 1;
        if trace::active() {
            trace::sim_instant(
                "retry_exhausted",
                "des.faults",
                inf.cell,
                self.q.now().secs(),
                vec![("device", device as f64), ("round", round as f64)],
            );
        }
        match self.des.policy {
            Policy::Sync | Policy::SemiSync { .. } => self.resolve_barrier_slot(),
            Policy::Async => self.launch_async(device),
        }
    }

    /// Async: start the device's next personal round, if budget remains.
    fn launch_async(&mut self, device: usize) {
        if self.remaining_budget == 0
            || !self.devices[device].present
            || self.actives[device].is_some()
        {
            return;
        }
        self.remaining_budget -= 1;
        let round = self.devices[device].next_round;
        self.devices[device].next_round += 1;
        let rec = self.sched.device_round(round, device);
        // async has no round barrier: fold the realized cost per launch,
        // in serial event order — the virtual-timeline reward boundary
        self.observe_policy_launch(&rec);
        self.launch_cell(device, round, rec);
    }

    /// Feed one launched cell's realized cost back to the learned
    /// policy (no-op for oracle strategies).  The reward is the cost of
    /// the cut the bandit *chose* — a burst failover may still degrade
    /// the launched record afterwards, but that is the fault plane's
    /// business, not the arm's.
    fn observe_policy_launch(&self, rec: &RoundRecord) {
        if self.sched.policy_enabled() {
            self.sched.policy_observe(&[PolicyObs {
                device_idx: rec.device_idx,
                snr_up_db: rec.snr_up_db,
                cut: rec.cut,
                cost: rec.cost,
            }]);
        }
    }

    /// Sync/semi-sync: open global round `round` with every present
    /// device; defer if the fleet is momentarily empty (churn).
    fn start_global_round(&mut self, round: usize) {
        self.barrier_round = round;
        self.barrier_open = false;
        let present: Vec<usize> = (0..self.devices.len())
            .filter(|&i| self.devices[i].present)
            .collect();
        if present.is_empty() {
            return; // the next Arrive restarts us
        }
        self.barrier_outstanding = present.len();
        self.barrier_open = true;
        let mut delays = Vec::with_capacity(present.len());
        let mut services = Vec::with_capacity(present.len());
        let mut rewards = Vec::new();
        for &i in &present {
            let rec = self.sched.device_round(round, i);
            delays.push(rec.delay_s);
            services.push(rec.server_compute_s);
            if self.sched.policy_enabled() {
                rewards.push(PolicyObs {
                    device_idx: rec.device_idx,
                    snr_up_db: rec.snr_up_db,
                    cut: rec.cut,
                    cost: rec.cost,
                });
            }
            self.launch_cell(i, round, rec);
        }
        // fold after the whole barrier launches, in device order — the
        // exact reward boundary the round engine uses, so churn-free
        // sync DES stays bit-identical to it for learned strategies too
        self.sched.policy_observe(&rewards);
        let factor = match self.des.policy {
            Policy::SemiSync { deadline_factor } => Some(deadline_factor),
            // sync + faults: `timeout_factor` demotes the round's
            // stragglers through the same dropout path (DESIGN.md §17)
            Policy::Sync => self
                .faults
                .as_ref()
                .map(|f| f.spec().timeout_factor)
                .filter(|&t| t > 0.0),
            Policy::Async => None,
        };
        if let Some(factor) = factor {
            // deadline = factor × (median analytic round delay + the
            // serialization the *most loaded cell's* queue adds when
            // its participants share C slots).  With one cell the max
            // load is the whole barrier — the legacy formula exactly.
            let mut per_cell_load = vec![0usize; self.cells.count()];
            for &i in &present {
                per_cell_load[self.cells.cell_of(i, round)] += 1;
            }
            let max_load = per_cell_load.iter().copied().max().unwrap_or(0);
            let drain_batches =
                (max_load as f64 / self.servers[0].capacity() as f64).ceil() - 1.0;
            let deadline = factor
                * (stats::median(&delays) + drain_batches.max(0.0) * stats::median(&services));
            self.q.push_after(deadline, EventKind::Deadline { round });
        }
    }

    fn close_global_round(&mut self) {
        self.barrier_open = false;
        let next = self.barrier_round + 1;
        if next >= self.rounds {
            self.done = true;
        } else {
            self.start_global_round(next);
        }
    }

    /// A barrier participant resolved (merge or cancel).
    fn resolve_barrier_slot(&mut self) {
        debug_assert!(self.barrier_open && self.barrier_outstanding > 0);
        self.barrier_outstanding -= 1;
        if self.barrier_outstanding == 0 {
            self.close_global_round();
        }
    }

    /// Abandon the device's in-flight cell (churn departure).
    fn cancel_active(&mut self, device: usize) {
        if let Some(round) = self.actives[device].take() {
            let cell = self
                .inflight
                .remove(&(device, round))
                .map(|i| i.cell)
                .unwrap_or(0);
            self.dropped += 1;
            obs::metrics().des_drops_churn.inc(device);
            if trace::active() {
                trace::sim_instant(
                    "churn_cancel",
                    "des.churn",
                    cell,
                    self.q.now().secs(),
                    vec![("device", device as f64), ("round", round as f64)],
                );
            }
            match self.des.policy {
                Policy::Sync | Policy::SemiSync { .. } => self.resolve_barrier_slot(),
                Policy::Async => {
                    // the freed budget goes to any idle present device
                    // (a device that merged while the budget was
                    // exhausted has no other wake-up)
                    self.remaining_budget += 1;
                    self.relaunch_idle();
                }
            }
        }
    }

    /// Async: hand available budget to idle present devices.
    fn relaunch_idle(&mut self) {
        for i in 0..self.devices.len() {
            if self.remaining_budget == 0 {
                break;
            }
            self.launch_async(i);
        }
    }

    fn on_arrive(&mut self, device: usize) {
        if self.devices[device].present {
            return;
        }
        self.devices[device].present = true;
        self.arrivals += 1;
        if let Some(up) = self.devices[device].churn.next_present_s() {
            self.q.push_after(up, EventKind::Depart { device });
        }
        match self.des.policy {
            Policy::Async => self.launch_async(device),
            Policy::Sync | Policy::SemiSync { .. } => {
                // join at the next barrier; if the round start was
                // deferred because the fleet emptied, start it now
                if !self.barrier_open && !self.done {
                    self.start_global_round(self.barrier_round);
                }
            }
        }
    }

    fn on_depart(&mut self, device: usize) {
        if !self.devices[device].present {
            return;
        }
        self.devices[device].present = false;
        self.departures += 1;
        self.cancel_active(device);
        if let Some(away) = self.devices[device].churn.next_away_s() {
            self.q.push_after(away, EventKind::Arrive { device });
        }
    }

    fn on_uplink_done(&mut self, device: usize, round: usize) {
        if !self.is_active(device, round) {
            return;
        }
        let inf = &self.inflight[&(device, round)];
        let job = Job {
            device,
            round,
            service_s: inf.record.server_compute_s,
            enqueued_at: self.q.now(),
        };
        // route to the cell chosen at launch — the precomputed serving
        // cell of this (device, round), unless a burst failover rerouted
        let cell = inf.cell;
        let now = self.q.now();
        let actives = &self.actives;
        let batches = self.servers[cell].enqueue(job, now, |d, k| actives[d] == Some(k));
        self.schedule_batches(cell, batches);
    }

    fn on_server_batch_done(&mut self, cell: usize, jobs: Vec<(usize, usize)>) {
        let now = self.q.now();
        for (device, round) in jobs {
            if !self.is_active(device, round) {
                continue; // cancelled while in service — wasted work
            }
            self.start_downlink(device, round, 0);
        }
        let actives = &self.actives;
        let refills = self.servers[cell].on_batch_done(now, |d, k| actives[d] == Some(k));
        self.schedule_batches(cell, refills);
    }

    fn on_merge_ready(&mut self, device: usize, round: usize) {
        if !self.is_active(device, round) {
            return;
        }
        let inf = self.inflight.remove(&(device, round)).unwrap();
        self.actives[device] = None;

        // Stage 2/4/5 control-plane effects, applied atomically at the
        // merge instant.  The merge carries the version it was *based
        // on* + 1, so concurrent fresher merges are never regressed and
        // `Aggregator::staleness` reports real lag.
        self.version += 1;
        let v = self.version;
        let based = inf.base_version + 1;
        let cut = inf.record.cut;
        let bytes = inf.record.adapter_bytes;
        // star-to-cloud: the routed cell's aggregation level absorbs
        // the merge, then forwards it to the cloud level — both through
        // the unordered (monotone) paths, so event order cannot matter
        let cell = inf.cell;
        let ca = &mut self.cell_aggs[cell];
        ca.bytes_distributed += bytes;
        ca.server_update_unordered(cut, based);
        ca.merge_unordered(device, cut, based, bytes);
        self.agg.bytes_distributed += bytes;
        self.agg.server_update_unordered(cut, based);
        self.agg.merge_unordered(device, cut, based, bytes);
        let staleness = v - based;
        let weight = match self.des.policy {
            Policy::Async => 1.0 / (1.0 + staleness as f64),
            _ => 1.0,
        };
        self.peak_staleness = self
            .peak_staleness
            .max(self.agg.staleness(v))
            .max(staleness);

        obs::metrics().des_merges.inc(device);
        let now_s = self.q.now().secs();
        if trace::active() {
            trace::sim_span(
                "device_round",
                "des.round",
                cell,
                inf.start_s,
                now_s,
                vec![
                    ("device", device as f64),
                    ("round", round as f64),
                    ("staleness", staleness as f64),
                ],
            );
        }
        self.records.push(DesRecord {
            start_s: inf.start_s,
            finish_s: now_s,
            wait_s: inf.wait_s,
            staleness,
            weight,
            degraded: inf.degraded,
            record: inf.record,
        });

        match self.des.policy {
            Policy::Sync | Policy::SemiSync { .. } => self.resolve_barrier_slot(),
            Policy::Async => self.launch_async(device),
        }
    }

    /// The round deadline fired: the semi-sync straggler cutoff, or —
    /// under `sync` with faults — the timeout that demotes stragglers
    /// to the same dropout path (DESIGN.md §17).
    fn on_deadline(&mut self, round: usize) {
        if !self.barrier_open || self.barrier_round != round {
            return; // stale — the round already closed
        }
        let fault_timeout = matches!(self.des.policy, Policy::Sync);
        for device in 0..self.devices.len() {
            if self.actives[device] == Some(round) {
                self.actives[device] = None;
                let cell = self
                    .inflight
                    .remove(&(device, round))
                    .map(|i| i.cell)
                    .unwrap_or(0);
                self.dropped += 1;
                self.barrier_outstanding -= 1;
                if fault_timeout {
                    self.timeout_demotions += 1;
                    obs::metrics().des_fault_timeouts.inc(device);
                } else {
                    obs::metrics().des_drops_straggler.inc(device);
                }
                if trace::active() {
                    trace::sim_instant(
                        if fault_timeout { "timeout_demotion" } else { "straggler_drop" },
                        "des.deadline",
                        cell,
                        self.q.now().secs(),
                        vec![("device", device as f64), ("round", round as f64)],
                    );
                }
            }
        }
        debug_assert_eq!(self.barrier_outstanding, 0);
        self.close_global_round();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChannelState, ExpConfig};
    use crate::coordinator::Strategy;
    use crate::exp::verify::verify_bit_identical;

    fn quick_cfg(rounds: usize) -> ExpConfig {
        let mut cfg = ExpConfig::paper();
        cfg.workload.rounds = rounds;
        cfg
    }

    fn engine_outcome(cfg: ExpConfig, policy: Policy, capacity: usize) -> DesOutcome {
        des_engine(cfg, policy, capacity).run()
    }

    #[test]
    fn sync_policy_reproduces_round_engine_bitwise() {
        let cfg = quick_cfg(3);
        let sched = Scheduler::new(cfg.clone(), ChannelState::Normal, Strategy::Card);
        let reference = sched.run_parallel(4);
        let out = engine_outcome(cfg, Policy::Sync, 64);
        let des_records: Vec<RoundRecord> =
            out.records.iter().map(|r| r.record.clone()).collect();
        if let Err(e) = verify_bit_identical(&reference, &des_records) {
            panic!("{e:#}");
        }
        assert!(out.aggregator.is_consistent());
        assert_eq!(out.launched as usize, out.records.len());
        assert_eq!(out.dropped, 0);
    }

    #[test]
    fn sync_policy_reproduces_round_engine_under_correlated_mobile_channels() {
        use crate::config::{FadingModel, MobilityModel};
        let mut cfg = quick_cfg(3);
        cfg.channel.process.model = FadingModel::Markov;
        cfg.mobility.model = MobilityModel::Linear;
        cfg.mobility.speed_mps = 3.0;
        cfg.mobility.round_s = 10.0;
        let sched = Scheduler::new(cfg.clone(), ChannelState::Normal, Strategy::Card);
        let reference = sched.run_parallel(4);
        let out = engine_outcome(cfg, Policy::Sync, 64);
        let des_records: Vec<RoundRecord> =
            out.records.iter().map(|r| r.record.clone()).collect();
        if let Err(e) = verify_bit_identical(&reference, &des_records) {
            panic!("{e:#}");
        }
    }

    #[test]
    fn runs_are_deterministic() {
        for policy in [
            Policy::Sync,
            Policy::SemiSync {
                deadline_factor: 1.2,
            },
            Policy::Async,
        ] {
            let a = engine_outcome(quick_cfg(3), policy, 2);
            let b = engine_outcome(quick_cfg(3), policy, 2);
            assert_eq!(a.records.len(), b.records.len(), "{}", policy.name());
            assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits(), "{}", policy.name());
            assert_eq!(
                a.server.utilization.to_bits(),
                b.server.utilization.to_bits(),
                "{}",
                policy.name()
            );
            for (x, y) in a.records.iter().zip(&b.records) {
                assert_eq!(x.finish_s.to_bits(), y.finish_s.to_bits());
                assert_eq!(x.wait_s.to_bits(), y.wait_s.to_bits());
            }
        }
    }

    #[test]
    fn async_completes_full_budget_without_churn() {
        let out = engine_outcome(quick_cfg(4), Policy::Async, 2);
        assert_eq!(out.records.len(), 4 * 5, "rounds × devices merges");
        assert_eq!(out.dropped, 0);
        assert!(out.aggregator.is_consistent());
        assert!(out.makespan_s > 0.0);
        // capacity 2 with 5 devices in flight must queue somebody
        assert!(out.server.peak_depth >= 1);
        assert!(out.server.utilization > 0.0 && out.server.utilization <= 1.0);
        // every record's observed latency covers its analytic delay
        // phases at least approximately (queueing only adds)
        for r in &out.records {
            assert!(r.latency_s() > 0.0 && r.latency_s().is_finite());
            assert!(r.wait_s >= 0.0);
        }
        // nothing dropped ⇒ dispatched energy equals merged energy
        // (up to summation order)
        let merged: f64 = out.records.iter().map(|r| r.record.energy_j).sum();
        assert!(
            (out.energy_spent_j - merged).abs() <= merged.abs() * 1e-9,
            "spent {} vs merged {merged}",
            out.energy_spent_j
        );
    }

    #[test]
    fn async_staleness_observed_and_weighted() {
        let out = engine_outcome(quick_cfg(4), Policy::Async, 2);
        // with 5 concurrent devices, some merge must land while others
        // are in flight
        assert!(out.peak_staleness > 0, "no staleness in a concurrent run");
        let any_downweighted = out.records.iter().any(|r| r.weight < 1.0);
        assert!(any_downweighted, "staleness never weighted a merge");
        for r in &out.records {
            let expect = 1.0 / (1.0 + r.staleness as f64);
            assert!((r.weight - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn semi_sync_tight_deadline_drops_stragglers() {
        let out = engine_outcome(
            quick_cfg(3),
            Policy::SemiSync {
                deadline_factor: 0.25,
            },
            64,
        );
        assert!(out.dropped > 0, "a 0.25× deadline must drop the tail");
        assert_eq!(out.launched, out.records.len() as u64 + out.dropped);
        assert!(out.aggregator.is_consistent());
        // dispatched energy can only exceed merged energy (wasted work)
        let merged: f64 = out.records.iter().map(|r| r.record.energy_j).sum();
        assert!(out.energy_spent_j >= merged - merged.abs() * 1e-9);
    }

    #[test]
    fn churn_preserves_cell_accounting() {
        let mut cfg = quick_cfg(3);
        cfg.churn.depart_rate_hz = 0.002;
        cfg.churn.arrive_rate_hz = 0.02;
        for policy in [Policy::Sync, Policy::Async] {
            let out = engine_outcome(cfg.clone(), policy, 4);
            // every launched cell either merged or dropped — no leaks
            assert_eq!(
                out.launched,
                out.records.len() as u64 + out.dropped,
                "{}",
                policy.name()
            );
            // a device must depart before it can return
            assert!(out.departures >= out.arrivals, "{}", policy.name());
            assert!(out.aggregator.is_consistent(), "{}", policy.name());
            // determinism under churn
            let again = engine_outcome(cfg.clone(), policy, 4);
            assert_eq!(out.records.len(), again.records.len());
            assert_eq!(out.departures, again.departures);
            assert_eq!(out.makespan_s.to_bits(), again.makespan_s.to_bits());
        }
    }

    #[test]
    fn single_cell_per_cell_stats_mirror_the_globals() {
        let out = engine_outcome(quick_cfg(3), Policy::Sync, 4);
        assert_eq!(out.per_cell.len(), 1);
        assert_eq!(out.handovers, 0);
        let c = &out.per_cell[0];
        assert_eq!(c.position_m, (0.0, 0.0));
        assert_eq!(c.handovers_in, 0);
        assert!(c.aggregator_consistent);
        // one cell: the per-cell entry IS the global figure, bitwise
        assert_eq!(c.energy_spent_j.to_bits(), out.energy_spent_j.to_bits());
        assert_eq!(c.server.utilization.to_bits(), out.server.utilization.to_bits());
        assert_eq!(c.server.served_jobs, out.server.served_jobs);
        assert_eq!(c.server.mean_wait_s.to_bits(), out.server.mean_wait_s.to_bits());
    }

    #[test]
    fn multi_cell_partitions_queues_and_conserves_totals() {
        // paper fleet at 10–30 m + line cells at 0 and 40 m: the 20 m
        // midline splits the static fleet 3/2, no handovers possible
        let mut cfg = quick_cfg(3);
        cfg.cells.count = 2;
        cfg.cells.spacing_m = 40.0;
        let out = engine_outcome(cfg.clone(), Policy::Sync, 4);
        assert_eq!(out.per_cell.len(), 2);
        for c in &out.per_cell {
            assert!(c.server.served_jobs > 0, "both cells must see work");
            assert!(c.aggregator_consistent);
        }
        assert_eq!(out.handovers, 0, "static fleet cannot hand over");
        // per-cell totals reproduce the global figures exactly
        let e: f64 = out.per_cell.iter().map(|c| c.energy_spent_j).sum();
        assert_eq!(e.to_bits(), out.energy_spent_j.to_bits());
        let served: u64 = out.per_cell.iter().map(|c| c.server.served_jobs).sum();
        assert_eq!(served, out.server.served_jobs);
        assert_eq!(served as usize, out.records.len());
        assert!(out.aggregator.is_consistent());
        // the radio plane is cell-independent: the record stream (cut
        // decisions, delays, energies) matches the single-cell run bit
        // for bit — only queueing is routed differently
        let mut single = cfg;
        single.cells.count = 1;
        let base = engine_outcome(single, Policy::Sync, 4);
        assert_eq!(base.records.len(), out.records.len());
        for (a, b) in base.records.iter().zip(&out.records) {
            assert_eq!(a.record.delay_s.to_bits(), b.record.delay_s.to_bits());
            assert_eq!(a.record.energy_j.to_bits(), b.record.energy_j.to_bits());
            assert_eq!(a.record.cut, b.record.cut);
        }
    }

    #[test]
    fn multi_cell_runs_are_deterministic_across_policies() {
        let mut cfg = quick_cfg(3);
        cfg.cells.count = 3;
        cfg.cells.spacing_m = 15.0;
        cfg.mobility.model = crate::config::MobilityModel::Waypoint;
        cfg.mobility.speed_mps = 8.0;
        cfg.mobility.round_s = 5.0;
        cfg.mobility.range_m = 30.0;
        for policy in [
            Policy::Sync,
            Policy::SemiSync { deadline_factor: 1.2 },
            Policy::Async,
        ] {
            let a = engine_outcome(cfg.clone(), policy, 2);
            let b = engine_outcome(cfg.clone(), policy, 2);
            assert_eq!(a.handovers, b.handovers, "{}", policy.name());
            assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits(), "{}", policy.name());
            for (x, y) in a.per_cell.iter().zip(&b.per_cell) {
                assert_eq!(x.energy_spent_j.to_bits(), y.energy_spent_j.to_bits());
                assert_eq!(x.server.served_jobs, y.server.served_jobs);
                assert_eq!(x.handovers_in, y.handovers_in);
            }
            // handover bookkeeping is internally consistent
            let inbound: u64 = a.per_cell.iter().map(|c| c.handovers_in).sum();
            assert_eq!(inbound, a.handovers, "{}", policy.name());
            // and the energy ledger still sums exactly
            let e: f64 = a.per_cell.iter().map(|c| c.energy_spent_j).sum();
            assert_eq!(e.to_bits(), a.energy_spent_j.to_bits(), "{}", policy.name());
        }
    }

    fn des_engine(cfg: ExpConfig, policy: Policy, capacity: usize) -> DesEngine {
        let sched = Arc::new(Scheduler::new(cfg, ChannelState::Normal, Strategy::Card));
        DesEngine::new(
            sched,
            DesConfig {
                policy,
                capacity,
                batch: 1,
            },
        )
    }

    /// Field-by-field bitwise comparison of two outcomes — the
    /// currency of both the zero-perturbation and the checkpoint/resume
    /// anchors.
    fn assert_outcome_bits(a: &DesOutcome, b: &DesOutcome) {
        assert_eq!(a.records.len(), b.records.len());
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.record.device_idx, y.record.device_idx);
            assert_eq!(x.record.round, y.record.round);
            assert_eq!(x.record.cut, y.record.cut);
            assert_eq!(x.record.delay_s.to_bits(), y.record.delay_s.to_bits());
            assert_eq!(x.record.energy_j.to_bits(), y.record.energy_j.to_bits());
            assert_eq!(x.start_s.to_bits(), y.start_s.to_bits());
            assert_eq!(x.finish_s.to_bits(), y.finish_s.to_bits());
            assert_eq!(x.wait_s.to_bits(), y.wait_s.to_bits());
            assert_eq!(x.staleness, y.staleness);
            assert_eq!(x.weight.to_bits(), y.weight.to_bits());
            assert_eq!(x.degraded, y.degraded);
        }
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
        assert_eq!(a.energy_spent_j.to_bits(), b.energy_spent_j.to_bits());
        assert_eq!(a.retry_energy_j.to_bits(), b.retry_energy_j.to_bits());
        assert_eq!(a.server.served_jobs, b.server.served_jobs);
        assert_eq!(a.server.busy_slot_s.to_bits(), b.server.busy_slot_s.to_bits());
        assert_eq!(a.server.mean_wait_s.to_bits(), b.server.mean_wait_s.to_bits());
        assert_eq!(a.server.utilization.to_bits(), b.server.utilization.to_bits());
        assert_eq!(
            (a.launched, a.dropped, a.departures, a.arrivals, a.handovers),
            (b.launched, b.dropped, b.departures, b.arrivals, b.handovers)
        );
        assert_eq!(
            (a.retries, a.timeout_demotions, a.failovers, a.slot_failures, a.slot_repairs),
            (b.retries, b.timeout_demotions, b.failovers, b.slot_failures, b.slot_repairs)
        );
        assert_eq!(a.peak_staleness, b.peak_staleness);
        assert_eq!(a.per_cell.len(), b.per_cell.len());
        for (x, y) in a.per_cell.iter().zip(&b.per_cell) {
            assert_eq!(x.energy_spent_j.to_bits(), y.energy_spent_j.to_bits());
            assert_eq!(x.server.served_jobs, y.server.served_jobs);
            assert_eq!(x.server.busy_slot_s.to_bits(), y.server.busy_slot_s.to_bits());
        }
    }

    #[test]
    fn dormant_fault_plane_is_bitwise_invisible() {
        // timeout_factor alone arms nothing: with every injection rate
        // zero the fault plane must not exist, and the timeline must be
        // bit-identical to a config without the [faults] table at all
        let mut cfg = quick_cfg(3);
        cfg.faults.timeout_factor = 2.0;
        for policy in [
            Policy::Sync,
            Policy::SemiSync { deadline_factor: 1.2 },
            Policy::Async,
        ] {
            let base = engine_outcome(quick_cfg(3), policy, 2);
            let out = engine_outcome(cfg.clone(), policy, 2);
            assert_outcome_bits(&base, &out);
            assert_eq!(
                out.retries + out.timeout_demotions + out.failovers + out.slot_failures,
                0,
                "{}",
                policy.name()
            );
            assert_eq!(out.retry_energy_j.to_bits(), 0f64.to_bits());
        }
    }

    #[test]
    fn link_outages_retry_with_backoff_and_account_energy() {
        let mut cfg = quick_cfg(3);
        cfg.faults.link_outage_rate_hz = 10.0;
        let out = engine_outcome(cfg.clone(), Policy::Sync, 4);
        assert!(out.retries > 0, "a 10 Hz outage rate must interrupt something");
        assert!(out.retry_energy_j > 0.0, "interrupted partials must book waste");
        // every launched cell still either merges or drops — no leaks
        assert_eq!(out.launched, out.records.len() as u64 + out.dropped);
        // the fault timeline is as deterministic as the clean one
        let again = engine_outcome(cfg, Policy::Sync, 4);
        assert_eq!(out.retries, again.retries);
        assert_eq!(out.dropped, again.dropped);
        assert_eq!(out.retry_energy_j.to_bits(), again.retry_energy_j.to_bits());
        assert_eq!(out.makespan_s.to_bits(), again.makespan_s.to_bits());
    }

    #[test]
    fn sync_timeout_factor_demotes_stragglers() {
        let mut cfg = quick_cfg(3);
        // arm the plane with a rate that effectively never strikes, so
        // the only fault-path effect left is the timeout demotion
        cfg.faults.link_outage_rate_hz = 1e-12;
        cfg.faults.timeout_factor = 0.25;
        let out = engine_outcome(cfg, Policy::Sync, 2);
        assert!(out.timeout_demotions > 0, "a 0.25× deadline must demote the tail");
        assert_eq!(out.dropped, out.timeout_demotions);
        assert_eq!(out.launched, out.records.len() as u64 + out.dropped);
        assert_eq!(out.retries, 0);
    }

    #[test]
    fn slot_failures_delay_batches_and_tally() {
        let base = engine_outcome(quick_cfg(3), Policy::Sync, 2);
        let mut cfg = quick_cfg(3);
        cfg.faults.slot_fail_prob = 0.6;
        let out = engine_outcome(cfg, Policy::Sync, 2);
        assert!(out.slot_failures > 0, "p=0.6 over 15 dispatches must strike");
        assert_eq!(out.slot_failures, out.slot_repairs);
        assert_eq!(out.retries, 0);
        // repairs delay batches but never drop them
        assert_eq!(out.records.len(), base.records.len());
        assert!(out.makespan_s >= base.makespan_s);
        assert!(
            out.server.busy_slot_s > base.server.busy_slot_s,
            "repair time must occupy slots: {} vs {}",
            out.server.busy_slot_s,
            base.server.busy_slot_s
        );
    }

    #[test]
    fn burst_failover_reroutes_to_the_runner_up_cell() {
        let mut cfg = quick_cfg(3);
        cfg.cells.count = 2;
        cfg.cells.spacing_m = 40.0;
        cfg.faults.burst_rate_per_round = 1.0;
        let out = engine_outcome(cfg, Policy::Sync, 4);
        // the burst center device is at distance 0 from itself, so an
        // always-on burst strikes at least one launch per round
        assert!(out.failovers > 0);
        assert!(
            out.records.iter().all(|r| !r.degraded),
            "with two sites the failover reroutes, never degrades"
        );
        assert_eq!(out.dropped, 0);
        assert!(out.aggregator.is_consistent());
    }

    #[test]
    fn single_cell_burst_degrades_to_the_device_heavy_cut() {
        let mut cfg = quick_cfg(3);
        cfg.faults.burst_rate_per_round = 1.0;
        let out = engine_outcome(cfg, Policy::Sync, 4);
        assert!(out.failovers > 0);
        assert!(
            out.records.iter().any(|r| r.degraded),
            "no alternate site: burst-struck launches must degrade"
        );
        // degradation completes the round anyway — nothing drops
        assert_eq!(out.records.len(), 3 * 5);
        assert_eq!(out.dropped, 0);
    }

    #[test]
    fn checkpoint_resume_is_bit_identical_mid_fault_storm() {
        let mut cfg = quick_cfg(3);
        cfg.faults.link_outage_rate_hz = 0.3;
        cfg.faults.slot_fail_prob = 0.2;
        cfg.faults.burst_rate_per_round = 0.5;
        for policy in [Policy::Sync, Policy::Async] {
            let eng = des_engine(cfg.clone(), policy, 2);
            let full = eng.run();
            for frac in [0.25, 0.5, 0.9] {
                match eng.run_until(full.makespan_s * frac) {
                    RunState::Checkpoint(snap) => {
                        assert!(snap.now_s <= full.makespan_s * frac);
                        assert_outcome_bits(&full, &eng.resume(&snap));
                    }
                    RunState::Done(out) => assert_outcome_bits(&full, &out),
                }
            }
        }
    }

    #[test]
    fn checkpoint_resume_without_faults_covers_churn_state() {
        let mut cfg = quick_cfg(3);
        cfg.churn.depart_rate_hz = 0.002;
        cfg.churn.arrive_rate_hz = 0.02;
        let eng = des_engine(cfg, Policy::Async, 2);
        let full = eng.run();
        match eng.run_until(full.makespan_s * 0.5) {
            RunState::Checkpoint(snap) => assert_outcome_bits(&full, &eng.resume(&snap)),
            RunState::Done(out) => assert_outcome_bits(&full, &out),
        }
    }

    #[test]
    fn checkpoints_compose() {
        let mut cfg = quick_cfg(3);
        cfg.faults.link_outage_rate_hz = 0.4;
        let eng = des_engine(cfg, Policy::SemiSync { deadline_factor: 1.2 }, 2);
        let full = eng.run();
        let RunState::Checkpoint(first) = eng.run_until(full.makespan_s * 0.3) else {
            panic!("run drained before 30% of its own makespan");
        };
        // pausing twice must equal pausing once
        match eng.resume_until(&first, full.makespan_s * 0.7) {
            RunState::Checkpoint(second) => assert_outcome_bits(&full, &eng.resume(&second)),
            RunState::Done(out) => assert_outcome_bits(&full, &out),
        }
    }

    #[test]
    #[should_panic(expected = "different experiment config")]
    fn resume_refuses_a_foreign_checkpoint() {
        let eng = des_engine(quick_cfg(3), Policy::Sync, 2);
        let RunState::Checkpoint(snap) = eng.run_until(1e-9) else {
            unreachable!("a 3-round run cannot drain by t=1e-9");
        };
        let other = des_engine(quick_cfg(4), Policy::Sync, 2);
        other.resume(&snap);
    }

    #[test]
    fn policy_parse_roundtrip() {
        assert_eq!(Policy::parse("sync", 1.5), Some(Policy::Sync));
        assert_eq!(
            Policy::parse("SEMI-SYNC", 2.0),
            Some(Policy::SemiSync {
                deadline_factor: 2.0
            })
        );
        assert_eq!(Policy::parse("async", 1.5), Some(Policy::Async));
        assert_eq!(Policy::parse("bogus", 1.5), None);
        assert_eq!(Policy::Sync.name(), "sync");
        assert_eq!(Policy::Async.name(), "async");
    }
}
