//! Pluggable fading processes (DESIGN.md §13).
//!
//! The per-round channel gain is no longer hardwired to an i.i.d.
//! Rayleigh draw: [`FadingProcess`] realizes the power gain of any
//! `(device, round)` link cell under one of three processes, each
//! **counter-indexed** — the gain is a pure O(1) function of
//! `(seed, device, round, direction)`, never of shared generator
//! state — so serial, interleaved, and parallel fleet executions stay
//! bit-identical under every model (the §8 determinism contract).
//!
//! * **`iid`** — today's memoryless Rayleigh block fading.  Gains are
//!   drawn from the *cell RNG handed in by the scheduler*, in the same
//!   order as before this abstraction existed, so the default config
//!   is bit-identical to the pre-refactor engine by construction.
//! * **`markov`** — Gauss–Markov (AR(1)) correlated Rayleigh fading.
//!   The complex field is a windowed moving average of counter-indexed
//!   Gaussian innovations — the stationary MA form of the AR(1)
//!   recursion `h[n] = ρ·h[n-1] + √(1-ρ²)·w[n]` truncated at window W
//!   and renormalized to exact unit power, so any cell is O(W) with no
//!   recursion over rounds.  Lag-τ field autocorrelation is
//!   ρ^τ·(1-ρ^{2(W-τ)})/(1-ρ^{2W}) — geometrically decaying, within a
//!   ρ^{2(W-τ)} truncation term of the exact AR(1).
//! * **`jakes`** — sum-of-sinusoids with device-seeded phases and
//!   arrival angles: `h[n] = K^{-1/2} Σ_k exp(i(ω_D n cosθ_k + φ_k))`.
//!   The round index enters only through the closed-form phase, so any
//!   cell is O(K); the expected field autocorrelation is the classic
//!   Clarke/Jakes `J₀(ω_D τ)`.
//!
//! All three are unit-mean power processes with Rayleigh-distributed
//! (or, for `jakes`, asymptotically Rayleigh) envelopes, so swapping
//! the process changes the *temporal structure* of the channel, not
//! its marginal statistics.

use crate::config::{FadingModel, FadingProcessSpec};
use crate::util::rng::{Rng, SplitMix64};

/// Direction tags for the per-link sub-streams.
const DIR_UP: u64 = 0;
const DIR_DOWN: u64 = 1;

/// A realized fading process over a fleet of devices.
#[derive(Clone, Debug)]
pub struct FadingProcess {
    kind: Kind,
}

#[derive(Clone, Debug)]
enum Kind {
    Iid,
    Markov {
        rho: f64,
        window: usize,
        /// √((1-ρ²)/(1-ρ^{2W})) · (1/√2) — renormalizes the truncated
        /// MA sum to variance ½ per quadrature component (unit power)
        norm: f64,
        root: u64,
    },
    Jakes {
        paths: usize,
        inv_sqrt_k: f64,
        /// per (device, direction, path): (per-round phase increment
        /// ω_D·cosθ_k, device-seeded phase offset φ_k) — flat layout
        /// `[(device·2 + dir)·K + k]`
        rays: Vec<(f64, f64)>,
    },
}

impl FadingProcess {
    /// Build the process for `n_devices` devices.  `root` seeds every
    /// counter-indexed stream; the scheduler derives it from its own
    /// `(seed, channel state)` stream root.
    pub fn new(spec: &FadingProcessSpec, root: u64, n_devices: usize) -> Self {
        let kind = match spec.model {
            FadingModel::Iid => Kind::Iid,
            FadingModel::Markov => {
                let w2 = spec.rho.powi(2 * spec.window as i32);
                Kind::Markov {
                    rho: spec.rho,
                    window: spec.window,
                    norm: ((1.0 - spec.rho * spec.rho) / (1.0 - w2)).sqrt()
                        * std::f64::consts::FRAC_1_SQRT_2,
                    root,
                }
            }
            FadingModel::Jakes => {
                let k = spec.paths;
                let omega_d = 2.0 * std::f64::consts::PI * spec.doppler;
                let mut rays = Vec::with_capacity(n_devices * 2 * k);
                for device in 0..n_devices as u64 {
                    for dir in [DIR_UP, DIR_DOWN] {
                        let mut rng = Rng::new(SplitMix64::stream_seed(root, &[device, dir]));
                        for _ in 0..k {
                            let theta = rng.range(0.0, 2.0 * std::f64::consts::PI);
                            let phi = rng.range(0.0, 2.0 * std::f64::consts::PI);
                            rays.push((omega_d * theta.cos(), phi));
                        }
                    }
                }
                Kind::Jakes {
                    paths: k,
                    inv_sqrt_k: 1.0 / (k as f64).sqrt(),
                    rays,
                }
            }
        };
        FadingProcess { kind }
    }

    /// Whether this is the memoryless default (the bit-compat anchor).
    pub fn is_iid(&self) -> bool {
        matches!(self.kind, Kind::Iid)
    }

    /// Power gains `(g_up, g_down)` for one `(device, round)` cell.
    ///
    /// `iid` consumes two draws from `rng` — the cell RNG — exactly as
    /// the pre-process engine did; the correlated processes touch only
    /// their own counter-indexed streams, leaving `rng` for the
    /// decision layer (Random-cut) untouched.
    pub fn gains(&self, device: usize, round: usize, rng: &mut Rng) -> (f64, f64) {
        match &self.kind {
            Kind::Iid => (rng.rayleigh_power(), rng.rayleigh_power()),
            Kind::Markov {
                rho,
                window,
                norm,
                root,
            } => (
                markov_gain(*root, device as u64, DIR_UP, round, *rho, *window, *norm),
                markov_gain(*root, device as u64, DIR_DOWN, round, *rho, *window, *norm),
            ),
            Kind::Jakes {
                paths,
                inv_sqrt_k,
                rays,
            } => (
                jakes_gain(&rays[(device * 2) * paths..], *paths, *inv_sqrt_k, round),
                jakes_gain(&rays[(device * 2 + 1) * paths..], *paths, *inv_sqrt_k, round),
            ),
        }
    }
}

/// Windowed-MA Gauss–Markov power gain: |h|² where each quadrature of
/// `h` is `norm · Σ_{j<W} ρ^j · u(round-j)` over counter-indexed
/// standard Gaussians.  Innovation indices below round 0 wrap through
/// u64 space — still unique pure tags, so the process extends to
/// "before the run started" and stays stationary from round 0.
fn markov_gain(
    root: u64,
    device: u64,
    dir: u64,
    round: usize,
    rho: f64,
    window: usize,
    norm: f64,
) -> f64 {
    let mut re = 0.0;
    let mut im = 0.0;
    let mut coeff = 1.0;
    for j in 0..window {
        let k = (round as i64 - j as i64) as u64;
        let mut u = Rng::new(SplitMix64::stream_seed(root, &[device, dir, k]));
        // one Box–Muller pair covers both quadratures
        re += coeff * u.gauss();
        im += coeff * u.gauss();
        coeff *= rho;
    }
    let (re, im) = (norm * re, norm * im);
    re * re + im * im
}

/// Jakes sum-of-sinusoids power gain at round `n` from the device's
/// precomputed rays.
fn jakes_gain(rays: &[(f64, f64)], paths: usize, inv_sqrt_k: f64, round: usize) -> f64 {
    let t = round as f64;
    let mut re = 0.0;
    let mut im = 0.0;
    for &(omega, phi) in &rays[..paths] {
        let (s, c) = (omega * t + phi).sin_cos();
        re += c;
        im += s;
    }
    let (re, im) = (re * inv_sqrt_k, im * inv_sqrt_k);
    re * re + im * im
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn spec(model: FadingModel) -> FadingProcessSpec {
        FadingProcessSpec {
            model,
            ..FadingProcessSpec::default()
        }
    }

    fn trace(process: &FadingProcess, device: usize, rounds: usize) -> Vec<f64> {
        (0..rounds)
            .map(|n| {
                let mut rng = Rng::new(SplitMix64::stream_seed(42, &[n as u64, device as u64]));
                process.gains(device, n, &mut rng).0
            })
            .collect()
    }

    fn lag1(xs: &[f64]) -> f64 {
        stats::pearson(&xs[..xs.len() - 1], &xs[1..])
    }

    #[test]
    fn iid_draws_exactly_two_rayleighs_from_the_cell_rng() {
        let p = FadingProcess::new(&spec(FadingModel::Iid), 7, 3);
        assert!(p.is_iid());
        let mut a = Rng::new(123);
        let mut b = Rng::new(123);
        let (g_up, g_down) = p.gains(1, 5, &mut a);
        assert_eq!(g_up.to_bits(), b.rayleigh_power().to_bits());
        assert_eq!(g_down.to_bits(), b.rayleigh_power().to_bits());
        // and nothing else was consumed
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn correlated_cells_are_pure_functions_of_the_seed() {
        for model in [FadingModel::Markov, FadingModel::Jakes] {
            let p1 = FadingProcess::new(&spec(model), 99, 4);
            let p2 = FadingProcess::new(&spec(model), 99, 4);
            for (device, round) in [(0, 0), (3, 17), (1, 200_000)] {
                // the cell rng must be ignored: hand in unrelated rngs
                let mut ra = Rng::new(1);
                let mut rb = Rng::new(2);
                let a = p1.gains(device, round, &mut ra);
                let b = p2.gains(device, round, &mut rb);
                assert_eq!(a.0.to_bits(), b.0.to_bits(), "{model:?}");
                assert_eq!(a.1.to_bits(), b.1.to_bits(), "{model:?}");
                // and the passed rng was not consumed
                assert_eq!(ra.next_u64(), Rng::new(1).next_u64());
            }
            // different roots realize different processes
            let p3 = FadingProcess::new(&spec(model), 100, 4);
            let mut r = Rng::new(3);
            assert_ne!(
                p1.gains(0, 0, &mut r).0.to_bits(),
                p3.gains(0, 0, &mut r).0.to_bits()
            );
        }
    }

    #[test]
    fn all_processes_have_unit_mean_power() {
        let n = 4000;
        for model in [FadingModel::Iid, FadingModel::Markov, FadingModel::Jakes] {
            let p = FadingProcess::new(&spec(model), 5, 8);
            // average across devices and rounds to beat the temporal
            // correlation of the non-iid processes
            let mut sum = 0.0;
            for device in 0..8 {
                sum += trace(&p, device, n / 8).iter().sum::<f64>();
            }
            let mean = sum / n as f64;
            // correlated processes have a reduced effective sample
            // count, so the bound is loose — this guards unit *scale*
            // (a missing normalizer would be off by 2×), not precision
            assert!(
                (mean - 1.0).abs() < 0.25,
                "{model:?}: mean power {mean} far from 1"
            );
        }
    }

    #[test]
    fn markov_and_jakes_are_correlated_iid_is_not() {
        let rounds = 400;
        let r_iid = lag1(&trace(
            &FadingProcess::new(&spec(FadingModel::Iid), 11, 2),
            0,
            rounds,
        ));
        let r_markov = lag1(&trace(
            &FadingProcess::new(&spec(FadingModel::Markov), 11, 2),
            0,
            rounds,
        ));
        let r_jakes = lag1(&trace(
            &FadingProcess::new(&spec(FadingModel::Jakes), 11, 2),
            0,
            rounds,
        ));
        assert!(r_iid.abs() < 0.25, "iid lag-1 autocorr {r_iid}");
        assert!(r_markov > 0.5, "markov lag-1 autocorr {r_markov}");
        assert!(r_jakes > 0.5, "jakes lag-1 autocorr {r_jakes}");
    }

    #[test]
    fn markov_rho_zero_is_memoryless() {
        let mut s = spec(FadingModel::Markov);
        s.rho = 0.0;
        s.window = 1;
        let p = FadingProcess::new(&s, 13, 2);
        let r = lag1(&trace(&p, 0, 400));
        assert!(r.abs() < 0.25, "rho=0 lag-1 autocorr {r}");
    }

    #[test]
    fn up_and_down_links_fade_independently() {
        for model in [FadingModel::Markov, FadingModel::Jakes] {
            let p = FadingProcess::new(&spec(model), 17, 2);
            let mut rng = Rng::new(0);
            // long trace: temporal correlation shrinks the effective
            // sample count, so the cross-correlation needs room
            let (ups, downs): (Vec<f64>, Vec<f64>) =
                (0..2000).map(|n| p.gains(0, n, &mut rng)).unzip();
            let r = stats::pearson(&ups, &downs);
            assert!(r.abs() < 0.4, "{model:?}: up/down correlation {r}");
        }
    }
}
