//! Wireless-network substrate: log-distance pathloss, pluggable
//! fading processes (i.i.d. Rayleigh / Gauss–Markov / Jakes) over
//! static or mobile placements, AWGN, and the 3GPP TS 38.214 CQI ->
//! spectral-efficiency mapping the paper cites for its rate model
//! (§III-A2), plus the multi-cell edge tier (device→cell association
//! with hysteresis handover).  See DESIGN.md §6, §13, and §15.

pub mod cells;
pub mod channel;
pub mod cqi;
pub mod fading;
pub mod link;
pub mod mobility;
pub mod pathloss;

pub use cells::CellGrid;
pub use channel::{Channel, LinkRealization};
pub use cqi::{cqi_for_snr, spectral_efficiency, CQI_TABLE};
pub use fading::FadingProcess;
pub use link::LinkProcess;
pub use mobility::Mobility;
