//! Wireless-network substrate: log-distance pathloss, Rayleigh block
//! fading, AWGN, and the 3GPP TS 38.214 CQI -> spectral-efficiency
//! mapping the paper cites for its rate model (§III-A2).

pub mod channel;
pub mod cqi;
pub mod pathloss;

pub use channel::{Channel, LinkRealization};
pub use cqi::{cqi_for_snr, spectral_efficiency, CQI_TABLE};
