//! SNR -> spectral-efficiency mapping via the 3GPP CQI table.
//!
//! The paper converts SNR to rate through "the CQI to MCS mapping table
//! [TS 38.214]" (§III-A2): R = B · y(SNR).  We implement y(·) as the
//! 4-bit CQI table 5.2.2.1-2 of TS 38.214 (QPSK…64QAM, efficiencies
//! 0.1523…5.5547 bit/s/Hz) with the standard per-CQI SNR thresholds
//! (1.02 dB/step BLER-10% fit used throughout the link-adaptation
//! literature).

/// One CQI row: minimum SNR [dB] to sustain it, spectral efficiency.
#[derive(Clone, Copy, Debug)]
pub struct CqiEntry {
    pub index: u8,
    pub snr_db: f64,
    pub efficiency: f64,
    pub modulation: &'static str,
}

/// TS 38.214 Table 5.2.2.1-2 (CQI indices 1..=15) with SNR thresholds.
pub const CQI_TABLE: [CqiEntry; 15] = [
    CqiEntry { index: 1,  snr_db: -6.7,  efficiency: 0.1523, modulation: "QPSK"   },
    CqiEntry { index: 2,  snr_db: -4.7,  efficiency: 0.2344, modulation: "QPSK"   },
    CqiEntry { index: 3,  snr_db: -2.3,  efficiency: 0.3770, modulation: "QPSK"   },
    CqiEntry { index: 4,  snr_db: 0.2,   efficiency: 0.6016, modulation: "QPSK"   },
    CqiEntry { index: 5,  snr_db: 2.4,   efficiency: 0.8770, modulation: "QPSK"   },
    CqiEntry { index: 6,  snr_db: 4.3,   efficiency: 1.1758, modulation: "QPSK"   },
    CqiEntry { index: 7,  snr_db: 5.9,   efficiency: 1.4766, modulation: "16QAM"  },
    CqiEntry { index: 8,  snr_db: 8.1,   efficiency: 1.9141, modulation: "16QAM"  },
    CqiEntry { index: 9,  snr_db: 10.3,  efficiency: 2.4063, modulation: "16QAM"  },
    CqiEntry { index: 10, snr_db: 11.7,  efficiency: 2.7305, modulation: "64QAM"  },
    CqiEntry { index: 11, snr_db: 14.1,  efficiency: 3.3223, modulation: "64QAM"  },
    CqiEntry { index: 12, snr_db: 16.3,  efficiency: 3.9023, modulation: "64QAM"  },
    CqiEntry { index: 13, snr_db: 18.7,  efficiency: 4.5234, modulation: "64QAM"  },
    CqiEntry { index: 14, snr_db: 21.0,  efficiency: 5.1152, modulation: "64QAM"  },
    CqiEntry { index: 15, snr_db: 22.7,  efficiency: 5.5547, modulation: "64QAM"  },
];

/// Spectral efficiency the outage floor is pinned to: CQI-1, the
/// table's lowest rung.  An outage link falls back to this efficiency
/// on 1/[`OUTAGE_BAND_DIVISOR`] of the band instead of 0 bit/s —
/// division-safe and matching retransmission-until-success behaviour
/// (`Channel::rate_bps`).
pub const OUTAGE_FLOOR_EFFICIENCY: f64 = CQI_TABLE[0].efficiency;

/// Fraction of the band (as a divisor) granted to an outage link.
pub const OUTAGE_BAND_DIVISOR: f64 = 50.0;

/// CQI index for a given SNR (0 = outage: below CQI-1 threshold).
///
/// Binary search over the (monotone) threshold column: the index is
/// exactly the number of thresholds at or below `snr_db`.  This sits on
/// the decision cache's key path (coordinator/kernel.rs), so it runs
/// once per link per round.
pub fn cqi_for_snr(snr_db: f64) -> u8 {
    CQI_TABLE.partition_point(|e| e.snr_db <= snr_db) as u8
}

/// y(SNR): spectral efficiency [bit/s/Hz].  Outage -> 0.
pub fn spectral_efficiency(snr_db: f64) -> f64 {
    match cqi_for_snr(snr_db) {
        0 => 0.0,
        i => CQI_TABLE[i as usize - 1].efficiency,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_monotone() {
        for w in CQI_TABLE.windows(2) {
            assert!(w[1].snr_db > w[0].snr_db);
            assert!(w[1].efficiency > w[0].efficiency);
        }
    }

    #[test]
    fn outage_below_first_threshold() {
        assert_eq!(cqi_for_snr(-10.0), 0);
        assert_eq!(spectral_efficiency(-10.0), 0.0);
    }

    #[test]
    fn saturates_at_cqi15() {
        assert_eq!(cqi_for_snr(50.0), 15);
        assert!((spectral_efficiency(50.0) - 5.5547).abs() < 1e-9);
    }

    #[test]
    fn threshold_boundaries() {
        assert_eq!(cqi_for_snr(-6.7), 1);
        assert_eq!(cqi_for_snr(-6.71), 0);
        assert_eq!(cqi_for_snr(10.3), 9);
        assert_eq!(cqi_for_snr(10.29), 8);
    }

    /// The pre-PR linear scan, kept as the equivalence oracle.
    fn cqi_for_snr_linear(snr_db: f64) -> u8 {
        let mut best = 0;
        for e in &CQI_TABLE {
            if snr_db >= e.snr_db {
                best = e.index;
            } else {
                break;
            }
        }
        best
    }

    #[test]
    fn binary_search_matches_linear_scan_on_dense_grid() {
        // dense sweep across (and far beyond) the table's span,
        // including every threshold and its immediate neighbourhood
        let mut snr = -30.0;
        while snr <= 40.0 {
            assert_eq!(cqi_for_snr(snr), cqi_for_snr_linear(snr), "snr={snr}");
            snr += 0.01;
        }
        for e in &CQI_TABLE {
            for s in [
                e.snr_db,
                e.snr_db - 1e-12,
                e.snr_db + 1e-12,
                e.snr_db - 0.05,
                e.snr_db + 0.05,
            ] {
                assert_eq!(cqi_for_snr(s), cqi_for_snr_linear(s), "snr={s}");
            }
        }
        for s in [f64::NEG_INFINITY, f64::INFINITY, -1e9, 1e9] {
            assert_eq!(cqi_for_snr(s), cqi_for_snr_linear(s), "snr={s}");
        }
    }

    #[test]
    fn step_function_between_thresholds() {
        assert_eq!(spectral_efficiency(6.0), spectral_efficiency(7.9));
    }

    #[test]
    fn outage_floor_is_cqi1_on_a_fiftieth_of_the_band() {
        // the floor constant is pinned to the table's CQI-1 row — if the
        // table ever changes, the outage floor must move with it
        assert_eq!(
            OUTAGE_FLOOR_EFFICIENCY.to_bits(),
            CQI_TABLE[0].efficiency.to_bits()
        );
        assert_eq!(CQI_TABLE[0].index, 1);
        assert_eq!(OUTAGE_FLOOR_EFFICIENCY.to_bits(), 0.1523f64.to_bits());
        assert_eq!(OUTAGE_BAND_DIVISOR.to_bits(), 50.0f64.to_bits());
        // the floor is below even a full-band CQI-1 link
        assert!(OUTAGE_FLOOR_EFFICIENCY / OUTAGE_BAND_DIVISOR < CQI_TABLE[0].efficiency);
    }

    #[test]
    fn efficiency_matches_standard_values() {
        // spot-check against TS 38.214 Table 5.2.2.1-2
        assert!((CQI_TABLE[0].efficiency - 0.1523).abs() < 1e-9);
        assert!((CQI_TABLE[6].efficiency - 1.4766).abs() < 1e-9);
        assert!((CQI_TABLE[14].efficiency - 5.5547).abs() < 1e-9);
    }
}
