//! Log-distance pathloss model.
//!
//! PL(d) = PL₀ + 10·α·log₁₀(d/d₀)  [dB]
//!
//! α is the pathloss exponent; the paper's Fig. 4 channel states map to
//! α = 2 (Good), 4 (Normal), 6 (Poor) (§V-B).

use crate::config::ChannelSpec;

/// Pathloss in dB at distance `d_m` with exponent `alpha`.
pub fn pathloss_db(ch: &ChannelSpec, d_m: f64, alpha: f64) -> f64 {
    let d = d_m.max(ch.d0_m); // clamp inside the reference distance
    ch.pl0_db + 10.0 * alpha * (d / ch.d0_m).log10()
}

/// dBm -> Watts.
pub fn dbm_to_watts(dbm: f64) -> f64 {
    1e-3 * 10f64.powf(dbm / 10.0)
}

/// dB ratio -> linear.
pub fn db_to_lin(db: f64) -> f64 {
    10f64.powf(db / 10.0)
}

/// linear ratio -> dB.
pub fn lin_to_db(lin: f64) -> f64 {
    10.0 * lin.log10()
}

/// Noise power over bandwidth `bw_hz` [W], including noise figure.
pub fn noise_watts(ch: &ChannelSpec, bw_hz: f64) -> f64 {
    dbm_to_watts(ch.noise_dbm_per_hz + ch.noise_figure_db) * bw_hz
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ch() -> ChannelSpec {
        ChannelSpec::default()
    }

    #[test]
    fn pathloss_increases_with_distance_and_alpha() {
        let c = ch();
        assert!(pathloss_db(&c, 100.0, 2.0) > pathloss_db(&c, 10.0, 2.0));
        assert!(pathloss_db(&c, 50.0, 6.0) > pathloss_db(&c, 50.0, 2.0));
    }

    #[test]
    fn reference_distance_gives_pl0() {
        let c = ch();
        assert!((pathloss_db(&c, c.d0_m, 4.0) - c.pl0_db).abs() < 1e-12);
        // inside d0 clamps (no negative-gain near-field nonsense)
        assert!((pathloss_db(&c, 0.01, 4.0) - c.pl0_db).abs() < 1e-12);
    }

    #[test]
    fn ten_x_distance_adds_10_alpha_db() {
        let c = ch();
        let d1 = pathloss_db(&c, 10.0, 3.0);
        let d2 = pathloss_db(&c, 100.0, 3.0);
        assert!((d2 - d1 - 30.0).abs() < 1e-9);
    }

    #[test]
    fn unit_conversions() {
        assert!((dbm_to_watts(30.0) - 1.0).abs() < 1e-12);
        assert!((dbm_to_watts(0.0) - 1e-3).abs() < 1e-15);
        assert!((db_to_lin(3.0) - 1.9952).abs() < 1e-3);
        assert!((lin_to_db(100.0) - 20.0).abs() < 1e-12);
    }

    #[test]
    fn thermal_noise_magnitude() {
        // -174 dBm/Hz + 9 dB NF over 100 MHz ≈ -85 dBm ≈ 3.2e-12 W
        let c = ch();
        let n = noise_watts(&c, 100e6);
        assert!(n > 1e-12 && n < 1e-11, "{n}");
    }
}
