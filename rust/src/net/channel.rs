//! Per-round wireless channel realization.
//!
//! Chains pathloss (log-distance, state-dependent exponent) + Rayleigh
//! block fading + AWGN into an SNR, then maps SNR -> rate via the 3GPP
//! CQI table:  R_{m,n} = B · y(SNR_{m,n})  (§III-A2).
//!
//! Block fading: one i.i.d. |CN(0,1)|² draw per link per round — the
//! "dynamic wireless channel" that makes the optimal cut flip across
//! rounds in Fig. 3.

use crate::config::{ChannelSpec, ChannelState, DeviceSpec};
use crate::model::LinkRates;
use crate::util::rng::Rng;

use super::cqi::spectral_efficiency;
use super::pathloss::{dbm_to_watts, lin_to_db, noise_watts, pathloss_db};

/// A device's realized link for one training round.
#[derive(Clone, Copy, Debug)]
pub struct LinkRealization {
    pub snr_up_db: f64,
    pub snr_down_db: f64,
    pub rates: LinkRates,
}

#[derive(Clone, Debug)]
pub struct Channel {
    pub spec: ChannelSpec,
    pub state: ChannelState,
}

impl Channel {
    pub fn new(spec: ChannelSpec, state: ChannelState) -> Self {
        Self { spec, state }
    }

    /// Mean (no-fading) SNR for a link [dB].
    pub fn mean_snr_db(&self, distance_m: f64, tx_dbm: f64) -> f64 {
        let pl = pathloss_db(&self.spec, distance_m, self.state.pathloss_exp());
        let rx_w = dbm_to_watts(tx_dbm - pl);
        lin_to_db(rx_w / noise_watts(&self.spec, self.spec.bandwidth_hz))
    }

    /// Realize one round's links for a device (block fading).
    pub fn realize(&self, dev: &DeviceSpec, rng: &mut Rng) -> LinkRealization {
        let mean_up = self.mean_snr_db(dev.distance_m, self.spec.tx_power_device_dbm);
        let mean_down = self.mean_snr_db(dev.distance_m, self.spec.tx_power_ap_dbm);
        self.realize_from_means(mean_up, mean_down, rng)
    }

    /// [`Channel::realize`] with the (placement-pure) mean SNRs already
    /// computed — the fleet engine precomputes them per device so the
    /// per-round cost is just the fading draw.  Draws the same RNG
    /// stream in the same order, so the realization is bit-identical.
    pub fn realize_from_means(
        &self,
        mean_up_db: f64,
        mean_down_db: f64,
        rng: &mut Rng,
    ) -> LinkRealization {
        let (g_up, g_down) = if self.spec.fading {
            (rng.rayleigh_power(), rng.rayleigh_power())
        } else {
            (1.0, 1.0)
        };
        self.realize_with_gains(mean_up_db, mean_down_db, g_up, g_down)
    }

    /// Realize a link from mean SNRs and externally supplied fading
    /// power gains — the seam the pluggable [`FadingProcess`] plugs
    /// into (`net/link.rs`).  With the gains the i.i.d. path would have
    /// drawn, this is bit-identical to [`Channel::realize_from_means`]
    /// (same operations, same association).
    ///
    /// [`FadingProcess`]: super::fading::FadingProcess
    pub fn realize_with_gains(
        &self,
        mean_up_db: f64,
        mean_down_db: f64,
        g_up: f64,
        g_down: f64,
    ) -> LinkRealization {
        let snr_up = mean_up_db + lin_to_db(g_up);
        let snr_down = mean_down_db + lin_to_db(g_down);
        LinkRealization {
            snr_up_db: snr_up,
            snr_down_db: snr_down,
            rates: LinkRates {
                up_bps: self.rate_bps(snr_up),
                down_bps: self.rate_bps(snr_down),
            },
        }
    }

    /// R = B · y(SNR).  Outage is floored to a minimal control-channel
    /// rate (CQI-1 at 1/50 of the band, `net::cqi`'s named floor
    /// constants) instead of 0 — division-safe and matches
    /// retransmission-until-success behaviour.
    pub fn rate_bps(&self, snr_db: f64) -> f64 {
        let eff = spectral_efficiency(snr_db);
        if eff > 0.0 {
            self.spec.bandwidth_hz * eff
        } else {
            self.spec.bandwidth_hz * super::cqi::OUTAGE_FLOOR_EFFICIENCY
                / super::cqi::OUTAGE_BAND_DIVISOR
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChannelState::*;

    fn dev(dist: f64) -> DeviceSpec {
        DeviceSpec {
            name: "d".into(),
            platform: "p".into(),
            freq_hz: 1e9,
            cores: 1024.0,
            flops_per_cycle: 2.0,
            distance_m: dist,
        }
    }

    #[test]
    fn good_beats_normal_beats_poor() {
        let d = dev(20.0);
        let mk = |s| Channel::new(ChannelSpec::default(), s);
        let snr = |s| mk(s).mean_snr_db(d.distance_m, 23.0);
        assert!(snr(Good) > snr(Normal));
        assert!(snr(Normal) > snr(Poor));
    }

    #[test]
    fn downlink_stronger_than_uplink() {
        // AP transmits at 30 dBm vs device 23 dBm
        let ch = Channel::new(ChannelSpec::default(), Normal);
        let mut rng = Rng::new(1);
        let r = ch.realize(&dev(20.0), &mut rng);
        // with independent fading this holds in expectation; check means
        let up = ch.mean_snr_db(20.0, ch.spec.tx_power_device_dbm);
        let down = ch.mean_snr_db(20.0, ch.spec.tx_power_ap_dbm);
        assert!((down - up - 7.0).abs() < 1e-9);
        assert!(r.rates.up_bps > 0.0 && r.rates.down_bps > 0.0);
    }

    #[test]
    fn fading_varies_across_rounds() {
        let ch = Channel::new(ChannelSpec::default(), Normal);
        let d = dev(25.0);
        let mut rng = Rng::new(2);
        let rates: Vec<f64> = (0..20).map(|_| ch.realize(&d, &mut rng).rates.up_bps).collect();
        let distinct = rates
            .iter()
            .filter(|&&r| (r - rates[0]).abs() > 1.0)
            .count();
        assert!(distinct > 5, "fading should move the rate across rounds");
    }

    #[test]
    fn no_fading_is_deterministic() {
        let mut spec = ChannelSpec::default();
        spec.fading = false;
        let ch = Channel::new(spec, Good);
        let d = dev(25.0);
        let mut r1 = Rng::new(3);
        let mut r2 = Rng::new(4);
        assert_eq!(
            ch.realize(&d, &mut r1).rates.up_bps,
            ch.realize(&d, &mut r2).rates.up_bps
        );
    }

    #[test]
    fn realize_from_means_bitwise_matches_realize() {
        let ch = Channel::new(ChannelSpec::default(), Normal);
        let d = dev(25.0);
        let mean_up = ch.mean_snr_db(d.distance_m, ch.spec.tx_power_device_dbm);
        let mean_down = ch.mean_snr_db(d.distance_m, ch.spec.tx_power_ap_dbm);
        let mut r1 = Rng::new(77);
        let mut r2 = Rng::new(77);
        for _ in 0..50 {
            let a = ch.realize(&d, &mut r1);
            let b = ch.realize_from_means(mean_up, mean_down, &mut r2);
            assert_eq!(a.snr_up_db.to_bits(), b.snr_up_db.to_bits());
            assert_eq!(a.snr_down_db.to_bits(), b.snr_down_db.to_bits());
            assert_eq!(a.rates.up_bps.to_bits(), b.rates.up_bps.to_bits());
            assert_eq!(a.rates.down_bps.to_bits(), b.rates.down_bps.to_bits());
        }
    }

    #[test]
    fn realize_with_gains_bitwise_matches_rng_path() {
        let ch = Channel::new(ChannelSpec::default(), Normal);
        let mut r1 = Rng::new(5);
        for _ in 0..50 {
            // replay the exact gains the RNG path will draw
            let mut probe = r1.clone();
            let (g_up, g_down) = (probe.rayleigh_power(), probe.rayleigh_power());
            let a = ch.realize_from_means(18.0, 25.0, &mut r1);
            let b = ch.realize_with_gains(18.0, 25.0, g_up, g_down);
            assert_eq!(a.snr_up_db.to_bits(), b.snr_up_db.to_bits());
            assert_eq!(a.snr_down_db.to_bits(), b.snr_down_db.to_bits());
            assert_eq!(a.rates.up_bps.to_bits(), b.rates.up_bps.to_bits());
            assert_eq!(a.rates.down_bps.to_bits(), b.rates.down_bps.to_bits());
        }
    }

    #[test]
    fn outage_floor_rate_pinned_to_named_constants() {
        let ch = Channel::new(ChannelSpec::default(), Poor);
        let expect = ch.spec.bandwidth_hz * 0.1523 / 50.0;
        assert_eq!(ch.rate_bps(-40.0).to_bits(), expect.to_bits());
    }

    #[test]
    fn outage_floor_is_positive() {
        let ch = Channel::new(ChannelSpec::default(), Poor);
        assert!(ch.rate_bps(-40.0) > 0.0);
        assert!(ch.rate_bps(-40.0) < ch.rate_bps(0.0));
    }

    #[test]
    fn calibration_good_channel_hits_high_cqi() {
        // Device at 10 m with α=2 should saturate near the top of the
        // CQI table (paper's "Good" state).
        let ch = Channel::new(ChannelSpec::default(), Good);
        let snr = ch.mean_snr_db(10.0, 23.0);
        assert!(snr > 22.7, "good-state SNR = {snr} dB");
    }

    #[test]
    fn calibration_poor_channel_degrades() {
        let ch = Channel::new(ChannelSpec::default(), Poor);
        let snr = ch.mean_snr_db(30.0, 23.0);
        assert!(snr < 5.0, "poor-state SNR = {snr} dB should be low");
    }
}
