//! Multi-cell edge tier: a [`CellGrid`] of edge-server sites with
//! per-round device→cell association and hysteresis-guarded handover
//! (DESIGN.md §15).
//!
//! The paper assumes a single edge server at the origin.  `CellGrid`
//! generalizes that to N sites laid out by a [`CellLayout`], with cell
//! 0 always at the origin so `count = 1` reproduces the legacy
//! topology exactly.  Association is by **strongest pathloss**: under
//! the shared log-distance model (uniform exponent α across sites) the
//! strongest site is simply the nearest one, and a device switches its
//! serving cell only when the candidate's pathloss beats the serving
//! cell's by at least `hysteresis_db` — the standard A3-style margin
//! that keeps a device from ping-ponging while it straddles a
//! boundary.
//!
//! Every assignment is **precomputed at construction** from the
//! closed-form [`Mobility::position_at`] trajectories.  The serving
//! cell of any `(device, round)` is therefore a pure function of
//! `(config, seed)`, read-only during the run — the DES engine can
//! route jobs to per-cell queues in any event order without
//! re-deriving association state, preserving bit-level determinism.
//!
//! The radio plane is deliberately **not** moved to the serving cell:
//! SNRs, rates, and per-record energy stay the scheduler's pure
//! function of the origin-AP link, so a `count = 1` grid (and the
//! record streams of any count) remain bit-identical to the pre-cell
//! engines.  The cell tier governs *where server-side work queues*,
//! not what the channel looks like; see DESIGN.md §15 for the shared
//! radio-plane assumption.

use crate::config::{CellLayout, CellsSpec, ServerSpec};

use super::mobility::Mobility;

/// Distance clamp for the pathloss comparison so a trajectory passing
/// exactly through a site never produces log10(0) = -inf.
const D_CLAMP_M: f64 = 1e-3;

/// N edge-server sites + the precomputed per-round serving-cell
/// assignment of every device.
#[derive(Clone, Debug)]
pub struct CellGrid {
    positions: Vec<(f64, f64)>,
    /// Per-cell compute spec.  Today every site clones the experiment's
    /// single `ServerSpec`; the per-cell vector is the seam for
    /// heterogeneous sites.
    servers: Vec<ServerSpec>,
    /// `assignments[device][round]` — serving cell index.
    assignments: Vec<Vec<usize>>,
    /// `alt_assignments[device][round]` — nearest site *excluding* the
    /// serving cell: the failover target when the serving cell's link
    /// is inside a fault burst (DESIGN.md §17).  On a single-cell grid
    /// this degenerates to cell 0.
    alt_assignments: Vec<Vec<usize>>,
    handovers_in: Vec<u64>,
    total_handovers: u64,
}

impl CellGrid {
    /// Build the grid and precompute every device's serving-cell trace
    /// over `rounds` rounds.  `alpha` is the pathloss exponent shared
    /// by all sites (from the experiment's channel state).
    pub fn new(
        spec: &CellsSpec,
        server: &ServerSpec,
        mobility: &Mobility,
        devices: usize,
        rounds: usize,
        alpha: f64,
    ) -> Self {
        let positions = layout_positions(spec);
        let n_cells = positions.len();
        let rounds = rounds.max(1);
        let mut handovers_in = vec![0u64; n_cells];
        let mut total_handovers = 0u64;
        let mut assignments = Vec::with_capacity(devices);
        let mut alt_assignments = Vec::with_capacity(devices);
        for dev in 0..devices {
            let mut trace = Vec::with_capacity(rounds);
            let mut alt = Vec::with_capacity(rounds);
            let mut serving = nearest_cell(&positions, mobility.position_at(dev, 0));
            trace.push(serving);
            alt.push(nearest_cell_excluding(&positions, mobility.position_at(dev, 0), serving));
            for round in 1..rounds {
                let pos = mobility.position_at(dev, round);
                let candidate = nearest_cell(&positions, pos);
                if candidate != serving {
                    // A3-style margin: switch only when the
                    // candidate's pathloss undercuts the serving
                    // cell's by more than the hysteresis, i.e.
                    // 10·α·log10(d_serving/d_candidate) > h
                    let d_s = distance(positions[serving], pos).max(D_CLAMP_M);
                    let d_c = distance(positions[candidate], pos).max(D_CLAMP_M);
                    if 10.0 * alpha * (d_s / d_c).log10() > spec.hysteresis_db {
                        serving = candidate;
                        handovers_in[candidate] += 1;
                        total_handovers += 1;
                    }
                }
                trace.push(serving);
                alt.push(nearest_cell_excluding(&positions, pos, serving));
            }
            assignments.push(trace);
            alt_assignments.push(alt);
        }
        CellGrid {
            positions,
            servers: vec![server.clone(); n_cells],
            assignments,
            alt_assignments,
            handovers_in,
            total_handovers,
        }
    }

    /// Number of cell sites.
    pub fn count(&self) -> usize {
        self.positions.len()
    }

    /// Site position [m] of `cell`.
    pub fn position(&self, cell: usize) -> (f64, f64) {
        self.positions[cell]
    }

    /// Compute spec of `cell`'s edge server.
    pub fn server(&self, cell: usize) -> &ServerSpec {
        &self.servers[cell]
    }

    /// Serving cell of `device` at `round` (rounds past the precomputed
    /// horizon keep the last assignment).
    pub fn cell_of(&self, device: usize, round: usize) -> usize {
        let trace = &self.assignments[device];
        trace[round.min(trace.len() - 1)]
    }

    /// Failover target of `device` at `round` (DESIGN.md §17): the
    /// nearest site *other than the serving cell* — the cell the
    /// hysteresis comparison ranks second.  Equals the serving cell on
    /// a single-cell grid (no alternate exists).
    pub fn second_cell_of(&self, device: usize, round: usize) -> usize {
        let trace = &self.alt_assignments[device];
        trace[round.min(trace.len() - 1)]
    }

    /// Handovers that landed on `cell` (inbound re-associations).
    pub fn handovers_into(&self, cell: usize) -> u64 {
        self.handovers_in[cell]
    }

    /// Total handovers across the fleet and horizon.
    pub fn total_handovers(&self) -> u64 {
        self.total_handovers
    }
}

/// Site coordinates for a layout — cell 0 is always at the origin.
fn layout_positions(spec: &CellsSpec) -> Vec<(f64, f64)> {
    let n = spec.count.max(1);
    let s = spec.spacing_m;
    match spec.layout {
        CellLayout::Line => (0..n).map(|i| (i as f64 * s, 0.0)).collect(),
        CellLayout::Ring => (0..n)
            .map(|i| {
                if i == 0 {
                    (0.0, 0.0)
                } else {
                    let theta =
                        2.0 * std::f64::consts::PI * (i - 1) as f64 / (n - 1) as f64;
                    (s * theta.cos(), s * theta.sin())
                }
            })
            .collect(),
        CellLayout::Grid => {
            let side = (n as f64).sqrt().ceil() as usize;
            (0..n)
                .map(|i| ((i % side) as f64 * s, (i / side) as f64 * s))
                .collect()
        }
    }
}

fn distance(site: (f64, f64), pos: (f64, f64)) -> f64 {
    let (dx, dy) = (pos.0 - site.0, pos.1 - site.1);
    (dx * dx + dy * dy).sqrt()
}

/// Nearest site to `pos` (ties break to the lowest index).  With a
/// uniform pathloss exponent, nearest == strongest pathloss.
fn nearest_cell(positions: &[(f64, f64)], pos: (f64, f64)) -> usize {
    let mut best = 0;
    let mut best_d = f64::INFINITY;
    for (i, &site) in positions.iter().enumerate() {
        let d = distance(site, pos);
        if d < best_d {
            best_d = d;
            best = i;
        }
    }
    best
}

/// Nearest site to `pos` other than `exclude` — the failover target.
/// Falls back to `exclude` itself when it is the only site.
fn nearest_cell_excluding(positions: &[(f64, f64)], pos: (f64, f64), exclude: usize) -> usize {
    let mut best = exclude;
    let mut best_d = f64::INFINITY;
    for (i, &site) in positions.iter().enumerate() {
        if i == exclude {
            continue;
        }
        let d = distance(site, pos);
        if d < best_d {
            best_d = d;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DeviceSpec, MobilityModel, MobilitySpec};

    fn devices(dists: &[f64]) -> Vec<DeviceSpec> {
        dists
            .iter()
            .enumerate()
            .map(|(i, &d)| DeviceSpec {
                name: format!("d{i}"),
                platform: "p".into(),
                freq_hz: 1e9,
                cores: 1024.0,
                flops_per_cycle: 2.0,
                distance_m: d,
            })
            .collect()
    }

    fn mobility(model: MobilityModel, devs: &[DeviceSpec], root: u64) -> Mobility {
        let spec = MobilitySpec {
            model,
            speed_mps: 3.0,
            round_s: 10.0,
            range_m: 80.0,
            min_distance_m: 1.0,
        };
        Mobility::new(&spec, devs, root)
    }

    fn cells(count: usize, layout: CellLayout, hysteresis_db: f64) -> CellsSpec {
        CellsSpec {
            count,
            layout,
            spacing_m: 60.0,
            hysteresis_db,
        }
    }

    #[test]
    fn single_cell_grid_is_trivial() {
        let devs = devices(&[10.0, 45.0, 90.0]);
        let m = mobility(MobilityModel::Waypoint, &devs, 5);
        for layout in CellLayout::ALL {
            let g = CellGrid::new(&cells(1, layout, 3.0), &ServerSpec::default(), &m, 3, 40, 4.0);
            assert_eq!(g.count(), 1);
            assert_eq!(g.position(0), (0.0, 0.0));
            assert_eq!(g.total_handovers(), 0);
            assert_eq!(g.handovers_into(0), 0);
            for dev in 0..3 {
                for round in 0..40 {
                    assert_eq!(g.cell_of(dev, round), 0);
                }
            }
        }
    }

    #[test]
    fn layouts_put_cell_zero_at_the_origin() {
        let devs = devices(&[10.0]);
        let m = mobility(MobilityModel::Static, &devs, 1);
        let srv = ServerSpec::default();
        // line: sites on the x-axis at the spacing pitch
        let g = CellGrid::new(&cells(3, CellLayout::Line, 3.0), &srv, &m, 1, 1, 4.0);
        assert_eq!(g.position(0), (0.0, 0.0));
        assert_eq!(g.position(1), (60.0, 0.0));
        assert_eq!(g.position(2), (120.0, 0.0));
        // ring: cell 0 at the origin, the rest on the spacing radius
        let g = CellGrid::new(&cells(5, CellLayout::Ring, 3.0), &srv, &m, 1, 1, 4.0);
        assert_eq!(g.position(0), (0.0, 0.0));
        for c in 1..5 {
            let (x, y) = g.position(c);
            assert!(((x * x + y * y).sqrt() - 60.0).abs() < 1e-9, "cell {c}");
        }
        // grid: row-major square lattice
        let g = CellGrid::new(&cells(4, CellLayout::Grid, 3.0), &srv, &m, 1, 1, 4.0);
        assert_eq!(g.position(0), (0.0, 0.0));
        assert_eq!(g.position(1), (60.0, 0.0));
        assert_eq!(g.position(2), (0.0, 60.0));
        assert_eq!(g.position(3), (60.0, 60.0));
        // every cell carries a server spec
        assert_eq!(g.server(3).cores, srv.cores);
    }

    #[test]
    fn static_fleet_associates_nearest_and_never_hands_over() {
        // devices at 10, 50, 100 m on the x-axis; line cells at 0, 60, 120
        let devs = devices(&[10.0, 50.0, 100.0]);
        let m = mobility(MobilityModel::Static, &devs, 2);
        let g = CellGrid::new(&cells(3, CellLayout::Line, 3.0), &ServerSpec::default(), &m, 3, 20, 4.0);
        let expect = [0usize, 1, 2];
        for (dev, &cell) in expect.iter().enumerate() {
            for round in 0..20 {
                assert_eq!(g.cell_of(dev, round), cell, "device {dev}");
            }
        }
        assert_eq!(g.total_handovers(), 0);
    }

    #[test]
    fn huge_hysteresis_pins_the_initial_cell() {
        let devs = devices(&(0..16).map(|i| 15.0 + 7.0 * i as f64).collect::<Vec<_>>());
        let m = mobility(MobilityModel::Waypoint, &devs, 9);
        let g =
            CellGrid::new(&cells(4, CellLayout::Line, 1e6), &ServerSpec::default(), &m, 16, 60, 4.0);
        assert_eq!(g.total_handovers(), 0);
        for dev in 0..16 {
            let first = g.cell_of(dev, 0);
            for round in 0..60 {
                assert_eq!(g.cell_of(dev, round), first);
            }
        }
    }

    #[test]
    fn zero_hysteresis_tracks_the_nearest_cell_every_round() {
        let devs = devices(&(0..12).map(|i| 10.0 + 11.0 * i as f64).collect::<Vec<_>>());
        let m = mobility(MobilityModel::Linear, &devs, 13);
        let spec = cells(4, CellLayout::Line, 0.0);
        let g = CellGrid::new(&spec, &ServerSpec::default(), &m, 12, 50, 4.0);
        let positions = layout_positions(&spec);
        for dev in 0..12 {
            for round in 0..50 {
                let want = nearest_cell(&positions, m.position_at(dev, round));
                // zero margin: any strictly-nearer candidate wins, so the
                // serving cell is exactly the per-round nearest cell
                assert_eq!(g.cell_of(dev, round), want, "device {dev} round {round}");
            }
        }
    }

    #[test]
    fn handover_counts_match_the_trace_transitions() {
        let devs = devices(&(0..20).map(|i| 12.0 + 9.0 * i as f64).collect::<Vec<_>>());
        let m = mobility(MobilityModel::Waypoint, &devs, 21);
        let g = CellGrid::new(&cells(3, CellLayout::Line, 2.0), &ServerSpec::default(), &m, 20, 80, 4.0);
        let mut transitions = 0u64;
        let mut inbound = vec![0u64; 3];
        for dev in 0..20 {
            for round in 1..80 {
                let (prev, cur) = (g.cell_of(dev, round - 1), g.cell_of(dev, round));
                if prev != cur {
                    transitions += 1;
                    inbound[cur] += 1;
                }
            }
        }
        assert_eq!(g.total_handovers(), transitions);
        for c in 0..3 {
            assert_eq!(g.handovers_into(c), inbound[c], "cell {c}");
        }
        let per_cell_sum: u64 = (0..3).map(|c| g.handovers_into(c)).sum();
        assert_eq!(per_cell_sum, transitions);
    }

    #[test]
    fn assignment_traces_are_pure() {
        let devs = devices(&[20.0, 65.0, 110.0]);
        let m = mobility(MobilityModel::Waypoint, &devs, 3);
        let spec = cells(3, CellLayout::Line, 3.0);
        let a = CellGrid::new(&spec, &ServerSpec::default(), &m, 3, 30, 4.0);
        let b = CellGrid::new(&spec, &ServerSpec::default(), &m, 3, 30, 4.0);
        for dev in 0..3 {
            for round in 0..30 {
                assert_eq!(a.cell_of(dev, round), b.cell_of(dev, round));
            }
        }
        assert_eq!(a.total_handovers(), b.total_handovers());
    }

    #[test]
    fn second_cell_is_the_nearest_non_serving_site() {
        // static devices at 10, 50, 100 m; line cells at 0, 60, 120 m
        let devs = devices(&[10.0, 50.0, 100.0]);
        let m = mobility(MobilityModel::Static, &devs, 2);
        let spec = cells(3, CellLayout::Line, 3.0);
        let positions = layout_positions(&spec);
        let g = CellGrid::new(&spec, &ServerSpec::default(), &m, 3, 20, 4.0);
        for dev in 0..3 {
            for round in 0..20 {
                let serving = g.cell_of(dev, round);
                let second = g.second_cell_of(dev, round);
                assert_ne!(second, serving, "device {dev} round {round}");
                let want =
                    nearest_cell_excluding(&positions, m.position_at(dev, round), serving);
                assert_eq!(second, want, "device {dev} round {round}");
            }
        }
        // single-cell grid: no alternate exists, degenerate to serving
        let g1 = CellGrid::new(&cells(1, CellLayout::Line, 3.0), &ServerSpec::default(), &m, 3, 20, 4.0);
        for dev in 0..3 {
            assert_eq!(g1.second_cell_of(dev, 5), g1.cell_of(dev, 5));
        }
    }

    #[test]
    fn waypoint_boundary_crossing_reassociates_exactly_once() {
        // Devices start just inside cell 0's hysteresis band (x₀ ≈ 29 m
        // between line cells at 0 and 60 m, h = 3 dB, α = 4: switching
        // back to cell 0 would need d₁ > d₀·10^{3/40} ≈ 1.19·d₀, which
        // no point of an A→B ping-pong leg anchored at x₀ ≥ 28 can
        // satisfy).  So a waypoint loop that ever clears the margin
        // toward cell 1 hands over there exactly once and then *stays*
        // with cell 1 even as the loop carries it back across the
        // midline — the anti-ping-pong guarantee.  The seeded scan is
        // pure, so the trajectories it finds are stable.
        let spec = cells(2, CellLayout::Line, 3.0);
        let positions = layout_positions(&spec);
        let alpha = 4.0;
        let rounds = 10;
        let mut checked = 0;
        for root in 0..64u64 {
            let devs = devices(&[28.5, 29.0, 29.5, 30.0]);
            let m = mobility(MobilityModel::Waypoint, &devs, root);
            for dev in 0..devs.len() {
                // margin signal: positive once cell 1's pathloss beats
                // cell 0's by more than the hysteresis
                let margin = |round: usize| {
                    let pos = m.position_at(dev, round);
                    let d0 = distance(positions[0], pos).max(D_CLAMP_M);
                    let d1 = distance(positions[1], pos).max(D_CLAMP_M);
                    10.0 * alpha * (d0 / d1).log10() - spec.hysteresis_db
                };
                if (1..rounds).any(|n| margin(n) > 0.0) {
                    let g = CellGrid::new(
                        &spec,
                        &ServerSpec::default(),
                        &m,
                        devs.len(),
                        rounds,
                        alpha,
                    );
                    assert_eq!(g.cell_of(dev, 0), 0, "root {root} device {dev}");
                    assert_eq!(g.cell_of(dev, rounds - 1), 1, "root {root} device {dev}");
                    let transitions = (1..rounds)
                        .filter(|&n| g.cell_of(dev, n) != g.cell_of(dev, n - 1))
                        .count();
                    assert_eq!(transitions, 1, "root {root} device {dev}");
                    checked += 1;
                }
            }
        }
        assert!(checked >= 1, "scan found no boundary-crossing trajectory");
    }
}
