//! Device mobility: closed-form per-round distance trajectories
//! (DESIGN.md §13).
//!
//! The paper freezes each device at `DeviceSpec::distance_m`.
//! [`Mobility`] generalizes that placement into a trajectory whose
//! position at round `n` is a **closed-form O(1) function** of
//! `(seed, device, n)` — no integration, no per-round state — so the
//! fleet engines keep their any-order/any-thread bit-determinism, and
//! the DES engine's round-indexed channel sampling needs no new
//! machinery.  The AP sits at the origin; every device starts on the
//! x-axis at its configured placement distance.
//!
//! * **static** — `d(n) = d₀` (the default; schedulers keep their
//!   placement-pure mean-SNR fast path).
//! * **linear** — constant velocity along a device-seeded heading:
//!   `pos(n) = (d₀ + v·n·cosψ, v·n·sinψ)` with `v = speed·round_s`.
//! * **waypoint** — ping-pong between the start position A and a
//!   device-seeded waypoint B (≤ `range_m` away): position is the
//!   triangle-wave interpolation of the A→B segment.
//!
//! Distances are floored at `min_distance_m` so a trajectory can pass
//! near — never through — the AP.

use crate::config::{DeviceSpec, MobilityModel, MobilitySpec};
use crate::util::rng::{Rng, SplitMix64};

/// Per-fleet mobility plan: one closed-form trajectory per device.
#[derive(Clone, Debug)]
pub struct Mobility {
    min_distance_m: f64,
    paths: Vec<Trajectory>,
}

#[derive(Clone, Copy, Debug)]
enum Trajectory {
    Static {
        d0: f64,
    },
    Linear {
        x0: f64,
        /// velocity per round [m/round]
        vx: f64,
        vy: f64,
    },
    Waypoint {
        ax: f64,
        ay: f64,
        bx: f64,
        by: f64,
        /// fraction of the A→B segment traversed per round
        step: f64,
    },
}

impl Mobility {
    /// Build trajectories for a fleet.  `root` seeds the per-device
    /// heading/waypoint draws; it should derive from the experiment
    /// seed only (not the channel state), so Fig.-4-style state sweeps
    /// compare identical trajectories.
    pub fn new(spec: &MobilitySpec, devices: &[DeviceSpec], root: u64) -> Self {
        let v_round = spec.speed_mps * spec.round_s;
        let paths = devices
            .iter()
            .enumerate()
            .map(|(i, dev)| {
                let d0 = dev.distance_m;
                match spec.model {
                    MobilityModel::Static => Trajectory::Static { d0 },
                    _ if v_round == 0.0 => Trajectory::Static { d0 },
                    MobilityModel::Linear => {
                        let mut rng = Rng::new(SplitMix64::stream_seed(root, &[i as u64]));
                        let psi = rng.range(0.0, 2.0 * std::f64::consts::PI);
                        Trajectory::Linear {
                            x0: d0,
                            vx: v_round * psi.cos(),
                            vy: v_round * psi.sin(),
                        }
                    }
                    MobilityModel::Waypoint => {
                        // waypoint drawn relative to the *start position*
                        // so |B - A| <= range_m, honouring the spec's
                        // "maximum excursion from the start placement"
                        let mut rng = Rng::new(SplitMix64::stream_seed(root, &[i as u64]));
                        let beta = rng.range(0.0, 2.0 * std::f64::consts::PI);
                        let excursion = rng.range(0.0, spec.range_m);
                        let (bx, by) = (d0 + excursion * beta.cos(), excursion * beta.sin());
                        let len = ((bx - d0) * (bx - d0) + by * by).sqrt();
                        if len == 0.0 {
                            Trajectory::Static { d0 }
                        } else {
                            Trajectory::Waypoint {
                                ax: d0,
                                ay: 0.0,
                                bx,
                                by,
                                step: v_round / len,
                            }
                        }
                    }
                }
            })
            .collect();
        Mobility {
            min_distance_m: spec.min_distance_m,
            paths,
        }
    }

    /// Whether every trajectory is frozen (the mean-SNR fast path).
    pub fn is_static(&self) -> bool {
        self.paths.iter().all(|t| matches!(t, Trajectory::Static { .. }))
    }

    /// Cartesian position [m] of `device` at round `round` — the same
    /// closed-form trajectory [`Mobility::distance_at`] takes the norm
    /// of, exposed for the multi-cell tier's per-site pathloss ranking
    /// (DESIGN.md §15).  Unlike `distance_at`, positions carry no
    /// `min_distance_m` floor: the floor guards the *radio link* from
    /// a singular pathloss at the serving AP, while association only
    /// compares distances between candidate sites.
    pub fn position_at(&self, device: usize, round: usize) -> (f64, f64) {
        match self.paths[device] {
            Trajectory::Static { d0 } => (d0, 0.0),
            Trajectory::Linear { x0, vx, vy } => {
                let t = round as f64;
                (x0 + vx * t, vy * t)
            }
            Trajectory::Waypoint { ax, ay, bx, by, step } => {
                let u = (step * round as f64).rem_euclid(2.0);
                let frac = if u <= 1.0 { u } else { 2.0 - u };
                (ax + frac * (bx - ax), ay + frac * (by - ay))
            }
        }
    }

    /// Distance to the AP [m] of `device` at round `round` — a pure
    /// closed-form function of the plan and the round index.
    pub fn distance_at(&self, device: usize, round: usize) -> f64 {
        let d = match self.paths[device] {
            Trajectory::Static { d0 } => return d0,
            Trajectory::Linear { x0, vx, vy } => {
                let t = round as f64;
                let (x, y) = (x0 + vx * t, vy * t);
                (x * x + y * y).sqrt()
            }
            Trajectory::Waypoint { ax, ay, bx, by, step } => {
                // triangle wave: 0 → 1 → 0 → … along the A→B segment
                let u = (step * round as f64).rem_euclid(2.0);
                let frac = if u <= 1.0 { u } else { 2.0 - u };
                let (x, y) = (ax + frac * (bx - ax), ay + frac * (by - ay));
                (x * x + y * y).sqrt()
            }
        };
        d.max(self.min_distance_m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn devices(dists: &[f64]) -> Vec<DeviceSpec> {
        dists
            .iter()
            .enumerate()
            .map(|(i, &d)| DeviceSpec {
                name: format!("d{i}"),
                platform: "p".into(),
                freq_hz: 1e9,
                cores: 1024.0,
                flops_per_cycle: 2.0,
                distance_m: d,
            })
            .collect()
    }

    fn spec(model: MobilityModel) -> MobilitySpec {
        MobilitySpec {
            model,
            speed_mps: 3.0,
            round_s: 10.0,
            range_m: 40.0,
            min_distance_m: 1.0,
        }
    }

    #[test]
    fn static_returns_the_placement_exactly() {
        let devs = devices(&[10.0, 37.5]);
        let m = Mobility::new(&spec(MobilityModel::Static), &devs, 7);
        assert!(m.is_static());
        for (i, dev) in devs.iter().enumerate() {
            for round in [0, 1, 999] {
                assert_eq!(m.distance_at(i, round).to_bits(), dev.distance_m.to_bits());
            }
        }
    }

    #[test]
    fn zero_speed_degenerates_to_static() {
        let devs = devices(&[20.0]);
        let mut s = spec(MobilityModel::Linear);
        s.speed_mps = 0.0;
        let m = Mobility::new(&s, &devs, 7);
        assert!(m.is_static());
    }

    #[test]
    fn linear_starts_at_placement_and_moves() {
        let devs = devices(&[25.0, 40.0]);
        let m = Mobility::new(&spec(MobilityModel::Linear), &devs, 3);
        assert!(!m.is_static());
        for i in 0..devs.len() {
            assert!((m.distance_at(i, 0) - devs[i].distance_m).abs() < 1e-12);
            // 30 m per round: round 5 must have moved the device
            assert!((m.distance_at(i, 5) - devs[i].distance_m).abs() > 1.0);
            // straight-line motion: displacement from start grows
            // monotonically, so distance eventually grows unboundedly
            assert!(m.distance_at(i, 500) > m.distance_at(i, 5));
        }
    }

    #[test]
    fn linear_distance_obeys_the_triangle_inequality() {
        let devs = devices(&[30.0]);
        let m = Mobility::new(&spec(MobilityModel::Linear), &devs, 11);
        let step = 30.0; // speed 3 m/s × 10 s/round
        for n in 0..20 {
            // the device walks exactly n·step metres from its start, so
            // its AP distance can change by at most that much
            assert!((m.distance_at(0, n) - 30.0).abs() <= n as f64 * step + 1e-9);
        }
    }

    #[test]
    fn waypoint_ping_pongs_within_bounds() {
        let devs = devices(&[20.0, 35.0, 8.0]);
        let s = spec(MobilityModel::Waypoint);
        let m = Mobility::new(&s, &devs, 5);
        assert!(!m.is_static());
        for i in 0..devs.len() {
            let d0 = devs[i].distance_m;
            let mut min_d = f64::INFINITY;
            let mut max_d: f64 = 0.0;
            for n in 0..400 {
                let d = m.distance_at(i, n);
                // the device never strays more than range_m from its
                // start position, so its AP distance can deviate from
                // d0 by at most range_m (triangle inequality)
                assert!(d >= s.min_distance_m, "{d}");
                assert!((d - d0).abs() <= s.range_m + 1e-9, "{d} vs d0={d0}");
                min_d = min_d.min(d);
                max_d = max_d.max(d);
            }
            assert!(max_d > min_d, "waypoint trajectory never moved");
            // ping-pong: the device returns near its start, repeatedly
            let near_start = (0..400)
                .filter(|&n| (m.distance_at(i, n) - d0).abs() < 1.0)
                .count();
            assert!(near_start >= 2, "no loop closure for device {i}");
        }
    }

    #[test]
    fn trajectories_are_pure_and_seeded() {
        let devs = devices(&[15.0, 28.0]);
        let a = Mobility::new(&spec(MobilityModel::Waypoint), &devs, 9);
        let b = Mobility::new(&spec(MobilityModel::Waypoint), &devs, 9);
        let c = Mobility::new(&spec(MobilityModel::Waypoint), &devs, 10);
        let mut diverged = false;
        for n in 0..50 {
            assert_eq!(a.distance_at(0, n).to_bits(), b.distance_at(0, n).to_bits());
            if a.distance_at(0, n).to_bits() != c.distance_at(0, n).to_bits() {
                diverged = true;
            }
        }
        assert!(diverged, "seed must steer the waypoint draw");
    }

    #[test]
    fn position_norm_matches_distance_up_to_the_floor() {
        for model in [MobilityModel::Static, MobilityModel::Linear, MobilityModel::Waypoint] {
            let devs = devices(&[12.0, 33.0]);
            let m = Mobility::new(&spec(model), &devs, 4);
            for i in 0..devs.len() {
                for n in 0..60 {
                    let (x, y) = m.position_at(i, n);
                    let norm = (x * x + y * y).sqrt();
                    let d = m.distance_at(i, n);
                    // distance_at floors at min_distance_m; the raw
                    // position does not
                    assert!((d - norm.max(1.0)).abs() < 1e-9, "{model:?} dev {i} round {n}");
                }
            }
        }
    }

    #[test]
    fn min_distance_floor_holds() {
        // a device starting 2 m out with a 40 m excursion budget can
        // pass arbitrarily close to the AP — the floor must hold
        let devs = devices(&[2.0]);
        let mut s = spec(MobilityModel::Linear);
        s.min_distance_m = 1.5;
        let m = Mobility::new(&s, &devs, 1);
        for n in 0..200 {
            assert!(m.distance_at(0, n) >= 1.5);
        }
    }
}
