//! [`LinkProcess`]: the per-fleet link realization process
//! (DESIGN.md §13) — pathloss over a (possibly moving) placement,
//! composed with a pluggable fading process.
//!
//! This replaces the scheduler's hardwired "precomputed mean SNRs +
//! i.i.d. Rayleigh draw" path.  The contract is unchanged: realizing
//! the link of any `(device, round)` cell is a pure function of
//! `(config, seed, cell coordinates)`, so the parallel engines remain
//! bit-identical to serial under every process/mobility combination.
//! Under the default `iid` process with static mobility, the fast path
//! reproduces the pre-refactor engine **bit for bit**: the same
//! precomputed means, the same two Rayleigh draws from the same cell
//! RNG, the same arithmetic.

use crate::config::ExpConfig;
use crate::util::rng::{Rng, SplitMix64};

use super::channel::{Channel, LinkRealization};
use super::fading::FadingProcess;
use super::mobility::Mobility;

/// Stream-tag prefixes for the process sub-roots.  The first tag is
/// `u64::MAX` — unreachable as a round index — so process streams can
/// never collide with the scheduler's `[round, device]` cell streams
/// hanging off the same root.
const FADING_TAG: [u64; 2] = [u64::MAX, 0xFADE];
const MOBILITY_TAG: [u64; 2] = [u64::MAX, 0x0B17E];

/// Fading + mobility over one fleet's links.
#[derive(Clone, Debug)]
pub struct LinkProcess {
    pub channel: Channel,
    fading: FadingProcess,
    mobility: Mobility,
    /// Per-device `(uplink, downlink)` mean SNR [dB], precomputed when
    /// every trajectory is static — pathloss is then a pure function
    /// of the fixed placement, and the per-round cost is just the
    /// fading evaluation (the pre-refactor fast path).
    static_means: Option<Vec<(f64, f64)>>,
}

impl LinkProcess {
    /// Build the link process for a fleet.
    ///
    /// `stream_root` is the scheduler's `(seed, channel state)` root —
    /// the fading process hangs its counter streams off it so fading
    /// realizations differ across channel states exactly like the
    /// i.i.d. cell streams do.  Mobility trajectories seed from
    /// `cfg.seed` alone: like device placements, they are part of the
    /// *scenario*, identical across the channel states a Fig.-4-style
    /// sweep compares.
    pub fn new(channel: Channel, cfg: &ExpConfig, stream_root: u64) -> Self {
        let fading_root = SplitMix64::stream_seed(stream_root, &FADING_TAG);
        let mobility_root = SplitMix64::stream_seed(cfg.seed, &MOBILITY_TAG);
        let fading = FadingProcess::new(&cfg.channel.process, fading_root, cfg.devices.len());
        let mobility = Mobility::new(&cfg.mobility, &cfg.devices, mobility_root);
        let static_means = if mobility.is_static() {
            Some(
                cfg.devices
                    .iter()
                    .map(|d| Self::means_of(&channel, d.distance_m))
                    .collect(),
            )
        } else {
            None
        };
        LinkProcess {
            channel,
            fading,
            mobility,
            static_means,
        }
    }

    fn means_of(channel: &Channel, distance_m: f64) -> (f64, f64) {
        (
            channel.mean_snr_db(distance_m, channel.spec.tx_power_device_dbm),
            channel.mean_snr_db(distance_m, channel.spec.tx_power_ap_dbm),
        )
    }

    /// Whether the placement is frozen (mean-SNR fast path active).
    pub fn is_static(&self) -> bool {
        self.static_means.is_some()
    }

    /// Whether the fading process is the memoryless default.
    pub fn is_iid(&self) -> bool {
        self.fading.is_iid()
    }

    /// Distance to the AP of `device` at `round` [m] (telemetry).
    pub fn distance_at(&self, device: usize, round: usize) -> f64 {
        self.mobility.distance_at(device, round)
    }

    /// The fleet's mobility plan — the multi-cell tier ranks candidate
    /// sites against its closed-form positions (DESIGN.md §15).
    pub fn mobility(&self) -> &Mobility {
        &self.mobility
    }

    /// Mean (no-fading) SNRs for a cell, recomputed from the trajectory.
    fn means_at(&self, device: usize, round: usize) -> (f64, f64) {
        Self::means_of(&self.channel, self.mobility.distance_at(device, round))
    }

    /// Realize one `(device, round)` link — the engine fast path.
    /// `rng` must be the cell's own counter-derived stream; only the
    /// `iid` process consumes it (two Rayleigh draws, the pre-process
    /// order).
    pub fn realize(&self, device: usize, round: usize, rng: &mut Rng) -> LinkRealization {
        let (mean_up, mean_down) = match &self.static_means {
            Some(means) => means[device],
            None => self.means_at(device, round),
        };
        self.realize_from(mean_up, mean_down, device, round, rng)
    }

    /// [`LinkProcess::realize`] with every placement-derived term
    /// recomputed from scratch — the full-recompute reference path
    /// (`Scheduler::device_round_ref`).  Bit-identical to the fast
    /// path: the precomputed means are these same expressions.
    pub fn realize_ref(&self, device: usize, round: usize, rng: &mut Rng) -> LinkRealization {
        let (mean_up, mean_down) = self.means_at(device, round);
        self.realize_from(mean_up, mean_down, device, round, rng)
    }

    fn realize_from(
        &self,
        mean_up: f64,
        mean_down: f64,
        device: usize,
        round: usize,
        rng: &mut Rng,
    ) -> LinkRealization {
        let (g_up, g_down) = if self.channel.spec.fading {
            self.fading.gains(device, round, rng)
        } else {
            (1.0, 1.0)
        };
        self.channel.realize_with_gains(mean_up, mean_down, g_up, g_down)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChannelState, FadingModel, MobilityModel};

    fn cfg() -> ExpConfig {
        ExpConfig::paper()
    }

    fn process(cfg: &ExpConfig, state: ChannelState) -> LinkProcess {
        let channel = Channel::new(cfg.channel.clone(), state);
        let stream_root = cfg.seed ^ ((state.pathloss_exp() as u64) << 32);
        LinkProcess::new(channel, cfg, stream_root)
    }

    #[test]
    fn default_process_bitwise_matches_legacy_channel_realize() {
        // iid + static: LinkProcess must reproduce Channel::realize
        // exactly, drawing the same stream in the same order
        let cfg = cfg();
        let lp = process(&cfg, ChannelState::Normal);
        assert!(lp.is_static() && lp.is_iid());
        for (i, dev) in cfg.devices.iter().enumerate() {
            for round in [0usize, 3, 17] {
                let mut r1 = Rng::new(round as u64 * 31 + i as u64);
                let mut r2 = r1.clone();
                let a = lp.channel.realize(dev, &mut r1);
                let b = lp.realize(i, round, &mut r2);
                assert_eq!(a.snr_up_db.to_bits(), b.snr_up_db.to_bits());
                assert_eq!(a.snr_down_db.to_bits(), b.snr_down_db.to_bits());
                assert_eq!(a.rates.up_bps.to_bits(), b.rates.up_bps.to_bits());
                assert_eq!(a.rates.down_bps.to_bits(), b.rates.down_bps.to_bits());
            }
        }
    }

    #[test]
    fn ref_path_bitwise_matches_fast_path_everywhere() {
        for model in FadingModel::ALL {
            for mobile in [false, true] {
                let mut c = cfg();
                c.channel.process.model = model;
                if mobile {
                    c.mobility.model = MobilityModel::Linear;
                    c.mobility.speed_mps = 2.0;
                    c.mobility.round_s = 15.0;
                }
                let lp = process(&c, ChannelState::Poor);
                assert_eq!(lp.is_static(), !mobile);
                for i in 0..c.devices.len() {
                    for round in [0usize, 5, 40] {
                        let mut r1 = Rng::new(7);
                        let mut r2 = Rng::new(7);
                        let a = lp.realize(i, round, &mut r1);
                        let b = lp.realize_ref(i, round, &mut r2);
                        assert_eq!(a.snr_up_db.to_bits(), b.snr_up_db.to_bits(), "{model:?}");
                        assert_eq!(a.rates.up_bps.to_bits(), b.rates.up_bps.to_bits());
                        assert_eq!(a.rates.down_bps.to_bits(), b.rates.down_bps.to_bits());
                    }
                }
            }
        }
    }

    #[test]
    fn mobility_moves_the_mean_snr() {
        let mut c = cfg();
        c.channel.fading = false; // isolate the pathloss term
        c.mobility.model = MobilityModel::Linear;
        c.mobility.speed_mps = 5.0;
        c.mobility.round_s = 20.0;
        let lp = process(&c, ChannelState::Normal);
        assert!(!lp.is_static());
        let mut rng = Rng::new(0);
        let s0 = lp.realize(0, 0, &mut rng).snr_up_db;
        let s9 = lp.realize(0, 9, &mut rng).snr_up_db;
        assert!(
            (s0 - s9).abs() > 0.5,
            "100 m/round of motion must move the mean SNR ({s0} vs {s9})"
        );
        // and round 0 matches the static placement exactly
        let mut static_cfg = cfg();
        static_cfg.channel.fading = false;
        let static_lp = process(&static_cfg, ChannelState::Normal);
        let mut r = Rng::new(0);
        assert_eq!(
            static_lp.realize(0, 0, &mut r).snr_up_db.to_bits(),
            s0.to_bits()
        );
    }

    #[test]
    fn fading_off_disables_every_process() {
        for model in FadingModel::ALL {
            let mut c = cfg();
            c.channel.fading = false;
            c.channel.process.model = model;
            let lp = process(&c, ChannelState::Good);
            let mut r1 = Rng::new(1);
            let mut r2 = Rng::new(2);
            // no fading: realization is rng-independent and repeatable
            assert_eq!(
                lp.realize(2, 4, &mut r1).snr_up_db.to_bits(),
                lp.realize(2, 4, &mut r2).snr_up_db.to_bits()
            );
        }
    }
}
