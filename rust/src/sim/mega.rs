//! `mega-sweep`: the million-device streaming tier (DESIGN.md §18).
//!
//! One scenario preset, one very large synthetic fleet, run end-to-end
//! through the round engine's streaming SoA path (`ExecMode::Cached` →
//! bounded [`crate::coordinator::RoundBatch`] windows →
//! `exp::SummarySink`).  Nothing on that path grows with the fleet:
//! the engine holds one `SOA_WINDOW` of columns, the summary holds
//! Welford accumulators, a per-cut histogram, and a capped delay
//! reservoir.  The tier exists to *prove* that claim on every commit —
//! it reports
//!
//! * **cells/sec** — end-to-end streaming throughput (decision +
//!   channel + column fold), the rate `BENCH_mega.json` tracks across
//!   PRs; and
//! * **peak RSS** — the process high-water mark from
//!   `/proc/self/status` (`util::benchkit::peak_rss_bytes`), the
//!   memory ceiling the CI guard holds the streaming path to.
//!
//! The regression guard (`--check`) is asymmetric by design: the
//! committed baseline (`ci/mega_baseline.json`) stores an absolute
//! `min_cells_per_s` floor and an absolute `max_peak_rss_bytes`
//! ceiling.  Throughput floors are deliberately loose (raw rates track
//! the host CPU), but the RSS ceiling is tight enough that a
//! regression which re-materializes per-cell records at fleet scale —
//! the exact failure mode the SoA rework removed — blows straight
//! through it.
//!
//! Before the timed run, every invocation re-anchors correctness: the
//! same SoA-vs-oracle bit-identity gate the test suite runs
//! (`exp::verify::verify_soa_matches_oracles`) executes on a
//! scaled-down twin of the benched configuration, so a drifted stream
//! fails loudly instead of reporting a fast wrong number.

use crate::config::scenario::Scenario;
use crate::exp::{self, ExperimentBuilder, Report, ReportMeta};
use crate::obs;
use crate::util::benchkit::{peak_rss_bytes, Bencher};
use crate::util::json::{self, Json};

/// Fleet size of the scaled-down correctness twin each run gates on.
const GATE_DEVICES: usize = 192;
/// Round count of the correctness twin.
const GATE_ROUNDS: usize = 2;

/// One mega-sweep measurement: streaming throughput + memory ceiling
/// of the SoA round engine at fleet scale.
#[derive(Clone, Debug)]
pub struct MegaBench {
    pub scenario: String,
    pub n_devices: usize,
    pub rounds: usize,
    pub threads: usize,
    pub seed: u64,
    /// cells streamed (n_devices × rounds)
    pub cells: usize,
    pub wall_s: f64,
    /// end-to-end streaming throughput over the timed window
    pub cells_per_s: f64,
    /// process peak RSS after the run (`VmHWM`); `None` off-Linux
    pub peak_rss_bytes: Option<u64>,
    /// SoA windows the engine streamed (registry delta over the run)
    pub soa_chunks: u64,
    pub mean_delay_s: f64,
    pub p50_delay_s: f64,
    pub p95_delay_s: f64,
    pub p99_delay_s: f64,
    pub p999_delay_s: f64,
    pub mean_energy_j: f64,
    pub mean_cut: f64,
}

/// Run the tier on `scenario` with an `n_devices` synthetic fleet.
pub fn run(
    scenario: &Scenario,
    n_devices: usize,
    rounds: usize,
    threads: usize,
    seed: u64,
    bench: &mut Bencher,
) -> anyhow::Result<MegaBench> {
    anyhow::ensure!(n_devices > 0, "device count must be >= 1");
    anyhow::ensure!(rounds > 0, "rounds must be >= 1");

    // correctness anchor first: the streaming SoA path must be
    // bit-identical to both retained oracles on a scaled-down twin of
    // this exact preset/seed/threads before we time anything
    let twin = ExperimentBuilder::preset(scenario.name)
        .devices(n_devices.min(GATE_DEVICES))
        .rounds(rounds.min(GATE_ROUNDS))
        .seed(seed)
        .threads(threads)
        .build()?;
    exp::verify::verify_soa_matches_oracles(&twin)?;

    let experiment = ExperimentBuilder::preset(scenario.name)
        .devices(n_devices)
        .rounds(rounds)
        .seed(seed)
        .threads(threads)
        .build()?;

    let chunks_before = obs::metrics().soa_chunks.value();
    let t0 = std::time::Instant::now();
    let (summary, outcome) = experiment.run_summary()?;
    let wall = t0.elapsed().as_secs_f64();
    let soa_chunks = obs::metrics().soa_chunks.value() - chunks_before;

    anyhow::ensure!(
        outcome.cells == n_devices * rounds,
        "engine streamed {} cells, expected {}",
        outcome.cells,
        n_devices * rounds
    );
    anyhow::ensure!(
        summary.cells() == outcome.cells as u64,
        "summary folded {} cells, engine streamed {}",
        summary.cells(),
        outcome.cells
    );

    let cells_per_s = outcome.cells as f64 / wall.max(1e-9);
    let pct = summary.delay_percentiles();
    bench.record_once(
        &format!("mega_{}_n{n_devices}", scenario.name),
        wall,
        Some((cells_per_s, "cell")),
    );
    Ok(MegaBench {
        scenario: scenario.name.to_string(),
        n_devices,
        rounds,
        threads,
        seed,
        cells: outcome.cells,
        wall_s: wall,
        cells_per_s,
        peak_rss_bytes: peak_rss_bytes(),
        soa_chunks,
        mean_delay_s: summary.delay.mean(),
        p50_delay_s: pct.p50,
        p95_delay_s: pct.p95,
        p99_delay_s: pct.p99,
        p999_delay_s: pct.p999,
        mean_energy_j: summary.energy.mean(),
        mean_cut: summary.mean_cut(),
    })
}

impl MegaBench {
    /// Human summary (what the CLI prints above the bench table).
    pub fn render(&self) -> String {
        let rss = match self.peak_rss_bytes {
            Some(b) => format!("{:.1} MiB", b as f64 / (1024.0 * 1024.0)),
            None => "n/a (no /proc)".to_string(),
        };
        format!(
            "mega-sweep — {} × {} devices × {} rounds (seed {}, {} threads)\n\
             streamed        {} cells in {:.2} s  ({:.0} cells/s, {} SoA windows)\n\
             peak RSS        {}\n\
             delay           mean {:.3} s   p50 {:.3}   p95 {:.3}   p99 {:.3}   p99.9 {:.3}\n\
             energy / cut    mean {:.3} J   mean cut {:.1}",
            self.scenario,
            self.n_devices,
            self.rounds,
            self.seed,
            self.threads,
            self.cells,
            self.wall_s,
            self.cells_per_s,
            self.soa_chunks,
            rss,
            self.mean_delay_s,
            self.p50_delay_s,
            self.p95_delay_s,
            self.p99_delay_s,
            self.p999_delay_s,
            self.mean_energy_j,
            self.mean_cut,
        )
    }

    /// The enveloped report (`BENCH_mega.json`): shared
    /// `schema_version`/`meta` wrapper around [`MegaBench::to_json`].
    pub fn report(&self) -> Report {
        Report::new(
            ReportMeta {
                kind: "mega-sweep",
                preset: self.scenario.clone(),
                seed: self.seed,
                threads: self.threads,
                rounds: Some(self.rounds),
            },
            self.to_json(),
            self.render(),
        )
    }

    /// Emitter payload (the `data` member of the report envelope).
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("schema", Json::Str("edgesplit/mega-sweep/v1".into())),
            ("scenario", Json::Str(self.scenario.clone())),
            ("n_devices", Json::Num(self.n_devices as f64)),
            ("rounds", Json::Num(self.rounds as f64)),
            ("threads", Json::Num(self.threads as f64)),
            // string, not number: u64 seeds above 2^53 would lose
            // precision through the f64-backed Json::Num
            ("seed", Json::Str(self.seed.to_string())),
            ("cells", Json::Num(self.cells as f64)),
            ("wall_s", Json::Num(self.wall_s)),
            ("cells_per_s", Json::Num(self.cells_per_s)),
            (
                "peak_rss_bytes",
                match self.peak_rss_bytes {
                    Some(b) => Json::Num(b as f64),
                    None => Json::Null,
                },
            ),
            ("soa_chunks", Json::Num(self.soa_chunks as f64)),
            ("mean_delay_s", Json::Num(self.mean_delay_s)),
            ("p50_delay_s", Json::Num(self.p50_delay_s)),
            ("p95_delay_s", Json::Num(self.p95_delay_s)),
            ("p99_delay_s", Json::Num(self.p99_delay_s)),
            ("p999_delay_s", Json::Num(self.p999_delay_s)),
            ("mean_energy_j", Json::Num(self.mean_energy_j)),
            ("mean_cut", Json::Num(self.mean_cut)),
        ])
    }

    /// The CI regression guard: fail when throughput falls below the
    /// committed `min_cells_per_s` floor or peak RSS climbs above the
    /// committed `max_peak_rss_bytes` ceiling (see the module docs for
    /// why the floor is loose and the ceiling is the real tripwire).
    pub fn check_against(&self, baseline: &Json) -> anyhow::Result<()> {
        let field = |name: &str| -> anyhow::Result<f64> {
            // accept both the flat committed-baseline shape and a full
            // report envelope (fields under `data`), so a baseline
            // regenerated from an emitted BENCH_mega.json keeps working
            baseline
                .at(&["data", name])
                .or_else(|| baseline.get(name))
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("baseline is missing numeric field '{name}'"))
        };
        let floor = field("min_cells_per_s")?;
        anyhow::ensure!(
            self.cells_per_s >= floor,
            "mega-sweep throughput regression: {:.0} cells/s fell below the committed \
             floor of {:.0} cells/s",
            self.cells_per_s,
            floor
        );
        let ceiling = field("max_peak_rss_bytes")?;
        let rss = self.peak_rss_bytes.ok_or_else(|| {
            anyhow::anyhow!(
                "the baseline commits a peak-RSS ceiling but this platform has no \
                 /proc/self/status probe — the memory guard cannot run"
            )
        })?;
        anyhow::ensure!(
            (rss as f64) <= ceiling,
            "mega-sweep memory regression: peak RSS {:.1} MiB climbed above the committed \
             ceiling of {:.1} MiB — the streaming path is materializing per-cell state",
            rss as f64 / (1024.0 * 1024.0),
            ceiling / (1024.0 * 1024.0)
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::scenario;
    use crate::coordinator::SOA_WINDOW;

    fn quick() -> MegaBench {
        let mut bench = Bencher::new("mega-test");
        run(&scenario::DENSE_URBAN, 600, 2, 2, 7, &mut bench).unwrap()
    }

    #[test]
    fn streams_the_whole_fleet_and_measures() {
        let r = quick();
        assert_eq!(r.cells, 1200);
        assert!(r.cells_per_s > 0.0);
        assert!(r.wall_s > 0.0);
        assert!(r.soa_chunks > 0, "the SoA path must have filled chunks");
        assert!(r.mean_delay_s > 0.0 && r.mean_delay_s.is_finite());
        assert!(r.mean_energy_j > 0.0);
        assert!(r.p50_delay_s <= r.p95_delay_s && r.p95_delay_s <= r.p99_delay_s);
        #[cfg(target_os = "linux")]
        assert!(r.peak_rss_bytes.is_some(), "Linux must report VmHWM");
    }

    #[test]
    fn covers_partial_windows_beyond_one_soa_window() {
        // a fleet that is not a multiple of the window still streams
        // every cell exactly once
        let mut bench = Bencher::new("mega-window");
        let n = SOA_WINDOW + 37;
        let r = run(&scenario::HETEROGENEOUS_FLEET, n, 1, 4, 11, &mut bench).unwrap();
        assert_eq!(r.cells, n);
    }

    #[test]
    fn json_round_trips() {
        let r = quick();
        let js = r.to_json().to_string();
        assert!(js.contains("mega-sweep/v1"));
        assert!(js.contains("cells_per_s"));
        assert!(js.contains("peak_rss_bytes"));
        assert!(js.contains("soa_chunks"));
        let parsed = Json::parse(&js).unwrap();
        assert_eq!(parsed.get("cells").and_then(Json::as_usize), Some(r.cells));
        // the report envelope wraps the same payload
        let env = Json::parse(&r.report().to_json().to_string()).unwrap();
        assert_eq!(env.get("schema_version").and_then(Json::as_usize), Some(1));
        assert_eq!(
            env.at(&["meta", "preset"]).and_then(Json::as_str),
            Some(r.scenario.as_str())
        );
        assert!(env.at(&["data", "cells_per_s"]).is_some());
    }

    #[test]
    fn check_accepts_loose_and_rejects_tight_baselines() {
        let r = quick();
        let loose = json::obj(vec![
            ("min_cells_per_s", Json::Num(0.0)),
            ("max_peak_rss_bytes", Json::Num(1e15)),
        ]);
        r.check_against(&loose).unwrap();
        // an enveloped baseline (fields under `data`) also works
        let enveloped = json::obj(vec![("data", loose)]);
        r.check_against(&enveloped).unwrap();
        // an unreachable throughput floor trips the guard
        let fast = json::obj(vec![
            ("min_cells_per_s", Json::Num(1e15)),
            ("max_peak_rss_bytes", Json::Num(1e15)),
        ]);
        assert!(r.check_against(&fast).is_err());
        // a one-byte RSS ceiling trips the guard (Linux; elsewhere the
        // missing probe is itself an error, never a silent pass)
        let tiny = json::obj(vec![
            ("min_cells_per_s", Json::Num(0.0)),
            ("max_peak_rss_bytes", Json::Num(1.0)),
        ]);
        assert!(r.check_against(&tiny).is_err());
        // and a malformed baseline is an error, not a silent pass
        assert!(r.check_against(&Json::Null).is_err());
    }

    #[test]
    fn rejects_degenerate_input() {
        let mut bench = Bencher::new("bad");
        assert!(run(&scenario::DENSE_URBAN, 0, 2, 1, 0, &mut bench).is_err());
        assert!(run(&scenario::DENSE_URBAN, 4, 0, 1, 0, &mut bench).is_err());
    }

    #[test]
    fn render_reports_throughput_and_rss() {
        let s = quick().render();
        assert!(s.contains("mega-sweep"));
        assert!(s.contains("cells/s"));
        assert!(s.contains("peak RSS"));
        assert!(s.contains("SoA windows"));
    }
}
