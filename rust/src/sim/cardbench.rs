//! `card-bench`: the decision-kernel microbenchmark (DESIGN.md §12,
//! EXPERIMENTS.md) — measures the Alg.-1 decision path three ways over
//! one realized channel trace and emits `BENCH_card.json` so the perf
//! trajectory is machine-readable from CI:
//!
//! * **legacy** — the pre-kernel scan (`Strategy::decide_ref`): every
//!   cost call re-derives the FLOP/size model terms;
//! * **kernel** — the precomputed `CutTable` slice scan;
//! * **cached** — the kernel behind the CQI-keyed decision cache.
//!
//! All three modes see the *same* per-cell link realizations, and the
//! kernel/cached decisions are asserted bit-identical to legacy before
//! any rate is reported — a benchmark that drifted from the reference
//! would be measuring a different computation.
//!
//! The regression guard (`--check`) compares **speedups** (kernel and
//! cached decisions/sec normalized by the same-run legacy rate), not
//! raw decisions/sec: raw rates track the host CPU, while the ratio is
//! a property of the code.  The guard fails when a speedup drops below
//! 70% of the committed baseline's — i.e. a >30% decisions/sec
//! regression relative to what the baseline machine would see.
//!
//! The bench also runs one full engine pass per **fading process**
//! (iid/markov/jakes, DESIGN.md §13) and reports each run's
//! decision-cache hit rate: correlated channels revisit CQI keys, so
//! their hit rates should sit above the memoryless default's — the
//! per-process block in `BENCH_card.json` tracks that across PRs.

use crate::config::scenario::{Scenario, HETEROGENEOUS_FLEET};
use crate::config::FadingModel;
use crate::coordinator::{Decision, DecisionCache, Strategy};
use crate::exp::{self, ExperimentBuilder, NullSink, Report, ReportMeta};
use crate::net::channel::LinkRealization;
use crate::obs;
use crate::util::benchkit::Bencher;
use crate::util::json::{self, Json};
use crate::util::pool;
use crate::util::rng::Rng;

/// Decision-cache behaviour of one fading process on the benched
/// preset: how hard correlated channels lean on the CQI-keyed memo.
#[derive(Clone, Debug)]
pub struct ProcessHitRate {
    pub process: String,
    pub hit_rate: f64,
}

/// One full `card-bench` measurement.
#[derive(Clone, Debug)]
pub struct CardBench {
    pub scenario: String,
    pub n_devices: usize,
    pub rounds: usize,
    pub threads: usize,
    pub seed: u64,
    /// decisions timed per mode (n_devices × rounds)
    pub decisions: usize,
    pub legacy_decisions_per_s: f64,
    pub kernel_decisions_per_s: f64,
    pub cached_decisions_per_s: f64,
    pub speedup_kernel_vs_legacy: f64,
    pub speedup_cached_vs_legacy: f64,
    pub cache_hit_rate: f64,
    /// full engine cells/sec (decision + channel + record), serial
    pub cells_serial_per_s: f64,
    /// same on the persistent worker pool with `threads` participants
    pub cells_pooled_per_s: f64,
    pub pool_speedup: f64,
    /// decision-cache hit rate of a full engine run under each fading
    /// process (same preset/fleet/rounds) — correlated processes
    /// revisit CQI keys, so their hit rates should sit above `iid`'s
    pub process_hit_rates: Vec<ProcessHitRate>,
    /// pool cells claimed per worker slot during the pooled window
    /// (slot 0 = the participating caller; registry delta, DESIGN.md §16)
    pub pool_tasks_per_worker: Vec<u64>,
    /// pool idle parks during the pooled window (workers that found no
    /// work and blocked on the condvar)
    pub pool_idle_parks: u64,
}

/// Position-dependent digest over **every** `Decision` field: a
/// divergence in any field at any cell — including two opposite-sign
/// divergences that a plain sum would cancel — changes the value.
fn digest(acc: u64, idx: usize, d: &Decision) -> u64 {
    let mut h = acc ^ (idx as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    for bits in [
        d.cut as u64,
        d.freq_hz.to_bits(),
        d.cost.to_bits(),
        d.delay_s.to_bits(),
        d.energy_j.to_bits(),
    ] {
        h = (h ^ bits).wrapping_mul(0x100_0000_01B3);
    }
    h
}

/// Run the benchmark on `scenario` with an `n_devices` synthetic fleet.
pub fn run(
    scenario: &Scenario,
    n_devices: usize,
    rounds: usize,
    threads: usize,
    seed: u64,
    bench: &mut Bencher,
) -> anyhow::Result<CardBench> {
    anyhow::ensure!(n_devices > 0, "device count must be >= 1");
    anyhow::ensure!(rounds > 0, "rounds must be >= 1");
    let mut cfg = scenario.config(n_devices, seed)?;
    cfg.workload.rounds = rounds;
    // the base experiment supplies the kernel view (link process, cut
    // tables, cost model) every timed mode scans over
    let base = ExperimentBuilder::from_config(cfg.clone())
        .channel_state(scenario.state)
        .threads(1)
        .build()?;
    let sched = base.scheduler();

    // one shared channel trace through the configured link process:
    // every mode decides on identical rates
    let mut rng = Rng::new(seed ^ 0xCA7D);
    let mut cells: Vec<(usize, LinkRealization)> = Vec::with_capacity(n_devices * rounds);
    for n in 0..rounds {
        for i in 0..cfg.devices.len() {
            cells.push((i, sched.link.realize(i, n, &mut rng)));
        }
    }
    let decisions = cells.len();

    // --- legacy: pre-kernel scan, full model re-evaluation ------------
    let mut dummy = Rng::new(0); // CARD never draws from it
    let t0 = std::time::Instant::now();
    let mut legacy_digest = 0u64;
    for (idx, &(i, link)) in cells.iter().enumerate() {
        let d = Strategy::Card.decide_ref(
            &sched.cost_model,
            &cfg.server,
            &cfg.devices[i],
            link.rates,
            &mut dummy,
        );
        legacy_digest = digest(legacy_digest, idx, &d);
    }
    let legacy_s = t0.elapsed().as_secs_f64();

    // --- kernel: precomputed cut-table scan ---------------------------
    let tables = sched.tables();
    let t0 = std::time::Instant::now();
    let mut kernel_digest = 0u64;
    for (idx, &(i, link)) in cells.iter().enumerate() {
        let d = Strategy::Card.decide_on(&tables[i], link.rates, &mut dummy);
        kernel_digest = digest(kernel_digest, idx, &d);
    }
    let kernel_s = t0.elapsed().as_secs_f64();
    anyhow::ensure!(
        kernel_digest == legacy_digest,
        "kernel scan diverged from the legacy reference — refusing to report"
    );

    // --- cached: kernel behind the CQI-keyed memo ---------------------
    let cache = DecisionCache::new(n_devices);
    let t0 = std::time::Instant::now();
    let mut cached_digest = 0u64;
    for (idx, &(i, link)) in cells.iter().enumerate() {
        let key = DecisionCache::key(link.snr_up_db, link.snr_down_db);
        let d = match cache.lookup(i, key) {
            Some((cut, f_hz, cost)) => tables[i].realize(cut, f_hz, cost, link.rates),
            None => {
                let d = Strategy::Card.decide_on(&tables[i], link.rates, &mut dummy);
                cache.store(i, key, d.cut, d.freq_hz, d.cost);
                d
            }
        };
        cached_digest = digest(cached_digest, idx, &d);
    }
    let cached_s = t0.elapsed().as_secs_f64();
    anyhow::ensure!(
        cached_digest == legacy_digest,
        "cached path diverged from the legacy reference — refusing to report"
    );

    // --- whole-engine cells/sec: serial vs persistent pool ------------
    // fresh experiments so both start from a cold decision cache
    let serial_exp = ExperimentBuilder::from_config(cfg.clone())
        .channel_state(scenario.state)
        .threads(1)
        .build()?;
    let t0 = std::time::Instant::now();
    let serial_records = serial_exp.run_collect()?;
    let serial_s = t0.elapsed().as_secs_f64();

    let pooled_exp = ExperimentBuilder::from_config(cfg.clone())
        .channel_state(scenario.state)
        .threads(threads)
        .build()?;
    // warm the persistent pool so the timed window measures cells, not
    // the one-time worker spawn
    pool::global().workers();
    // registry deltas across the pooled window: who claimed the cells,
    // and how often workers went idle (observation only — the pooled
    // records stay bit-identical to serial either way)
    let claimed_before = obs::metrics().pool_claimed.values();
    let parks_before = obs::metrics().pool_parks.value();
    let t0 = std::time::Instant::now();
    let pooled_records = pooled_exp.run_collect()?;
    let pooled_s = t0.elapsed().as_secs_f64();
    exp::verify::verify_bit_identical(&serial_records, &pooled_records)?;
    let mut pool_tasks_per_worker: Vec<u64> = obs::metrics()
        .pool_claimed
        .values()
        .iter()
        .zip(&claimed_before)
        .map(|(after, before)| after - before)
        .collect();
    while pool_tasks_per_worker.len() > 1 && *pool_tasks_per_worker.last().unwrap() == 0 {
        pool_tasks_per_worker.pop();
    }
    let pool_idle_parks = obs::metrics().pool_parks.value() - parks_before;

    // --- decision-cache hit rate per fading process --------------------
    // same preset/fleet/rounds, one full engine run per process: the
    // first real workout of the PR-3 cache under correlated channels.
    // The preset's own process already ran as the pooled measurement —
    // reuse its hit rate instead of re-running the engine.
    let mut process_hit_rates = Vec::with_capacity(FadingModel::ALL.len());
    for model in FadingModel::ALL {
        let hit_rate = if model == cfg.channel.process.model {
            pooled_exp.scheduler().cache_hit_rate()
        } else {
            let mut pcfg = cfg.clone();
            pcfg.channel.process.model = model;
            let e = ExperimentBuilder::from_config(pcfg)
                .channel_state(scenario.state)
                .threads(threads)
                .build()?;
            e.run_into(&mut NullSink)?;
            e.scheduler().cache_hit_rate()
        };
        process_hit_rates.push(ProcessHitRate {
            process: model.name().to_string(),
            hit_rate,
        });
    }

    let per_s = |elapsed: f64| decisions as f64 / elapsed.max(1e-9);
    let result = CardBench {
        scenario: scenario.name.to_string(),
        n_devices,
        rounds,
        threads,
        seed,
        decisions,
        legacy_decisions_per_s: per_s(legacy_s),
        kernel_decisions_per_s: per_s(kernel_s),
        cached_decisions_per_s: per_s(cached_s),
        speedup_kernel_vs_legacy: legacy_s / kernel_s.max(1e-12),
        speedup_cached_vs_legacy: legacy_s / cached_s.max(1e-12),
        cache_hit_rate: cache.hit_rate(),
        cells_serial_per_s: per_s(serial_s),
        cells_pooled_per_s: per_s(pooled_s),
        pool_speedup: serial_s / pooled_s.max(1e-12),
        process_hit_rates,
        pool_tasks_per_worker,
        pool_idle_parks,
    };
    let rows = [
        ("decide_legacy", legacy_s, result.legacy_decisions_per_s, "decision"),
        ("decide_kernel", kernel_s, result.kernel_decisions_per_s, "decision"),
        ("decide_cached", cached_s, result.cached_decisions_per_s, "decision"),
        ("cells_serial", serial_s, result.cells_serial_per_s, "cell"),
        ("cells_pooled", pooled_s, result.cells_pooled_per_s, "cell"),
    ];
    for (name, secs, rate, unit) in rows {
        bench.record_once(name, secs, Some((rate, unit)));
    }
    Ok(result)
}

/// Run with the acceptance-spec defaults: heterogeneous-fleet preset.
pub fn run_default(
    n_devices: usize,
    rounds: usize,
    threads: usize,
    seed: u64,
    bench: &mut Bencher,
) -> anyhow::Result<CardBench> {
    run(&HETEROGENEOUS_FLEET, n_devices, rounds, threads, seed, bench)
}

impl CardBench {
    /// Human summary (what the CLI prints above the bench table).
    pub fn render(&self) -> String {
        let by_process = self
            .process_hit_rates
            .iter()
            .map(|p| format!("{} {:.1}%", p.process, 100.0 * p.hit_rate))
            .collect::<Vec<_>>()
            .join("   ");
        let by_worker = self
            .pool_tasks_per_worker
            .iter()
            .enumerate()
            .map(|(i, &n)| {
                if i == 0 {
                    format!("caller {n}")
                } else {
                    format!("w{} {n}", i - 1)
                }
            })
            .collect::<Vec<_>>()
            .join("   ");
        format!(
            "card-bench — {} × {} devices × {} rounds (seed {})\n\
             decisions/sec   legacy {:>12.0}   kernel {:>12.0} ({:.1}×)   cached {:>12.0} ({:.1}×)\n\
             cache hit-rate  {:.1}%\n\
             hit-rate by fading process   {}\n\
             cells/sec       serial {:>12.0}   pooled {:>12.0} ({:.1}× on {} threads)\n\
             pool claims     {}   (idle parks {})",
            self.scenario,
            self.n_devices,
            self.rounds,
            self.seed,
            self.legacy_decisions_per_s,
            self.kernel_decisions_per_s,
            self.speedup_kernel_vs_legacy,
            self.cached_decisions_per_s,
            self.speedup_cached_vs_legacy,
            100.0 * self.cache_hit_rate,
            by_process,
            self.cells_serial_per_s,
            self.cells_pooled_per_s,
            self.pool_speedup,
            self.threads,
            by_worker,
            self.pool_idle_parks,
        )
    }

    /// The enveloped report (`BENCH_card.json`): shared
    /// `schema_version`/`meta` wrapper around [`CardBench::to_json`].
    pub fn report(&self) -> Report {
        Report::new(
            ReportMeta {
                kind: "card-bench",
                preset: self.scenario.clone(),
                seed: self.seed,
                threads: self.threads,
                rounds: Some(self.rounds),
            },
            self.to_json(),
            self.render(),
        )
    }

    /// Emitter payload (the `data` member of the report envelope).
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("schema", Json::Str("edgesplit/card-bench/v1".into())),
            ("scenario", Json::Str(self.scenario.clone())),
            ("n_devices", Json::Num(self.n_devices as f64)),
            ("rounds", Json::Num(self.rounds as f64)),
            ("threads", Json::Num(self.threads as f64)),
            // string, not number: u64 seeds above 2^53 would lose
            // precision through the f64-backed Json::Num
            ("seed", Json::Str(self.seed.to_string())),
            ("decisions", Json::Num(self.decisions as f64)),
            ("legacy_decisions_per_s", Json::Num(self.legacy_decisions_per_s)),
            ("kernel_decisions_per_s", Json::Num(self.kernel_decisions_per_s)),
            ("cached_decisions_per_s", Json::Num(self.cached_decisions_per_s)),
            ("speedup_kernel_vs_legacy", Json::Num(self.speedup_kernel_vs_legacy)),
            ("speedup_cached_vs_legacy", Json::Num(self.speedup_cached_vs_legacy)),
            ("cache_hit_rate", Json::Num(self.cache_hit_rate)),
            ("cells_serial_per_s", Json::Num(self.cells_serial_per_s)),
            ("cells_pooled_per_s", Json::Num(self.cells_pooled_per_s)),
            ("pool_speedup", Json::Num(self.pool_speedup)),
            (
                "process_hit_rates",
                json::obj(
                    self.process_hit_rates
                        .iter()
                        .map(|p| (p.process.as_str(), Json::Num(p.hit_rate)))
                        .collect(),
                ),
            ),
            (
                "pool_tasks_per_worker",
                Json::Arr(
                    self.pool_tasks_per_worker
                        .iter()
                        .map(|&n| Json::Num(n as f64))
                        .collect(),
                ),
            ),
            ("pool_idle_parks", Json::Num(self.pool_idle_parks as f64)),
        ])
    }

    /// The CI regression guard: fail when a decision-path speedup drops
    /// below 70% of the committed baseline's (see the module docs for
    /// why speedups, not raw rates, are compared).
    pub fn check_against(&self, baseline: &Json) -> anyhow::Result<()> {
        let field = |name: &str| -> anyhow::Result<f64> {
            // accept both the flat committed-baseline shape and a full
            // report envelope (speedups under `data`), so a baseline
            // regenerated from an emitted BENCH_card.json keeps working
            baseline
                .at(&["data", name])
                .or_else(|| baseline.get(name))
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("baseline is missing numeric field '{name}'"))
        };
        let kernel_floor = 0.7 * field("speedup_kernel_vs_legacy")?;
        let cached_floor = 0.7 * field("speedup_cached_vs_legacy")?;
        anyhow::ensure!(
            self.speedup_kernel_vs_legacy >= kernel_floor,
            "decision-kernel regression: kernel speedup {:.2}× fell below 70% of the \
             committed baseline ({:.2}× floor)",
            self.speedup_kernel_vs_legacy,
            kernel_floor
        );
        anyhow::ensure!(
            self.speedup_cached_vs_legacy >= cached_floor,
            "decision-cache regression: cached speedup {:.2}× fell below 70% of the \
             committed baseline ({:.2}× floor)",
            self.speedup_cached_vs_legacy,
            cached_floor
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> CardBench {
        let mut bench = Bencher::new("card-bench-test");
        run_default(40, 3, 2, 5, &mut bench).unwrap()
    }

    #[test]
    fn measures_all_three_modes_and_agrees() {
        let r = quick();
        assert_eq!(r.decisions, 120);
        assert!(r.legacy_decisions_per_s > 0.0);
        assert!(r.kernel_decisions_per_s > 0.0);
        assert!(r.cached_decisions_per_s > 0.0);
        assert!(r.speedup_kernel_vs_legacy > 0.0);
        assert!(r.cache_hit_rate >= 0.0 && r.cache_hit_rate <= 1.0);
        assert!(r.cells_serial_per_s > 0.0 && r.cells_pooled_per_s > 0.0);
    }

    #[test]
    fn reports_hit_rates_for_every_fading_process() {
        let mut bench = Bencher::new("card-bench-process");
        // enough rounds for correlated fading to revisit CQI keys
        let r = run_default(30, 12, 2, 5, &mut bench).unwrap();
        assert_eq!(r.process_hit_rates.len(), 3);
        let rate = |name: &str| {
            r.process_hit_rates
                .iter()
                .find(|p| p.process == name)
                .unwrap_or_else(|| panic!("missing process '{name}'"))
                .hit_rate
        };
        for name in ["iid", "markov", "jakes"] {
            assert!((0.0..=1.0).contains(&rate(name)), "{name}");
        }
        // the acceptance bar: correlated fading leans on the decision
        // cache harder than the memoryless default
        assert!(
            rate("markov") > rate("iid"),
            "markov {} should beat iid {}",
            rate("markov"),
            rate("iid")
        );
    }

    #[test]
    fn json_round_trips() {
        let r = quick();
        let js = r.to_json().to_string();
        assert!(js.contains("card-bench/v1"));
        assert!(js.contains("speedup_kernel_vs_legacy"));
        assert!(js.contains("cache_hit_rate"));
        assert!(js.contains("process_hit_rates"));
        assert!(js.contains("markov"));
        assert!(js.contains("pool_tasks_per_worker"));
        assert!(js.contains("pool_idle_parks"));
        let parsed = Json::parse(&js).unwrap();
        assert_eq!(parsed.get("n_devices").and_then(Json::as_usize), Some(r.n_devices));
        assert!(parsed
            .at(&["process_hit_rates", "iid"])
            .and_then(Json::as_f64)
            .is_some());
        // the report envelope wraps the same payload
        let env = Json::parse(&r.report().to_json().to_string()).unwrap();
        assert_eq!(env.get("schema_version").and_then(Json::as_usize), Some(1));
        assert_eq!(
            env.at(&["meta", "preset"]).and_then(Json::as_str),
            Some(r.scenario.as_str())
        );
        assert!(env.at(&["data", "cache_hit_rate"]).is_some());
    }

    #[test]
    fn check_accepts_self_and_rejects_inflated_baseline() {
        let r = quick();
        // a result always clears a baseline of itself — flat payload or
        // full report envelope
        r.check_against(&Json::parse(&r.to_json().to_string()).unwrap()).unwrap();
        r.check_against(&Json::parse(&r.report().to_json().to_string()).unwrap())
            .unwrap();
        // a baseline claiming an absurd speedup must trip the guard
        let inflated = json::obj(vec![
            ("speedup_kernel_vs_legacy", Json::Num(1e9)),
            ("speedup_cached_vs_legacy", Json::Num(1e9)),
        ]);
        assert!(r.check_against(&inflated).is_err());
        // and a malformed baseline is an error, not a silent pass
        assert!(r.check_against(&Json::Null).is_err());
    }

    #[test]
    fn rejects_degenerate_input() {
        let mut bench = Bencher::new("bad");
        assert!(run_default(0, 2, 1, 0, &mut bench).is_err());
        assert!(run_default(4, 0, 1, 0, &mut bench).is_err());
    }

    #[test]
    fn render_mentions_every_mode() {
        let s = quick().render();
        assert!(s.contains("legacy"));
        assert!(s.contains("kernel"));
        assert!(s.contains("cached"));
        assert!(s.contains("cache hit-rate"));
        assert!(s.contains("pooled"));
        assert!(s.contains("pool claims"));
        assert!(s.contains("idle parks"));
    }
}
