//! Ablation sweeps (DESIGN.md experiment index A1/A2): how the CARD
//! decision landscape responds to the weight w, the compression ratio
//! φ, and the channel bandwidth — the design choices the paper fixes in
//! Table II.

use crate::config::{ChannelState, ExpConfig};
use crate::exp::ExperimentBuilder;
use crate::util::table::Table;

use super::metrics::Summary;

#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub value: f64,
    pub mean_delay_s: f64,
    pub mean_energy_j: f64,
    pub mean_freq_ghz: f64,
    pub frac_cut_full: f64,
}

fn run_point(cfg: &ExpConfig, state: ChannelState) -> anyhow::Result<(Summary, usize)> {
    // parallel fleet engine, summarized online; bit-identical to the
    // serial reference path
    let experiment = ExperimentBuilder::from_config(cfg.clone())
        .channel_state(state)
        .build()?;
    let n_layers = experiment.scheduler().cost_model.n_layers();
    let (s, _) = experiment.run_summary()?;
    Ok((s, n_layers))
}

/// A1: sweep the delay/energy weight w ∈ [0, 1].
pub fn sweep_w(base: &ExpConfig, values: &[f64]) -> anyhow::Result<Vec<SweepPoint>> {
    let mut out = Vec::new();
    for &w in values {
        let mut cfg = base.clone();
        cfg.card.w = w;
        let (s, nl) = run_point(&cfg, ChannelState::Normal)?;
        let (_, at_i) = s.endpoint_fractions(nl);
        out.push(SweepPoint {
            value: w,
            mean_delay_s: s.delay.mean(),
            mean_energy_j: s.energy.mean(),
            mean_freq_ghz: s.mean_freq_ghz(),
            frac_cut_full: at_i,
        });
    }
    Ok(out)
}

/// A2a: sweep the compression ratio φ.
pub fn sweep_phi(base: &ExpConfig, values: &[f64]) -> anyhow::Result<Vec<SweepPoint>> {
    let mut out = Vec::new();
    for &phi in values {
        let mut cfg = base.clone();
        cfg.workload.phi = phi;
        let (s, nl) = run_point(&cfg, ChannelState::Poor)?;
        let (_, at_i) = s.endpoint_fractions(nl);
        out.push(SweepPoint {
            value: phi,
            mean_delay_s: s.delay.mean(),
            mean_energy_j: s.energy.mean(),
            mean_freq_ghz: s.mean_freq_ghz(),
            frac_cut_full: at_i,
        });
    }
    Ok(out)
}

/// A2b: sweep bandwidth [MHz].
pub fn sweep_bandwidth(base: &ExpConfig, values_mhz: &[f64]) -> anyhow::Result<Vec<SweepPoint>> {
    let mut out = Vec::new();
    for &mhz in values_mhz {
        let mut cfg = base.clone();
        cfg.channel.bandwidth_hz = mhz * 1e6;
        let (s, nl) = run_point(&cfg, ChannelState::Normal)?;
        let (_, at_i) = s.endpoint_fractions(nl);
        out.push(SweepPoint {
            value: mhz,
            mean_delay_s: s.delay.mean(),
            mean_energy_j: s.energy.mean(),
            mean_freq_ghz: s.mean_freq_ghz(),
            frac_cut_full: at_i,
        });
    }
    Ok(out)
}

pub fn render(title: &str, label: &str, points: &[SweepPoint]) -> String {
    let mut t = Table::new(
        title,
        &[label, "delay [s]", "energy [J]", "f* [GHz]", "frac cut=I"],
    );
    for p in points {
        t.row(vec![
            format!("{:.3}", p.value),
            format!("{:.2}", p.mean_delay_s),
            format!("{:.1}", p.mean_energy_j),
            format!("{:.2}", p.mean_freq_ghz),
            format!("{:.2}", p.frac_cut_full),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ExpConfig {
        let mut c = ExpConfig::paper();
        c.workload.rounds = 6;
        c
    }

    #[test]
    fn w_extremes_recover_single_objectives() {
        let pts = sweep_w(&cfg(), &[0.0, 1.0]).unwrap();
        // w=0: pure energy — minimal frequency, everything on devices
        assert!(pts[0].frac_cut_full > 0.99);
        // w=1: pure delay — max frequency
        assert!(pts[1].mean_freq_ghz > 2.4);
        // delay at w=1 must be lower than at w=0
        assert!(pts[1].mean_delay_s < pts[0].mean_delay_s);
        // energy at w=0 must be lower than at w=1
        assert!(pts[0].mean_energy_j < pts[1].mean_energy_j);
    }

    #[test]
    fn w_sweep_is_paretoish() {
        // as w grows, delay (weighted objective) should not increase
        let pts = sweep_w(&cfg(), &[0.1, 0.3, 0.5, 0.7, 0.9]).unwrap();
        for pair in pts.windows(2) {
            assert!(
                pair[1].mean_delay_s <= pair[0].mean_delay_s * 1.05,
                "delay should trend down with w"
            );
        }
    }

    #[test]
    fn heavier_compression_helps_poor_channel_delay() {
        let pts = sweep_phi(&cfg(), &[0.05, 0.5]).unwrap();
        assert!(pts[0].mean_delay_s < pts[1].mean_delay_s);
    }

    #[test]
    fn more_bandwidth_less_delay() {
        let pts = sweep_bandwidth(&cfg(), &[20.0, 200.0]).unwrap();
        assert!(pts[1].mean_delay_s < pts[0].mean_delay_s);
    }

    #[test]
    fn render_has_all_rows() {
        let pts = sweep_w(&cfg(), &[0.2, 0.8]).unwrap();
        let s = render("t", "w", &pts);
        assert!(s.contains("0.200") && s.contains("0.800"));
    }
}
