//! Experiment harness: one module per paper figure + ablation sweeps
//! (see DESIGN.md §5 experiment index).

pub mod ablate;
pub mod fig3;
pub mod fig4;
pub mod metrics;

pub use metrics::{reduction_pct, Summary};
