//! Experiment harness: one module per paper figure, ablation sweeps,
//! and the fleet-scale scenario engine (see DESIGN.md §5 experiment
//! index).

pub mod ablate;
pub mod cardbench;
pub mod fig3;
pub mod fig4;
pub mod fleet;
pub mod mega;
pub mod metrics;
pub mod policysweep;

pub use cardbench::CardBench;
pub use fleet::{FleetPoint, FleetSweep};
pub use mega::MegaBench;
pub use policysweep::{PolicyCurve, PolicySweep, POLICY_STRATEGIES};
pub use metrics::{reduction_pct, Percentiles, Summary};
