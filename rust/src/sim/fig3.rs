//! Fig. 3 reproduction: per-device CARD decisions across training rounds
//! under a dynamic (Rayleigh block-fading) channel.
//!
//!   (a) optimal cut layer per device per round   — always 0 or I,
//!       stronger devices at I, weaker at 0, flips with fading;
//!   (b) server frequency allocation per device per round — higher for
//!       weaker devices (they offload more).

use crate::config::{ChannelState, ExpConfig};
use crate::coordinator::RoundRecord;
use crate::exp::ExperimentBuilder;
use crate::util::table::Table;

#[derive(Clone, Debug)]
pub struct Fig3Result {
    pub records: Vec<RoundRecord>,
    pub n_devices: usize,
    pub rounds: usize,
    pub n_layers: usize,
}

pub fn run(cfg: &ExpConfig, state: ChannelState) -> anyhow::Result<Fig3Result> {
    // the parallel round engine is bit-identical to the serial
    // reference path, so the figure is reproducible at any thread count
    let experiment = ExperimentBuilder::from_config(cfg.clone())
        .channel_state(state)
        .build()?;
    let n_layers = experiment.scheduler().cost_model.n_layers();
    let records = experiment.run_collect()?;
    Ok(Fig3Result {
        n_devices: cfg.devices.len(),
        rounds: cfg.workload.rounds,
        n_layers,
        records,
    })
}

impl Fig3Result {
    /// Cut-layer matrix: rows = devices, cols = rounds (Fig. 3a).
    pub fn cut_matrix(&self) -> Vec<Vec<usize>> {
        let mut m = vec![vec![0usize; self.rounds]; self.n_devices];
        for r in &self.records {
            m[r.device_idx][r.round] = r.cut;
        }
        m
    }

    /// Frequency matrix [GHz]: rows = devices, cols = rounds (Fig. 3b).
    pub fn freq_matrix(&self) -> Vec<Vec<f64>> {
        let mut m = vec![vec![0f64; self.rounds]; self.n_devices];
        for r in &self.records {
            m[r.device_idx][r.round] = r.freq_hz / 1e9;
        }
        m
    }

    /// Render both panels as tables (what the bench prints).
    pub fn render(&self, device_names: &[String]) -> String {
        let mut headers: Vec<String> = vec!["device".into()];
        headers.extend((1..=self.rounds).map(|n| format!("r{n}")));
        let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();

        let mut t1 = Table::new("Fig. 3(a) — optimal cut layer per round", &hrefs);
        for (i, row) in self.cut_matrix().iter().enumerate() {
            let mut cells = vec![device_names[i].clone()];
            cells.extend(row.iter().map(|c| c.to_string()));
            t1.row(cells);
        }
        let mut t2 = Table::new("Fig. 3(b) — server GPU frequency [GHz] per round", &hrefs);
        for (i, row) in self.freq_matrix().iter().enumerate() {
            let mut cells = vec![device_names[i].clone()];
            cells.extend(row.iter().map(|f| format!("{f:.2}")));
            t2.row(cells);
        }
        format!("{}\n\n{}", t1.render(), t2.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ExpConfig {
        let mut c = ExpConfig::paper();
        c.workload.rounds = 12;
        c
    }

    #[test]
    fn decisions_are_endpoints() {
        let r = run(&cfg(), ChannelState::Normal).unwrap();
        for row in r.cut_matrix() {
            for c in row {
                assert!(c == 0 || c == r.n_layers, "interior cut {c}");
            }
        }
    }

    #[test]
    fn capability_ordering_of_cuts() {
        // Device 1 mostly keeps layers local; Device 5 mostly offloads.
        let r = run(&cfg(), ChannelState::Normal).unwrap();
        let m = r.cut_matrix();
        let mean = |row: &[usize]| row.iter().sum::<usize>() as f64 / row.len() as f64;
        assert!(mean(&m[0]) > mean(&m[4]));
    }

    #[test]
    fn frequency_allocation_follows_eq16_not_fig3b_narrative() {
        // DISCREPANCY (documented in EXPERIMENTS.md): the paper's Fig. 3(b)
        // narrative says weaker devices get a HIGHER server frequency, but
        // its own Eq. (16) implies the opposite: Q ∝ ∛(ΔE/ΔD), and a weak
        // device's (c=I, F_min) corner inflates D_max hence ΔD, shrinking
        // Q — while the F^{m,S}_min floor additionally lifts strong
        // devices' clamped f*.  We implement Eq. (16) faithfully and
        // assert ITS direction.
        let r = run(&cfg(), ChannelState::Normal).unwrap();
        let f = r.freq_matrix();
        let mean = |row: &[f64]| row.iter().sum::<f64>() / row.len() as f64;
        assert!(
            mean(&f[0]) > mean(&f[4]),
            "Eq. 16 direction violated: dev1 {} !> dev5 {}",
            mean(&f[0]),
            mean(&f[4])
        );
        // every allocation respects the per-device feasibility window
        let cfgx = cfg();
        for (i, row) in f.iter().enumerate() {
            let floor = cfgx.devices[i].server_freq_floor(&cfgx.server) / 1e9;
            for &ghz in row {
                assert!(ghz >= floor - 1e-9 && ghz <= cfgx.server.max_freq_hz / 1e9 + 1e-9);
            }
        }
    }

    #[test]
    fn render_contains_all_devices() {
        let c = cfg();
        let r = run(&c, ChannelState::Normal).unwrap();
        let names: Vec<String> = c.devices.iter().map(|d| d.name.clone()).collect();
        let out = r.render(&names);
        for n in &names {
            assert!(out.contains(n.as_str()));
        }
    }
}
