//! Fig. 4 reproduction: training delay + server energy per round for
//! CARD vs the paper's two benchmarks (Server-only, Device-only) across
//! the three channel states (Good/Normal/Poor).
//!
//! Headline numbers (paper §V-B): CARD reduces average training delay
//! by 70.8 % vs Device-only and server energy by 53.1 % vs Server-only.

use crate::config::{ChannelState, ExpConfig};
use crate::coordinator::Strategy;
use crate::exp::ExperimentBuilder;
use crate::util::pool;
use crate::util::table::{fmt_joules, fmt_secs, Table};

use super::metrics::reduction_pct;

#[derive(Clone, Debug)]
pub struct Cell {
    pub strategy: String,
    pub state: ChannelState,
    pub mean_delay_s: f64,
    pub mean_energy_j: f64,
}

#[derive(Clone, Debug)]
pub struct Fig4Result {
    pub cells: Vec<Cell>,
    /// averaged over channel states, as the paper's headline
    pub delay_reduction_vs_device_only_pct: f64,
    pub energy_reduction_vs_server_only_pct: f64,
}

pub const STRATEGIES: [Strategy; 3] = [Strategy::Card, Strategy::ServerOnly, Strategy::DeviceOnly];

pub fn run(cfg: &ExpConfig) -> anyhow::Result<Fig4Result> {
    // the 3 x 3 (state x strategy) grid is embarrassingly parallel; each
    // cell's records are bit-identical to a serial run of that cell
    let mut cases = Vec::new();
    for state in ChannelState::ALL {
        for strat in STRATEGIES {
            cases.push((state, strat));
        }
    }
    let results = pool::par_map_indexed(
        pool::default_parallelism(),
        &cases,
        |_, &(state, strat)| -> anyhow::Result<Cell> {
            let experiment = ExperimentBuilder::from_config(cfg.clone())
                .channel_state(state)
                .strategy(strat)
                .threads(1)
                .build()?;
            let (s, _) = experiment.run_summary()?;
            Ok(Cell {
                strategy: strat.name(),
                state,
                mean_delay_s: s.delay.mean(),
                mean_energy_j: s.energy.mean(),
            })
        },
    );
    let mut cells = Vec::with_capacity(results.len());
    for r in results {
        cells.push(r?);
    }

    let mean_over_states = |name: &str, f: fn(&Cell) -> f64| -> f64 {
        let v: Vec<f64> = cells
            .iter()
            .filter(|c| c.strategy == name)
            .map(f)
            .collect();
        v.iter().sum::<f64>() / v.len() as f64
    };
    let card_delay = mean_over_states("CARD (proposed)", |c| c.mean_delay_s);
    let devonly_delay = mean_over_states("Device-only", |c| c.mean_delay_s);
    let card_energy = mean_over_states("CARD (proposed)", |c| c.mean_energy_j);
    let servonly_energy = mean_over_states("Server-only", |c| c.mean_energy_j);

    Ok(Fig4Result {
        cells,
        delay_reduction_vs_device_only_pct: reduction_pct(devonly_delay, card_delay),
        energy_reduction_vs_server_only_pct: reduction_pct(servonly_energy, card_energy),
    })
}

impl Fig4Result {
    pub fn render(&self) -> String {
        let mut t = Table::new(
            "Fig. 4 — per-round training delay & server energy",
            &["channel", "method", "delay", "energy"],
        );
        for c in &self.cells {
            t.row(vec![
                c.state.name().to_string(),
                c.strategy.clone(),
                fmt_secs(c.mean_delay_s),
                fmt_joules(c.mean_energy_j),
            ]);
        }
        format!(
            "{}\n\nheadline: delay −{:.1}% vs Device-only (paper: −70.8%), \
             server energy −{:.1}% vs Server-only (paper: −53.1%)",
            t.render(),
            self.delay_reduction_vs_device_only_pct,
            self.energy_reduction_vs_server_only_pct,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ExpConfig {
        let mut c = ExpConfig::paper();
        c.workload.rounds = 10;
        c
    }

    #[test]
    fn produces_nine_cells() {
        let r = run(&cfg()).unwrap();
        assert_eq!(r.cells.len(), 9);
    }

    #[test]
    fn paper_shape_delay_ordering() {
        // Server-only fastest, Device-only slowest, CARD in between —
        // in every channel state (Fig. 4 left panel ordering).
        let r = run(&cfg()).unwrap();
        for state in ChannelState::ALL {
            let get = |name: &str| {
                r.cells
                    .iter()
                    .find(|c| c.state == state && c.strategy == name)
                    .unwrap()
                    .mean_delay_s
            };
            let so = get("Server-only");
            let card = get("CARD (proposed)");
            let donly = get("Device-only");
            assert!(
                so <= card && card < donly,
                "{}: so={so:.1} card={card:.1} donly={donly:.1}",
                state.name()
            );
        }
    }

    #[test]
    fn paper_shape_energy_ordering() {
        // Device-only lowest server energy, Server-only highest, CARD
        // in between (Fig. 4 right panel).
        let r = run(&cfg()).unwrap();
        for state in ChannelState::ALL {
            let get = |name: &str| {
                r.cells
                    .iter()
                    .find(|c| c.state == state && c.strategy == name)
                    .unwrap()
                    .mean_energy_j
            };
            assert!(get("Device-only") <= get("CARD (proposed)"));
            assert!(get("CARD (proposed)") < get("Server-only"));
        }
    }

    #[test]
    fn headline_reductions_substantial() {
        // We match the paper's *shape*: large double-digit reductions on
        // both axes (exact 70.8/53.1 depends on their unpublished channel
        // calibration — see EXPERIMENTS.md).
        let r = run(&cfg()).unwrap();
        assert!(
            r.delay_reduction_vs_device_only_pct > 40.0,
            "delay reduction {:.1}%",
            r.delay_reduction_vs_device_only_pct
        );
        assert!(
            r.energy_reduction_vs_server_only_pct > 25.0,
            "energy reduction {:.1}%",
            r.energy_reduction_vs_server_only_pct
        );
    }

    #[test]
    fn render_mentions_paper_numbers() {
        let r = run(&cfg()).unwrap();
        let s = r.render();
        assert!(s.contains("70.8"));
        assert!(s.contains("53.1"));
    }
}
