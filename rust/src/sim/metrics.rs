//! Aggregation of `RoundRecord` streams into the summary statistics the
//! figures report.

use crate::coordinator::{RoundBatch, RoundRecord};
use crate::util::stats::{self, Accum, ReservoirSampler};

/// p50/p95/p99/p99.9 snapshot of a sample set — the tail view both
/// `fleet-sweep` and `des-sweep` report next to means.
#[derive(Clone, Copy, Debug, Default)]
pub struct Percentiles {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    /// extreme tail — at fleet scale (10k devices × rounds) p99 still
    /// averages over hundreds of cells; p99.9 isolates the stragglers
    pub p999: f64,
}

impl Percentiles {
    /// Linear-interpolated percentiles (NaN on empty input, like
    /// `stats::percentile`).  Sorts the samples once for all four;
    /// `total_cmp` keeps NaN samples (a poisoned upstream metric) from
    /// panicking the sort — they order to the extremes (above +∞, or
    /// below -∞ for sign-bit-set NaN), skewing the tail rather than
    /// crashing the whole sweep.
    pub fn of(xs: &[f64]) -> Percentiles {
        let mut v: Vec<f64> = xs.to_vec();
        v.sort_by(|a, b| a.total_cmp(b));
        Percentiles {
            p50: stats::percentile_sorted(&v, 50.0),
            p95: stats::percentile_sorted(&v, 95.0),
            p99: stats::percentile_sorted(&v, 99.0),
            p999: stats::percentile_sorted(&v, 99.9),
        }
    }
}

/// Per-strategy (or per-cell) aggregate over a set of round records.
///
/// Every field is **bounded** regardless of how many records are
/// folded: Welford accumulators, a per-cut-layer count histogram
/// (`n_layers + 1` slots), a running frequency sum, and a reservoir
/// sample of delays for the tail view — the streaming-only memory
/// ceiling behind the mega-sweep tier.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub delay: Accum,
    pub energy: Accum,
    pub device_compute: Accum,
    pub server_compute: Accum,
    pub transmission: Accum,
    pub cost: Accum,
    /// occurrence count per selected cut layer, indexed by cut —
    /// replaces the old unbounded per-record `Vec<usize>`
    pub cut_counts: Vec<u64>,
    /// records folded (Σ `cut_counts`)
    cells: u64,
    /// running Σ freq [GHz] — the same left fold the old per-record
    /// vector summed to, so means are bit-identical
    freq_ghz_sum: f64,
    /// bounded uniform sample of per-record round delays for the
    /// percentile view — exact below the reservoir cap
    pub delay_samples: ReservoirSampler,
}

impl Summary {
    pub fn from_records<'a>(records: impl IntoIterator<Item = &'a RoundRecord>) -> Self {
        // Accum::default() == Accum::new() (sentinel-correct), so the
        // derived Default covers every field
        let mut s = Summary::default();
        for r in records {
            s.push(r);
        }
        s
    }

    /// Fold one record into the aggregate — the online path
    /// `exp::SummarySink` streams through, so sweeps never hold a full
    /// record vector per grid point.  `from_records` is this in a loop.
    pub fn push(&mut self, r: &RoundRecord) {
        self.delay.push(r.delay_s);
        self.delay_samples.push(r.delay_s);
        self.energy.push(r.energy_j);
        self.device_compute.push(r.device_compute_s);
        self.server_compute.push(r.server_compute_s);
        self.transmission.push(r.transmission_s);
        self.cost.push(r.cost);
        self.push_cut(r.cut);
        self.freq_ghz_sum += r.freq_hz / 1e9;
    }

    /// Fold one SoA window column-wise — bit-identical to calling
    /// [`Summary::push`] per cell: each accumulator sees the same value
    /// sequence; only the (irrelevant) interleaving between independent
    /// accumulators changes.
    pub fn push_batch(&mut self, b: &RoundBatch) {
        for &x in &b.delay_s {
            self.delay.push(x);
            self.delay_samples.push(x);
        }
        for &x in &b.energy_j {
            self.energy.push(x);
        }
        for &x in &b.device_compute_s {
            self.device_compute.push(x);
        }
        for &x in &b.server_compute_s {
            self.server_compute.push(x);
        }
        for &x in &b.transmission_s {
            self.transmission.push(x);
        }
        for &x in &b.cost {
            self.cost.push(x);
        }
        for &c in &b.cut {
            self.push_cut(c);
        }
        for &f in &b.freq_hz {
            self.freq_ghz_sum += f / 1e9;
        }
    }

    fn push_cut(&mut self, cut: usize) {
        if cut >= self.cut_counts.len() {
            self.cut_counts.resize(cut + 1, 0);
        }
        self.cut_counts[cut] += 1;
        self.cells += 1;
    }

    /// Records folded so far.
    pub fn cells(&self) -> u64 {
        self.cells
    }

    /// Mean selected cut layer over all records (0 when empty).
    pub fn mean_cut(&self) -> f64 {
        let sum: u64 = self
            .cut_counts
            .iter()
            .enumerate()
            .map(|(c, &n)| c as u64 * n)
            .sum();
        sum as f64 / self.cells.max(1) as f64
    }

    /// Mean selected device frequency [GHz] (NaN when empty, like the
    /// vector mean it replaces).
    pub fn mean_freq_ghz(&self) -> f64 {
        self.freq_ghz_sum / self.cells as f64
    }

    /// Round-delay tail percentiles (p50/p95/p99) over the records —
    /// exact up to the reservoir cap, a uniform subsample beyond it.
    pub fn delay_percentiles(&self) -> Percentiles {
        Percentiles::of(self.delay_samples.as_slice())
    }

    /// Fraction of decisions at each endpoint (Fig. 3a structure).
    pub fn endpoint_fractions(&self, n_layers: usize) -> (f64, f64) {
        if self.cells == 0 {
            return (0.0, 0.0);
        }
        let n = self.cells as f64;
        let at = |c: usize| self.cut_counts.get(c).copied().unwrap_or(0) as f64 / n;
        (at(0), at(n_layers))
    }
}

/// Percentage reduction of `ours` relative to `base` (positive = we win).
pub fn reduction_pct(base: f64, ours: f64) -> f64 {
    if base == 0.0 {
        return 0.0;
    }
    100.0 * (base - ours) / base
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(cut: usize, delay: f64, energy: f64) -> RoundRecord {
        RoundRecord {
            round: 0,
            device_idx: 0,
            device_name: "d".into(),
            strategy: "s".into(),
            cut,
            freq_hz: 1e9,
            cost: 0.5,
            snr_up_db: 10.0,
            snr_down_db: 12.0,
            rate_up_bps: 1e8,
            rate_down_bps: 1e8,
            delay_s: delay,
            device_compute_s: delay * 0.5,
            server_compute_s: delay * 0.3,
            transmission_s: delay * 0.2,
            energy_j: energy,
            adapter_bytes: 0.0,
            smashed_bytes_round: 0.0,
            loss: None,
            backend_wallclock_s: None,
        }
    }

    #[test]
    fn summary_aggregates() {
        let rs = vec![rec(0, 10.0, 100.0), rec(32, 20.0, 300.0)];
        let s = Summary::from_records(&rs);
        assert_eq!(s.delay.mean(), 15.0);
        assert_eq!(s.energy.mean(), 200.0);
        assert_eq!(s.cells(), 2);
        assert_eq!(s.cut_counts[0], 1);
        assert_eq!(s.cut_counts[32], 1);
        assert_eq!(s.cut_counts.iter().sum::<u64>(), 2);
        assert_eq!(s.mean_cut(), 16.0);
        assert!((s.mean_freq_ghz() - 1.0).abs() < 1e-12);
        assert_eq!(Summary::default().mean_cut(), 0.0);
        assert!(Summary::default().mean_freq_ghz().is_nan());
    }

    #[test]
    fn endpoint_fractions_counts() {
        let rs = vec![rec(0, 1.0, 1.0), rec(32, 1.0, 1.0), rec(16, 1.0, 1.0), rec(0, 1.0, 1.0)];
        let s = Summary::from_records(&rs);
        let (a, b) = s.endpoint_fractions(32);
        assert!((a - 0.5).abs() < 1e-12);
        assert!((b - 0.25).abs() < 1e-12);
    }

    #[test]
    fn delay_percentiles_track_tail() {
        let rs: Vec<RoundRecord> = (1..=100).map(|i| rec(0, i as f64, 1.0)).collect();
        let p = Summary::from_records(&rs).delay_percentiles();
        assert!((p.p50 - 50.5).abs() < 1e-9, "p50={}", p.p50);
        assert!((p.p95 - 95.05).abs() < 1e-9, "p95={}", p.p95);
        assert!((p.p99 - 99.01).abs() < 1e-9, "p99={}", p.p99);
        assert!((p.p999 - 99.901).abs() < 1e-9, "p999={}", p.p999);
        assert!(p.p50 <= p.p95 && p.p95 <= p.p99 && p.p99 <= p.p999);
        // empty summaries report NaN, not a panic
        assert!(Summary::default().delay_percentiles().p50.is_nan());
    }

    #[test]
    fn percentiles_tolerate_nan_samples() {
        // a poisoned sample must not panic the sort; NaN orders above
        // +inf under total_cmp, so finite percentiles stay sane
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        let p = Percentiles::of(&xs);
        assert!((p.p50 - 2.5).abs() < 1e-9, "p50={}", p.p50);
        assert!(p.p99.is_nan(), "NaN should surface in the tail");
    }

    #[test]
    fn default_summary_accums_are_sentinel_correct() {
        // Default::default() must behave like Accum::new(): pushing one
        // sample makes it both min and max (a zeroed default would
        // report min = 0.0 here)
        let mut s = Summary::default();
        s.delay.push(5.0);
        assert_eq!(s.delay.min(), 5.0);
        assert_eq!(s.delay.max(), 5.0);
    }

    #[test]
    fn reduction_math() {
        assert!((reduction_pct(100.0, 29.2) - 70.8).abs() < 1e-9);
        assert!((reduction_pct(100.0, 46.9) - 53.1).abs() < 1e-9);
        assert_eq!(reduction_pct(0.0, 5.0), 0.0);
    }
}
