//! Aggregation of `RoundRecord` streams into the summary statistics the
//! figures report.

use crate::coordinator::RoundRecord;
use crate::util::stats::{self, Accum};

/// p50/p95/p99/p99.9 snapshot of a sample set — the tail view both
/// `fleet-sweep` and `des-sweep` report next to means.
#[derive(Clone, Copy, Debug, Default)]
pub struct Percentiles {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    /// extreme tail — at fleet scale (10k devices × rounds) p99 still
    /// averages over hundreds of cells; p99.9 isolates the stragglers
    pub p999: f64,
}

impl Percentiles {
    /// Linear-interpolated percentiles (NaN on empty input, like
    /// `stats::percentile`).  Sorts the samples once for all four;
    /// `total_cmp` keeps NaN samples (a poisoned upstream metric) from
    /// panicking the sort — they order to the extremes (above +∞, or
    /// below -∞ for sign-bit-set NaN), skewing the tail rather than
    /// crashing the whole sweep.
    pub fn of(xs: &[f64]) -> Percentiles {
        let mut v: Vec<f64> = xs.to_vec();
        v.sort_by(|a, b| a.total_cmp(b));
        Percentiles {
            p50: stats::percentile_sorted(&v, 50.0),
            p95: stats::percentile_sorted(&v, 95.0),
            p99: stats::percentile_sorted(&v, 99.0),
            p999: stats::percentile_sorted(&v, 99.9),
        }
    }
}

/// Per-strategy (or per-cell) aggregate over a set of round records.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub delay: Accum,
    pub energy: Accum,
    pub device_compute: Accum,
    pub server_compute: Accum,
    pub transmission: Accum,
    pub cost: Accum,
    pub cuts: Vec<usize>,
    pub freqs_ghz: Vec<f64>,
    /// raw per-record round delays, kept for percentile reporting
    pub delay_samples: Vec<f64>,
}

impl Summary {
    pub fn from_records<'a>(records: impl IntoIterator<Item = &'a RoundRecord>) -> Self {
        // Accum::default() == Accum::new() (sentinel-correct), so the
        // derived Default covers every field
        let mut s = Summary::default();
        for r in records {
            s.push(r);
        }
        s
    }

    /// Fold one record into the aggregate — the online path
    /// `exp::SummarySink` streams through, so sweeps never hold a full
    /// record vector per grid point.  `from_records` is this in a loop.
    pub fn push(&mut self, r: &RoundRecord) {
        self.delay.push(r.delay_s);
        self.delay_samples.push(r.delay_s);
        self.energy.push(r.energy_j);
        self.device_compute.push(r.device_compute_s);
        self.server_compute.push(r.server_compute_s);
        self.transmission.push(r.transmission_s);
        self.cost.push(r.cost);
        self.cuts.push(r.cut);
        self.freqs_ghz.push(r.freq_hz / 1e9);
    }

    /// Mean selected cut layer over all records (0 when empty).
    pub fn mean_cut(&self) -> f64 {
        self.cuts.iter().sum::<usize>() as f64 / self.cuts.len().max(1) as f64
    }

    /// Round-delay tail percentiles (p50/p95/p99) over the records.
    pub fn delay_percentiles(&self) -> Percentiles {
        Percentiles::of(&self.delay_samples)
    }

    /// Fraction of decisions at each endpoint (Fig. 3a structure).
    pub fn endpoint_fractions(&self, n_layers: usize) -> (f64, f64) {
        if self.cuts.is_empty() {
            return (0.0, 0.0);
        }
        let n = self.cuts.len() as f64;
        let at0 = self.cuts.iter().filter(|&&c| c == 0).count() as f64 / n;
        let ati = self.cuts.iter().filter(|&&c| c == n_layers).count() as f64 / n;
        (at0, ati)
    }
}

/// Percentage reduction of `ours` relative to `base` (positive = we win).
pub fn reduction_pct(base: f64, ours: f64) -> f64 {
    if base == 0.0 {
        return 0.0;
    }
    100.0 * (base - ours) / base
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(cut: usize, delay: f64, energy: f64) -> RoundRecord {
        RoundRecord {
            round: 0,
            device_idx: 0,
            device_name: "d".into(),
            strategy: "s".into(),
            cut,
            freq_hz: 1e9,
            cost: 0.5,
            snr_up_db: 10.0,
            snr_down_db: 12.0,
            rate_up_bps: 1e8,
            rate_down_bps: 1e8,
            delay_s: delay,
            device_compute_s: delay * 0.5,
            server_compute_s: delay * 0.3,
            transmission_s: delay * 0.2,
            energy_j: energy,
            adapter_bytes: 0.0,
            smashed_bytes_round: 0.0,
            loss: None,
            backend_wallclock_s: None,
        }
    }

    #[test]
    fn summary_aggregates() {
        let rs = vec![rec(0, 10.0, 100.0), rec(32, 20.0, 300.0)];
        let s = Summary::from_records(&rs);
        assert_eq!(s.delay.mean(), 15.0);
        assert_eq!(s.energy.mean(), 200.0);
        assert_eq!(s.cuts, vec![0, 32]);
        assert_eq!(s.mean_cut(), 16.0);
        assert_eq!(Summary::default().mean_cut(), 0.0);
    }

    #[test]
    fn endpoint_fractions_counts() {
        let rs = vec![rec(0, 1.0, 1.0), rec(32, 1.0, 1.0), rec(16, 1.0, 1.0), rec(0, 1.0, 1.0)];
        let s = Summary::from_records(&rs);
        let (a, b) = s.endpoint_fractions(32);
        assert!((a - 0.5).abs() < 1e-12);
        assert!((b - 0.25).abs() < 1e-12);
    }

    #[test]
    fn delay_percentiles_track_tail() {
        let rs: Vec<RoundRecord> = (1..=100).map(|i| rec(0, i as f64, 1.0)).collect();
        let p = Summary::from_records(&rs).delay_percentiles();
        assert!((p.p50 - 50.5).abs() < 1e-9, "p50={}", p.p50);
        assert!((p.p95 - 95.05).abs() < 1e-9, "p95={}", p.p95);
        assert!((p.p99 - 99.01).abs() < 1e-9, "p99={}", p.p99);
        assert!((p.p999 - 99.901).abs() < 1e-9, "p999={}", p.p999);
        assert!(p.p50 <= p.p95 && p.p95 <= p.p99 && p.p99 <= p.p999);
        // empty summaries report NaN, not a panic
        assert!(Summary::default().delay_percentiles().p50.is_nan());
    }

    #[test]
    fn percentiles_tolerate_nan_samples() {
        // a poisoned sample must not panic the sort; NaN orders above
        // +inf under total_cmp, so finite percentiles stay sane
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        let p = Percentiles::of(&xs);
        assert!((p.p50 - 2.5).abs() < 1e-9, "p50={}", p.p50);
        assert!(p.p99.is_nan(), "NaN should surface in the tail");
    }

    #[test]
    fn default_summary_accums_are_sentinel_correct() {
        // Default::default() must behave like Accum::new(): pushing one
        // sample makes it both min and max (a zeroed default would
        // report min = 0.0 here)
        let mut s = Summary::default();
        s.delay.push(5.0);
        assert_eq!(s.delay.min(), 5.0);
        assert_eq!(s.delay.max(), 5.0);
    }

    #[test]
    fn reduction_math() {
        assert!((reduction_pct(100.0, 29.2) - 70.8).abs() < 1e-9);
        assert!((reduction_pct(100.0, 46.9) - 53.1).abs() < 1e-9);
        assert_eq!(reduction_pct(0.0, 5.0), 0.0);
    }
}
