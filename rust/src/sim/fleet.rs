//! Fleet-scale scenario engine: sweep device counts across the scenario
//! registry on the parallel round engine, emitting per-scenario
//! delay/energy summaries (via `util::benchkit`) and a machine-readable
//! `BENCH_fleet.json` for CI perf-trajectory tracking.
//!
//! Every sweep point is an [`exp::ExperimentBuilder`]-built experiment:
//! CARD over an `n`-device synthetic fleet for the scenario's
//! configured rounds, streamed through an `exp::SummarySink` so the
//! grid never materializes a full record vector per point.  The
//! serial-vs-parallel determinism gate is the shared
//! [`exp::verify::verify_records_match_serial`]; by default it runs at
//! exactly one grid point per scenario — the *largest*, where the
//! parallel engine schedules the most concurrent cells and a
//! divergence would be most consequential — reusing the point's own
//! collected records so only the serial reference is re-run.
//! `gate_all` opts back into gating every point (exhaustive, and
//! proportionally slower: each gated point pays a full
//! single-threaded re-run).

use crate::config::scenario::Scenario;
use crate::exp::{self, ExperimentBuilder, Report, ReportMeta};
use crate::sim::metrics::Summary;
use crate::util::benchkit::Bencher;
use crate::util::json::{self, Json};
use crate::util::table::{fmt_joules, fmt_secs, Table};

/// Resolve the fleet-sweep device grid from the CLI's three overlapping
/// knobs, highest precedence first:
///
/// * `--grid` — an explicit list, validated strictly increasing (a
///   shuffled or duplicated grid is almost always a typo, and the
///   determinism gate assumes the largest count is last);
/// * `--max-devices` — the decade ladder 10, 100, 1000, … capped at
///   (and always including) `N`, the one-flag way to scale the sweep;
/// * `--counts` — the legacy comma list, kept as the default.
///
/// Every path rejects a zero count here, before any experiment builds.
pub fn resolve_grid(
    grid: Option<Vec<usize>>,
    max_devices: Option<usize>,
    counts: Vec<usize>,
) -> anyhow::Result<Vec<usize>> {
    if let Some(g) = grid {
        anyhow::ensure!(!g.is_empty(), "--grid selected no device counts");
        for &n in &g {
            anyhow::ensure!(n > 0, "--grid entries must be >= 1");
        }
        for w in g.windows(2) {
            anyhow::ensure!(
                w[0] < w[1],
                "--grid must be strictly increasing (got {} then {})",
                w[0],
                w[1]
            );
        }
        return Ok(g);
    }
    if let Some(max) = max_devices {
        anyhow::ensure!(max > 0, "--max-devices must be >= 1");
        let mut g = Vec::new();
        let mut n = 10usize;
        while n < max {
            g.push(n);
            n = n.saturating_mul(10);
        }
        g.push(max);
        return Ok(g);
    }
    for &n in &counts {
        anyhow::ensure!(n > 0, "--counts entries must be >= 1");
    }
    Ok(counts)
}

/// One (scenario, fleet size) measurement.
#[derive(Clone, Debug)]
pub struct FleetPoint {
    pub scenario: String,
    pub n_devices: usize,
    pub rounds: usize,
    pub threads: usize,
    pub wall_s: f64,
    pub device_rounds_per_s: f64,
    pub mean_delay_s: f64,
    pub p50_delay_s: f64,
    pub p95_delay_s: f64,
    pub p99_delay_s: f64,
    pub p999_delay_s: f64,
    pub mean_energy_j: f64,
    pub mean_cut: f64,
}

/// Full sweep result.
#[derive(Clone, Debug)]
pub struct FleetSweep {
    pub points: Vec<FleetPoint>,
    pub threads: usize,
    pub seed: u64,
}

/// Run the scenario × device-count grid.  `rounds` overrides the preset
/// round count when given; timings land in `bench` (one entry per
/// point) so the caller can render the standard benchkit report.
/// `gate_all` runs the serial-vs-parallel determinism gate at every
/// grid point instead of only the largest one.
pub fn sweep(
    scenarios: &[Scenario],
    counts: &[usize],
    rounds: Option<usize>,
    threads: usize,
    seed: u64,
    gate_all: bool,
    bench: &mut Bencher,
) -> anyhow::Result<FleetSweep> {
    anyhow::ensure!(!scenarios.is_empty(), "no scenarios selected");
    anyhow::ensure!(!counts.is_empty(), "no device counts selected");
    let gate_n = *counts.iter().max().unwrap();
    let mut points = Vec::with_capacity(scenarios.len() * counts.len());
    for sc in scenarios {
        for &n in counts {
            anyhow::ensure!(n > 0, "device count must be >= 1");
            let mut builder = ExperimentBuilder::preset(sc.name)
                .devices(n)
                .seed(seed)
                .threads(threads);
            if let Some(r) = rounds {
                builder = builder.rounds(r);
            }
            let experiment = builder.build()?;
            let n_rounds = experiment.config().workload.rounds;
            let gated = gate_all || n == gate_n;

            // gated points materialize their records once so the
            // determinism gate can compare them against the serial
            // reference without re-running the parallel engine; every
            // other point streams through the online summary
            let t0 = std::time::Instant::now();
            let (online, gate_records) = if gated {
                (None, Some(experiment.run_collect()?))
            } else {
                (Some(experiment.run_summary()?.0), None)
            };
            let wall = t0.elapsed().as_secs_f64();

            // determinism gate: the parallel engine must reproduce the
            // serial reference bit for bit — at the largest fleet of
            // each scenario by default, everywhere with `gate_all`.
            // Gated records are summarized outside the timed window so
            // wall_s keeps tracking the engine alone.
            let s = if let Some(records) = &gate_records {
                exp::verify::verify_records_match_serial(&experiment, records)?;
                Summary::from_records(records)
            } else {
                online.expect("non-gated points stream their summary")
            };

            let pct = s.delay_percentiles();
            let device_rounds = (n * n_rounds) as f64;
            let rate = device_rounds / wall.max(1e-9);
            bench.record_once(
                &format!("{}_n{n}", sc.name),
                wall,
                Some((rate, "device-round")),
            );
            points.push(FleetPoint {
                scenario: sc.name.to_string(),
                n_devices: n,
                rounds: n_rounds,
                threads,
                wall_s: wall,
                device_rounds_per_s: rate,
                mean_delay_s: s.delay.mean(),
                p50_delay_s: pct.p50,
                p95_delay_s: pct.p95,
                p99_delay_s: pct.p99,
                p999_delay_s: pct.p999,
                mean_energy_j: s.energy.mean(),
                mean_cut: s.mean_cut(),
            });
        }
    }
    Ok(FleetSweep {
        points,
        threads,
        seed,
    })
}

impl FleetSweep {
    /// ASCII summary table (scenario × fleet size).
    pub fn render(&self) -> String {
        let mut t = Table::new(
            &format!(
                "fleet-sweep — parallel round engine ({} workers, seed {})",
                self.threads, self.seed
            ),
            &[
                "scenario",
                "devices",
                "rounds",
                "wall",
                "device-rounds/s",
                "mean delay",
                "p50 delay",
                "p95 delay",
                "p99 delay",
                "p99.9 delay",
                "mean energy",
                "mean cut",
            ],
        );
        for p in &self.points {
            t.row(vec![
                p.scenario.clone(),
                p.n_devices.to_string(),
                p.rounds.to_string(),
                fmt_secs(p.wall_s),
                format!("{:.0}", p.device_rounds_per_s),
                fmt_secs(p.mean_delay_s),
                fmt_secs(p.p50_delay_s),
                fmt_secs(p.p95_delay_s),
                fmt_secs(p.p99_delay_s),
                fmt_secs(p.p999_delay_s),
                fmt_joules(p.mean_energy_j),
                format!("{:.1}", p.mean_cut),
            ]);
        }
        t.render()
    }

    /// Emitter payload (the `data` member of the report envelope).
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("schema", Json::Str("edgesplit/fleet-sweep/v1".into())),
            // string, not number: u64 seeds above 2^53 would lose
            // precision through the f64-backed Json::Num
            ("seed", Json::Str(self.seed.to_string())),
            ("threads", Json::Num(self.threads as f64)),
            (
                "points",
                Json::Arr(
                    self.points
                        .iter()
                        .map(|p| {
                            json::obj(vec![
                                ("scenario", Json::Str(p.scenario.clone())),
                                ("n_devices", Json::Num(p.n_devices as f64)),
                                ("rounds", Json::Num(p.rounds as f64)),
                                ("threads", Json::Num(p.threads as f64)),
                                ("wall_s", Json::Num(p.wall_s)),
                                ("device_rounds_per_s", Json::Num(p.device_rounds_per_s)),
                                ("mean_delay_s", Json::Num(p.mean_delay_s)),
                                ("p50_delay_s", Json::Num(p.p50_delay_s)),
                                ("p95_delay_s", Json::Num(p.p95_delay_s)),
                                ("p99_delay_s", Json::Num(p.p99_delay_s)),
                                ("p999_delay_s", Json::Num(p.p999_delay_s)),
                                ("mean_energy_j", Json::Num(p.mean_energy_j)),
                                ("mean_cut", Json::Num(p.mean_cut)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// The enveloped report (`BENCH_fleet*.json`): shared
    /// `schema_version`/`meta` wrapper around [`FleetSweep::to_json`].
    pub fn report(&self, scenario_sel: &str, rounds: Option<usize>) -> Report {
        Report::new(
            ReportMeta {
                kind: "fleet-sweep",
                preset: scenario_sel.to_string(),
                seed: self.seed,
                threads: self.threads,
                rounds,
            },
            self.to_json(),
            self.render(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::scenario;

    #[test]
    fn small_sweep_produces_grid_and_json() {
        let mut bench = Bencher::new("fleet-sweep-test");
        let scenarios = [scenario::DENSE_URBAN, scenario::BURSTY_CHANNEL];
        let sweep = sweep(&scenarios, &[4, 9], Some(2), 4, 7, false, &mut bench).unwrap();
        assert_eq!(sweep.points.len(), 4);
        assert_eq!(bench.results().len(), 4);
        for p in &sweep.points {
            assert!(p.mean_delay_s > 0.0 && p.mean_delay_s.is_finite());
            assert!(p.mean_energy_j >= 0.0);
            assert_eq!(p.rounds, 2);
            // percentile ordering of the delay tail
            assert!(p.p50_delay_s <= p.p95_delay_s && p.p95_delay_s <= p.p99_delay_s);
            assert!(p.p99_delay_s <= p.p999_delay_s);
            assert!(p.p50_delay_s > 0.0);
        }
        let js = sweep.to_json().to_string();
        assert!(js.contains("\"n_devices\":4"));
        assert!(js.contains("dense-urban"));
        assert!(js.contains("fleet-sweep/v1"));
        assert!(js.contains("p95_delay_s"));
        assert!(js.contains("p999_delay_s"));
        // and it round-trips through our own parser
        assert!(Json::parse(&js).is_ok());
    }

    #[test]
    fn report_wraps_payload_in_versioned_envelope() {
        let mut bench = Bencher::new("fleet-envelope");
        let sweep =
            sweep(&[scenario::DENSE_URBAN], &[3], Some(1), 2, 7, false, &mut bench).unwrap();
        let j = sweep.report("dense-urban", Some(1)).to_json();
        assert_eq!(j.get("schema_version").and_then(Json::as_usize), Some(1));
        assert_eq!(j.at(&["meta", "preset"]).and_then(Json::as_str), Some("dense-urban"));
        assert!(j.at(&["data", "points"]).is_some());
    }

    #[test]
    fn determinism_gate_runs_on_largest_count() {
        // the gate would Err on divergence; a clean pass is the assertion
        let mut bench = Bencher::new("gate");
        let sweep = sweep(
            &[scenario::HETEROGENEOUS_FLEET],
            &[6],
            Some(3),
            8,
            123,
            false,
            &mut bench,
        )
        .unwrap();
        assert_eq!(sweep.points.len(), 1);
    }

    #[test]
    fn gate_all_covers_every_point() {
        let mut bench = Bencher::new("gate-all");
        let sweep = sweep(
            &[scenario::DENSE_URBAN],
            &[3, 5, 7],
            Some(2),
            4,
            9,
            true,
            &mut bench,
        )
        .unwrap();
        assert_eq!(sweep.points.len(), 3);
    }

    #[test]
    fn grid_resolution_precedence_and_validation() {
        // --grid wins over everything and must be strictly increasing
        assert_eq!(
            resolve_grid(Some(vec![5, 50, 500]), Some(9999), vec![1, 2]).unwrap(),
            vec![5, 50, 500]
        );
        assert!(resolve_grid(Some(vec![]), None, vec![1]).is_err());
        assert!(resolve_grid(Some(vec![10, 10]), None, vec![1]).is_err());
        assert!(resolve_grid(Some(vec![100, 10]), None, vec![1]).is_err());
        assert!(resolve_grid(Some(vec![0, 10]), None, vec![1]).is_err());

        // --max-devices builds the decade ladder capped at N
        assert_eq!(resolve_grid(None, Some(1000), vec![1]).unwrap(), vec![10, 100, 1000]);
        assert_eq!(
            resolve_grid(None, Some(2500), vec![1]).unwrap(),
            vec![10, 100, 1000, 2500]
        );
        assert_eq!(resolve_grid(None, Some(7), vec![1]).unwrap(), vec![7]);
        assert_eq!(resolve_grid(None, Some(10), vec![1]).unwrap(), vec![10]);
        assert!(resolve_grid(None, Some(0), vec![1]).is_err());

        // the legacy --counts list passes through, zeros rejected
        assert_eq!(resolve_grid(None, None, vec![4, 2, 9]).unwrap(), vec![4, 2, 9]);
        assert!(resolve_grid(None, None, vec![4, 0]).is_err());
    }

    #[test]
    fn rejects_degenerate_input() {
        let mut bench = Bencher::new("bad");
        assert!(sweep(&[], &[4], None, 1, 0, false, &mut bench).is_err());
        assert!(sweep(&[scenario::DENSE_URBAN], &[], None, 1, 0, false, &mut bench).is_err());
        assert!(sweep(&[scenario::DENSE_URBAN], &[0], None, 1, 0, false, &mut bench).is_err());
    }

    #[test]
    fn render_lists_every_point() {
        let mut bench = Bencher::new("render");
        let sweep =
            sweep(&[scenario::SPARSE_RURAL], &[3, 5], Some(1), 2, 1, false, &mut bench).unwrap();
        let out = sweep.render();
        assert!(out.contains("sparse-rural"));
        assert!(out.contains("device-rounds/s"));
        assert!(out.contains("p95 delay"));
    }
}
