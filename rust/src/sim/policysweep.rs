//! Regret benchmarking for the online-learning cut policies
//! (DESIGN.md §19): run every learned strategy plus the CARD oracle
//! and Random-cut across the scenario registry, accumulate per-round
//! cumulative regret vs CARD, and emit `BENCH_policy.json` under the
//! `edgesplit/policy-sweep/v1` envelope.
//!
//! The comparison is exact, not statistical: learned decisions draw
//! exploration noise from their own salted stream (`policy::POLICY_SALT`),
//! so every strategy sees bit-identical link realizations.  CARD picks
//! the cost-minimal cut at the CARD-optimal frequency, and the bandits
//! pick from a cut grid at the same frequency over the same cut table —
//! so per-cell regret `cost(strategy) − cost(CARD)` is non-negative by
//! construction, CARD's self-regret is exactly zero, and a curve that
//! flattens is a bandit that has learned the context's best arm.
//!
//! Two determinism gates run before any curve is trusted:
//!
//! * channel isolation — every strategy's per-record SNRs/rates must
//!   equal CARD's bit for bit (checked inline from the collected
//!   records, no extra runs);
//! * thread determinism — learned streams must be bit-identical from
//!   the serial reference and the parallel engine
//!   ([`exp::verify::verify_learned_thread_determinism`]; first
//!   scenario per strategy by default, everywhere with `gate_all`).

use crate::config::scenario::Scenario;
use crate::coordinator::Strategy;
use crate::exp::{self, ExperimentBuilder, Report, ReportMeta};
use crate::util::benchkit::Bencher;
use crate::util::json::{self, Json};
use crate::util::table::{fmt_secs, Table};

/// The strategy slate every policy sweep runs: the oracle, the
/// exploration floor, and the three learned policies.
pub const POLICY_STRATEGIES: [Strategy; 5] = [
    Strategy::Card,
    Strategy::RandomCut,
    Strategy::EpsGreedy,
    Strategy::Ucb1,
    Strategy::Thompson,
];

/// One (scenario, strategy) regret curve.
#[derive(Clone, Debug)]
pub struct PolicyCurve {
    pub scenario: String,
    /// [`Strategy::key`] of the strategy that produced this curve.
    pub strategy: &'static str,
    pub n_devices: usize,
    pub rounds: usize,
    pub wall_s: f64,
    /// mean per-cell cost U over the whole run
    pub mean_cost: f64,
    /// `cumulative_regret[r]` = Σ over rounds `<= r`, devices, of
    /// `cost(strategy) − cost(CARD)` — non-decreasing, 0 for CARD
    pub cumulative_regret: Vec<f64>,
    /// `cumulative_regret.last()` (0.0 for an empty run)
    pub final_regret: f64,
    /// learned-policy decision tallies (0 for CARD/Random)
    pub explore: u64,
    pub exploit: u64,
}

impl PolicyCurve {
    /// Final regret averaged per round — the slope a sublinear curve
    /// drives toward zero.
    pub fn regret_per_round(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.final_regret / self.rounds as f64
        }
    }
}

/// Full sweep result: the strategy slate × the scenario selection.
#[derive(Clone, Debug)]
pub struct PolicySweep {
    pub curves: Vec<PolicyCurve>,
    pub threads: usize,
    pub seed: u64,
}

/// Look up a finished curve by (scenario, strategy key).
impl PolicySweep {
    pub fn curve(&self, scenario: &str, strategy: &str) -> Option<&PolicyCurve> {
        self.curves
            .iter()
            .find(|c| c.scenario == scenario && c.strategy == strategy)
    }
}

/// Run the strategy slate over `scenarios` with an `n_devices` fleet.
/// `rounds` overrides each preset's round count; `gate_all` runs the
/// thread-determinism gate for every (scenario, learned strategy) pair
/// instead of only the first scenario.  Timings land in `bench`.
pub fn sweep(
    scenarios: &[Scenario],
    n_devices: usize,
    rounds: Option<usize>,
    threads: usize,
    seed: u64,
    gate_all: bool,
    bench: &mut Bencher,
) -> anyhow::Result<PolicySweep> {
    anyhow::ensure!(!scenarios.is_empty(), "no scenarios selected");
    anyhow::ensure!(n_devices > 0, "fleet size must be >= 1");
    let mut curves = Vec::with_capacity(scenarios.len() * POLICY_STRATEGIES.len());
    for (si, sc) in scenarios.iter().enumerate() {
        // the CARD baseline first: its records anchor both the regret
        // arithmetic and the channel-isolation check
        let mut baseline = None;
        for strategy in POLICY_STRATEGIES {
            let mut builder = ExperimentBuilder::preset(sc.name)
                .devices(n_devices)
                .seed(seed)
                .threads(threads)
                .strategy(strategy);
            if let Some(r) = rounds {
                builder = builder.rounds(r);
            }
            let experiment = builder.build()?;
            let n_rounds = experiment.config().workload.rounds;

            if strategy.is_learned() && (gate_all || si == 0) {
                exp::verify::verify_learned_thread_determinism(
                    experiment.config(),
                    sc.state,
                    strategy,
                )?;
            }

            let t0 = std::time::Instant::now();
            let records = experiment.run_collect()?;
            let wall = t0.elapsed().as_secs_f64();

            if baseline.is_none() {
                anyhow::ensure!(
                    strategy == Strategy::Card,
                    "the strategy slate must lead with CARD"
                );
                baseline = Some(records.clone());
            }
            let card: &[crate::coordinator::RoundRecord] =
                baseline.as_deref().expect("CARD baseline collected above");
            anyhow::ensure!(
                card.len() == records.len(),
                "{}: record count diverged from the CARD baseline",
                strategy.name()
            );

            let mut cumulative = vec![0.0f64; n_rounds];
            let mut cost_sum = 0.0f64;
            for (c, r) in card.iter().zip(&records) {
                // the policy stream is salted away from the cell
                // stream, so every strategy must see CARD's links
                anyhow::ensure!(
                    c.snr_up_db.to_bits() == r.snr_up_db.to_bits()
                        && c.rate_up_bps.to_bits() == r.rate_up_bps.to_bits(),
                    "{} perturbed the channel at round {} device {}",
                    strategy.name(),
                    c.round,
                    c.device_idx
                );
                let regret = r.cost - c.cost;
                anyhow::ensure!(
                    regret >= 0.0,
                    "{}: negative per-cell regret {regret} at round {} device {} — \
                     CARD is per-cell optimal over the cut grid",
                    strategy.name(),
                    c.round,
                    c.device_idx
                );
                cumulative[r.round] += regret;
                cost_sum += r.cost;
            }
            for r in 1..n_rounds {
                cumulative[r] += cumulative[r - 1];
            }
            let final_regret = cumulative.last().copied().unwrap_or(0.0);
            let (explore, exploit) = experiment.scheduler().policy_counters().unwrap_or((0, 0));
            crate::obs::metrics()
                .policy_regret_milli
                .observe((final_regret * 1e3).round() as u64);
            bench.record_once(
                &format!("{}_{}", sc.name, strategy.key()),
                wall,
                Some(((n_devices * n_rounds) as f64 / wall.max(1e-9), "device-round")),
            );
            curves.push(PolicyCurve {
                scenario: sc.name.to_string(),
                strategy: strategy.key(),
                n_devices,
                rounds: n_rounds,
                wall_s: wall,
                mean_cost: cost_sum / records.len().max(1) as f64,
                cumulative_regret: cumulative,
                final_regret,
                explore,
                exploit,
            });
        }
    }
    Ok(PolicySweep {
        curves,
        threads,
        seed,
    })
}

impl PolicySweep {
    /// ASCII summary table (scenario × strategy).
    pub fn render(&self) -> String {
        let mut t = Table::new(
            &format!(
                "policy-sweep — regret vs CARD ({} workers, seed {})",
                self.threads, self.seed
            ),
            &[
                "scenario",
                "strategy",
                "devices",
                "rounds",
                "final regret",
                "regret/round",
                "explore",
                "exploit",
                "mean cost",
                "wall",
            ],
        );
        for c in &self.curves {
            t.row(vec![
                c.scenario.clone(),
                c.strategy.to_string(),
                c.n_devices.to_string(),
                c.rounds.to_string(),
                format!("{:.4}", c.final_regret),
                format!("{:.6}", c.regret_per_round()),
                c.explore.to_string(),
                c.exploit.to_string(),
                format!("{:.4}", c.mean_cost),
                fmt_secs(c.wall_s),
            ]);
        }
        t.render()
    }

    /// Emitter payload (the `data` member of the report envelope).
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("schema", Json::Str("edgesplit/policy-sweep/v1".into())),
            // string, not number: u64 seeds above 2^53 would lose
            // precision through the f64-backed Json::Num
            ("seed", Json::Str(self.seed.to_string())),
            ("threads", Json::Num(self.threads as f64)),
            (
                "curves",
                Json::Arr(
                    self.curves
                        .iter()
                        .map(|c| {
                            json::obj(vec![
                                ("scenario", Json::Str(c.scenario.clone())),
                                ("strategy", Json::Str(c.strategy.to_string())),
                                ("n_devices", Json::Num(c.n_devices as f64)),
                                ("rounds", Json::Num(c.rounds as f64)),
                                ("wall_s", Json::Num(c.wall_s)),
                                ("mean_cost", Json::Num(c.mean_cost)),
                                ("final_regret", Json::Num(c.final_regret)),
                                ("regret_per_round", Json::Num(c.regret_per_round())),
                                ("explore", Json::Num(c.explore as f64)),
                                ("exploit", Json::Num(c.exploit as f64)),
                                (
                                    "cumulative_regret",
                                    Json::Arr(
                                        c.cumulative_regret
                                            .iter()
                                            .map(|&v| Json::Num(v))
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// The enveloped report (`BENCH_policy*.json`): shared
    /// `schema_version`/`meta` wrapper around [`PolicySweep::to_json`].
    pub fn report(&self, scenario_sel: &str, rounds: Option<usize>) -> Report {
        Report::new(
            ReportMeta {
                kind: "policy-sweep",
                preset: scenario_sel.to_string(),
                seed: self.seed,
                threads: self.threads,
                rounds,
            },
            self.to_json(),
            self.render(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::scenario;

    #[test]
    fn card_self_regret_is_exactly_zero_and_curves_never_decrease() {
        let mut bench = Bencher::new("policy-sanity");
        let sweep = sweep(
            &[scenario::DENSE_URBAN],
            6,
            Some(20),
            2,
            7,
            false,
            &mut bench,
        )
        .unwrap();
        assert_eq!(sweep.curves.len(), POLICY_STRATEGIES.len());
        let card = sweep.curve("dense-urban", "card").unwrap();
        assert_eq!(card.final_regret, 0.0);
        assert!(card.cumulative_regret.iter().all(|&v| v == 0.0));
        assert_eq!((card.explore, card.exploit), (0, 0));
        for c in &sweep.curves {
            assert_eq!(c.cumulative_regret.len(), c.rounds);
            assert!(c.final_regret >= 0.0);
            for w in c.cumulative_regret.windows(2) {
                assert!(w[1] >= w[0], "{}: regret curve decreased", c.strategy);
            }
        }
        // learned curves actually made decisions
        for key in ["eps-greedy", "ucb1", "thompson"] {
            let c = sweep.curve("dense-urban", key).unwrap();
            assert_eq!(c.explore + c.exploit, (6 * 20) as u64, "{key}");
        }
    }

    #[test]
    fn json_payload_round_trips_with_full_curves() {
        let mut bench = Bencher::new("policy-json");
        let sweep = sweep(
            &[scenario::BURSTY_CHANNEL],
            4,
            Some(5),
            1,
            3,
            false,
            &mut bench,
        )
        .unwrap();
        let js = sweep.to_json().to_string();
        assert!(js.contains("policy-sweep/v1"));
        assert!(js.contains("cumulative_regret"));
        assert!(js.contains("\"strategy\":\"ucb1\""));
        assert!(Json::parse(&js).is_ok());
        let j = sweep.report("bursty-channel", Some(5)).to_json();
        assert_eq!(j.get("schema_version").and_then(Json::as_usize), Some(1));
        assert_eq!(j.get("kind").and_then(Json::as_str), Some("policy-sweep"));
        assert!(j.at(&["data", "curves"]).is_some());
    }

    #[test]
    fn render_lists_every_strategy() {
        let mut bench = Bencher::new("policy-render");
        let sweep = sweep(
            &[scenario::SPARSE_RURAL],
            3,
            Some(4),
            1,
            1,
            false,
            &mut bench,
        )
        .unwrap();
        let out = sweep.render();
        for key in ["card", "random-cut", "eps-greedy", "ucb1", "thompson"] {
            assert!(out.contains(key), "render missing {key}");
        }
        assert!(out.contains("final regret"));
    }

    #[test]
    fn rejects_degenerate_input() {
        let mut bench = Bencher::new("policy-bad");
        assert!(sweep(&[], 4, None, 1, 0, false, &mut bench).is_err());
        assert!(sweep(&[scenario::DENSE_URBAN], 0, None, 1, 0, false, &mut bench).is_err());
    }
}
