//! `edgesplit` — leader binary: figure reproduction, CARD decisions,
//! and real split fine-tuning from AOT artifacts.
//!
//! ```text
//! edgesplit fig3                 # Fig. 3(a)+(b): decisions over rounds
//! edgesplit fig4                 # Fig. 4: CARD vs baselines × channels
//! edgesplit ablate --sweep w     # A1/A2 sweeps
//! edgesplit fleet-sweep          # scenario × device-count grid (parallel)
//! edgesplit des-sweep            # discrete-event engine: policy × scenario grid
//! edgesplit cell-sweep           # multi-cell tier: cells × scenario grid + handover
//! edgesplit chaos-sweep          # fault-injection grid: scenario × fault-rate ladder
//! edgesplit policy-sweep         # learned cut policies: regret vs the CARD oracle
//! edgesplit card-bench           # decision kernel: legacy vs table vs cached
//! edgesplit mega-sweep           # million-device streaming tier: cells/sec + peak RSS
//! edgesplit decide --state poor  # one-shot CARD decision per device
//! edgesplit train --arch tiny    # REAL split fine-tuning (PJRT)
//! edgesplit show devices|params  # Table I / Table II
//! ```

use anyhow::{anyhow, bail, Result};

use edgesplit::cli::{preflight_writable, render_help, Args, FlagSpec};
use edgesplit::config::scenario::{self, Scenario};
use edgesplit::config::{CellLayout, ChannelState, ExpConfig};
use edgesplit::coordinator::Strategy;
use edgesplit::data::{Batcher, Corpus};
use edgesplit::des::{self, Policy};
use edgesplit::exp::ExperimentBuilder;
use edgesplit::obs;
use edgesplit::runtime::{artifact_dir, ArtifactStore, SplitExecutor};
use edgesplit::util::json::Json;
use edgesplit::sim::{ablate, cardbench, fig3, fig4, fleet, mega, policysweep};
use edgesplit::util::benchkit::Bencher;
use edgesplit::util::logging;
use edgesplit::util::pool;
use edgesplit::util::rng::Rng;
use edgesplit::util::table::{fmt_bytes, fmt_joules, fmt_secs, Table};

fn flag_specs() -> Vec<FlagSpec> {
    vec![
        FlagSpec { name: "config", value: Some("file.toml"), help: "experiment config (TOML); defaults to the paper's Tables I+II", default: None },
        FlagSpec { name: "rounds", value: Some("N"), help: "training rounds", default: None },
        FlagSpec { name: "w", value: Some("0..1"), help: "delay/energy weight (Eq. 12)", default: None },
        FlagSpec { name: "seed", value: Some("u64"), help: "root RNG seed", default: None },
        FlagSpec { name: "state", value: Some("good|normal|poor"), help: "channel state", default: Some("normal") },
        FlagSpec { name: "channel-model", value: Some("iid|markov|jakes"), help: "fading process override for config-driven commands (fig3/fig4/ablate/decide/train); sweeps take it from their scenario presets", default: None },
        FlagSpec { name: "strategy", value: Some("card|server-only|device-only|static:C|random|eps-greedy|ucb1|thompson"), help: "decision strategy (learned policies: DESIGN.md §19)", default: Some("card") },
        FlagSpec { name: "sweep", value: Some("w|phi|bandwidth"), help: "ablation sweep to run", default: Some("w") },
        FlagSpec { name: "scenario", value: Some("name|all"), help: "sweep scenario preset (see `show scenarios`)", default: Some("all") },
        FlagSpec { name: "counts", value: Some("N,N,..."), help: "sweep device counts", default: Some("10,100,1000,10000") },
        FlagSpec { name: "max-devices", value: Some("N"), help: "fleet-sweep: decade device grid 10,100,... capped at N (overrides --counts)", default: None },
        FlagSpec { name: "grid", value: Some("N,N,..."), help: "fleet-sweep: explicit strictly-increasing device grid (overrides --max-devices/--counts)", default: None },
        FlagSpec { name: "threads", value: Some("N"), help: "parallel participants per job (default: all cores; the persistent pool caps extra threads at core count — results are identical at any value)", default: None },
        FlagSpec { name: "out", value: Some("file.json"), help: "sweep JSON output path (default: BENCH_fleet.json / BENCH_des.json / BENCH_cells.json / BENCH_faults.json / BENCH_policy.json / BENCH_mega.json)", default: None },
        FlagSpec { name: "gate-all", value: None, help: "fleet-sweep / policy-sweep: run the serial determinism gate at every grid point (default: largest / first scenario only)", default: None },
        FlagSpec { name: "devices", value: Some("N"), help: "card-bench / chaos-sweep / policy-sweep / mega-sweep fleet size (default: 10000 / 24 / 24 / 1000000)", default: None },
        FlagSpec { name: "check", value: Some("file.json"), help: "card-bench: fail if decision speedups drop >30% vs this committed baseline; mega-sweep: enforce its cells/sec floor + peak-RSS ceiling", default: None },
        FlagSpec { name: "policy", value: Some("sync|semi-sync|async|all"), help: "des-sweep aggregation policy", default: Some("all") },
        FlagSpec { name: "capacity", value: Some("N"), help: "des-sweep server queue slots", default: Some("4") },
        FlagSpec { name: "batch", value: Some("N"), help: "des-sweep max jobs fused per server dispatch", default: Some("1") },
        FlagSpec { name: "deadline-factor", value: Some("f"), help: "des-sweep semi-sync straggler deadline factor", default: Some("1.5") },
        FlagSpec { name: "rates", value: Some("f,f,..."), help: "chaos-sweep fault-rate ladder; one knob drives link outages [Hz], slot failures, and bursts (0 = fault-free baseline)", default: Some("0,0.02,0.1,0.5") },
        FlagSpec { name: "cells", value: Some("N,N,..."), help: "cell-sweep edge-server cell counts", default: Some("1,4") },
        FlagSpec { name: "cell-layout", value: Some("line|ring|grid"), help: "cell-sweep site placement layout", default: Some("line") },
        FlagSpec { name: "spacing", value: Some("m"), help: "cell-sweep inter-site spacing [m]", default: Some("60") },
        FlagSpec { name: "hysteresis", value: Some("dB"), help: "cell-sweep handover hysteresis margin [dB]", default: Some("3") },
        FlagSpec { name: "arch", value: Some("tiny|small"), help: "artifact config for real training", default: Some("tiny") },
        FlagSpec { name: "steps", value: Some("N"), help: "real-training steps (train)", default: Some("30") },
        FlagSpec { name: "lr", value: Some("f"), help: "LoRA learning rate (train)", default: Some("0.5") },
        FlagSpec { name: "trace", value: Some("file.json"), help: "record a Chrome trace_event timeline of the run (wall-time engine phases + simulated-time DES activity) and write it here; records stay bit-identical", default: None },
        FlagSpec { name: "in", value: Some("file.json"), help: "obs-report: BENCH envelope whose data.telemetry block to render (default: a live run)", default: None },
        FlagSpec { name: "log-level", value: Some("error..trace"), help: "stderr verbosity", default: None },
        FlagSpec { name: "help", value: None, help: "print help", default: None },
    ]
}

const SUBCOMMANDS: [(&str, &str); 15] = [
    ("fig3", "reproduce Fig. 3: cut layer + frequency decisions over rounds"),
    ("fig4", "reproduce Fig. 4: delay/energy vs baselines across channel states"),
    ("ablate", "A1/A2 sweeps: w, phi, bandwidth"),
    ("fleet-sweep", "scenario × device-count grid on the parallel round engine"),
    ("des-sweep", "discrete-event engine: policy × scenario × device-count grid"),
    ("cell-sweep", "multi-cell tier: cell-count × scenario grid with handover + per-cell energy"),
    ("chaos-sweep", "fault-injection grid: scenario × fault-rate ladder with retry/demotion accounting"),
    ("policy-sweep", "online-learning cut policies: cumulative regret vs the CARD oracle per scenario"),
    ("card-bench", "decision-kernel microbench: legacy vs cut-table vs cached (+pool)"),
    ("mega-sweep", "million-device streaming tier: SoA cells/sec + peak-RSS ceiling guard"),
    ("obs-report", "render the telemetry registry (live run or a BENCH envelope's data.telemetry)"),
    ("decide", "one-shot CARD decision for each device"),
    ("train", "REAL split fine-tuning over PJRT artifacts"),
    ("show", "print Table I (devices) / Table II (params) / arch / scenarios"),
    ("help", "print this help"),
];

fn main() {
    logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &flag_specs())?;
    if let Some(l) = args.str_of("log-level") {
        logging::set_level(
            logging::Level::parse(l).ok_or_else(|| anyhow!("bad log level '{l}'"))?,
        );
    }
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    if args.bool_of("help") || cmd == "help" {
        print!(
            "{}",
            render_help(
                "edgesplit",
                "energy-efficient split learning for LLM fine-tuning (CARD)",
                &SUBCOMMANDS,
                &flag_specs()
            )
        );
        return Ok(());
    }
    // every subcommand is flag-only past its name (`show` takes one
    // extra target) — stray positionals were silently ignored before
    args.expect_positionals(if cmd == "show" { 2 } else { 1 })?;

    let mut cfg = match args.str_of("config") {
        Some(path) => ExpConfig::from_file(path)?,
        None => ExpConfig::paper(),
    };
    let rounds_flag = args.usize_of("rounds")?;
    if let Some(r) = rounds_flag {
        cfg.workload.rounds = r;
    }
    if let Some(w) = args.f64_of("w")? {
        cfg.card.w = w;
    }
    if let Some(s) = args.u64_of("seed")? {
        cfg.seed = s;
    }
    if let Some(m) = args.str_of("channel-model") {
        // the sweep subcommands rebuild their configs from scenario
        // presets, which define their own [channel.process] — reject
        // the override there instead of silently ignoring it
        if matches!(
            cmd,
            "fleet-sweep" | "des-sweep" | "cell-sweep" | "chaos-sweep" | "policy-sweep"
                | "card-bench" | "mega-sweep"
        ) {
            bail!(
                "--channel-model does not apply to {cmd}: its presets define the \
                 channel process — pick a preset instead (e.g. --scenario \
                 correlated-indoor for markov, mobile-vehicular for jakes)"
            );
        }
        cfg.channel.process.model = edgesplit::config::FadingModel::parse(m)
            .ok_or_else(|| anyhow!("bad --channel-model '{m}' (iid|markov|jakes)"))?;
    }
    cfg.validate()?;

    let state = ChannelState::parse(args.str_of("state").unwrap_or("normal"))
        .ok_or_else(|| anyhow!("bad --state"))?;
    // typed error listing the whole strategy family on a typo,
    // mirroring BuildError::UnknownPreset
    let strategy = edgesplit::exp::parse_strategy(args.str_of("strategy").unwrap_or("card"))?;

    // --trace works on every subcommand: recording spans both engines,
    // and the timeline is written once the command finishes (DESIGN.md
    // §16).  Enabling it never perturbs a record.
    let trace_path = args.str_of("trace");
    if let Some(path) = trace_path {
        // the timeline is written only at process exit — an unwritable
        // path used to fail a long run at the very end, so probe it
        // before dispatch (typed CliError)
        preflight_writable("trace", path)?;
        obs::trace::enable();
    }
    let result = match cmd {
        "fig3" => cmd_fig3(&cfg, state),
        "fig4" => cmd_fig4(&cfg),
        "ablate" => cmd_ablate(&cfg, args.str_of("sweep").unwrap_or("w")),
        "fleet-sweep" => cmd_fleet_sweep(&args, cfg.seed, rounds_flag),
        "des-sweep" => cmd_des_sweep(&args, cfg.seed, rounds_flag),
        "cell-sweep" => cmd_cell_sweep(&args, cfg.seed, rounds_flag),
        "chaos-sweep" => cmd_chaos_sweep(&args, cfg.seed, rounds_flag),
        "policy-sweep" => cmd_policy_sweep(&args, cfg.seed, rounds_flag),
        "card-bench" => cmd_card_bench(&args, cfg.seed, rounds_flag),
        "mega-sweep" => cmd_mega_sweep(&args, cfg.seed, rounds_flag),
        "decide" => cmd_decide(&cfg, state),
        "train" => cmd_train(
            &cfg,
            state,
            strategy,
            args.str_of("arch").unwrap_or("tiny"),
            args.usize_of("steps")?.unwrap_or(30),
            args.f64_of("lr")?.unwrap_or(0.5) as f32,
        ),
        "obs-report" => cmd_obs_report(&args, &cfg, state),
        "show" => cmd_show(&cfg, args.positional().get(1).map(|s| s.as_str())),
        other => bail!("unknown command '{other}' (try `edgesplit help`)"),
    };
    result?;
    if let Some(path) = trace_path {
        let events = obs::trace::len();
        obs::trace::write_to(path)?;
        println!("wrote trace {path} ({events} events)");
    }
    Ok(())
}

fn cmd_fig3(cfg: &ExpConfig, state: ChannelState) -> Result<()> {
    let r = fig3::run(cfg, state)?;
    let names: Vec<String> = cfg.devices.iter().map(|d| d.name.clone()).collect();
    println!("{}", r.render(&names));
    Ok(())
}

fn cmd_fig4(cfg: &ExpConfig) -> Result<()> {
    let r = fig4::run(cfg)?;
    println!("{}", r.render());
    Ok(())
}

fn cmd_ablate(cfg: &ExpConfig, sweep: &str) -> Result<()> {
    match sweep {
        "w" => {
            let vals = [0.0, 0.1, 0.2, 0.35, 0.5, 0.65, 0.8, 0.9, 1.0];
            let pts = ablate::sweep_w(cfg, &vals)?;
            println!("{}", ablate::render("A1 — weight w sweep (Normal channel)", "w", &pts));
        }
        "phi" => {
            let vals = [0.02, 0.05, 0.1, 0.2, 0.4, 0.8, 1.0];
            let pts = ablate::sweep_phi(cfg, &vals)?;
            println!("{}", ablate::render("A2a — compression φ sweep (Poor channel)", "phi", &pts));
        }
        "bandwidth" => {
            let vals = [10.0, 20.0, 50.0, 100.0, 200.0, 400.0];
            let pts = ablate::sweep_bandwidth(cfg, &vals)?;
            println!("{}", ablate::render("A2b — bandwidth sweep [MHz] (Normal channel)", "MHz", &pts));
        }
        other => bail!("unknown sweep '{other}' (w|phi|bandwidth)"),
    }
    Ok(())
}

fn parse_scenarios(scenario_sel: &str) -> Result<Vec<Scenario>> {
    if scenario_sel.eq_ignore_ascii_case("all") {
        Ok(scenario::ALL.to_vec())
    } else {
        Ok(vec![Scenario::by_name(scenario_sel).ok_or_else(|| {
            anyhow!(
                "unknown scenario '{scenario_sel}' (have: {}, all)",
                scenario::ALL.map(|s| s.name).join(", ")
            )
        })?])
    }
}

fn parse_counts(counts_s: &str) -> Result<Vec<usize>> {
    parse_count_list(counts_s, "--counts")
}

fn parse_count_list(list_s: &str, flag: &str) -> Result<Vec<usize>> {
    list_s
        .split(',')
        .map(|s| {
            s.trim()
                .parse::<usize>()
                .map_err(|_| anyhow!("bad device count '{}' in {flag}", s.trim()))
        })
        .collect()
}

fn cmd_fleet_sweep(args: &Args, seed: u64, rounds: Option<usize>) -> Result<()> {
    let scenario_sel = args.str_of("scenario").unwrap_or("all");
    let scenarios = parse_scenarios(scenario_sel)?;
    // device-grid precedence: --grid > --max-devices > --counts
    // (validated in fleet::resolve_grid — zero counts and non-monotone
    // grids are rejected before any experiment builds)
    let grid = args
        .str_of("grid")
        .map(|s| parse_count_list(s, "--grid"))
        .transpose()?;
    let counts = fleet::resolve_grid(
        grid,
        args.usize_of("max-devices")?,
        parse_counts(args.str_of("counts").unwrap_or("10,100,1000,10000"))?,
    )?;
    let gate_all = args.bool_of("gate-all");
    let out = args.str_of("out").unwrap_or("BENCH_fleet.json");
    let threads = args
        .usize_of("threads")?
        .unwrap_or_else(pool::default_parallelism);

    let mut bench = Bencher::new("fleet-sweep");
    let sweep = fleet::sweep(&scenarios, &counts, rounds, threads, seed, gate_all, &mut bench)?;
    let report = sweep.report(scenario_sel, rounds);
    println!("{}\n", report.render());
    if gate_all {
        println!("determinism gate: parallel == serial (bit-identical) at every grid point\n");
    } else {
        println!(
            "determinism gate: parallel == serial (bit-identical) at n = {} for every scenario \
             (--gate-all checks every point)\n",
            counts.iter().max().unwrap()
        );
    }
    bench.report();

    report.write(out)?;
    println!("\nwrote {out} ({} sweep points)", sweep.points.len());
    Ok(())
}

fn cmd_des_sweep(args: &Args, seed: u64, rounds: Option<usize>) -> Result<()> {
    let scenario_sel = args.str_of("scenario").unwrap_or("all");
    let scenarios = parse_scenarios(scenario_sel)?;
    let counts = parse_counts(args.str_of("counts").unwrap_or("10,100,1000,10000"))?;
    let threads = args
        .usize_of("threads")?
        .unwrap_or_else(pool::default_parallelism);
    let capacity = args.usize_of("capacity")?.unwrap_or(4);
    let batch = args.usize_of("batch")?.unwrap_or(1);
    let deadline_factor = args.f64_of("deadline-factor")?.unwrap_or(1.5);
    let policy_sel = args.str_of("policy").unwrap_or("all");
    let policies: Vec<Policy> = if policy_sel.eq_ignore_ascii_case("all") {
        vec![
            Policy::Sync,
            Policy::SemiSync { deadline_factor },
            Policy::Async,
        ]
    } else {
        vec![Policy::parse(policy_sel, deadline_factor).ok_or_else(|| {
            anyhow!("unknown policy '{policy_sel}' (sync|semi-sync|async|all)")
        })?]
    };
    let out = args.str_of("out").unwrap_or("BENCH_des.json");

    let mut bench = Bencher::new("des-sweep");
    let sweep = des::sweep(
        &scenarios,
        &counts,
        &policies,
        rounds,
        capacity,
        batch,
        threads,
        seed,
        &mut bench,
    )?;
    let report = sweep.report(scenario_sel, rounds);
    println!("{}\n", report.render());
    println!(
        "server queue: {capacity} slot(s), batch {batch}; every point is a deterministic \
         single-threaded DES run ({} fanned out across {threads} workers)",
        sweep.points.len()
    );
    println!(
        "determinism gate: churn-free sync DES == serial round engine (bit-identical) at \
         n = {} for every scenario\n",
        counts.iter().max().unwrap()
    );
    bench.report();

    report.write(out)?;
    println!("\nwrote {out} ({} sweep points)", sweep.points.len());
    Ok(())
}

fn cmd_cell_sweep(args: &Args, seed: u64, rounds: Option<usize>) -> Result<()> {
    let scenario_sel = args.str_of("scenario").unwrap_or("all");
    let scenarios = parse_scenarios(scenario_sel)?;
    let counts = parse_counts(args.str_of("counts").unwrap_or("10,100,1000,10000"))?;
    let cell_counts = parse_counts(args.str_of("cells").unwrap_or("1,4"))
        .map_err(|e| anyhow!("{e} (--cells takes a comma-separated cell-count list)"))?;
    let layout_s = args.str_of("cell-layout").unwrap_or("line");
    let layout = CellLayout::parse(layout_s)
        .ok_or_else(|| anyhow!("bad --cell-layout '{layout_s}' (line|ring|grid)"))?;
    let spacing_m = args.f64_of("spacing")?.unwrap_or(60.0);
    let hysteresis_db = args.f64_of("hysteresis")?.unwrap_or(3.0);
    let threads = args
        .usize_of("threads")?
        .unwrap_or_else(pool::default_parallelism);
    let capacity = args.usize_of("capacity")?.unwrap_or(4);
    let batch = args.usize_of("batch")?.unwrap_or(1);
    let out = args.str_of("out").unwrap_or("BENCH_cells.json");

    let mut bench = Bencher::new("cell-sweep");
    let sweep = des::cellsweep::sweep(
        &scenarios,
        &counts,
        &cell_counts,
        layout,
        spacing_m,
        hysteresis_db,
        rounds,
        capacity,
        batch,
        threads,
        seed,
        &mut bench,
    )?;
    let report = sweep.report(scenario_sel, rounds);
    println!("{}\n", report.render());
    println!(
        "cell tier: {layout_s} layout, {spacing_m} m spacing, {hysteresis_db} dB hysteresis; \
         {capacity} queue slot(s) per cell, batch {batch}; aggregation policy: sync"
    );
    println!(
        "determinism gate: single-cell sync DES == serial round engine (bit-identical) at \
         n = {} for every scenario; per-cell energy sums reproduce the global figure exactly\n",
        counts.iter().max().unwrap()
    );
    bench.report();

    report.write(out)?;
    println!("\nwrote {out} ({} sweep points)", sweep.points.len());
    Ok(())
}

fn parse_rates(rates_s: &str) -> Result<Vec<f64>> {
    rates_s
        .split(',')
        .map(|s| {
            s.trim()
                .parse::<f64>()
                .map_err(|_| anyhow!("bad fault rate '{}' in --rates", s.trim()))
        })
        .collect()
}

fn cmd_chaos_sweep(args: &Args, seed: u64, rounds: Option<usize>) -> Result<()> {
    let scenario_sel = args.str_of("scenario").unwrap_or("all");
    let scenarios = parse_scenarios(scenario_sel)?;
    let rates = parse_rates(args.str_of("rates").unwrap_or("0,0.02,0.1,0.5"))?;
    let n_devices = args.usize_of("devices")?.unwrap_or(24);
    let threads = args
        .usize_of("threads")?
        .unwrap_or_else(pool::default_parallelism);
    let capacity = args.usize_of("capacity")?.unwrap_or(4);
    let batch = args.usize_of("batch")?.unwrap_or(1);
    let out = args.str_of("out").unwrap_or("BENCH_faults.json");

    let mut bench = Bencher::new("chaos-sweep");
    let sweep = des::chaos_sweep(
        &scenarios,
        &rates,
        n_devices,
        rounds,
        capacity,
        batch,
        threads,
        seed,
        &mut bench,
    )?;
    let report = sweep.report(scenario_sel, rounds);
    println!("{}\n", report.render());
    println!(
        "fault plane: the ladder value drives link outages [Hz], slot failures, and \
         correlated bursts together (sync policy, {capacity} queue slot(s), batch {batch}, \
         n = {n_devices}); a 0 entry is the fault-free baseline"
    );
    println!(
        "robustness gates: a dormant [faults] table is bitwise invisible, and \
         checkpoint → envelope round-trip → resume reproduces the uninterrupted run \
         bit for bit, for every scenario\n"
    );
    bench.report();

    report.write(out)?;
    println!("\nwrote {out} ({} sweep points)", sweep.points.len());
    Ok(())
}

fn cmd_policy_sweep(args: &Args, seed: u64, rounds: Option<usize>) -> Result<()> {
    let scenario_sel = args.str_of("scenario").unwrap_or("all");
    let scenarios = parse_scenarios(scenario_sel)?;
    let n_devices = args.usize_of("devices")?.unwrap_or(24);
    let threads = args
        .usize_of("threads")?
        .unwrap_or_else(pool::default_parallelism);
    let gate_all = args.bool_of("gate-all");
    let out = args.str_of("out").unwrap_or("BENCH_policy.json");

    let mut bench = Bencher::new("policy-sweep");
    let sweep = policysweep::sweep(
        &scenarios, n_devices, rounds, threads, seed, gate_all, &mut bench,
    )?;
    let report = sweep.report(scenario_sel, rounds);
    println!("{}\n", report.render());
    println!(
        "regret arithmetic: per-cell cost(strategy) − cost(CARD) on bit-identical link \
         realizations (the learned policies explore on their own salted stream, so CARD's \
         records are bitwise untouched); every curve is non-negative and non-decreasing"
    );
    if gate_all {
        println!(
            "determinism gates: channel isolation on every curve; learned streams \
             serial == parallel (bit-identical) for every scenario\n"
        );
    } else {
        println!(
            "determinism gates: channel isolation on every curve; learned streams \
             serial == parallel (bit-identical) on the first scenario \
             (--gate-all checks every scenario)\n"
        );
    }
    bench.report();

    report.write(out)?;
    println!("\nwrote {out} ({} regret curves)", sweep.curves.len());
    Ok(())
}

fn cmd_card_bench(args: &Args, seed: u64, rounds: Option<usize>) -> Result<()> {
    let scenario_sel = args.str_of("scenario").unwrap_or("all");
    let scenario = if scenario_sel.eq_ignore_ascii_case("all") {
        // card-bench measures one preset, not a grid — say so instead
        // of silently reinterpreting the shared flag's default
        println!("card-bench benches a single preset: using heterogeneous-fleet (pass --scenario <name> to pick another)\n");
        scenario::HETEROGENEOUS_FLEET
    } else {
        parse_scenarios(scenario_sel)?[0]
    };
    let n_devices = args.usize_of("devices")?.unwrap_or(10_000);
    let rounds = rounds.unwrap_or(10);
    let threads = args
        .usize_of("threads")?
        .unwrap_or_else(pool::default_parallelism);
    let out = args.str_of("out").unwrap_or("BENCH_card.json");

    let mut bench = Bencher::new("card-bench");
    let result = cardbench::run(&scenario, n_devices, rounds, threads, seed, &mut bench)?;
    let report = result.report();
    println!("{}\n", report.render());
    bench.report();

    // write the measurement before any guard verdict so a failing run
    // still leaves its BENCH_card.json behind for inspection
    report.write(out)?;
    println!("\nwrote {out}");

    if let Some(baseline_path) = args.str_of("check") {
        let text = std::fs::read_to_string(baseline_path)
            .map_err(|e| anyhow!("reading baseline {baseline_path}: {e}"))?;
        let baseline = edgesplit::util::json::Json::parse(&text)
            .map_err(|e| anyhow!("parsing baseline {baseline_path}: {e}"))?;
        result.check_against(&baseline)?;
        println!("regression guard: speedups within 30% of {baseline_path}");
    }
    Ok(())
}

fn cmd_mega_sweep(args: &Args, seed: u64, rounds: Option<usize>) -> Result<()> {
    let scenario_sel = args.str_of("scenario").unwrap_or("all");
    let scenario = if scenario_sel.eq_ignore_ascii_case("all") {
        // mega-sweep times one preset at fleet scale, not a grid — say
        // so instead of silently reinterpreting the shared flag default
        println!("mega-sweep benches a single preset: using heterogeneous-fleet (pass --scenario <name> to pick another)\n");
        scenario::HETEROGENEOUS_FLEET
    } else {
        parse_scenarios(scenario_sel)?[0]
    };
    let n_devices = args.usize_of("devices")?.unwrap_or(1_000_000);
    // default 1 round: the tier scales the fleet axis, not the time axis
    let rounds = rounds.unwrap_or(1);
    let threads = args
        .usize_of("threads")?
        .unwrap_or_else(pool::default_parallelism);
    let out = args.str_of("out").unwrap_or("BENCH_mega.json");

    let mut bench = Bencher::new("mega-sweep");
    let result = mega::run(&scenario, n_devices, rounds, threads, seed, &mut bench)?;
    let report = result.report();
    println!("{}\n", report.render());
    println!(
        "correctness anchor: the streaming SoA path matched both oracles bit for bit on a \
         scaled-down twin before timing\n"
    );
    bench.report();

    // write the measurement before any guard verdict so a failing run
    // still leaves its BENCH_mega.json behind for inspection
    report.write(out)?;
    println!("\nwrote {out}");

    if let Some(baseline_path) = args.str_of("check") {
        let text = std::fs::read_to_string(baseline_path)
            .map_err(|e| anyhow!("reading baseline {baseline_path}: {e}"))?;
        let baseline = Json::parse(&text)
            .map_err(|e| anyhow!("parsing baseline {baseline_path}: {e}"))?;
        result.check_against(&baseline)?;
        println!("regression guard: cells/sec floor and peak-RSS ceiling hold vs {baseline_path}");
    }
    Ok(())
}

fn cmd_obs_report(args: &Args, cfg: &ExpConfig, state: ChannelState) -> Result<()> {
    if let Some(path) = args.str_of("in") {
        // offline mode: render the telemetry block a BENCH envelope carries
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {path}: {e}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parsing {path}: {e}"))?;
        let tel = j.at(&["data", "telemetry"]).ok_or_else(|| {
            anyhow!("{path} carries no data.telemetry block — re-emit it on this build")
        })?;
        print!("{}", render_telemetry_json(tel));
        return Ok(());
    }
    // live mode: run one experiment with the phase timers on, then dump
    // the registry
    obs::registry::set_timers_enabled(true);
    let experiment = ExperimentBuilder::from_config(cfg.clone())
        .channel_state(state)
        .build()?;
    let (_, outcome) = experiment.run_summary()?;
    println!(
        "live run: {} cells, {} thread(s), preset paper config\n",
        outcome.cells,
        experiment.threads()
    );
    print!("{}", obs::Snapshot::collect().render());
    Ok(())
}

/// Render a `data.telemetry` JSON block (`edgesplit/telemetry/v1`) as
/// the same tables [`obs::Snapshot::render`] prints for a live registry.
fn render_telemetry_json(tel: &Json) -> String {
    let mut out = String::new();
    if let Some(m) = tel.get("counters").and_then(Json::as_obj) {
        let mut t = Table::new("telemetry — counters", &["key", "value"]);
        for (k, v) in m {
            t.row(vec![k.clone(), format!("{}", v.as_f64().unwrap_or(0.0) as u64)]);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    if let Some(m) = tel.get("gauges").and_then(Json::as_obj) {
        let mut t = Table::new("telemetry — gauges", &["key", "last", "max"]);
        for (k, v) in m {
            let last = v.get("last").and_then(Json::as_f64).unwrap_or(0.0);
            let max = v.get("max").and_then(Json::as_f64).unwrap_or(0.0);
            t.row(vec![k.clone(), format!("{}", last as u64), format!("{}", max as u64)]);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    if let Some(m) = tel.get("histograms").and_then(Json::as_obj) {
        let mut t = Table::new("telemetry — histograms", &["key", "count", "sum", "mean"]);
        for (k, v) in m {
            let count = v.get("count").and_then(Json::as_f64).unwrap_or(0.0);
            let sum = v.get("sum").and_then(Json::as_f64).unwrap_or(0.0);
            let mean = if count > 0.0 { sum / count } else { 0.0 };
            t.row(vec![
                k.clone(),
                format!("{}", count as u64),
                format!("{sum:.6}"),
                format!("{mean:.6}"),
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    if let Some(pool) = tel.get("pool") {
        let mut t = Table::new("telemetry — worker pool", &["slot", "tasks claimed"]);
        if let Some(per) = pool
            .get("tasks_claimed_per_worker")
            .and_then(Json::as_arr)
        {
            for (i, v) in per.iter().enumerate() {
                let who = if i == 0 { "caller".to_string() } else { format!("worker {}", i - 1) };
                t.row(vec![who, format!("{}", v.as_f64().unwrap_or(0.0) as u64)]);
            }
        }
        let parks = pool.get("idle_parks").and_then(Json::as_f64).unwrap_or(0.0);
        t.row(vec!["idle parks".into(), format!("{}", parks as u64)]);
        out.push_str(&t.render());
    }
    out
}

fn cmd_decide(cfg: &ExpConfig, state: ChannelState) -> Result<()> {
    // one analytic round through the unified experiment API — the exact
    // per-cell RNG streams, link process, and decision kernel every
    // engine uses, so what `decide` prints is what a round-0 run does
    let experiment = ExperimentBuilder::from_config(cfg.clone())
        .channel_state(state)
        .rounds(1)
        .threads(1)
        .build()?;
    let records = experiment.run_collect()?;
    let mut t = Table::new(
        &format!("CARD decisions — {} channel", state.name()),
        &["device", "SNR up [dB]", "rate up", "cut c*", "f* [GHz]", "delay", "energy", "U"],
    );
    for r in &records {
        t.row(vec![
            r.device_name.to_string(),
            format!("{:.1}", r.snr_up_db),
            format!("{}/s", fmt_bytes(r.rate_up_bps / 8.0)),
            r.cut.to_string(),
            format!("{:.2}", r.freq_hz / 1e9),
            fmt_secs(r.delay_s),
            fmt_joules(r.energy_j),
            format!("{:.3}", r.cost),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_train(
    cfg: &ExpConfig,
    state: ChannelState,
    strategy: Strategy,
    arch: &str,
    steps: usize,
    lr: f32,
) -> Result<()> {
    let dir = artifact_dir(arch);
    let store = ArtifactStore::open(&dir)?;
    let mcfg = store.config.clone();
    println!(
        "loaded artifacts '{}' ({} layers, d={}, batch={}x{})",
        mcfg.name, mcfg.n_layers, mcfg.d_model, mcfg.batch_size, mcfg.seq_len
    );

    // per-device corpora + batchers
    let batchers: Vec<Batcher> = (0..cfg.devices.len())
        .map(|i| {
            let mut rng = Rng::new(cfg.seed ^ (0xD00D + i as u64));
            let corpus = Corpus::synthetic(i, 60_000, 0.1, &mut rng);
            Batcher::new(corpus, mcfg.batch_size, mcfg.seq_len, cfg.seed ^ (0xBA7C + i as u64))
        })
        .collect();
    let mut executor = SplitExecutor::new(store, batchers, lr, cfg.seed)?;

    // scheduler drives decisions; executor runs the real math — the cost
    // model must describe the model actually being trained
    let mut sim_cfg = cfg.clone();
    sim_cfg.workload.arch = mcfg.name.clone();
    sim_cfg.workload.batch_size = mcfg.batch_size;
    sim_cfg.workload.seq_len = mcfg.seq_len;
    sim_cfg.workload.rounds = steps
        .div_ceil(sim_cfg.workload.local_epochs * cfg.devices.len())
        .max(1);
    let experiment = ExperimentBuilder::from_config(sim_cfg)
        .channel_state(state)
        .strategy(strategy)
        .build()?;
    let records = experiment.run_trained(&mut executor)?;

    let mut t = Table::new(
        &format!("real split fine-tuning ({} strategy)", strategy.name()),
        &["round", "device", "cut", "loss", "delay (model)", "energy (model)", "wallclock"],
    );
    for r in &records {
        t.row(vec![
            r.round.to_string(),
            r.device_name.to_string(),
            r.cut.to_string(),
            r.loss.map(|l| format!("{l:.4}")).unwrap_or_default(),
            fmt_secs(r.delay_s),
            fmt_joules(r.energy_j),
            r.backend_wallclock_s.map(fmt_secs).unwrap_or_default(),
        ]);
    }
    t.print();
    let first = executor.loss_log.first().map(|x| x.1).unwrap_or(f64::NAN);
    let last = executor.loss_log.last().map(|x| x.1).unwrap_or(f64::NAN);
    println!(
        "\nsteps executed: {}   loss {first:.4} -> {last:.4}   adapters consistent: {}",
        executor.loss_log.len(),
        executor.aggregator.is_consistent()
    );
    Ok(())
}

fn cmd_show(cfg: &ExpConfig, what: Option<&str>) -> Result<()> {
    match what.unwrap_or("devices") {
        "devices" => {
            let mut t = Table::new(
                "Table I — server and devices",
                &["type", "platform", "GPU max freq", "cores", "distance"],
            );
            t.row(vec![
                "Server".into(),
                cfg.server.platform.clone(),
                format!("{:.2} GHz", cfg.server.max_freq_hz / 1e9),
                format!("{}", cfg.server.cores as u64),
                "-".into(),
            ]);
            for d in &cfg.devices {
                t.row(vec![
                    d.name.clone(),
                    d.platform.clone(),
                    format!("{:.1} GHz", d.freq_hz / 1e9),
                    format!("{}", d.cores as u64),
                    format!("{:.0} m", d.distance_m),
                ]);
            }
            t.print();
        }
        "params" => {
            let mut t = Table::new("Table II — simulation parameters", &["parameter", "value"]);
            t.row(vec!["δ_m^D (FLOPs/core/cycle)".into(), format!("{}", cfg.devices[0].flops_per_cycle)]);
            t.row(vec!["δ^S".into(), format!("{}", cfg.server.flops_per_cycle)]);
            t.row(vec!["ξ (W/Hz³)".into(), format!("{:e}", cfg.server.xi)]);
            t.row(vec!["w".into(), format!("{}", cfg.card.w)]);
            t.row(vec!["T_{m,n} (local epochs)".into(), format!("{}", cfg.workload.local_epochs)]);
            t.row(vec!["φ (compression)".into(), format!("{}", cfg.workload.phi)]);
            t.row(vec!["rounds N".into(), format!("{}", cfg.workload.rounds)]);
            t.row(vec!["bandwidth".into(), format!("{:.0} MHz", cfg.channel.bandwidth_hz / 1e6)]);
            t.print();
        }
        "arch" => {
            let arch = edgesplit::model::LlmArch::by_name(&cfg.workload.arch)
                .ok_or_else(|| anyhow!("unknown arch"))?;
            let mut t = Table::new("model architecture (cost model)", &["field", "value"]);
            t.row(vec!["name".into(), arch.name.clone()]);
            t.row(vec!["layers I".into(), arch.n_layers.to_string()]);
            t.row(vec!["d_model".into(), arch.d_model.to_string()]);
            t.row(vec!["d_ff".into(), arch.d_ff.to_string()]);
            t.row(vec!["vocab".into(), arch.vocab_size.to_string()]);
            t.row(vec!["LoRA rank".into(), arch.lora_rank.to_string()]);
            t.row(vec!["params".into(), format!("{:.2}B", arch.total_params() as f64 / 1e9)]);
            t.row(vec!["trainable (LoRA)".into(), format!("{:.1}M", (arch.n_layers * arch.lora_layer_params()) as f64 / 1e6)]);
            t.print();
        }
        "scenarios" => {
            let mut t = Table::new(
                "scenario registry (fleet-sweep presets)",
                &["name", "channel", "process", "mobility", "placement [m]", "summary"],
            );
            for sc in scenario::ALL {
                // expand a 1-device fleet to read the preset's channel
                // process / mobility tables
                let preset = sc.config(1, 0)?;
                t.row(vec![
                    sc.name.to_string(),
                    sc.state.name().to_string(),
                    preset.channel.process.model.name().to_string(),
                    preset.mobility.model.name().to_string(),
                    format!("{:.0}-{:.0}", sc.dist_range.0, sc.dist_range.1),
                    sc.summary.to_string(),
                ]);
            }
            t.print();
        }
        other => bail!("unknown show target '{other}' (devices|params|arch|scenarios)"),
    }
    Ok(())
}
