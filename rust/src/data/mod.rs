//! Data substrate: synthetic device corpora + mini-batch sampling for
//! the real split fine-tuning runs.

pub mod corpus;

pub use corpus::{Batcher, Corpus};
