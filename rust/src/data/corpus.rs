//! Synthetic training corpus for the end-to-end split fine-tuning runs.
//!
//! The paper fine-tunes on "geo-distributed personal data" we do not
//! have (DESIGN.md §2), so each device gets a *learnable* synthetic
//! byte-level corpus: a device-specific mixture of template phrases
//! (strong, learnable structure) corrupted with Zipf-distributed byte
//! noise (vocabulary-shaped randomness).  Loss on this corpus drops
//! quickly from ln(256) when the model learns, which is exactly the
//! signal the E2E experiment needs.

use crate::util::rng::{zipf_table, Rng};

/// Template phrases shared across devices (the "common language"), with
/// device-specific vocabulary injected to make data non-IID across
/// devices as in the paper's setting.
const TEMPLATES: [&str; 6] = [
    "the quick brown fox jumps over the lazy dog. ",
    "split learning places early layers on the device. ",
    "low rank adapters make fine tuning cheap. ",
    "edge servers trade energy for latency. ",
    "the cut layer decides who computes what. ",
    "wireless channels fade and rates change. ",
];

#[derive(Clone, Debug)]
pub struct Corpus {
    /// token stream (byte-level vocab, ids 0..=255)
    pub tokens: Vec<u8>,
}

impl Corpus {
    /// Build a device's corpus: `len` tokens, `noise` fraction of Zipf
    /// bytes, device-tagged phrases.
    pub fn synthetic(device_idx: usize, len: usize, noise: f64, rng: &mut Rng) -> Self {
        let tag = format!("device {} says: ", device_idx + 1);
        let ztab = zipf_table(256, 1.3);
        let mut tokens = Vec::with_capacity(len + 128);
        while tokens.len() < len {
            if rng.f64() < noise {
                // noise burst: 4–16 Zipf bytes
                let n = 4 + rng.below(12) as usize;
                for _ in 0..n {
                    tokens.push(rng.zipf(256, 1.3, &ztab) as u8);
                }
            } else {
                let t = TEMPLATES[rng.below(TEMPLATES.len() as u64) as usize];
                tokens.extend_from_slice(tag.as_bytes());
                tokens.extend_from_slice(t.as_bytes());
            }
        }
        tokens.truncate(len);
        Self { tokens }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

/// Mini-batch sampler: random windows of `seq_len + 1` tokens, split
/// into (input, next-token labels).
#[derive(Clone, Debug)]
pub struct Batcher {
    corpus: Corpus,
    pub batch_size: usize,
    pub seq_len: usize,
    rng: Rng,
}

impl Batcher {
    pub fn new(corpus: Corpus, batch_size: usize, seq_len: usize, seed: u64) -> Self {
        assert!(
            corpus.len() > seq_len + 1,
            "corpus ({}) shorter than seq_len+1 ({})",
            corpus.len(),
            seq_len + 1
        );
        Self {
            corpus,
            batch_size,
            seq_len,
            rng: Rng::new(seed),
        }
    }

    /// Returns (tokens, labels), each batch_size × seq_len i32, flattened
    /// row-major — ready for the `embed_fwd` / `head_loss_grad` artifacts.
    pub fn next_batch(&mut self) -> (Vec<i32>, Vec<i32>) {
        let n = self.corpus.len();
        let mut toks = Vec::with_capacity(self.batch_size * self.seq_len);
        let mut labs = Vec::with_capacity(self.batch_size * self.seq_len);
        for _ in 0..self.batch_size {
            let start = self.rng.below((n - self.seq_len - 1) as u64) as usize;
            let window = &self.corpus.tokens[start..start + self.seq_len + 1];
            toks.extend(window[..self.seq_len].iter().map(|&b| b as i32));
            labs.extend(window[1..].iter().map(|&b| b as i32));
        }
        (toks, labs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_exact_length_and_range() {
        let mut rng = Rng::new(1);
        let c = Corpus::synthetic(0, 10_000, 0.1, &mut rng);
        assert_eq!(c.len(), 10_000);
    }

    #[test]
    fn corpora_differ_across_devices() {
        let mut r1 = Rng::new(2);
        let mut r2 = Rng::new(2);
        let a = Corpus::synthetic(0, 2000, 0.1, &mut r1);
        let b = Corpus::synthetic(1, 2000, 0.1, &mut r2);
        assert_ne!(a.tokens, b.tokens, "device tag must differentiate data");
    }

    #[test]
    fn corpus_has_learnable_structure() {
        // template text should dominate: printable ASCII >> high bytes
        let mut rng = Rng::new(3);
        let c = Corpus::synthetic(0, 20_000, 0.1, &mut rng);
        let printable = c
            .tokens
            .iter()
            .filter(|&&b| (32..127).contains(&b))
            .count();
        assert!(printable as f64 > 0.8 * c.len() as f64);
    }

    #[test]
    fn batcher_shapes_and_shift() {
        let mut rng = Rng::new(4);
        let c = Corpus::synthetic(0, 5000, 0.0, &mut rng);
        let mut b = Batcher::new(c, 4, 32, 9);
        let (toks, labs) = b.next_batch();
        assert_eq!(toks.len(), 4 * 32);
        assert_eq!(labs.len(), 4 * 32);
        // labels are inputs shifted by one within each row
        for row in 0..4 {
            for i in 0..31 {
                assert_eq!(toks[row * 32 + i + 1], labs[row * 32 + i]);
            }
        }
    }

    #[test]
    fn batches_vary() {
        let mut rng = Rng::new(5);
        let c = Corpus::synthetic(0, 5000, 0.2, &mut rng);
        let mut b = Batcher::new(c, 2, 16, 10);
        let (t1, _) = b.next_batch();
        let (t2, _) = b.next_batch();
        assert_ne!(t1, t2);
    }

    #[test]
    #[should_panic(expected = "corpus")]
    fn batcher_rejects_short_corpus() {
        let c = Corpus {
            tokens: vec![1, 2, 3],
        };
        Batcher::new(c, 1, 16, 0);
    }
}
