//! CLI substrate (no `clap` in the offline crate set): a small
//! subcommand + flag parser with typed accessors and generated help.

use std::collections::BTreeMap;

#[derive(Debug)]
pub enum CliError {
    UnknownFlag(String),
    MissingValue(String),
    BadValue(String, String, &'static str),
    UnexpectedPositional(String),
    /// An output path a flag points at cannot be opened for writing —
    /// caught before dispatch so a long run cannot fail only at exit.
    UnwritablePath {
        flag: String,
        path: String,
        source: String,
    },
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::UnknownFlag(name) => write!(f, "unknown flag '--{name}' (see --help)"),
            CliError::MissingValue(name) => write!(f, "flag '--{name}' expects a value"),
            CliError::BadValue(name, value, ty) => {
                write!(f, "flag '--{name}': cannot parse '{value}' as {ty}")
            }
            CliError::UnexpectedPositional(arg) => write!(
                f,
                "unexpected positional argument '{arg}' (options are flags: --name value; see --help)"
            ),
            CliError::UnwritablePath { flag, path, source } => write!(
                f,
                "flag '--{flag}': cannot write to '{path}': {source} \
                 (checked up front so the run cannot fail only at exit)"
            ),
        }
    }
}

impl std::error::Error for CliError {}

/// Probe an output path for writability *before* the command runs.
/// Opens (creating if absent) for write; a file created only by the
/// probe is removed again so a failing command leaves nothing behind.
pub fn preflight_writable(flag: &str, path: &str) -> Result<(), CliError> {
    let existed = std::path::Path::new(path).exists();
    match std::fs::OpenOptions::new().write(true).create(true).open(path) {
        Ok(_) => {
            if !existed {
                let _ = std::fs::remove_file(path);
            }
            Ok(())
        }
        Err(e) => Err(CliError::UnwritablePath {
            flag: flag.to_string(),
            path: path.to_string(),
            source: e.to_string(),
        }),
    }
}

/// Flag specification for help + validation.
#[derive(Clone, Debug)]
pub struct FlagSpec {
    pub name: &'static str,
    pub value: Option<&'static str>, // None = boolean switch
    pub help: &'static str,
    pub default: Option<&'static str>,
}

pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse `argv` (without the program/subcommand) against `specs`.
    pub fn parse(argv: &[String], specs: &[FlagSpec]) -> Result<Args, CliError> {
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        let spec_of = |name: &str| specs.iter().find(|s| s.name == name);
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // --flag=value
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                let spec =
                    spec_of(name).ok_or_else(|| CliError::UnknownFlag(name.to_string()))?;
                let value = if spec.value.is_some() {
                    match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| CliError::MissingValue(name.to_string()))?,
                    }
                } else {
                    "true".to_string()
                };
                flags.insert(name.to_string(), value);
            } else {
                positional.push(a.clone());
            }
        }
        // fill defaults
        for s in specs {
            if let Some(d) = s.default {
                flags.entry(s.name.to_string()).or_insert_with(|| d.to_string());
            }
        }
        Ok(Args { flags, positional })
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Reject stray positional arguments beyond the `allowed` leading
    /// ones (the subcommand name, plus e.g. `show`'s target) — every
    /// subcommand is flag-only past those, so anything extra is a typo
    /// that used to be silently ignored.
    pub fn expect_positionals(&self, allowed: usize) -> Result<(), CliError> {
        match self.positional.get(allowed) {
            Some(extra) => Err(CliError::UnexpectedPositional(extra.clone())),
            None => Ok(()),
        }
    }

    pub fn str_of(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn bool_of(&self, name: &str) -> bool {
        matches!(self.str_of(name), Some("true") | Some("1") | Some("yes"))
    }

    pub fn f64_of(&self, name: &str) -> Result<Option<f64>, CliError> {
        match self.flags.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| CliError::BadValue(name.into(), v.clone(), "number")),
        }
    }

    pub fn usize_of(&self, name: &str) -> Result<Option<usize>, CliError> {
        match self.flags.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| CliError::BadValue(name.into(), v.clone(), "integer")),
        }
    }

    pub fn u64_of(&self, name: &str) -> Result<Option<u64>, CliError> {
        match self.flags.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| CliError::BadValue(name.into(), v.clone(), "integer")),
        }
    }
}

pub fn render_help(program: &str, about: &str, subcommands: &[(&str, &str)], specs: &[FlagSpec]) -> String {
    let mut s = format!("{program} — {about}\n\nUSAGE:\n  {program} <command> [flags]\n");
    if !subcommands.is_empty() {
        s.push_str("\nCOMMANDS:\n");
        for (name, help) in subcommands {
            s.push_str(&format!("  {name:<14} {help}\n"));
        }
    }
    if !specs.is_empty() {
        s.push_str("\nFLAGS:\n");
        for f in specs {
            let arg = match f.value {
                Some(v) => format!("--{} <{v}>", f.name),
                None => format!("--{}", f.name),
            };
            let default = f
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  {arg:<26} {}{default}\n", f.help));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<FlagSpec> {
        vec![
            FlagSpec {
                name: "rounds",
                value: Some("N"),
                help: "training rounds",
                default: Some("20"),
            },
            FlagSpec {
                name: "w",
                value: Some("0..1"),
                help: "cost weight",
                default: None,
            },
            FlagSpec {
                name: "verbose",
                value: None,
                help: "chatty",
                default: None,
            },
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_positional() {
        let a = Args::parse(&sv(&["fig3", "--rounds", "7", "--verbose"]), &specs()).unwrap();
        assert_eq!(a.positional(), &["fig3".to_string()]);
        assert_eq!(a.usize_of("rounds").unwrap(), Some(7));
        assert!(a.bool_of("verbose"));
    }

    #[test]
    fn equals_syntax_and_defaults() {
        let a = Args::parse(&sv(&["--w=0.4"]), &specs()).unwrap();
        assert_eq!(a.f64_of("w").unwrap(), Some(0.4));
        assert_eq!(a.usize_of("rounds").unwrap(), Some(20)); // default
    }

    #[test]
    fn rejects_unknown_and_bad_values() {
        assert!(matches!(
            Args::parse(&sv(&["--bogus"]), &specs()),
            Err(CliError::UnknownFlag(_))
        ));
        assert!(matches!(
            Args::parse(&sv(&["--rounds", "xyz"]), &specs())
                .unwrap()
                .usize_of("rounds"),
            Err(CliError::BadValue(..))
        ));
        assert!(matches!(
            Args::parse(&sv(&["--rounds"]), &specs()),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn rejects_unexpected_positionals() {
        let a = Args::parse(&sv(&["fig3", "stray", "--rounds", "7"]), &specs()).unwrap();
        // the subcommand itself is fine...
        assert!(a.expect_positionals(2).is_ok());
        // ...but anything past the allowance is a typed error naming it
        let err = a.expect_positionals(1).unwrap_err();
        assert!(matches!(&err, CliError::UnexpectedPositional(s) if s == "stray"));
        assert!(err.to_string().contains("stray"));
        // flag-only invocations always pass
        let b = Args::parse(&sv(&["fig4"]), &specs()).unwrap();
        assert!(b.expect_positionals(1).is_ok());
    }

    #[test]
    fn preflight_rejects_unwritable_and_cleans_probe() {
        let err = preflight_writable("trace", "/nonexistent-dir/trace.json").unwrap_err();
        assert!(matches!(&err, CliError::UnwritablePath { flag, .. } if flag == "trace"));
        assert!(err.to_string().contains("/nonexistent-dir/trace.json"));

        let dir = std::env::temp_dir().join("edgesplit-preflight-test");
        std::fs::create_dir_all(&dir).unwrap();
        let fresh = dir.join("fresh.json");
        let fresh_s = fresh.to_str().unwrap();
        let _ = std::fs::remove_file(&fresh);
        preflight_writable("trace", fresh_s).unwrap();
        // the probe must not leave an empty file behind
        assert!(!fresh.exists());
        // an existing file passes and is left intact
        std::fs::write(&fresh, "keep").unwrap();
        preflight_writable("out", fresh_s).unwrap();
        assert_eq!(std::fs::read_to_string(&fresh).unwrap(), "keep");
        let _ = std::fs::remove_file(&fresh);
    }

    #[test]
    fn help_renders() {
        let h = render_help("edgesplit", "about", &[("fig3", "fig3 help")], &specs());
        assert!(h.contains("--rounds <N>"));
        assert!(h.contains("fig3 help"));
        assert!(h.contains("[default: 20]"));
    }
}
