//! The paper's system contribution (L3): the CARD decision algorithm
//! (Alg. 1, Eqs. 12–16), the split-learning round scheduler
//! (Stages 1–5), baseline strategies, and adapter aggregation (Eq. 6).

pub mod aggregator;
pub mod baselines;
pub mod card;
pub mod cost;
pub mod kernel;
pub mod scheduler;
pub mod soa;

pub use aggregator::Aggregator;
pub use baselines::Strategy;
pub use card::{Card, Decision};
pub use cost::{Bounds, CostModel};
pub use kernel::{CellEval, CutTable, DecisionCache, ModelTerms};
pub use scheduler::{
    build_cost_model, BackendStats, CellValues, RoundRecord, Scheduler, TrainBackend,
};
pub use soa::{RoundBatch, SOA_CHUNK, SOA_WINDOW};
