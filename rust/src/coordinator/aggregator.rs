//! Adapter aggregation & version bookkeeping — Stage 5, Eq. (6).
//!
//! The paper trains devices sequentially against one shared adapter set:
//! after T local epochs the device uploads its device-side adapters
//! R^{D,T} and the server concatenates them with its own R^{S,T}
//! (Eq. 6), so the merged R becomes the starting point for the next
//! device.  This module tracks that merge: per-layer ownership (which
//! side last updated each layer), staleness, and the Stage-2/5 payload
//! ledger.  The actual numeric adapter state lives in the runtime
//! executor; this is the coordinator's control-plane view.

/// Which side of the split last wrote a layer's adapters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Owner {
    Device(usize),
    Server,
}

#[derive(Clone, Debug)]
pub struct LayerVersion {
    pub owner: Owner,
    /// round index of the last update
    pub round: usize,
    /// total updates applied to this layer
    pub updates: u64,
}

/// Control-plane view of the shared LoRA adapter stack.
#[derive(Clone, Debug)]
pub struct Aggregator {
    pub layers: Vec<LayerVersion>,
    /// cumulative Stage-2 (downlink) adapter bytes
    pub bytes_distributed: f64,
    /// cumulative Stage-5 (uplink) adapter bytes
    pub bytes_collected: f64,
    merges: u64,
}

impl Aggregator {
    pub fn new(n_layers: usize) -> Self {
        Self {
            layers: vec![
                LayerVersion {
                    owner: Owner::Server,
                    round: 0,
                    updates: 0,
                };
                n_layers
            ],
            bytes_distributed: 0.0,
            bytes_collected: 0.0,
            merges: 0,
        }
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Stage 1+2: the split at cut `c` hands layers [0, c) to `device`.
    /// Returns the number of layers distributed (payload accounting is
    /// the caller's A(c)).
    pub fn distribute(&mut self, device: usize, cut: usize, round: usize, bytes: f64) -> usize {
        assert!(cut <= self.layers.len(), "cut beyond model depth");
        for l in &mut self.layers[..cut] {
            l.owner = Owner::Device(device);
            l.round = round;
        }
        self.bytes_distributed += bytes;
        cut
    }

    /// Stage 4 server-side updates: layers [c, I) were updated by the
    /// server during this round's BP.
    pub fn server_update(&mut self, cut: usize, round: usize) {
        for l in &mut self.layers[cut..] {
            l.owner = Owner::Server;
            l.round = round;
            l.updates += 1;
        }
    }

    /// Stage 5, Eq. (6): merge device-side adapters back.  After the
    /// merge every layer is server-owned (the server holds R complete).
    pub fn merge(&mut self, device: usize, cut: usize, round: usize, bytes: f64) {
        for l in &mut self.layers[..cut] {
            debug_assert_eq!(l.owner, Owner::Device(device), "merge from non-owner");
            l.owner = Owner::Server;
            l.round = round;
            l.updates += 1;
        }
        self.bytes_collected += bytes;
        self.merges += 1;
    }

    /// Out-of-order-safe variant of [`Aggregator::server_update`] for
    /// the DES async/semi-sync paths: concurrent device leases may
    /// interleave arbitrarily, so layer version coordinates advance
    /// monotonically instead of being overwritten.
    pub fn server_update_unordered(&mut self, cut: usize, round: usize) {
        for l in &mut self.layers[cut..] {
            l.owner = Owner::Server;
            l.round = l.round.max(round);
            l.updates += 1;
        }
    }

    /// Out-of-order-safe variant of [`Aggregator::merge`]: accepts a
    /// device's adapters regardless of who currently owns the layers
    /// (a fresher concurrent lease may already have overwritten them)
    /// and never regresses a layer's version coordinate.  Used by the
    /// DES engine, where merges arrive in completion order, not
    /// distribution order.
    pub fn merge_unordered(&mut self, _device: usize, cut: usize, round: usize, bytes: f64) {
        for l in &mut self.layers[..cut] {
            l.owner = Owner::Server;
            l.round = l.round.max(round);
            l.updates += 1;
        }
        self.bytes_collected += bytes;
        self.merges += 1;
    }

    /// All layers consistent at the server (invariant between rounds).
    pub fn is_consistent(&self) -> bool {
        self.layers.iter().all(|l| l.owner == Owner::Server)
    }

    /// Max round-lag across layers (0 = everything fresh this round).
    pub fn staleness(&self, current_round: usize) -> usize {
        self.layers
            .iter()
            .map(|l| current_round.saturating_sub(l.round))
            .max()
            .unwrap_or(0)
    }

    pub fn merges(&self) -> u64 {
        self.merges
    }

    /// Rebuild an aggregator from checkpointed state (`merges` is
    /// private, so resume cannot construct this literally).
    pub fn from_parts(
        layers: Vec<LayerVersion>,
        bytes_distributed: f64,
        bytes_collected: f64,
        merges: u64,
    ) -> Self {
        Self {
            layers,
            bytes_distributed,
            bytes_collected,
            merges,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_round_restores_consistency() {
        let mut a = Aggregator::new(32);
        a.distribute(2, 10, 1, 5e6);
        assert!(!a.is_consistent());
        a.server_update(10, 1);
        a.merge(2, 10, 1, 5e6);
        assert!(a.is_consistent());
        assert_eq!(a.merges(), 1);
    }

    #[test]
    fn update_counts_accumulate_everywhere() {
        let mut a = Aggregator::new(8);
        for round in 1..=3 {
            a.distribute(0, 4, round, 1.0);
            a.server_update(4, round);
            a.merge(0, 4, round, 1.0);
        }
        // both halves of the model updated every round
        assert!(a.layers.iter().all(|l| l.updates == 3));
        assert_eq!(a.bytes_distributed, 3.0);
        assert_eq!(a.bytes_collected, 3.0);
    }

    #[test]
    fn cut_zero_touches_nothing_device_side() {
        let mut a = Aggregator::new(8);
        assert_eq!(a.distribute(1, 0, 1, 0.0), 0);
        a.server_update(0, 1);
        a.merge(1, 0, 1, 0.0);
        assert!(a.is_consistent());
        assert!(a.layers.iter().all(|l| l.updates == 1));
    }

    #[test]
    fn staleness_tracks_oldest_layer() {
        let mut a = Aggregator::new(4);
        a.server_update(0, 5); // all updated at round 5
        assert_eq!(a.staleness(5), 0);
        assert_eq!(a.staleness(9), 4);
    }

    #[test]
    #[should_panic(expected = "cut beyond model depth")]
    fn distribute_validates_cut() {
        let mut a = Aggregator::new(4);
        a.distribute(0, 5, 1, 0.0);
    }

    #[test]
    fn unordered_merge_tolerates_interleaved_leases() {
        // two concurrent leases over overlapping prefixes, merged in
        // completion order (1 before 0) — the ordered path would panic
        // on the non-owner debug assert
        let mut a = Aggregator::new(8);
        a.distribute(0, 6, 1, 1.0);
        a.distribute(1, 4, 2, 1.0);
        a.merge_unordered(1, 4, 2, 1.0);
        a.merge_unordered(0, 6, 1, 1.0);
        assert!(a.is_consistent());
        assert_eq!(a.merges(), 2);
        // version coordinates are monotone: layer 0 keeps round 2 even
        // though the later merge carried round 1
        assert_eq!(a.layers[0].round, 2);
        assert_eq!(a.layers[5].round, 1);
    }

    #[test]
    fn unordered_server_update_is_monotone() {
        let mut a = Aggregator::new(4);
        a.server_update_unordered(0, 7);
        a.server_update_unordered(0, 3);
        assert!(a.layers.iter().all(|l| l.round == 7));
        assert_eq!(a.staleness(7), 0);
    }
}
