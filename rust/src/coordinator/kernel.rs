//! The decision kernel (DESIGN.md §12): precomputed cut tables and the
//! CQI-keyed decision cache that turn the Alg.-1 scan — the innermost
//! loop of both fleet engines — into a tight, branch-free slice walk.
//!
//! ## Why this is exact, not approximate
//!
//! Every value the kernel produces is computed with the **same floating
//! point operations in the same association order** as the reference
//! `CostModel`/`DelayModel`/`EnergyModel` chain; the only difference is
//! that f- and rate-independent subterms (η_D(c), η_S(c), wire bytes,
//! the per-epoch device compute delay) are evaluated once per
//! `(CostModel, ServerSpec, DeviceSpec)` instead of once per cost call.
//! IEEE-754 arithmetic is deterministic, so hoisting a subexpression
//! out of a loop cannot change a single bit of any result — asserted
//! bitwise against the legacy path by this module's tests and by
//! `rust/tests/decision_kernel.rs`.
//!
//! The cache key is exact for the same reason: realized link rates are
//! `R = B · y(CQI(SNR))` (net/cqi.rs) with the outage floor also a pure
//! function of the CQI-0 bucket, so per device there are at most 16×16
//! distinct `(rate_up, rate_down)` pairs — the `(cqi_up, cqi_down)`
//! pair *is* the rate pair, and a memoized decision replayed for the
//! same key is the decision the scan would have produced.  Fading moves
//! the SNR continuously, but SNR only enters the round record, never
//! the decision.  Random-cut consumes the cell RNG and must bypass the
//! cache (`Strategy::cacheable`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::config::{DeviceSpec, ServerSpec};
use crate::model::LinkRates;
use crate::net::cqi::cqi_for_snr;

use super::card::Decision;
use super::cost::{Bounds, CostModel};

/// Cut-indexed terms that depend only on `(CostModel, ServerSpec)` —
/// shared (via `Arc`) by every device's [`CutTable`] so a 10⁴-device
/// fleet pays the model evaluation once, not once per device.
#[derive(Debug)]
pub struct ModelTerms {
    /// I — cut candidates are 0..=n_layers
    pub n_layers: usize,
    /// T — local epochs per round
    pub epochs: f64,
    /// w — Eq. (12) weighting
    pub w: f64,
    /// ξ — server power coefficient
    pub xi: f64,
    /// T·ξ — the energy prefix before the f² factor (Eq. 11)
    xi_epochs: f64,
    /// δ^S — kept separate: legacy throughput is ((f·δ)·σ)
    server_delta: f64,
    /// σ^S
    server_sigma: f64,
    /// δ^S·σ^S — the Eq.-11 denominator (a single product in legacy too)
    delta_sigma: f64,
    /// F^S_max
    pub f_max: f64,
    /// η_D(c) — device-side training FLOPs
    eta_d: Vec<f64>,
    /// η_S(c) = η − η_D(c) — server-side training FLOPs
    pub eta_s: Vec<f64>,
    /// 8·φ·S(c) — smashed uplink bits per local epoch
    e8_smashed: Vec<f64>,
    /// 8·φ·S̃(c) — gradient downlink bits per local epoch
    e8_grad: Vec<f64>,
    /// 8·A(c) — adapter bits (each direction, once per round)
    e8_adapter: Vec<f64>,
    /// A(c) — adapter payload bytes (RoundRecord reporting)
    pub adapter_bytes: Vec<f64>,
    /// φ·S(c) + φ·S̃(c) — per-epoch wire bytes (RoundRecord reporting)
    pub wire_bytes_epoch: Vec<f64>,
}

impl ModelTerms {
    pub fn new(cm: &CostModel, server: &ServerSpec) -> Self {
        let i = cm.n_layers();
        let fl = &cm.delay.flops;
        let sz = &cm.delay.sizes;
        let mut t = ModelTerms {
            n_layers: i,
            epochs: cm.delay.epochs,
            w: cm.w,
            xi: server.xi,
            xi_epochs: cm.energy.epochs * server.xi,
            server_delta: server.flops_per_cycle,
            server_sigma: server.cores,
            delta_sigma: server.flops_per_cycle * server.cores,
            f_max: server.max_freq_hz,
            eta_d: Vec::with_capacity(i + 1),
            eta_s: Vec::with_capacity(i + 1),
            e8_smashed: Vec::with_capacity(i + 1),
            e8_grad: Vec::with_capacity(i + 1),
            e8_adapter: Vec::with_capacity(i + 1),
            adapter_bytes: Vec::with_capacity(i + 1),
            wire_bytes_epoch: Vec::with_capacity(i + 1),
        };
        for c in 0..=i {
            t.eta_d.push(fl.eta_device(c));
            t.eta_s.push(fl.eta_server(c));
            t.e8_smashed.push(8.0 * sz.smashed_wire_bytes(c));
            t.e8_grad.push(8.0 * sz.grad_wire_bytes(c));
            t.e8_adapter.push(8.0 * sz.adapter_bytes(c));
            t.adapter_bytes.push(sz.adapter_bytes(c));
            t.wire_bytes_epoch.push(sz.smashed_wire_bytes(c) + sz.grad_wire_bytes(c));
        }
        t
    }
}

/// Per-frequency subterms, computed once per scan instead of once per
/// cut candidate.  Matches the legacy association exactly:
/// `thr = (f·δ)·σ` and `e_prefix = ((T·ξ)·f)·f`.
#[derive(Clone, Copy, Debug)]
pub struct FreqTerms {
    pub f_hz: f64,
    thr: f64,
    e_prefix: f64,
}

/// The precomputed decision table for one `(CostModel, ServerSpec,
/// DeviceSpec)` triple: everything `Card::decide` and the baseline
/// strategies need, indexed flat by cut layer.
#[derive(Debug)]
pub struct CutTable {
    pub terms: Arc<ModelTerms>,
    /// η_D(c) / (f^D δ^D σ^D) — per-epoch device compute delay (Eq. 7)
    pub dev_compute: Vec<f64>,
    /// F^{m,S}_min — this device's server frequency floor
    pub f_min: f64,
}

impl CutTable {
    pub fn new(terms: Arc<ModelTerms>, dev: &DeviceSpec) -> Self {
        let dev_thr = dev.throughput();
        let dev_compute = terms.eta_d.iter().map(|&eta| eta / dev_thr).collect();
        let f_min = dev_thr / terms.delta_sigma;
        CutTable {
            terms,
            dev_compute,
            f_min,
        }
    }

    /// One-shot convenience for callers without a fleet (tests,
    /// `decide`, benches): builds a private `ModelTerms`.
    pub fn for_device(cm: &CostModel, server: &ServerSpec, dev: &DeviceSpec) -> Self {
        CutTable::new(Arc::new(ModelTerms::new(cm, server)), dev)
    }

    pub fn n_layers(&self) -> usize {
        self.terms.n_layers
    }

    #[inline]
    pub fn freq_terms(&self, f_hz: f64) -> FreqTerms {
        FreqTerms {
            f_hz,
            thr: f_hz * self.terms.server_delta * self.terms.server_sigma,
            e_prefix: self.terms.xi_epochs * f_hz * f_hz,
        }
    }

    /// Eq. (9): round transmission delay at cut `c`.
    #[inline]
    pub fn transmission(&self, c: usize, rates: LinkRates) -> f64 {
        let t = &self.terms;
        let per_epoch = t.e8_smashed[c] / rates.up_bps + t.e8_grad[c] / rates.down_bps;
        let adapters = t.e8_adapter[c] / rates.up_bps + t.e8_adapter[c] / rates.down_bps;
        t.epochs * per_epoch + adapters
    }

    /// T · d^{S,C} — round server compute delay at cut `c` (Eq. 8 × T).
    #[inline]
    pub fn server_compute_round(&self, c: usize, ft: &FreqTerms) -> f64 {
        self.terms.epochs * (self.terms.eta_s[c] / ft.thr)
    }

    /// T · d^{D,C} — round device compute delay at cut `c` (Eq. 7 × T).
    #[inline]
    pub fn device_compute_round(&self, c: usize) -> f64 {
        self.terms.epochs * self.dev_compute[c]
    }

    /// Eq. (10): full round delay.
    #[inline]
    pub fn delay(&self, c: usize, ft: &FreqTerms, rates: LinkRates) -> f64 {
        let compute = self.terms.epochs * (self.dev_compute[c] + self.terms.eta_s[c] / ft.thr);
        compute + self.transmission(c, rates)
    }

    /// Eq. (11): round server energy.
    #[inline]
    pub fn energy(&self, c: usize, ft: &FreqTerms) -> f64 {
        ft.e_prefix * self.terms.eta_s[c] / self.terms.delta_sigma
    }

    /// Eq. (12) under precomputed bounds.
    #[inline]
    pub fn cost(&self, d: f64, e: f64, b: &Bounds) -> f64 {
        let w = self.terms.w;
        w * (d - b.d_min) / b.delay_span() + (1.0 - w) * (e - b.e_min) / b.energy_span()
    }

    /// The paper's normalization corners (§III-C) — bit-identical to
    /// `CostModel::bounds`.
    pub fn bounds(&self, rates: LinkRates) -> Bounds {
        let i = self.terms.n_layers;
        let ft_min = self.freq_terms(self.f_min);
        let ft_max = self.freq_terms(self.terms.f_max);
        Bounds {
            d_max: self.delay(i, &ft_min, rates),
            e_min: self.energy(i, &ft_min),
            d_min: self.delay(0, &ft_max, rates),
            e_max: self.energy(0, &ft_max),
        }
    }

    /// Eq. (16): closed-form optimal server frequency — bit-identical
    /// to `Card::optimal_frequency`.
    pub fn optimal_frequency(&self, b: &Bounds) -> f64 {
        let w = self.terms.w;
        if w >= 1.0 {
            return self.terms.f_max;
        }
        if w <= 0.0 {
            return self.f_min;
        }
        let q = (w * b.energy_span() / (2.0 * self.terms.xi * (1.0 - w) * b.delay_span())).cbrt();
        q.clamp(self.f_min, self.terms.f_max)
    }

    /// Alg. 1's lower layer: argmin over c ∈ {0..I} at fixed f — the
    /// branch-free slice scan that replaces the legacy O(I) model
    /// re-evaluation.
    pub fn scan(&self, f_hz: f64, rates: LinkRates, b: &Bounds) -> Decision {
        let ft = self.freq_terms(f_hz);
        let mut best = Decision {
            cut: 0,
            freq_hz: f_hz,
            cost: f64::INFINITY,
            delay_s: 0.0,
            energy_j: 0.0,
        };
        for c in 0..=self.terms.n_layers {
            let d = self.delay(c, &ft, rates);
            let e = self.energy(c, &ft);
            let u = self.cost(d, e, b);
            if u < best.cost {
                best = Decision {
                    cut: c,
                    freq_hz: f_hz,
                    cost: u,
                    delay_s: d,
                    energy_j: e,
                };
            }
        }
        best
    }

    /// Fixed-(c, f) decision — what the baseline strategies emit.
    pub fn at(&self, c: usize, f_hz: f64, rates: LinkRates, b: &Bounds) -> Decision {
        let ft = self.freq_terms(f_hz);
        let d = self.delay(c, &ft, rates);
        let e = self.energy(c, &ft);
        Decision {
            cut: c,
            freq_hz: f_hz,
            cost: self.cost(d, e, b),
            delay_s: d,
            energy_j: e,
        }
    }

    /// Rebuild the full [`Decision`] from a cache hit: `(cut, f*, U*)`
    /// plus the rates the key encodes.  Delay/energy are recomputed
    /// through the same kernel ops the scan used, so the realized
    /// decision is bit-identical to the memoized scan's.
    pub fn realize(&self, cut: usize, f_hz: f64, cost: f64, rates: LinkRates) -> Decision {
        let ft = self.freq_terms(f_hz);
        Decision {
            cut,
            freq_hz: f_hz,
            cost,
            delay_s: self.delay(cut, &ft, rates),
            energy_j: self.energy(cut, &ft),
        }
    }

    /// The cache-hit fast path: [`CutTable::realize`] fused with the
    /// round record's Eq.-10 decomposition — `FreqTerms`, the Eq.-8
    /// division, and the transmission term are each evaluated once
    /// instead of once for the decision and again for the record.
    /// Every field is bit-identical to the unfused accessors (the
    /// shared subterms are the same expressions, computed once).
    pub fn realize_cell(&self, cut: usize, f_hz: f64, cost: f64, rates: LinkRates) -> CellEval {
        let ft = self.freq_terms(f_hz);
        let transmission_s = self.transmission(cut, rates);
        let sc_epoch = self.terms.eta_s[cut] / ft.thr;
        let compute = self.terms.epochs * (self.dev_compute[cut] + sc_epoch);
        CellEval {
            decision: Decision {
                cut,
                freq_hz: f_hz,
                cost,
                delay_s: compute + transmission_s,
                energy_j: self.energy(cut, &ft),
            },
            device_compute_s: self.terms.epochs * self.dev_compute[cut],
            server_compute_s: self.terms.epochs * sc_epoch,
            transmission_s,
        }
    }
}

/// A decision plus the Eq.-10 decomposition the round record reports,
/// produced in one kernel pass by [`CutTable::realize_cell`].
#[derive(Clone, Copy, Debug)]
pub struct CellEval {
    pub decision: Decision,
    /// T · d^{D,C}
    pub device_compute_s: f64,
    /// T · d^{S,C}
    pub server_compute_s: f64,
    /// D^V (Eq. 9)
    pub transmission_s: f64,
}

/// 16 CQI buckets per direction (0 = outage .. 15) — 256 keys/device.
const CQI_LEVELS: usize = 16;
const KEYS_PER_DEVICE: usize = CQI_LEVELS * CQI_LEVELS;
/// Words per slot: [tag = cut+1, f* bits, U* bits].
const SLOT_WORDS: usize = 3;

/// `n` zeroed `AtomicU64`s backed by `alloc_zeroed` pages: a 10⁴-device
/// cache reserves ~61 MB of *virtual* zero pages, and resident memory
/// grows only with slots actually touched (realized CQI pairs), unlike
/// `resize_with`, which would write — and so commit — every page up
/// front.
fn zeroed_atomic_words(n: usize) -> Vec<AtomicU64> {
    // AtomicU64 documents the same size and bit validity as u64; the
    // in-place reinterpret additionally needs equal alignment, which
    // holds on every 64-bit target.  Fall back to the committing path
    // where it does not (e.g. 32-bit targets with 4-byte-aligned u64).
    if std::mem::align_of::<AtomicU64>() == std::mem::align_of::<u64>() {
        let mut raw = std::mem::ManuallyDrop::new(vec![0u64; n]);
        let (ptr, len, cap) = (raw.as_mut_ptr(), raw.len(), raw.capacity());
        // SAFETY: identical size/alignment checked above; the zero bit
        // pattern is a valid AtomicU64; ManuallyDrop forfeits the u64
        // buffer so ownership transfers exactly once.
        unsafe { Vec::from_raw_parts(ptr as *mut AtomicU64, len, cap) }
    } else {
        let mut slots = Vec::with_capacity(n);
        slots.resize_with(n, || AtomicU64::new(0));
        slots
    }
}

/// A cache-line-isolated counter: sharded telemetry RMWs land on
/// separate lines instead of serializing every worker on one.
#[derive(Debug)]
#[repr(align(64))]
struct PaddedCounter(AtomicU64);

/// Telemetry shards — lookups index by `device % 8`, so neighbouring
/// cells (which differ in device) update different lines.
const COUNTER_SHARDS: usize = 8;

/// Lock-free memo of `(device, cqi_up, cqi_down) → (cut, f*, U*)`.
///
/// Each slot is three `AtomicU64` words written value-first, tag-last
/// (`Release`) and read tag-first (`Acquire`).  Decisions are pure
/// functions of the key, so racing writers store identical bits and
/// the data race on *values* is benign by construction — every
/// interleaving yields the same slot contents.  Hit/miss counters are
/// `Relaxed`, device-sharded telemetry for `card-bench`.
#[derive(Debug)]
pub struct DecisionCache {
    slots: Vec<AtomicU64>,
    hits: [PaddedCounter; COUNTER_SHARDS],
    misses: [PaddedCounter; COUNTER_SHARDS],
}

impl DecisionCache {
    pub fn new(n_devices: usize) -> Self {
        let n = n_devices * KEYS_PER_DEVICE * SLOT_WORDS;
        DecisionCache {
            slots: zeroed_atomic_words(n),
            hits: std::array::from_fn(|_| PaddedCounter(AtomicU64::new(0))),
            misses: std::array::from_fn(|_| PaddedCounter(AtomicU64::new(0))),
        }
    }

    /// Quantize one round's realized SNRs into the cache key.
    #[inline]
    pub fn key(snr_up_db: f64, snr_down_db: f64) -> usize {
        cqi_for_snr(snr_up_db) as usize * CQI_LEVELS + cqi_for_snr(snr_down_db) as usize
    }

    #[inline]
    fn base(&self, device_idx: usize, key: usize) -> usize {
        debug_assert!(key < KEYS_PER_DEVICE);
        (device_idx * KEYS_PER_DEVICE + key) * SLOT_WORDS
    }

    /// `(cut, f*, U*)` if this `(device, key)` was decided before.
    #[inline]
    pub fn lookup(&self, device_idx: usize, key: usize) -> Option<(usize, f64, f64)> {
        let base = self.base(device_idx, key);
        let shard = device_idx % COUNTER_SHARDS;
        let tag = self.slots[base].load(Ordering::Acquire);
        if tag == 0 {
            self.misses[shard].0.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        self.hits[shard].0.fetch_add(1, Ordering::Relaxed);
        let f_bits = self.slots[base + 1].load(Ordering::Relaxed);
        let u_bits = self.slots[base + 2].load(Ordering::Relaxed);
        Some((
            (tag - 1) as usize,
            f64::from_bits(f_bits),
            f64::from_bits(u_bits),
        ))
    }

    #[inline]
    pub fn store(&self, device_idx: usize, key: usize, cut: usize, f_hz: f64, cost: f64) {
        let base = self.base(device_idx, key);
        self.slots[base + 1].store(f_hz.to_bits(), Ordering::Relaxed);
        self.slots[base + 2].store(cost.to_bits(), Ordering::Relaxed);
        self.slots[base].store(cut as u64 + 1, Ordering::Release);
    }

    /// `(hits, misses)` since construction.
    pub fn stats(&self) -> (u64, u64) {
        let sum = |shards: &[PaddedCounter; COUNTER_SHARDS]| {
            shards.iter().map(|c| c.0.load(Ordering::Relaxed)).sum::<u64>()
        };
        (sum(&self.hits), sum(&self.misses))
    }

    /// Fraction of lookups answered from the cache (0 when unused).
    pub fn hit_rate(&self) -> f64 {
        let (h, m) = self.stats();
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExpConfig;
    use crate::coordinator::card::Card;
    use crate::coordinator::scheduler::build_cost_model;

    fn setup() -> (CostModel, ExpConfig) {
        let cfg = ExpConfig::paper();
        (build_cost_model(&cfg), cfg)
    }

    const RATE_GRID: [LinkRates; 4] = [
        LinkRates {
            up_bps: 300e6,
            down_bps: 500e6,
        },
        LinkRates {
            up_bps: 15.23e6 / 50.0,
            down_bps: 87.7e6,
        },
        LinkRates {
            up_bps: 555.47e6,
            down_bps: 555.47e6,
        },
        LinkRates {
            up_bps: 60.16e6,
            down_bps: 15.23e6,
        },
    ];

    #[test]
    fn table_terms_bitwise_match_legacy_models() {
        let (cm, cfg) = setup();
        let terms = Arc::new(ModelTerms::new(&cm, &cfg.server));
        for dev in &cfg.devices {
            let table = CutTable::new(terms.clone(), dev);
            assert_eq!(
                table.f_min.to_bits(),
                dev.server_freq_floor(&cfg.server).to_bits(),
                "{}",
                dev.name
            );
            for rates in RATE_GRID {
                for f_hz in [table.f_min, 1.7e9, cfg.server.max_freq_hz] {
                    let ft = table.freq_terms(f_hz);
                    for c in 0..=cm.n_layers() {
                        let d_ref = cm.delay.round(c, dev, &cfg.server, f_hz, rates);
                        let e_ref = cm.energy.round(c, &cfg.server, f_hz);
                        assert_eq!(
                            table.delay(c, &ft, rates).to_bits(),
                            d_ref.to_bits(),
                            "{} c={c} f={f_hz}",
                            dev.name
                        );
                        assert_eq!(
                            table.energy(c, &ft).to_bits(),
                            e_ref.to_bits(),
                            "{} c={c} f={f_hz}",
                            dev.name
                        );
                        assert_eq!(
                            table.transmission(c, rates).to_bits(),
                            cm.delay.transmission(c, rates).to_bits()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn table_bounds_and_cost_bitwise_match_legacy() {
        let (cm, cfg) = setup();
        for dev in &cfg.devices {
            let table = CutTable::for_device(&cm, &cfg.server, dev);
            for rates in RATE_GRID {
                let b_ref = cm.bounds(dev, &cfg.server, rates);
                let b = table.bounds(rates);
                assert_eq!(b.d_min.to_bits(), b_ref.d_min.to_bits());
                assert_eq!(b.d_max.to_bits(), b_ref.d_max.to_bits());
                assert_eq!(b.e_min.to_bits(), b_ref.e_min.to_bits());
                assert_eq!(b.e_max.to_bits(), b_ref.e_max.to_bits());
                let ft = table.freq_terms(2.0e9);
                for c in [0, 8, cm.n_layers()] {
                    let u_ref = cm.cost(c, 2.0e9, dev, &cfg.server, rates, &b_ref);
                    let d = table.delay(c, &ft, rates);
                    let e = table.energy(c, &ft);
                    assert_eq!(table.cost(d, e, &b).to_bits(), u_ref.to_bits());
                }
            }
        }
    }

    #[test]
    fn scan_bitwise_matches_legacy_decide() {
        for w in [0.0, 0.05, 0.2, 0.5, 0.8, 1.0] {
            let (mut cm, cfg) = setup();
            cm.w = w;
            let card = Card::new(&cm, &cfg.server);
            for dev in &cfg.devices {
                let table = CutTable::for_device(&cm, &cfg.server, dev);
                for rates in RATE_GRID {
                    let legacy = card.decide_ref(dev, rates);
                    let b = table.bounds(rates);
                    let f_star = table.optimal_frequency(&b);
                    assert_eq!(
                        f_star.to_bits(),
                        card.optimal_frequency(dev, &b).to_bits(),
                        "{} w={w}",
                        dev.name
                    );
                    let fast = table.scan(f_star, rates, &b);
                    assert_eq!(fast.cut, legacy.cut, "{} w={w}", dev.name);
                    assert_eq!(fast.freq_hz.to_bits(), legacy.freq_hz.to_bits());
                    assert_eq!(fast.cost.to_bits(), legacy.cost.to_bits());
                    assert_eq!(fast.delay_s.to_bits(), legacy.delay_s.to_bits());
                    assert_eq!(fast.energy_j.to_bits(), legacy.energy_j.to_bits());
                }
            }
        }
    }

    #[test]
    fn realize_reproduces_scan_bitwise() {
        let (cm, cfg) = setup();
        let dev = &cfg.devices[2];
        let table = CutTable::for_device(&cm, &cfg.server, dev);
        for rates in RATE_GRID {
            let b = table.bounds(rates);
            let d = table.scan(table.optimal_frequency(&b), rates, &b);
            let r = table.realize(d.cut, d.freq_hz, d.cost, rates);
            assert_eq!(r.delay_s.to_bits(), d.delay_s.to_bits());
            assert_eq!(r.energy_j.to_bits(), d.energy_j.to_bits());
            assert_eq!(r.cost.to_bits(), d.cost.to_bits());
            // the fused hit path matches the unfused accessors bitwise
            let cell = table.realize_cell(d.cut, d.freq_hz, d.cost, rates);
            let ft = table.freq_terms(d.freq_hz);
            assert_eq!(cell.decision.delay_s.to_bits(), d.delay_s.to_bits());
            assert_eq!(cell.decision.energy_j.to_bits(), d.energy_j.to_bits());
            assert_eq!(
                cell.device_compute_s.to_bits(),
                table.device_compute_round(d.cut).to_bits()
            );
            assert_eq!(
                cell.server_compute_s.to_bits(),
                table.server_compute_round(d.cut, &ft).to_bits()
            );
            assert_eq!(
                cell.transmission_s.to_bits(),
                table.transmission(d.cut, rates).to_bits()
            );
        }
    }

    #[test]
    fn cache_roundtrip_and_counters() {
        let cache = DecisionCache::new(3);
        let key = DecisionCache::key(12.0, -8.0);
        assert!(cache.lookup(1, key).is_none());
        cache.store(1, key, 32, 2.46e9, 0.125);
        let (c, f, u) = cache.lookup(1, key).unwrap();
        assert_eq!(c, 32);
        assert_eq!(f.to_bits(), 2.46e9f64.to_bits());
        assert_eq!(u.to_bits(), 0.125f64.to_bits());
        // same key, different device: independent slot
        assert!(cache.lookup(2, key).is_none());
        let (h, m) = cache.stats();
        assert_eq!((h, m), (1, 2));
        assert!((cache.hit_rate() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn cache_key_tracks_cqi_buckets() {
        // same CQI bucket -> same key; different bucket -> different key
        assert_eq!(DecisionCache::key(6.0, 12.0), DecisionCache::key(7.8, 13.9));
        assert_ne!(DecisionCache::key(6.0, 12.0), DecisionCache::key(9.0, 12.0));
        // outage maps to its own bucket
        assert_eq!(DecisionCache::key(-30.0, -30.0), 0);
        assert!(DecisionCache::key(50.0, 50.0) < 256);
    }

    #[test]
    fn concurrent_fills_converge() {
        let cache = std::sync::Arc::new(DecisionCache::new(1));
        std::thread::scope(|scope| {
            for _ in 0..8 {
                let cache = cache.clone();
                scope.spawn(move || {
                    for _ in 0..1000 {
                        match cache.lookup(0, 7) {
                            Some((c, f, u)) => {
                                assert_eq!(c, 5);
                                assert_eq!(f.to_bits(), 1.5e9f64.to_bits());
                                assert_eq!(u.to_bits(), 0.25f64.to_bits());
                            }
                            None => cache.store(0, 7, 5, 1.5e9, 0.25),
                        }
                    }
                });
            }
        });
        assert_eq!(cache.lookup(0, 7).unwrap().0, 5);
    }
}
