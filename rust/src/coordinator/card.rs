//! The CARD algorithm — Cut lAyer and computing Resource Decision
//! (Alg. 1, Eqs. 14–16).
//!
//! Problem P2 (per device, per round) decomposes into:
//!  * **upper layer** (P3): optimal server GPU frequency.  U(f) is
//!    convex in f (delay ∝ 1/f, energy ∝ f²), so the stationary point
//!    is the closed form of Eq. (16):
//!
//!    ```text
//!    f* = clamp( Q,  F^{m,S}_min, F^S_max ),   Q = ∛( w·ΔE / (2ξ(1−w)·ΔD) )
//!    ```
//!    — see `optimal_frequency`.
//!
//!    Note Q is independent of the cut layer c (both the delay and
//!    energy f-terms scale with the same η−η_D(c) factor), which is why
//!    Alg. 1 computes f* ONCE before the cut scan.
//!  * **lower layer** (P4): U(c) is non-convex in the general case, and
//!    c ranges over {0..I} — brute-force scan, O(I) total.

use crate::config::{DeviceSpec, ServerSpec};
use crate::model::LinkRates;

use super::cost::{Bounds, CostModel};
use super::kernel::CutTable;

/// A CARD (or baseline) decision for one device-round.
#[derive(Clone, Copy, Debug)]
pub struct Decision {
    /// c* — selected cut layer ∈ {0..I}
    pub cut: usize,
    /// (f^S)* — selected server GPU frequency [Hz]
    pub freq_hz: f64,
    /// U(c*, f*) under this round's bounds
    pub cost: f64,
    /// realized round delay D [s] (Eq. 10)
    pub delay_s: f64,
    /// realized server energy E [J] (Eq. 11)
    pub energy_j: f64,
}

pub struct Card<'a> {
    pub cost_model: &'a CostModel,
    pub server: &'a ServerSpec,
}

impl<'a> Card<'a> {
    pub fn new(cost_model: &'a CostModel, server: &'a ServerSpec) -> Self {
        Self { cost_model, server }
    }

    /// Eq. (16): closed-form optimal server frequency, clamped to
    /// [F^{m,S}_min, F^S_max].
    ///
    /// Derivation (DESIGN.md §6): with D(f) = T·η_S/(f·δσ) + const and
    /// E(f) = T·ξ·f²·η_S/(δσ),
    ///   dU/df = 0  ⇒  f³ = w·ΔE / (2ξ(1−w)·ΔD)
    /// — the η_S/(δσ) factors cancel between the two terms.
    pub fn optimal_frequency(&self, dev: &DeviceSpec, b: &Bounds) -> f64 {
        let w = self.cost_model.w;
        let xi = self.server.xi;
        let f_min = dev.server_freq_floor(self.server);
        let f_max = self.server.max_freq_hz;
        if w >= 1.0 {
            return f_max; // pure delay objective
        }
        if w <= 0.0 {
            return f_min; // pure energy objective
        }
        let q = (w * b.energy_span() / (2.0 * xi * (1.0 - w) * b.delay_span())).cbrt();
        q.clamp(f_min, f_max)
    }

    /// Alg. 1: f* via Eq. (16), then the cut scan — routed through a
    /// one-shot [`CutTable`] (fleet callers hold persistent tables and
    /// use the kernel directly; see `Scheduler`).  Bit-identical to
    /// [`Card::decide_ref`].
    pub fn decide(&self, dev: &DeviceSpec, rates: LinkRates) -> Decision {
        let table = CutTable::for_device(self.cost_model, self.server, dev);
        let b = table.bounds(rates);
        table.scan(table.optimal_frequency(&b), rates, &b)
    }

    /// The pre-kernel reference scan: f* via Eq. (16), then O(I) cost
    /// calls that each re-derive the FLOP/size model terms.  Kept as
    /// the bit-compat oracle for `rust/tests/decision_kernel.rs` and
    /// the `card-bench` legacy baseline — new callers want `decide`.
    pub fn decide_ref(&self, dev: &DeviceSpec, rates: LinkRates) -> Decision {
        let cm = self.cost_model;
        let b = cm.bounds(dev, self.server, rates);
        let f_star = self.optimal_frequency(dev, &b);

        let mut best = Decision {
            cut: 0,
            freq_hz: f_star,
            cost: f64::INFINITY,
            delay_s: 0.0,
            energy_j: 0.0,
        };
        for c in 0..=cm.n_layers() {
            let u = cm.cost(c, f_star, dev, self.server, rates, &b);
            if u < best.cost {
                let (d, e) = cm.delay_energy(c, f_star, dev, self.server, rates);
                best = Decision {
                    cut: c,
                    freq_hz: f_star,
                    cost: u,
                    delay_s: d,
                    energy_j: e,
                };
            }
        }
        best
    }

    /// Exhaustive 2-D reference search (cut × dense frequency grid) —
    /// the oracle the tests hold `decide` against.
    pub fn decide_bruteforce_2d(&self, dev: &DeviceSpec, rates: LinkRates, grid: usize) -> Decision {
        let cm = self.cost_model;
        let b = cm.bounds(dev, self.server, rates);
        let f_min = dev.server_freq_floor(self.server);
        let f_max = self.server.max_freq_hz;
        let mut best = Decision {
            cut: 0,
            freq_hz: f_min,
            cost: f64::INFINITY,
            delay_s: 0.0,
            energy_j: 0.0,
        };
        for c in 0..=cm.n_layers() {
            for k in 0..=grid {
                let f = f_min + (f_max - f_min) * k as f64 / grid as f64;
                let u = cm.cost(c, f, dev, self.server, rates, &b);
                if u < best.cost {
                    let (d, e) = cm.delay_energy(c, f, dev, self.server, rates);
                    best = Decision {
                        cut: c,
                        freq_hz: f,
                        cost: u,
                        delay_s: d,
                        energy_j: e,
                    };
                }
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExpConfig;
    use crate::coordinator::cost::CostModel;
    use crate::model::{DataSizeModel, DelayModel, EnergyModel, FlopModel, LlmArch};

    fn setup(w: f64) -> (CostModel, ExpConfig) {
        let mut cfg = ExpConfig::paper();
        cfg.card.w = w;
        let arch = LlmArch::llama1b();
        let fl = FlopModel::new(&arch, &cfg.workload);
        let cm = CostModel::new(
            DelayModel::new(
                fl.clone(),
                DataSizeModel::new(&arch, &cfg.workload),
                &cfg.workload,
            ),
            EnergyModel::new(fl, cfg.workload.local_epochs),
            w,
        );
        (cm, cfg)
    }

    const RATES: LinkRates = LinkRates {
        up_bps: 300e6,
        down_bps: 500e6,
    };

    #[test]
    fn frequency_matches_numeric_optimum() {
        // Closed form (Eq. 16) vs golden-section search on U(f) at fixed c.
        let (cm, cfg) = setup(0.2);
        let card = Card::new(&cm, &cfg.server);
        for dev in &cfg.devices {
            let b = cm.bounds(dev, &cfg.server, RATES);
            let f_star = card.optimal_frequency(dev, &b);
            // golden-section on [f_min, f_max]
            let (mut lo, mut hi) = (
                dev.server_freq_floor(&cfg.server),
                cfg.server.max_freq_hz,
            );
            let g = 0.618_033_988_75;
            let u = |f: f64| cm.cost(8, f, dev, &cfg.server, RATES, &b);
            for _ in 0..200 {
                let a = hi - g * (hi - lo);
                let c2 = lo + g * (hi - lo);
                if u(a) < u(c2) {
                    hi = c2;
                } else {
                    lo = a;
                }
            }
            let f_num = 0.5 * (lo + hi);
            assert!(
                (f_star - f_num).abs() / f_num < 1e-4,
                "{}: closed {f_star:.4e} vs numeric {f_num:.4e}",
                dev.name
            );
        }
    }

    #[test]
    fn card_matches_2d_bruteforce() {
        for w in [0.05, 0.2, 0.5, 0.8] {
            let (cm, cfg) = setup(w);
            let card = Card::new(&cm, &cfg.server);
            for dev in &cfg.devices {
                let fast = card.decide(dev, RATES);
                let brute = card.decide_bruteforce_2d(dev, RATES, 400);
                assert_eq!(fast.cut, brute.cut, "{} w={w}", dev.name);
                // closed-form f* is at least as good as the finite grid,
                // and within grid resolution of it
                assert!(
                    fast.cost <= brute.cost + 1e-9,
                    "{} w={w}: CARD {} worse than grid {}",
                    dev.name,
                    fast.cost,
                    brute.cost
                );
                assert!(
                    brute.cost - fast.cost < 1e-4,
                    "{} w={w}: grid {} too far from CARD {}",
                    dev.name,
                    brute.cost,
                    fast.cost
                );
            }
        }
    }

    #[test]
    fn decision_within_constraints() {
        let (cm, cfg) = setup(0.2);
        let card = Card::new(&cm, &cfg.server);
        for dev in &cfg.devices {
            let d = card.decide(dev, RATES);
            assert!(d.cut <= cm.n_layers());
            assert!(d.freq_hz >= dev.server_freq_floor(&cfg.server) - 1.0);
            assert!(d.freq_hz <= cfg.server.max_freq_hz + 1.0);
            assert!(d.cost.is_finite() && d.delay_s > 0.0 && d.energy_j >= 0.0);
        }
    }

    #[test]
    fn paper_endpoint_structure() {
        // Fig. 3(a): "its optimal cut is either 32 or 0" — uniform layers
        // make U(c) monotone, so the scan lands at an endpoint.
        let (cm, cfg) = setup(0.2);
        let card = Card::new(&cm, &cfg.server);
        let i = cm.n_layers();
        for dev in &cfg.devices {
            let d = card.decide(dev, RATES);
            assert!(
                d.cut == 0 || d.cut == i,
                "{}: interior cut {} (paper predicts endpoints)",
                dev.name,
                d.cut
            );
        }
    }

    #[test]
    fn strong_devices_cut_high_weak_cut_low() {
        // Fig. 3(a): as device capability decreases the optimal cut moves
        // from 32 to 0.
        let (cm, cfg) = setup(0.2);
        let card = Card::new(&cm, &cfg.server);
        let cuts: Vec<usize> = cfg
            .devices
            .iter()
            .map(|d| card.decide(d, RATES).cut)
            .collect();
        assert_eq!(cuts[0], cm.n_layers(), "Device 1 should keep layers local");
        assert_eq!(cuts[4], 0, "Device 5 should offload everything");
        // monotone non-increasing across Table I's capability ordering
        for w in cuts.windows(2) {
            assert!(w[0] >= w[1], "cuts not monotone: {cuts:?}");
        }
    }

    #[test]
    fn weight_extremes() {
        // w→1: minimize delay only — strongest server frequency.
        let (cm, cfg) = setup(1.0);
        let card = Card::new(&cm, &cfg.server);
        let d = card.decide(&cfg.devices[2], RATES);
        assert!((d.freq_hz - cfg.server.max_freq_hz).abs() < 1.0);
        // w→0: minimize energy only — frequency floor and full offloadING
        // avoided (energy minimal at c=I).
        let (cm0, cfg0) = setup(0.0);
        let card0 = Card::new(&cm0, &cfg0.server);
        let d0 = card0.decide(&cfg0.devices[2], RATES);
        assert!((d0.freq_hz - cfg0.devices[2].server_freq_floor(&cfg0.server)).abs() < 1.0);
        assert_eq!(d0.cut, cm0.n_layers());
    }

    #[test]
    fn kernel_decide_bitwise_matches_reference_scan() {
        for w in [0.0, 0.2, 0.7, 1.0] {
            let (cm, cfg) = setup(w);
            let card = Card::new(&cm, &cfg.server);
            for dev in &cfg.devices {
                let a = card.decide(dev, RATES);
                let b = card.decide_ref(dev, RATES);
                assert_eq!(a.cut, b.cut, "{} w={w}", dev.name);
                assert_eq!(a.freq_hz.to_bits(), b.freq_hz.to_bits());
                assert_eq!(a.cost.to_bits(), b.cost.to_bits());
                assert_eq!(a.delay_s.to_bits(), b.delay_s.to_bits());
                assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
            }
        }
    }

    #[test]
    fn q_independent_of_cut() {
        // The Eq. 16 stationary point must not depend on c: verify the
        // numeric optimum at two different cuts coincides.
        let (cm, cfg) = setup(0.3);
        let dev = &cfg.devices[1];
        let b = cm.bounds(dev, &cfg.server, RATES);
        let opt_at = |c: usize| {
            let mut best = (f64::INFINITY, 0.0);
            for k in 0..=2000 {
                let f = dev.server_freq_floor(&cfg.server)
                    + (cfg.server.max_freq_hz - dev.server_freq_floor(&cfg.server)) * k as f64
                        / 2000.0;
                let u = cm.cost(c, f, dev, &cfg.server, RATES, &b);
                if u < best.0 {
                    best = (u, f);
                }
            }
            best.1
        };
        let f8 = opt_at(8);
        let f24 = opt_at(24);
        assert!((f8 - f24).abs() / f8 < 5e-3, "{f8:.4e} vs {f24:.4e}");
    }
}
