//! The CARD cost function — Eq. (12) — and its normalization bounds.
//!
//!   U(f, c) = w·(D − D_min)/(D_max − D_min)
//!           + (1−w)·(E − E_min)/(E_max − E_min)
//!
//! Bounds follow the paper exactly (§III-C): (D_max, E_min) at
//! (c = I, f = F^{m,S}_min); (D_min, E_max) at (c = 0, f = F^S_max).
//! They are per-device, per-round quantities because they depend on the
//! realized link rates.

use crate::config::{DeviceSpec, ServerSpec};
use crate::model::{DelayModel, EnergyModel, LinkRates};

/// Per-round normalization bounds for one device.
#[derive(Clone, Copy, Debug)]
pub struct Bounds {
    pub d_min: f64,
    pub d_max: f64,
    pub e_min: f64,
    pub e_max: f64,
}

impl Bounds {
    pub fn delay_span(&self) -> f64 {
        (self.d_max - self.d_min).max(f64::MIN_POSITIVE)
    }

    pub fn energy_span(&self) -> f64 {
        (self.e_max - self.e_min).max(f64::MIN_POSITIVE)
    }
}

/// Cost-model bundle shared by CARD and every baseline strategy.
#[derive(Clone, Debug)]
pub struct CostModel {
    pub delay: DelayModel,
    pub energy: EnergyModel,
    /// w — Eq. (12) weighting
    pub w: f64,
}

impl CostModel {
    pub fn new(delay: DelayModel, energy: EnergyModel, w: f64) -> Self {
        Self { delay, energy, w }
    }

    pub fn n_layers(&self) -> usize {
        self.delay.flops.arch.n_layers
    }

    /// Paper's normalization corners (§III-C).
    pub fn bounds(&self, dev: &DeviceSpec, server: &ServerSpec, rates: LinkRates) -> Bounds {
        let i = self.n_layers();
        let f_min = dev.server_freq_floor(server);
        let f_max = server.max_freq_hz;
        Bounds {
            d_max: self.delay.round(i, dev, server, f_min, rates),
            e_min: self.energy.round(i, server, f_min),
            d_min: self.delay.round(0, dev, server, f_max, rates),
            e_max: self.energy.round(0, server, f_max),
        }
    }

    /// Eq. (12) for a concrete (c, f) under the given bounds.
    pub fn cost(
        &self,
        c: usize,
        f_hz: f64,
        dev: &DeviceSpec,
        server: &ServerSpec,
        rates: LinkRates,
        b: &Bounds,
    ) -> f64 {
        let d = self.delay.round(c, dev, server, f_hz, rates);
        let e = self.energy.round(c, server, f_hz);
        self.w * (d - b.d_min) / b.delay_span() + (1.0 - self.w) * (e - b.e_min) / b.energy_span()
    }

    /// (delay, energy) for a decision — used by the figure harnesses.
    pub fn delay_energy(
        &self,
        c: usize,
        f_hz: f64,
        dev: &DeviceSpec,
        server: &ServerSpec,
        rates: LinkRates,
    ) -> (f64, f64) {
        (
            self.delay.round(c, dev, server, f_hz, rates),
            self.energy.round(c, server, f_hz),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExpConfig;
    use crate::model::{DataSizeModel, FlopModel, LlmArch};

    pub fn paper_cost_model() -> (CostModel, ExpConfig) {
        let cfg = ExpConfig::paper();
        let arch = LlmArch::llama1b();
        let fl = FlopModel::new(&arch, &cfg.workload);
        let cm = CostModel::new(
            DelayModel::new(
                fl.clone(),
                DataSizeModel::new(&arch, &cfg.workload),
                &cfg.workload,
            ),
            EnergyModel::new(fl, cfg.workload.local_epochs),
            cfg.card.w,
        );
        (cm, cfg)
    }

    const RATES: LinkRates = LinkRates {
        up_bps: 200e6,
        down_bps: 400e6,
    };

    #[test]
    fn bounds_are_ordered() {
        let (cm, cfg) = paper_cost_model();
        for dev in &cfg.devices {
            let b = cm.bounds(dev, &cfg.server, RATES);
            assert!(b.d_max > b.d_min, "{}: {b:?}", dev.name);
            assert!(b.e_max > b.e_min, "{}: {b:?}", dev.name);
        }
    }

    #[test]
    fn cost_at_corners() {
        let (cm, cfg) = paper_cost_model();
        let dev = &cfg.devices[1];
        let b = cm.bounds(dev, &cfg.server, RATES);
        let i = cm.n_layers();
        // corner (0, F_max): delay term 0, energy term 1 -> U = 1-w
        let u1 = cm.cost(0, cfg.server.max_freq_hz, dev, &cfg.server, RATES, &b);
        assert!((u1 - (1.0 - cm.w)).abs() < 1e-9, "u1={u1}");
        // corner (I, F_min): delay term 1, energy term 0 -> U = w
        let u2 = cm.cost(
            i,
            dev.server_freq_floor(&cfg.server),
            dev,
            &cfg.server,
            RATES,
            &b,
        );
        assert!((u2 - cm.w).abs() < 1e-9, "u2={u2}");
    }

    #[test]
    fn weight_extremes_select_single_objective() {
        let (mut cm, cfg) = paper_cost_model();
        let dev = &cfg.devices[0];
        let b = cm.bounds(dev, &cfg.server, RATES);
        cm.w = 1.0; // delay only
        let fast = cm.cost(0, cfg.server.max_freq_hz, dev, &cfg.server, RATES, &b);
        let slow = cm.cost(
            cm.n_layers(),
            dev.server_freq_floor(&cfg.server),
            dev,
            &cfg.server,
            RATES,
            &b,
        );
        assert!(fast < slow);
        cm.w = 0.0; // energy only: same corners flip
        let fast = cm.cost(0, cfg.server.max_freq_hz, dev, &cfg.server, RATES, &b);
        let slow = cm.cost(
            cm.n_layers(),
            dev.server_freq_floor(&cfg.server),
            dev,
            &cfg.server,
            RATES,
            &b,
        );
        assert!(slow < fast);
    }
}
