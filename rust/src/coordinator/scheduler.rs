//! The split-learning round scheduler — Stages 1–5 of the proposed
//! framework (§II-B).
//!
//! Per training round n, for the selected device m:
//!   Stage 1  LLM splitting           — strategy decides (c, f*)
//!   Stage 2  adapter distribution    — A(c) bytes downlink
//!   Stage 3  forward propagation     — device FP, smashed uplink, server FP
//!   Stage 4  backward propagation    — server BP, gradient downlink, device BP
//!   Stage 5  adapter upload + merge  — A(c) bytes uplink, Eq. (6)
//!
//! The scheduler is backend-agnostic: delay/energy always come from the
//! analytic models (Eqs. 7–11) driven by the realized channel, while an
//! optional `TrainBackend` (the PJRT split executor) runs the *real*
//! LoRA fine-tuning for the same (device, cut, epochs) and reports loss.

use crate::config::{ChannelState, ExpConfig};
use crate::model::{DataSizeModel, DelayModel, EnergyModel, FlopModel, LlmArch};
use crate::net::Channel;
use crate::util::rng::Rng;

use super::baselines::Strategy;
use super::cost::CostModel;

/// Real-compute hook (implemented by `runtime::SplitExecutor`).
pub trait TrainBackend {
    /// Run `epochs` local epochs of split fine-tuning with the given cut
    /// for device `device_idx`; returns the mean training loss.
    fn train_round(
        &mut self,
        device_idx: usize,
        cut: usize,
        epochs: usize,
    ) -> anyhow::Result<BackendStats>;
}

#[derive(Clone, Copy, Debug)]
pub struct BackendStats {
    pub mean_loss: f64,
    pub wallclock_s: f64,
}

/// Placeholder backend type for analytic-only runs (never invoked).
pub enum NullBackend {}

impl TrainBackend for NullBackend {
    fn train_round(&mut self, _: usize, _: usize, _: usize) -> anyhow::Result<BackendStats> {
        match *self {}
    }
}

/// Everything measured for one (round, device) execution.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    pub device_idx: usize,
    pub device_name: String,
    pub strategy: String,
    // Stage 1 decision
    pub cut: usize,
    pub freq_hz: f64,
    pub cost: f64,
    // realized channel
    pub snr_up_db: f64,
    pub snr_down_db: f64,
    pub rate_up_bps: f64,
    pub rate_down_bps: f64,
    // Eq. (10) decomposition
    pub delay_s: f64,
    pub device_compute_s: f64,
    pub server_compute_s: f64,
    pub transmission_s: f64,
    // Eq. (11)
    pub energy_j: f64,
    // Stage 2+5 payloads
    pub adapter_bytes: f64,
    pub smashed_bytes_round: f64,
    // real-compute results (when a backend is attached)
    pub loss: Option<f64>,
    pub backend_wallclock_s: Option<f64>,
}

/// Builds the model stack (FLOPs/sizes/delay/energy/cost) for a config.
pub fn build_cost_model(cfg: &ExpConfig) -> CostModel {
    let arch = LlmArch::by_name(&cfg.workload.arch)
        .unwrap_or_else(|| panic!("unknown arch '{}'", cfg.workload.arch));
    let fl = FlopModel::new(&arch, &cfg.workload);
    CostModel::new(
        DelayModel::new(
            fl.clone(),
            DataSizeModel::new(&arch, &cfg.workload),
            &cfg.workload,
        ),
        EnergyModel::new(fl, cfg.workload.local_epochs),
        cfg.card.w,
    )
}

pub struct Scheduler {
    pub cfg: ExpConfig,
    pub cost_model: CostModel,
    pub channel: Channel,
    pub strategy: Strategy,
    rng: Rng,
}

impl Scheduler {
    pub fn new(cfg: ExpConfig, state: ChannelState, strategy: Strategy) -> Self {
        let cost_model = build_cost_model(&cfg);
        let channel = Channel::new(cfg.channel.clone(), state);
        let rng = Rng::new(cfg.seed ^ (state.pathloss_exp() as u64) << 32);
        Self {
            cfg,
            cost_model,
            channel,
            strategy,
            rng,
        }
    }

    /// Run one training round: every participating device executes
    /// Stages 1–5 (the paper iterates devices within a round).
    pub fn run_round<B: TrainBackend + ?Sized>(
        &mut self,
        round: usize,
        mut backend: Option<&mut B>,
    ) -> anyhow::Result<Vec<RoundRecord>> {
        let mut records = Vec::with_capacity(self.cfg.devices.len());
        for idx in 0..self.cfg.devices.len() {
            let dev = self.cfg.devices[idx].clone();
            // block-fading realization for this (device, round)
            let mut link_rng = self.rng.fork((round as u64) << 16 | idx as u64);
            let link = self.channel.realize(&dev, &mut link_rng);

            // Stage 1: decision
            let decision = self.strategy.decide(
                &self.cost_model,
                &self.cfg.server,
                &dev,
                link.rates,
                &mut self.rng,
            );

            // Stages 2–5: analytic accounting (Eqs. 7–11)
            let dm = &self.cost_model.delay;
            let t = self.cfg.workload.local_epochs as f64;
            let device_compute_s = t * dm.device_compute(decision.cut, &dev);
            let server_compute_s =
                t * dm.server_compute(decision.cut, &self.cfg.server, decision.freq_hz);
            let transmission_s = dm.transmission(decision.cut, link.rates);

            // real compute, if a backend is attached
            let (loss, wallclock) = match backend.as_mut() {
                Some(b) => {
                    let stats =
                        b.train_round(idx, decision.cut, self.cfg.workload.local_epochs)?;
                    (Some(stats.mean_loss), Some(stats.wallclock_s))
                }
                None => (None, None),
            };

            records.push(RoundRecord {
                round,
                device_idx: idx,
                device_name: dev.name.clone(),
                strategy: self.strategy.name(),
                cut: decision.cut,
                freq_hz: decision.freq_hz,
                cost: decision.cost,
                snr_up_db: link.snr_up_db,
                snr_down_db: link.snr_down_db,
                rate_up_bps: link.rates.up_bps,
                rate_down_bps: link.rates.down_bps,
                delay_s: decision.delay_s,
                device_compute_s,
                server_compute_s,
                transmission_s,
                energy_j: decision.energy_j,
                adapter_bytes: dm.sizes.adapter_bytes(decision.cut),
                smashed_bytes_round: t
                    * (dm.sizes.smashed_wire_bytes(decision.cut)
                        + dm.sizes.grad_wire_bytes(decision.cut)),
                loss,
                backend_wallclock_s: wallclock,
            });
        }
        Ok(records)
    }

    /// Analytic-only round (no real compute).
    pub fn run_round_analytic(&mut self, round: usize) -> anyhow::Result<Vec<RoundRecord>> {
        self.run_round::<NullBackend>(round, None)
    }

    /// Analytic-only full run.
    pub fn run_analytic(&mut self) -> anyhow::Result<Vec<RoundRecord>> {
        self.run::<NullBackend>(None)
    }

    /// Run all configured rounds.
    pub fn run<B: TrainBackend + ?Sized>(
        &mut self,
        mut backend: Option<&mut B>,
    ) -> anyhow::Result<Vec<RoundRecord>> {
        let mut all = Vec::new();
        for n in 0..self.cfg.workload.rounds {
            all.extend(self.run_round(n, backend.as_deref_mut())?);
        }
        Ok(all)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChannelState;

    fn quick_cfg() -> ExpConfig {
        let mut cfg = ExpConfig::paper();
        cfg.workload.rounds = 4;
        cfg
    }

    #[test]
    fn round_produces_record_per_device() {
        let mut s = Scheduler::new(quick_cfg(), ChannelState::Normal, Strategy::Card);
        let recs = s.run_round_analytic(0).unwrap();
        assert_eq!(recs.len(), 5);
        for r in &recs {
            assert!(r.delay_s > 0.0 && r.energy_j >= 0.0);
            assert!(r.rate_up_bps > 0.0);
        }
    }

    #[test]
    fn delay_decomposition_consistent() {
        let mut s = Scheduler::new(quick_cfg(), ChannelState::Normal, Strategy::Card);
        for r in s.run_round_analytic(0).unwrap() {
            let sum = r.device_compute_s + r.server_compute_s + r.transmission_s;
            assert!(
                (sum - r.delay_s).abs() < r.delay_s * 1e-9,
                "{}: {} != {}",
                r.device_name,
                sum,
                r.delay_s
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mk = || {
            let mut s = Scheduler::new(quick_cfg(), ChannelState::Good, Strategy::Card);
            s.run_analytic().unwrap()
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.cut, y.cut);
            assert!((x.delay_s - y.delay_s).abs() < 1e-12);
        }
    }

    #[test]
    fn channel_dynamics_flip_decisions_somewhere() {
        // Fig. 3(a): cut decisions change across rounds under fading —
        // at least for one device in 20 rounds.
        let mut cfg = quick_cfg();
        cfg.workload.rounds = 20;
        let mut s = Scheduler::new(cfg, ChannelState::Poor, Strategy::Card);
        let recs = s.run_analytic().unwrap();
        let mut any_flip = false;
        for dev in 0..5 {
            let cuts: Vec<usize> = recs
                .iter()
                .filter(|r| r.device_idx == dev)
                .map(|r| r.cut)
                .collect();
            if cuts.windows(2).any(|w| w[0] != w[1]) {
                any_flip = true;
            }
        }
        assert!(any_flip, "no decision dynamics under Poor fading channel");
    }

    #[test]
    fn backend_hook_invoked() {
        struct Fake {
            calls: usize,
        }
        impl TrainBackend for Fake {
            fn train_round(
                &mut self,
                _d: usize,
                _c: usize,
                e: usize,
            ) -> anyhow::Result<BackendStats> {
                self.calls += 1;
                Ok(BackendStats {
                    mean_loss: 1.23,
                    wallclock_s: 0.01 * e as f64,
                })
            }
        }
        let mut fake = Fake { calls: 0 };
        let mut s = Scheduler::new(quick_cfg(), ChannelState::Normal, Strategy::Card);
        let recs = s.run_round(0, Some(&mut fake)).unwrap();
        assert_eq!(fake.calls, 5);
        assert!(recs.iter().all(|r| r.loss == Some(1.23)));
    }
}
