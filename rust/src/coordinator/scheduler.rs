//! The split-learning round scheduler — Stages 1–5 of the proposed
//! framework (§II-B) — generalized into a fleet-scale round engine.
//!
//! Per training round n, for each participating device m:
//!   Stage 1  LLM splitting           — strategy decides (c, f*)
//!   Stage 2  adapter distribution    — A(c) bytes downlink
//!   Stage 3  forward propagation     — device FP, smashed uplink, server FP
//!   Stage 4  backward propagation    — server BP, gradient downlink, device BP
//!   Stage 5  adapter upload + merge  — A(c) bytes uplink, Eq. (6)
//!
//! The scheduler is backend-agnostic: delay/energy always come from the
//! analytic models (Eqs. 7–11) driven by the realized channel, while an
//! optional `TrainBackend` (the PJRT split executor) runs the *real*
//! LoRA fine-tuning for the same (device, cut, epochs) and reports loss.
//!
//! ## Parallel fleet rounds
//!
//! Every `(round, device)` cell draws from its own RNG stream, derived
//! counter-style from `(seed, channel state, round, device)` via
//! `SplitMix64::stream_seed` — never from shared mutable generator
//! state.  [`Scheduler::device_round`] is therefore a pure function of
//! its arguments, and [`Scheduler::run_parallel`] can schedule K devices
//! concurrently on the `util::pool` worker pool while reproducing the
//! serial path **bit for bit** (asserted by `rust/tests/fleet_parallel.rs`
//! and the `fleet-sweep` CLI's determinism gate).
//!
//! The channel itself is a pluggable [`LinkProcess`] (DESIGN.md §13):
//! i.i.d. / Gauss–Markov / Jakes fading over static or mobile
//! placements.  Every process is counter-indexed — the realization of
//! a cell stays a pure function of `(config, seed, round, device)` —
//! so the purity contract above holds for all of them, and the
//! CQI-keyed decision cache stays exact (decisions depend on the link
//! only through the quantized rate pair).

use std::sync::{Arc, RwLock};

use crate::config::{ChannelState, ExpConfig};
use crate::model::{DataSizeModel, DelayModel, EnergyModel, FlopModel, LlmArch};
use crate::net::channel::LinkRealization;
use crate::net::{Channel, LinkProcess};
use crate::obs;
use crate::policy::{PolicyBank, PolicyBankSnap, PolicyObs, POLICY_SALT};
use crate::util::pool;
use crate::util::rng::{Rng, SplitMix64};

use super::baselines::{kernel_fixed_cut, ref_fixed_cut, Strategy};
use super::card::Decision;
use super::cost::CostModel;
use super::kernel::{CellEval, CutTable, DecisionCache, ModelTerms};

/// Real-compute hook (implemented by `runtime::SplitExecutor`).
pub trait TrainBackend {
    /// Run `epochs` local epochs of split fine-tuning with the given cut
    /// for device `device_idx`; returns the mean training loss.
    fn train_round(
        &mut self,
        device_idx: usize,
        cut: usize,
        epochs: usize,
    ) -> anyhow::Result<BackendStats>;
}

#[derive(Clone, Copy, Debug)]
pub struct BackendStats {
    pub mean_loss: f64,
    pub wallclock_s: f64,
}

/// Placeholder backend type for analytic-only runs (never invoked).
pub enum NullBackend {}

impl TrainBackend for NullBackend {
    fn train_round(&mut self, _: usize, _: usize, _: usize) -> anyhow::Result<BackendStats> {
        match *self {}
    }
}

/// Everything measured for one (round, device) execution.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    pub device_idx: usize,
    /// interned — one allocation per device, not per record
    pub device_name: Arc<str>,
    /// interned — one allocation per scheduler
    pub strategy: Arc<str>,
    // Stage 1 decision
    pub cut: usize,
    pub freq_hz: f64,
    pub cost: f64,
    // realized channel
    pub snr_up_db: f64,
    pub snr_down_db: f64,
    pub rate_up_bps: f64,
    pub rate_down_bps: f64,
    // Eq. (10) decomposition
    pub delay_s: f64,
    pub device_compute_s: f64,
    pub server_compute_s: f64,
    pub transmission_s: f64,
    // Eq. (11)
    pub energy_j: f64,
    // Stage 2+5 payloads
    pub adapter_bytes: f64,
    pub smashed_bytes_round: f64,
    // real-compute results (when a backend is attached)
    pub loss: Option<f64>,
    pub backend_wallclock_s: Option<f64>,
}

/// The numeric payload of one `(round, device)` cell — every
/// [`RoundRecord`] field except the interned names and the optional
/// backend results.  This is what the SoA batch path
/// (`coordinator::soa`) writes into columns; [`Scheduler::device_round`]
/// is exactly these values plus the name wrapping, so the two paths
/// share their arithmetic by construction.
#[derive(Clone, Copy, Debug)]
pub struct CellValues {
    pub round: usize,
    pub device_idx: usize,
    pub cut: usize,
    pub freq_hz: f64,
    pub cost: f64,
    pub snr_up_db: f64,
    pub snr_down_db: f64,
    pub rate_up_bps: f64,
    pub rate_down_bps: f64,
    pub delay_s: f64,
    pub device_compute_s: f64,
    pub server_compute_s: f64,
    pub transmission_s: f64,
    pub energy_j: f64,
    pub adapter_bytes: f64,
    pub smashed_bytes_round: f64,
}

/// Builds the model stack (FLOPs/sizes/delay/energy/cost) for a config.
pub fn build_cost_model(cfg: &ExpConfig) -> CostModel {
    let arch = LlmArch::by_name(&cfg.workload.arch)
        .unwrap_or_else(|| panic!("unknown arch '{}'", cfg.workload.arch));
    let fl = FlopModel::new(&arch, &cfg.workload);
    CostModel::new(
        DelayModel::new(
            fl.clone(),
            DataSizeModel::new(&arch, &cfg.workload),
            &cfg.workload,
        ),
        EnergyModel::new(fl, cfg.workload.local_epochs),
        cfg.card.w,
    )
}

/// The round scheduler.  Its `run*` family is the in-crate substrate of
/// the unified experiment API (`exp::Engine`, DESIGN.md §14): construct
/// experiments through `exp::ExperimentBuilder`; outside `exp/` only
/// the bit-compat property suites call `run*` directly.
pub struct Scheduler {
    pub cfg: ExpConfig,
    pub cost_model: CostModel,
    /// The link realization process: pathloss over the (possibly
    /// moving) placement + the configured fading process
    /// (DESIGN.md §13).  Keeps the placement-pure mean-SNR fast path
    /// whenever mobility is off.
    pub link: LinkProcess,
    pub strategy: Strategy,
    /// Root of the per-(round, device) RNG stream tree.
    stream_root: u64,
    /// Decision kernel: one precomputed cut table per device, sharing
    /// one `ModelTerms` (DESIGN.md §12).
    tables: Vec<CutTable>,
    /// CQI-keyed decision memo (bypassed by non-cacheable strategies).
    cache: DecisionCache,
    /// Interned device names (one `Arc` clone per record, no `String`);
    /// shared as a slab so `RoundBatch` can resolve names lazily.
    names: Arc<[Arc<str>]>,
    strategy_name: Arc<str>,
    /// Contextual-bandit state for the learned strategy family
    /// (DESIGN.md §19).  Frozen within a round: decisions take the read
    /// lock; the engines fold realized costs at round boundaries under
    /// the write lock.  `None` for every oracle strategy.
    policy: Option<RwLock<PolicyBank>>,
}

impl Scheduler {
    pub fn new(cfg: ExpConfig, state: ChannelState, strategy: Strategy) -> Self {
        let cost_model = build_cost_model(&cfg);
        let channel = Channel::new(cfg.channel.clone(), state);
        let stream_root = cfg.seed ^ ((state.pathloss_exp() as u64) << 32);
        let link = LinkProcess::new(channel, &cfg, stream_root);
        let terms = Arc::new(ModelTerms::new(&cost_model, &cfg.server));
        let tables = cfg.devices.iter().map(|d| CutTable::new(terms.clone(), d)).collect();
        // non-cacheable strategies never touch the cache — skip the
        // n_devices × 256-slot allocation entirely
        let cache_devices = if strategy.cacheable() {
            cfg.devices.len()
        } else {
            0
        };
        let cache = DecisionCache::new(cache_devices);
        let names: Arc<[Arc<str>]> =
            cfg.devices.iter().map(|d| Arc::from(d.name.as_str())).collect();
        let strategy_name: Arc<str> = Arc::from(strategy.name().as_str());
        let policy = strategy
            .policy_kind()
            .map(|k| RwLock::new(PolicyBank::new(k, &cfg.devices, cost_model.n_layers())));
        Self {
            cfg,
            cost_model,
            link,
            strategy,
            stream_root,
            tables,
            cache,
            names,
            strategy_name,
            policy,
        }
    }

    /// The per-device cut tables (read-only kernel view).
    pub fn tables(&self) -> &[CutTable] {
        &self.tables
    }

    /// Decision-cache `(hits, misses)` since construction.
    pub fn cache_stats(&self) -> (u64, u64) {
        self.cache.stats()
    }

    /// Decision-cache hit rate since construction.
    pub fn cache_hit_rate(&self) -> f64 {
        self.cache.hit_rate()
    }

    /// True when the strategy is a learned policy (bandit state attached).
    pub fn policy_enabled(&self) -> bool {
        self.policy.is_some()
    }

    /// Forget all bandit state.  Every `run*` entry point calls this
    /// first so repeated runs of one scheduler reproduce bit-identically;
    /// the DES engine calls it from its prologue.
    pub fn policy_reset(&self) {
        if let Some(bank) = &self.policy {
            bank.write().expect("policy bank lock poisoned").reset();
        }
    }

    /// Fold realized cells into the bandit state — the reward step.
    /// Engines call this exactly once per (round, cell), at a round
    /// boundary (or launch boundary on the async DES path), in device
    /// order; no-op for oracle strategies.
    pub fn policy_observe(&self, obs: &[PolicyObs]) {
        if let Some(bank) = &self.policy {
            let mut b = bank.write().expect("policy bank lock poisoned");
            for o in obs {
                b.observe(o);
            }
        }
    }

    /// [`Scheduler::policy_observe`] from full records (AoS paths).
    pub fn policy_observe_records(&self, records: &[RoundRecord]) {
        if self.policy.is_none() {
            return;
        }
        let obs: Vec<PolicyObs> = records
            .iter()
            .map(|r| PolicyObs {
                device_idx: r.device_idx,
                snr_up_db: r.snr_up_db,
                cut: r.cut,
                cost: r.cost,
            })
            .collect();
        self.policy_observe(&obs);
    }

    /// Checkpointable copy of the bandit state, if any.
    pub fn policy_snapshot(&self) -> Option<PolicyBankSnap> {
        self.policy
            .as_ref()
            .map(|b| b.read().expect("policy bank lock poisoned").snapshot())
    }

    /// Restore bandit state from a checkpoint.
    pub fn policy_restore(&self, snap: &PolicyBankSnap) -> anyhow::Result<()> {
        match &self.policy {
            Some(bank) => bank.write().expect("policy bank lock poisoned").restore(snap),
            None => anyhow::bail!(
                "checkpoint carries policy state but strategy '{}' has no policy bank",
                self.strategy_name
            ),
        }
    }

    /// `(explore, exploit)` decision tallies since the last reset.
    pub fn policy_counters(&self) -> Option<(u64, u64)> {
        self.policy
            .as_ref()
            .map(|b| b.read().expect("policy bank lock poisoned").counters())
    }

    /// Registry slot for the per-strategy decision-cache counters
    /// (order matches `obs::registry::STRATEGY_KEYS`).
    fn obs_slot(&self) -> usize {
        match self.strategy {
            Strategy::Card => 0,
            Strategy::ServerOnly => 1,
            Strategy::DeviceOnly => 2,
            Strategy::StaticCut(_) => 3,
            Strategy::RandomCut => 4,
            Strategy::EpsGreedy => 5,
            Strategy::Ucb1 => 6,
            Strategy::Thompson => 7,
        }
    }

    /// The RNG stream for one `(round, device)` cell — a pure function
    /// of the scheduler's seed/state and the cell coordinates.
    fn cell_rng(&self, round: usize, device_idx: usize) -> Rng {
        Rng::new(SplitMix64::stream_seed(
            self.stream_root,
            &[round as u64, device_idx as u64],
        ))
    }

    /// The exploration stream for one cell — a *separate* counter-based
    /// stream under [`POLICY_SALT`], so learned decisions never consume
    /// channel draws: a learned run realizes bit-identical links to the
    /// CARD run it is benchmarked against (DESIGN.md §19).
    fn policy_rng(&self, round: usize, device_idx: usize) -> Rng {
        Rng::new(SplitMix64::stream_seed(
            self.stream_root ^ POLICY_SALT,
            &[round as u64, device_idx as u64],
        ))
    }

    /// Stage-1 decision for the learned family: choose a cut from the
    /// frozen bandit statistics, then price it at CARD's optimal
    /// frequency through the kernel (bit-identical to `StaticCut(cut)`).
    fn decide_learned(
        &self,
        bank: &RwLock<PolicyBank>,
        table: &CutTable,
        round: usize,
        device_idx: usize,
        link: &LinkRealization,
    ) -> Decision {
        let mut rng = self.policy_rng(round, device_idx);
        let cut = bank
            .read()
            .expect("policy bank lock poisoned")
            .choose_cut(device_idx, link.snr_up_db, &mut rng);
        kernel_fixed_cut(table, cut, link.rates)
    }

    /// Link realization for one cell through the configured
    /// [`LinkProcess`] — under the default i.i.d. process with static
    /// placement, bit-identical to the pre-process `Channel::realize`.
    #[inline]
    fn realize_link(&self, round: usize, device_idx: usize, rng: &mut Rng) -> LinkRealization {
        self.link.realize(device_idx, round, rng)
    }

    /// Execute Stages 1–5 analytically for one `(round, device)` cell,
    /// through the decision kernel and (for cacheable strategies) the
    /// CQI-keyed decision cache.
    ///
    /// Pure with respect to the scheduler (`&self`): the block-fading
    /// realization and any stochastic decision (Random-cut) both draw
    /// from the cell's own stream, and cache hits replay exactly what
    /// the scan would compute (DESIGN.md §12), so cells can run in any
    /// order or in parallel and produce identical records.
    pub fn device_round(&self, round: usize, device_idx: usize) -> RoundRecord {
        self.record_from_values(self.cell_values(round, device_idx))
    }

    /// The numeric core of [`Scheduler::device_round`]: Stages 1–5 for
    /// one cell, without touching the interned names.  The SoA batch
    /// path (`coordinator::soa::RoundBatch`) writes these values
    /// straight into columns; `device_round` wraps the same values into
    /// a [`RoundRecord`], so both paths are bitwise identical by
    /// construction.
    pub fn cell_values(&self, round: usize, device_idx: usize) -> CellValues {
        let mut rng = self.cell_rng(round, device_idx);
        // phase timers are opt-in (obs::registry::set_timers_enabled);
        // counters/timers observe only — no RNG stream is touched
        let t_link = obs::registry::timer_start();
        let link = self.realize_link(round, device_idx, &mut rng);
        obs::registry::timer_record(&obs::metrics().sched_realize_link_s, t_link);
        let table = &self.tables[device_idx];

        // Stage 1 (learned family): bandit chooses the cut from frozen
        // round-boundary state — stateful, so the CQI cache (which
        // assumes decisions are pure in the link) must stay bypassed
        if let Some(bank) = &self.policy {
            let t_dec = obs::registry::timer_start();
            let d = self.decide_learned(bank, table, round, device_idx, &link);
            obs::registry::timer_record(&obs::metrics().sched_decide_s, t_dec);
            return self.cell_values_from_decision(round, device_idx, &link, d);
        }

        // Stage 1: decision — memoized per (device, CQI pair)
        if self.strategy.cacheable() {
            let key = DecisionCache::key(link.snr_up_db, link.snr_down_db);
            if let Some((cut, f_hz, cost)) = self.cache.lookup(device_idx, key) {
                obs::metrics().cache_hit[self.obs_slot()].inc(device_idx);
                // hit fast path: decision + record decomposition fused
                let cell = table.realize_cell(cut, f_hz, cost, link.rates);
                return self.values_from_cell(round, device_idx, &link, cell);
            }
            obs::metrics().cache_miss[self.obs_slot()].inc(device_idx);
            let t_dec = obs::registry::timer_start();
            let d = self.strategy.decide_on(table, link.rates, &mut rng);
            obs::registry::timer_record(&obs::metrics().sched_decide_s, t_dec);
            self.cache.store(device_idx, key, d.cut, d.freq_hz, d.cost);
            self.cell_values_from_decision(round, device_idx, &link, d)
        } else {
            let t_dec = obs::registry::timer_start();
            let d = self.strategy.decide_on(table, link.rates, &mut rng);
            obs::registry::timer_record(&obs::metrics().sched_decide_s, t_dec);
            self.cell_values_from_decision(round, device_idx, &link, d)
        }
    }

    /// The kernel scan with the cache bypassed — the uncached reference
    /// the cache property tests compare against.
    pub fn device_round_uncached(&self, round: usize, device_idx: usize) -> RoundRecord {
        let mut rng = self.cell_rng(round, device_idx);
        let link = self.realize_link(round, device_idx, &mut rng);
        let table = &self.tables[device_idx];
        let decision = match &self.policy {
            Some(bank) => self.decide_learned(bank, table, round, device_idx, &link),
            None => self.strategy.decide_on(table, link.rates, &mut rng),
        };
        self.record_from_values(self.cell_values_from_decision(round, device_idx, &link, decision))
    }

    /// Re-execute one cell with the cut pinned: the channel realization
    /// comes from the cell's own stream exactly as in
    /// [`Scheduler::cell_values`], but Stage 1 is replaced by pricing
    /// `cut` at CARD's optimal frequency.  For a learned strategy this
    /// is bit-identical to the decision path whenever `cut` is what the
    /// bandit chose — checkpoint restore uses it to rebuild records
    /// without replaying bandit state (DESIGN.md §19).
    pub fn device_round_forced(&self, round: usize, device_idx: usize, cut: usize) -> RoundRecord {
        let mut rng = self.cell_rng(round, device_idx);
        let link = self.realize_link(round, device_idx, &mut rng);
        let d = kernel_fixed_cut(&self.tables[device_idx], cut, link.rates);
        self.record_from_values(self.cell_values_from_decision(round, device_idx, &link, d))
    }

    /// The pre-kernel cell path — full model re-evaluation per cost
    /// call, no tables, no cache.  Retained as the bit-compat oracle
    /// (`rust/tests/decision_kernel.rs`) and `card-bench` baseline.
    pub fn device_round_ref(&self, round: usize, device_idx: usize) -> RoundRecord {
        let dev = &self.cfg.devices[device_idx];
        let mut rng = self.cell_rng(round, device_idx);
        let link = self.link.realize_ref(device_idx, round, &mut rng);
        let decision = match &self.policy {
            Some(bank) => {
                let mut prng = self.policy_rng(round, device_idx);
                let cut = bank
                    .read()
                    .expect("policy bank lock poisoned")
                    .choose_cut(device_idx, link.snr_up_db, &mut prng);
                ref_fixed_cut(&self.cost_model, &self.cfg.server, dev, link.rates, cut)
            }
            None => self
                .strategy
                .decide_ref(&self.cost_model, &self.cfg.server, dev, link.rates, &mut rng),
        };

        let dm = &self.cost_model.delay;
        let t = self.cfg.workload.local_epochs as f64;
        RoundRecord {
            round,
            device_idx,
            device_name: self.names[device_idx].clone(),
            strategy: self.strategy_name.clone(),
            cut: decision.cut,
            freq_hz: decision.freq_hz,
            cost: decision.cost,
            snr_up_db: link.snr_up_db,
            snr_down_db: link.snr_down_db,
            rate_up_bps: link.rates.up_bps,
            rate_down_bps: link.rates.down_bps,
            delay_s: decision.delay_s,
            device_compute_s: t * dm.device_compute(decision.cut, dev),
            server_compute_s: t
                * dm.server_compute(decision.cut, &self.cfg.server, decision.freq_hz),
            transmission_s: dm.transmission(decision.cut, link.rates),
            energy_j: decision.energy_j,
            adapter_bytes: dm.sizes.adapter_bytes(decision.cut),
            smashed_bytes_round: t
                * (dm.sizes.smashed_wire_bytes(decision.cut)
                    + dm.sizes.grad_wire_bytes(decision.cut)),
            loss: None,
            backend_wallclock_s: None,
        }
    }

    /// Build the numeric cell values from a fused [`CellEval`]
    /// (cache-hit fast path) — bit-identical to
    /// [`Scheduler::cell_values_from_decision`].
    fn values_from_cell(
        &self,
        round: usize,
        device_idx: usize,
        link: &LinkRealization,
        cell: CellEval,
    ) -> CellValues {
        let table = &self.tables[device_idx];
        let t = self.cfg.workload.local_epochs as f64;
        let d = cell.decision;
        CellValues {
            round,
            device_idx,
            cut: d.cut,
            freq_hz: d.freq_hz,
            cost: d.cost,
            snr_up_db: link.snr_up_db,
            snr_down_db: link.snr_down_db,
            rate_up_bps: link.rates.up_bps,
            rate_down_bps: link.rates.down_bps,
            delay_s: d.delay_s,
            device_compute_s: cell.device_compute_s,
            server_compute_s: cell.server_compute_s,
            transmission_s: cell.transmission_s,
            energy_j: d.energy_j,
            adapter_bytes: table.terms.adapter_bytes[d.cut],
            smashed_bytes_round: t * table.terms.wire_bytes_epoch[d.cut],
        }
    }

    /// Stages 2–5: analytic accounting (Eqs. 7–11) from kernel terms.
    fn cell_values_from_decision(
        &self,
        round: usize,
        device_idx: usize,
        link: &LinkRealization,
        decision: Decision,
    ) -> CellValues {
        let table = &self.tables[device_idx];
        let ft = table.freq_terms(decision.freq_hz);
        let t = self.cfg.workload.local_epochs as f64;
        let cut = decision.cut;
        CellValues {
            round,
            device_idx,
            cut,
            freq_hz: decision.freq_hz,
            cost: decision.cost,
            snr_up_db: link.snr_up_db,
            snr_down_db: link.snr_down_db,
            rate_up_bps: link.rates.up_bps,
            rate_down_bps: link.rates.down_bps,
            delay_s: decision.delay_s,
            device_compute_s: table.device_compute_round(cut),
            server_compute_s: table.server_compute_round(cut, &ft),
            transmission_s: table.transmission(cut, link.rates),
            energy_j: decision.energy_j,
            adapter_bytes: table.terms.adapter_bytes[cut],
            smashed_bytes_round: t * table.terms.wire_bytes_epoch[cut],
        }
    }

    /// Wrap numeric cell values into a full [`RoundRecord`] — the only
    /// place the AoS paths touch the interned names.
    fn record_from_values(&self, v: CellValues) -> RoundRecord {
        RoundRecord {
            round: v.round,
            device_idx: v.device_idx,
            device_name: self.names[v.device_idx].clone(),
            strategy: self.strategy_name.clone(),
            cut: v.cut,
            freq_hz: v.freq_hz,
            cost: v.cost,
            snr_up_db: v.snr_up_db,
            snr_down_db: v.snr_down_db,
            rate_up_bps: v.rate_up_bps,
            rate_down_bps: v.rate_down_bps,
            delay_s: v.delay_s,
            device_compute_s: v.device_compute_s,
            server_compute_s: v.server_compute_s,
            transmission_s: v.transmission_s,
            energy_j: v.energy_j,
            adapter_bytes: v.adapter_bytes,
            smashed_bytes_round: v.smashed_bytes_round,
            loss: None,
            backend_wallclock_s: None,
        }
    }

    /// The interned device-name slab (shared with `RoundBatch` for lazy
    /// name resolution).
    pub(crate) fn names(&self) -> &Arc<[Arc<str>]> {
        &self.names
    }

    /// The interned strategy name.
    pub(crate) fn strategy_name(&self) -> &Arc<str> {
        &self.strategy_name
    }

    /// Run one training round serially: every participating device
    /// executes Stages 1–5 (the paper iterates devices within a round).
    /// The optional backend runs the real split fine-tuning per device.
    pub fn run_round<B: TrainBackend + ?Sized>(
        &self,
        round: usize,
        mut backend: Option<&mut B>,
    ) -> anyhow::Result<Vec<RoundRecord>> {
        let mut records = Vec::with_capacity(self.cfg.devices.len());
        for idx in 0..self.cfg.devices.len() {
            let mut rec = self.device_round(round, idx);
            if let Some(b) = backend.as_mut() {
                let stats = b.train_round(idx, rec.cut, self.cfg.workload.local_epochs)?;
                rec.loss = Some(stats.mean_loss);
                rec.backend_wallclock_s = Some(stats.wallclock_s);
            }
            records.push(rec);
        }
        // round boundary: fold this round's realized costs into the
        // bandit state (no-op for oracle strategies)
        self.policy_observe_records(&records);
        Ok(records)
    }

    /// One analytic round with up to `threads` devices in flight —
    /// bit-identical to [`Scheduler::run_round_analytic`].
    pub fn run_round_parallel(&self, round: usize, threads: usize) -> Vec<RoundRecord> {
        let idxs: Vec<usize> = (0..self.cfg.devices.len()).collect();
        let records =
            pool::par_map_indexed(threads, &idxs, |_, &idx| self.device_round(round, idx));
        // fold in device order regardless of completion order — the
        // pool returns results in index order, so the bandit update is
        // thread-count independent
        self.policy_observe_records(&records);
        records
    }

    /// All configured rounds with up to `threads` device-round cells in
    /// flight — the fleet-scale engine.  Bit-identical to
    /// [`Scheduler::run_analytic`] for the same config/seed.
    ///
    /// Learned strategies force a barrier at every round boundary
    /// (decisions in round n need the costs of rounds < n), so only the
    /// devices within a round run concurrently; oracle strategies keep
    /// the fully-flattened cell schedule.
    pub fn run_parallel(&self, threads: usize) -> Vec<RoundRecord> {
        if self.policy_enabled() {
            self.policy_reset();
            let mut all =
                Vec::with_capacity(self.cfg.workload.rounds * self.cfg.devices.len());
            for n in 0..self.cfg.workload.rounds {
                all.extend(self.run_round_parallel(n, threads));
            }
            return all;
        }
        let cells: Vec<(usize, usize)> = (0..self.cfg.workload.rounds)
            .flat_map(|n| (0..self.cfg.devices.len()).map(move |i| (n, i)))
            .collect();
        pool::par_map_indexed(threads, &cells, |_, &(n, i)| self.device_round(n, i))
    }

    /// All configured rounds through the kernel scan with the decision
    /// cache bypassed — serial; the reference stream for the cache
    /// bit-compat property tests.
    pub fn run_uncached(&self) -> Vec<RoundRecord> {
        self.policy_reset();
        let mut all = Vec::with_capacity(self.cfg.workload.rounds * self.cfg.devices.len());
        for n in 0..self.cfg.workload.rounds {
            let start = all.len();
            for i in 0..self.cfg.devices.len() {
                all.push(self.device_round_uncached(n, i));
            }
            self.policy_observe_records(&all[start..]);
        }
        all
    }

    /// All configured rounds through the pre-kernel reference path —
    /// serial; the legacy oracle for the kernel bit-compat tests.
    pub fn run_ref(&self) -> Vec<RoundRecord> {
        self.policy_reset();
        let mut all = Vec::with_capacity(self.cfg.workload.rounds * self.cfg.devices.len());
        for n in 0..self.cfg.workload.rounds {
            let start = all.len();
            for i in 0..self.cfg.devices.len() {
                all.push(self.device_round_ref(n, i));
            }
            self.policy_observe_records(&all[start..]);
        }
        all
    }

    /// Analytic-only round (no real compute), serial reference path.
    pub fn run_round_analytic(&self, round: usize) -> anyhow::Result<Vec<RoundRecord>> {
        self.run_round::<NullBackend>(round, None)
    }

    /// Analytic-only full run, serial reference path.
    pub fn run_analytic(&self) -> anyhow::Result<Vec<RoundRecord>> {
        self.run::<NullBackend>(None)
    }

    /// Run all configured rounds serially (backend-capable path).
    pub fn run<B: TrainBackend + ?Sized>(
        &self,
        mut backend: Option<&mut B>,
    ) -> anyhow::Result<Vec<RoundRecord>> {
        self.policy_reset();
        let mut all = Vec::new();
        for n in 0..self.cfg.workload.rounds {
            all.extend(self.run_round(n, backend.as_deref_mut())?);
        }
        Ok(all)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChannelState;

    fn quick_cfg() -> ExpConfig {
        let mut cfg = ExpConfig::paper();
        cfg.workload.rounds = 4;
        cfg
    }

    fn assert_bit_identical(a: &[RoundRecord], b: &[RoundRecord]) {
        // single comparator crate-wide: the same gate both sweeps run
        if let Err(e) = crate::exp::verify::verify_bit_identical(a, b) {
            panic!("{e:#}");
        }
    }

    #[test]
    fn round_produces_record_per_device() {
        let s = Scheduler::new(quick_cfg(), ChannelState::Normal, Strategy::Card);
        let recs = s.run_round_analytic(0).unwrap();
        assert_eq!(recs.len(), 5);
        for r in &recs {
            assert!(r.delay_s > 0.0 && r.energy_j >= 0.0);
            assert!(r.rate_up_bps > 0.0);
        }
    }

    #[test]
    fn delay_decomposition_consistent() {
        let s = Scheduler::new(quick_cfg(), ChannelState::Normal, Strategy::Card);
        for r in s.run_round_analytic(0).unwrap() {
            let sum = r.device_compute_s + r.server_compute_s + r.transmission_s;
            assert!(
                (sum - r.delay_s).abs() < r.delay_s * 1e-9,
                "{}: {} != {}",
                r.device_name,
                sum,
                r.delay_s
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mk = || {
            let s = Scheduler::new(quick_cfg(), ChannelState::Good, Strategy::Card);
            s.run_analytic().unwrap()
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.cut, y.cut);
            assert!((x.delay_s - y.delay_s).abs() < 1e-12);
        }
    }

    #[test]
    fn device_round_is_pure_and_order_independent() {
        let s = Scheduler::new(quick_cfg(), ChannelState::Poor, Strategy::Card);
        // evaluating a cell twice, or after other cells, changes nothing
        let first = s.device_round(2, 3);
        let _noise = (s.device_round(0, 0), s.device_round(3, 4));
        let again = s.device_round(2, 3);
        assert_bit_identical(&[first], &[again]);
    }

    #[test]
    fn parallel_round_bit_identical_to_serial() {
        let s = Scheduler::new(quick_cfg(), ChannelState::Normal, Strategy::Card);
        let serial = s.run_round_analytic(1).unwrap();
        for threads in [1, 2, 8] {
            assert_bit_identical(&serial, &s.run_round_parallel(1, threads));
        }
    }

    #[test]
    fn full_parallel_run_bit_identical_to_serial() {
        for strategy in [
            Strategy::Card,
            Strategy::RandomCut,
            Strategy::StaticCut(16),
            Strategy::EpsGreedy,
            Strategy::Ucb1,
            Strategy::Thompson,
        ] {
            let s = Scheduler::new(quick_cfg(), ChannelState::Poor, strategy);
            let serial = s.run_analytic().unwrap();
            assert_bit_identical(&serial, &s.run_parallel(8));
        }
    }

    #[test]
    fn cached_engine_bitwise_matches_uncached_and_legacy() {
        for strategy in [
            Strategy::Card,
            Strategy::ServerOnly,
            Strategy::DeviceOnly,
            Strategy::StaticCut(16),
            Strategy::RandomCut,
            Strategy::EpsGreedy,
            Strategy::Ucb1,
            Strategy::Thompson,
        ] {
            let s = Scheduler::new(quick_cfg(), ChannelState::Poor, strategy);
            let cached = s.run_analytic().unwrap();
            assert_bit_identical(&cached, &s.run_uncached());
            assert_bit_identical(&cached, &s.run_ref());
        }
    }

    #[test]
    fn learned_runs_never_perturb_the_channel_stream() {
        // the policy stream is salted away from the cell stream, so a
        // learned run must realize the exact links the CARD run sees
        let card = Scheduler::new(quick_cfg(), ChannelState::Poor, Strategy::Card);
        let oracle = card.run_analytic().unwrap();
        for strategy in [Strategy::EpsGreedy, Strategy::Ucb1, Strategy::Thompson] {
            let s = Scheduler::new(quick_cfg(), ChannelState::Poor, strategy);
            let recs = s.run_analytic().unwrap();
            for (a, b) in oracle.iter().zip(&recs) {
                assert_eq!(a.snr_up_db.to_bits(), b.snr_up_db.to_bits());
                assert_eq!(a.snr_down_db.to_bits(), b.snr_down_db.to_bits());
                assert_eq!(a.rate_up_bps.to_bits(), b.rate_up_bps.to_bits());
                assert_eq!(a.rate_down_bps.to_bits(), b.rate_down_bps.to_bits());
            }
        }
    }

    #[test]
    fn learned_rerun_reproduces_after_reset() {
        let s = Scheduler::new(quick_cfg(), ChannelState::Normal, Strategy::Ucb1);
        let a = s.run_analytic().unwrap();
        let b = s.run_analytic().unwrap();
        assert_bit_identical(&a, &b);
        assert!(s.policy_counters().unwrap().0 > 0, "bandit never explored");
    }

    #[test]
    fn forced_cut_replays_the_learned_decision_path() {
        let s = Scheduler::new(quick_cfg(), ChannelState::Normal, Strategy::Thompson);
        let recs = s.run_analytic().unwrap();
        // re-running a cell with its chosen cut pinned reproduces the
        // record bit-for-bit without replaying any bandit state
        for r in recs.iter().take(10) {
            let forced = s.device_round_forced(r.round, r.device_idx, r.cut);
            assert_bit_identical(std::slice::from_ref(r), &[forced]);
        }
    }

    #[test]
    fn cache_hits_accumulate_for_card_but_not_random_cut() {
        let mut cfg = quick_cfg();
        cfg.workload.rounds = 30;
        let s = Scheduler::new(cfg.clone(), ChannelState::Normal, Strategy::Card);
        s.run_analytic().unwrap();
        let (hits, misses) = s.cache_stats();
        assert!(hits > 0, "30 rounds of fading must revisit a CQI pair");
        assert!(misses > 0);
        assert!(s.cache_hit_rate() > 0.0 && s.cache_hit_rate() < 1.0);
        // Random-cut bypasses the cache entirely
        let r = Scheduler::new(cfg, ChannelState::Normal, Strategy::RandomCut);
        r.run_analytic().unwrap();
        assert_eq!(r.cache_stats(), (0, 0));
    }

    #[test]
    fn correlated_and_mobile_engines_stay_bit_deterministic() {
        use crate::config::{FadingModel, MobilityModel};
        for model in FadingModel::ALL {
            for mobile in [false, true] {
                let mut cfg = quick_cfg();
                cfg.channel.process.model = model;
                if mobile {
                    cfg.mobility.model = MobilityModel::Waypoint;
                    cfg.mobility.speed_mps = 4.0;
                    cfg.mobility.round_s = 10.0;
                }
                cfg.validate().unwrap();
                for strategy in [Strategy::Card, Strategy::RandomCut] {
                    let s = Scheduler::new(cfg.clone(), ChannelState::Normal, strategy);
                    let serial = s.run_analytic().unwrap();
                    for threads in [1, 4, 8] {
                        assert_bit_identical(&serial, &s.run_parallel(threads));
                    }
                    // uncached and legacy reference paths agree too
                    assert_bit_identical(&serial, &s.run_uncached());
                    assert_bit_identical(&serial, &s.run_ref());
                }
            }
        }
    }

    #[test]
    fn markov_fading_hits_the_decision_cache_harder_than_iid() {
        use crate::config::FadingModel;
        let mut cfg = quick_cfg();
        cfg.workload.rounds = 20;
        let iid = Scheduler::new(cfg.clone(), ChannelState::Normal, Strategy::Card);
        iid.run_analytic().unwrap();
        cfg.channel.process.model = FadingModel::Markov;
        cfg.channel.process.rho = 0.95;
        let markov = Scheduler::new(cfg, ChannelState::Normal, Strategy::Card);
        markov.run_analytic().unwrap();
        assert!(
            markov.cache_hit_rate() > iid.cache_hit_rate(),
            "correlated fading revisits CQI keys: markov {} <= iid {}",
            markov.cache_hit_rate(),
            iid.cache_hit_rate()
        );
    }

    #[test]
    fn channel_dynamics_flip_decisions_somewhere() {
        // Fig. 3(a): cut decisions change across rounds under fading —
        // at least for one device in 20 rounds.
        let mut cfg = quick_cfg();
        cfg.workload.rounds = 20;
        let s = Scheduler::new(cfg, ChannelState::Poor, Strategy::Card);
        let recs = s.run_analytic().unwrap();
        let mut any_flip = false;
        for dev in 0..5 {
            let cuts: Vec<usize> = recs
                .iter()
                .filter(|r| r.device_idx == dev)
                .map(|r| r.cut)
                .collect();
            if cuts.windows(2).any(|w| w[0] != w[1]) {
                any_flip = true;
            }
        }
        assert!(any_flip, "no decision dynamics under Poor fading channel");
    }

    #[test]
    fn backend_hook_invoked() {
        struct Fake {
            calls: usize,
        }
        impl TrainBackend for Fake {
            fn train_round(
                &mut self,
                _d: usize,
                _c: usize,
                e: usize,
            ) -> anyhow::Result<BackendStats> {
                self.calls += 1;
                Ok(BackendStats {
                    mean_loss: 1.23,
                    wallclock_s: 0.01 * e as f64,
                })
            }
        }
        let mut fake = Fake { calls: 0 };
        let s = Scheduler::new(quick_cfg(), ChannelState::Normal, Strategy::Card);
        let recs = s.run_round(0, Some(&mut fake)).unwrap();
        assert_eq!(fake.calls, 5);
        assert!(recs.iter().all(|r| r.loss == Some(1.23)));
    }

    #[test]
    fn backend_sees_same_decisions_as_analytic_path() {
        // the backend rides along without perturbing any RNG stream
        struct Fake;
        impl TrainBackend for Fake {
            fn train_round(&mut self, _: usize, _: usize, _: usize) -> anyhow::Result<BackendStats> {
                Ok(BackendStats {
                    mean_loss: 0.0,
                    wallclock_s: 0.0,
                })
            }
        }
        let s = Scheduler::new(quick_cfg(), ChannelState::Poor, Strategy::Card);
        let analytic = s.run_round_analytic(0).unwrap();
        let backed = s.run_round(0, Some(&mut Fake)).unwrap();
        for (a, b) in analytic.iter().zip(&backed) {
            assert_eq!(a.cut, b.cut);
            assert_eq!(a.freq_hz.to_bits(), b.freq_hz.to_bits());
        }
    }
}
