//! Decision strategies: CARD plus the paper's two benchmarks (§V-B) and
//! extra ablation strategies.
//!
//! * **ServerOnly** — "devices fine-tune the embedding module locally,
//!   and the server handles the rest": c = 0, server at F_max (no
//!   energy-aware scaling — that is exactly what CARD's 53.1 % energy
//!   saving is measured against).
//! * **DeviceOnly** — "devices fine-tune the embedding module and
//!   transform decoders locally": c = I; the server only runs the head,
//!   at its frequency floor.
//! * **StaticCut(c)** — fixed split with CARD's frequency rule
//!   (ablation: how much of the win is the *adaptive* cut?).
//! * **RandomCut** — uniform cut per round with CARD's frequency rule.

use crate::config::{DeviceSpec, ServerSpec};
use crate::model::LinkRates;
use crate::util::rng::Rng;

use super::card::{Card, Decision};
use super::cost::CostModel;
use super::kernel::CutTable;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    Card,
    ServerOnly,
    DeviceOnly,
    StaticCut(usize),
    RandomCut,
}

impl Strategy {
    pub fn name(&self) -> String {
        match self {
            Strategy::Card => "CARD (proposed)".into(),
            Strategy::ServerOnly => "Server-only".into(),
            Strategy::DeviceOnly => "Device-only".into(),
            Strategy::StaticCut(c) => format!("Static-cut({c})"),
            Strategy::RandomCut => "Random-cut".into(),
        }
    }

    pub fn parse(s: &str) -> Option<Strategy> {
        match s.to_ascii_lowercase().as_str() {
            "card" => Some(Strategy::Card),
            "server-only" | "serveronly" => Some(Strategy::ServerOnly),
            "device-only" | "deviceonly" => Some(Strategy::DeviceOnly),
            "random" | "random-cut" => Some(Strategy::RandomCut),
            other => other
                .strip_prefix("static:")
                .and_then(|c| c.parse().ok())
                .map(Strategy::StaticCut),
        }
    }

    /// A strategy is cacheable when its decision is a pure function of
    /// `(device, link rates)` — true for everything except Random-cut,
    /// which consumes the cell RNG and must bypass the decision cache
    /// (DESIGN.md §12).
    pub fn cacheable(&self) -> bool {
        !matches!(self, Strategy::RandomCut)
    }

    /// Decide (cut, frequency) for one device-round against a
    /// precomputed [`CutTable`] — the kernel path every engine uses.
    /// Bit-identical to [`Strategy::decide_ref`].
    pub fn decide_on(&self, table: &CutTable, rates: LinkRates, rng: &mut Rng) -> Decision {
        let b = table.bounds(rates);
        match *self {
            Strategy::Card => table.scan(table.optimal_frequency(&b), rates, &b),
            Strategy::ServerOnly => table.at(0, table.terms.f_max, rates, &b),
            Strategy::DeviceOnly => table.at(table.n_layers(), table.f_min, rates, &b),
            Strategy::StaticCut(c) => {
                let c = c.min(table.n_layers());
                table.at(c, table.optimal_frequency(&b), rates, &b)
            }
            Strategy::RandomCut => {
                let c = rng.below(table.n_layers() as u64 + 1) as usize;
                table.at(c, table.optimal_frequency(&b), rates, &b)
            }
        }
    }

    /// Decide (cut, frequency) for one device-round, building a
    /// one-shot table (convenience for callers without a fleet).
    pub fn decide(
        &self,
        cm: &CostModel,
        server: &ServerSpec,
        dev: &DeviceSpec,
        rates: LinkRates,
        rng: &mut Rng,
    ) -> Decision {
        self.decide_on(&CutTable::for_device(cm, server, dev), rates, rng)
    }

    /// The pre-kernel reference path (O(I) model re-evaluation per cost
    /// call) — kept as the bit-compat oracle and `card-bench` baseline.
    pub fn decide_ref(
        &self,
        cm: &CostModel,
        server: &ServerSpec,
        dev: &DeviceSpec,
        rates: LinkRates,
        rng: &mut Rng,
    ) -> Decision {
        let card = Card::new(cm, server);
        let b = cm.bounds(dev, server, rates);
        let fixed = |c: usize, f: f64| {
            let (d, e) = cm.delay_energy(c, f, dev, server, rates);
            Decision {
                cut: c,
                freq_hz: f,
                cost: cm.cost(c, f, dev, server, rates, &b),
                delay_s: d,
                energy_j: e,
            }
        };
        match *self {
            Strategy::Card => card.decide_ref(dev, rates),
            Strategy::ServerOnly => fixed(0, server.max_freq_hz),
            Strategy::DeviceOnly => fixed(cm.n_layers(), dev.server_freq_floor(server)),
            Strategy::StaticCut(c) => {
                let c = c.min(cm.n_layers());
                fixed(c, card.optimal_frequency(dev, &b))
            }
            Strategy::RandomCut => {
                let c = rng.below(cm.n_layers() as u64 + 1) as usize;
                fixed(c, card.optimal_frequency(dev, &b))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExpConfig;
    use crate::model::{DataSizeModel, DelayModel, EnergyModel, FlopModel, LlmArch};

    fn setup() -> (CostModel, ExpConfig) {
        let cfg = ExpConfig::paper();
        let arch = LlmArch::llama1b();
        let fl = FlopModel::new(&arch, &cfg.workload);
        let cm = CostModel::new(
            DelayModel::new(
                fl.clone(),
                DataSizeModel::new(&arch, &cfg.workload),
                &cfg.workload,
            ),
            EnergyModel::new(fl, cfg.workload.local_epochs),
            cfg.card.w,
        );
        (cm, cfg)
    }

    const RATES: LinkRates = LinkRates {
        up_bps: 300e6,
        down_bps: 500e6,
    };

    #[test]
    fn card_never_worse_than_baselines() {
        // CARD minimizes U over the joint feasible set that contains every
        // baseline's operating point ⇒ its cost must be ≤ all of them.
        let (cm, cfg) = setup();
        let mut rng = Rng::new(0);
        for dev in &cfg.devices {
            let u_card = Strategy::Card
                .decide(&cm, &cfg.server, dev, RATES, &mut rng)
                .cost;
            for s in [
                Strategy::ServerOnly,
                Strategy::DeviceOnly,
                Strategy::StaticCut(16),
                Strategy::RandomCut,
            ] {
                let u = s.decide(&cm, &cfg.server, dev, RATES, &mut rng).cost;
                assert!(
                    u_card <= u + 1e-9,
                    "{}: CARD {} > {} {}",
                    dev.name,
                    u_card,
                    s.name(),
                    u
                );
            }
        }
    }

    #[test]
    fn server_only_fastest_for_weak_devices() {
        let (cm, cfg) = setup();
        let mut rng = Rng::new(1);
        let weak = &cfg.devices[4];
        let so = Strategy::ServerOnly.decide(&cm, &cfg.server, weak, RATES, &mut rng);
        let do_ = Strategy::DeviceOnly.decide(&cm, &cfg.server, weak, RATES, &mut rng);
        assert!(so.delay_s < do_.delay_s);
    }

    #[test]
    fn device_only_lowest_server_energy() {
        let (cm, cfg) = setup();
        let mut rng = Rng::new(2);
        for dev in &cfg.devices {
            let so = Strategy::ServerOnly.decide(&cm, &cfg.server, dev, RATES, &mut rng);
            let do_ = Strategy::DeviceOnly.decide(&cm, &cfg.server, dev, RATES, &mut rng);
            assert!(do_.energy_j < so.energy_j, "{}", dev.name);
        }
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(Strategy::parse("card"), Some(Strategy::Card));
        assert_eq!(Strategy::parse("Server-Only"), Some(Strategy::ServerOnly));
        assert_eq!(Strategy::parse("static:16"), Some(Strategy::StaticCut(16)));
        assert_eq!(Strategy::parse("bogus"), None);
    }

    #[test]
    fn kernel_path_bitwise_matches_reference_for_every_strategy() {
        let (cm, cfg) = setup();
        for s in [
            Strategy::Card,
            Strategy::ServerOnly,
            Strategy::DeviceOnly,
            Strategy::StaticCut(16),
            Strategy::RandomCut,
        ] {
            for dev in &cfg.devices {
                // twin RNG streams so Random-cut draws identically
                let mut rng_a = Rng::new(99);
                let mut rng_b = Rng::new(99);
                let a = s.decide(&cm, &cfg.server, dev, RATES, &mut rng_a);
                let b = s.decide_ref(&cm, &cfg.server, dev, RATES, &mut rng_b);
                assert_eq!(a.cut, b.cut, "{} {}", s.name(), dev.name);
                assert_eq!(a.freq_hz.to_bits(), b.freq_hz.to_bits(), "{}", s.name());
                assert_eq!(a.cost.to_bits(), b.cost.to_bits(), "{}", s.name());
                assert_eq!(a.delay_s.to_bits(), b.delay_s.to_bits(), "{}", s.name());
                assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits(), "{}", s.name());
            }
        }
    }

    #[test]
    fn random_cut_is_the_only_uncacheable_strategy() {
        assert!(Strategy::Card.cacheable());
        assert!(Strategy::ServerOnly.cacheable());
        assert!(Strategy::DeviceOnly.cacheable());
        assert!(Strategy::StaticCut(4).cacheable());
        assert!(!Strategy::RandomCut.cacheable());
    }

    #[test]
    fn static_cut_clamps() {
        let (cm, cfg) = setup();
        let mut rng = Rng::new(3);
        let d = Strategy::StaticCut(999).decide(&cm, &cfg.server, &cfg.devices[0], RATES, &mut rng);
        assert_eq!(d.cut, cm.n_layers());
    }

    #[test]
    fn random_cut_varies() {
        let (cm, cfg) = setup();
        let mut rng = Rng::new(4);
        let cuts: Vec<usize> = (0..30)
            .map(|_| {
                Strategy::RandomCut
                    .decide(&cm, &cfg.server, &cfg.devices[0], RATES, &mut rng)
                    .cut
            })
            .collect();
        let mut uniq = cuts.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert!(uniq.len() > 5, "{cuts:?}");
    }
}
