//! Decision strategies: CARD plus the paper's two benchmarks (§V-B) and
//! extra ablation strategies.
//!
//! * **ServerOnly** — "devices fine-tune the embedding module locally,
//!   and the server handles the rest": c = 0, server at F_max (no
//!   energy-aware scaling — that is exactly what CARD's 53.1 % energy
//!   saving is measured against).
//! * **DeviceOnly** — "devices fine-tune the embedding module and
//!   transform decoders locally": c = I; the server only runs the head,
//!   at its frequency floor.
//! * **StaticCut(c)** — fixed split with CARD's frequency rule
//!   (ablation: how much of the win is the *adaptive* cut?).
//! * **RandomCut** — uniform cut per round with CARD's frequency rule.

use crate::config::{DeviceSpec, ServerSpec};
use crate::model::LinkRates;
use crate::util::rng::Rng;

use super::card::{Card, Decision};
use super::cost::CostModel;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    Card,
    ServerOnly,
    DeviceOnly,
    StaticCut(usize),
    RandomCut,
}

impl Strategy {
    pub fn name(&self) -> String {
        match self {
            Strategy::Card => "CARD (proposed)".into(),
            Strategy::ServerOnly => "Server-only".into(),
            Strategy::DeviceOnly => "Device-only".into(),
            Strategy::StaticCut(c) => format!("Static-cut({c})"),
            Strategy::RandomCut => "Random-cut".into(),
        }
    }

    pub fn parse(s: &str) -> Option<Strategy> {
        match s.to_ascii_lowercase().as_str() {
            "card" => Some(Strategy::Card),
            "server-only" | "serveronly" => Some(Strategy::ServerOnly),
            "device-only" | "deviceonly" => Some(Strategy::DeviceOnly),
            "random" | "random-cut" => Some(Strategy::RandomCut),
            other => other
                .strip_prefix("static:")
                .and_then(|c| c.parse().ok())
                .map(Strategy::StaticCut),
        }
    }

    /// Decide (cut, frequency) for one device-round.
    pub fn decide(
        &self,
        cm: &CostModel,
        server: &ServerSpec,
        dev: &DeviceSpec,
        rates: LinkRates,
        rng: &mut Rng,
    ) -> Decision {
        let card = Card::new(cm, server);
        let b = cm.bounds(dev, server, rates);
        let fixed = |c: usize, f: f64| {
            let (d, e) = cm.delay_energy(c, f, dev, server, rates);
            Decision {
                cut: c,
                freq_hz: f,
                cost: cm.cost(c, f, dev, server, rates, &b),
                delay_s: d,
                energy_j: e,
            }
        };
        match *self {
            Strategy::Card => card.decide(dev, rates),
            Strategy::ServerOnly => fixed(0, server.max_freq_hz),
            Strategy::DeviceOnly => fixed(cm.n_layers(), dev.server_freq_floor(server)),
            Strategy::StaticCut(c) => {
                let c = c.min(cm.n_layers());
                fixed(c, card.optimal_frequency(dev, &b))
            }
            Strategy::RandomCut => {
                let c = rng.below(cm.n_layers() as u64 + 1) as usize;
                fixed(c, card.optimal_frequency(dev, &b))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExpConfig;
    use crate::model::{DataSizeModel, DelayModel, EnergyModel, FlopModel, LlmArch};

    fn setup() -> (CostModel, ExpConfig) {
        let cfg = ExpConfig::paper();
        let arch = LlmArch::llama1b();
        let fl = FlopModel::new(&arch, &cfg.workload);
        let cm = CostModel::new(
            DelayModel::new(
                fl.clone(),
                DataSizeModel::new(&arch, &cfg.workload),
                &cfg.workload,
            ),
            EnergyModel::new(fl, cfg.workload.local_epochs),
            cfg.card.w,
        );
        (cm, cfg)
    }

    const RATES: LinkRates = LinkRates {
        up_bps: 300e6,
        down_bps: 500e6,
    };

    #[test]
    fn card_never_worse_than_baselines() {
        // CARD minimizes U over the joint feasible set that contains every
        // baseline's operating point ⇒ its cost must be ≤ all of them.
        let (cm, cfg) = setup();
        let mut rng = Rng::new(0);
        for dev in &cfg.devices {
            let u_card = Strategy::Card
                .decide(&cm, &cfg.server, dev, RATES, &mut rng)
                .cost;
            for s in [
                Strategy::ServerOnly,
                Strategy::DeviceOnly,
                Strategy::StaticCut(16),
                Strategy::RandomCut,
            ] {
                let u = s.decide(&cm, &cfg.server, dev, RATES, &mut rng).cost;
                assert!(
                    u_card <= u + 1e-9,
                    "{}: CARD {} > {} {}",
                    dev.name,
                    u_card,
                    s.name(),
                    u
                );
            }
        }
    }

    #[test]
    fn server_only_fastest_for_weak_devices() {
        let (cm, cfg) = setup();
        let mut rng = Rng::new(1);
        let weak = &cfg.devices[4];
        let so = Strategy::ServerOnly.decide(&cm, &cfg.server, weak, RATES, &mut rng);
        let do_ = Strategy::DeviceOnly.decide(&cm, &cfg.server, weak, RATES, &mut rng);
        assert!(so.delay_s < do_.delay_s);
    }

    #[test]
    fn device_only_lowest_server_energy() {
        let (cm, cfg) = setup();
        let mut rng = Rng::new(2);
        for dev in &cfg.devices {
            let so = Strategy::ServerOnly.decide(&cm, &cfg.server, dev, RATES, &mut rng);
            let do_ = Strategy::DeviceOnly.decide(&cm, &cfg.server, dev, RATES, &mut rng);
            assert!(do_.energy_j < so.energy_j, "{}", dev.name);
        }
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(Strategy::parse("card"), Some(Strategy::Card));
        assert_eq!(Strategy::parse("Server-Only"), Some(Strategy::ServerOnly));
        assert_eq!(Strategy::parse("static:16"), Some(Strategy::StaticCut(16)));
        assert_eq!(Strategy::parse("bogus"), None);
    }

    #[test]
    fn static_cut_clamps() {
        let (cm, cfg) = setup();
        let mut rng = Rng::new(3);
        let d = Strategy::StaticCut(999).decide(&cm, &cfg.server, &cfg.devices[0], RATES, &mut rng);
        assert_eq!(d.cut, cm.n_layers());
    }

    #[test]
    fn random_cut_varies() {
        let (cm, cfg) = setup();
        let mut rng = Rng::new(4);
        let cuts: Vec<usize> = (0..30)
            .map(|_| {
                Strategy::RandomCut
                    .decide(&cm, &cfg.server, &cfg.devices[0], RATES, &mut rng)
                    .cut
            })
            .collect();
        let mut uniq = cuts.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert!(uniq.len() > 5, "{cuts:?}");
    }
}
