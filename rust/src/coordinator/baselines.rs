//! Decision strategies: CARD plus the paper's two benchmarks (§V-B) and
//! extra ablation strategies.
//!
//! * **ServerOnly** — "devices fine-tune the embedding module locally,
//!   and the server handles the rest": c = 0, server at F_max (no
//!   energy-aware scaling — that is exactly what CARD's 53.1 % energy
//!   saving is measured against).
//! * **DeviceOnly** — "devices fine-tune the embedding module and
//!   transform decoders locally": c = I; the server only runs the head,
//!   at its frequency floor.
//! * **StaticCut(c)** — fixed split with CARD's frequency rule
//!   (ablation: how much of the win is the *adaptive* cut?).
//! * **RandomCut** — uniform cut per round with CARD's frequency rule.
//! * **EpsGreedy / Ucb1 / Thompson** — the online-learning family
//!   (DESIGN.md §19): contextual bandits that learn the cut from
//!   realized costs.  Stateful, so their decisions live behind the
//!   [`Scheduler`]'s policy bank, never in this enum's pure
//!   `decide*` paths.
//!
//! [`Scheduler`]: super::Scheduler

use crate::config::{DeviceSpec, ServerSpec};
use crate::model::LinkRates;
use crate::policy::PolicyKind;
use crate::util::rng::Rng;

use super::card::{Card, Decision};
use super::cost::CostModel;
use super::kernel::CutTable;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    Card,
    ServerOnly,
    DeviceOnly,
    StaticCut(usize),
    RandomCut,
    /// ε-greedy contextual bandit over (CQI bucket, device class).
    EpsGreedy,
    /// UCB1 (lower-confidence-bound) contextual bandit.
    Ucb1,
    /// Gaussian Thompson-sampling contextual bandit.
    Thompson,
}

impl Strategy {
    pub fn name(&self) -> String {
        match self {
            Strategy::Card => "CARD (proposed)".into(),
            Strategy::ServerOnly => "Server-only".into(),
            Strategy::DeviceOnly => "Device-only".into(),
            Strategy::StaticCut(c) => format!("Static-cut({c})"),
            Strategy::RandomCut => "Random-cut".into(),
            Strategy::EpsGreedy => "Eps-greedy".into(),
            Strategy::Ucb1 => "UCB1".into(),
            Strategy::Thompson => "Thompson".into(),
        }
    }

    /// Stable machine-readable slug — report fields and metric keys
    /// (must stay aligned with [`crate::obs::registry::STRATEGY_KEYS`]).
    pub fn key(&self) -> &'static str {
        match self {
            Strategy::Card => "card",
            Strategy::ServerOnly => "server-only",
            Strategy::DeviceOnly => "device-only",
            Strategy::StaticCut(_) => "static-cut",
            Strategy::RandomCut => "random-cut",
            Strategy::EpsGreedy => "eps-greedy",
            Strategy::Ucb1 => "ucb1",
            Strategy::Thompson => "thompson",
        }
    }

    pub fn parse(s: &str) -> Option<Strategy> {
        match s.to_ascii_lowercase().as_str() {
            "card" => Some(Strategy::Card),
            "server-only" | "serveronly" => Some(Strategy::ServerOnly),
            "device-only" | "deviceonly" => Some(Strategy::DeviceOnly),
            "random" | "random-cut" => Some(Strategy::RandomCut),
            "eps-greedy" | "epsgreedy" | "epsilon-greedy" => Some(Strategy::EpsGreedy),
            "ucb1" | "ucb" => Some(Strategy::Ucb1),
            "thompson" => Some(Strategy::Thompson),
            other => other
                .strip_prefix("static:")
                .and_then(|c| c.parse().ok())
                .map(Strategy::StaticCut),
        }
    }

    /// A strategy is cacheable when its decision is a pure function of
    /// `(device, link rates)` — false for Random-cut, which consumes
    /// the cell RNG, and for the learned family, whose decisions depend
    /// on bandit state that evolves across rounds (DESIGN.md §12, §19).
    pub fn cacheable(&self) -> bool {
        !matches!(
            self,
            Strategy::RandomCut | Strategy::EpsGreedy | Strategy::Ucb1 | Strategy::Thompson
        )
    }

    /// True for the online-learning family — decisions flow through the
    /// scheduler's policy bank, not [`Strategy::decide_on`].
    pub fn is_learned(&self) -> bool {
        self.policy_kind().is_some()
    }

    /// The bandit rule a learned strategy runs, if any.
    pub fn policy_kind(&self) -> Option<PolicyKind> {
        match self {
            Strategy::EpsGreedy => Some(PolicyKind::EpsGreedy),
            Strategy::Ucb1 => Some(PolicyKind::Ucb1),
            Strategy::Thompson => Some(PolicyKind::Thompson),
            _ => None,
        }
    }

    /// Decide (cut, frequency) for one device-round against a
    /// precomputed [`CutTable`] — the kernel path every engine uses.
    /// Bit-identical to [`Strategy::decide_ref`].
    pub fn decide_on(&self, table: &CutTable, rates: LinkRates, rng: &mut Rng) -> Decision {
        let b = table.bounds(rates);
        match *self {
            Strategy::Card => table.scan(table.optimal_frequency(&b), rates, &b),
            Strategy::ServerOnly => table.at(0, table.terms.f_max, rates, &b),
            Strategy::DeviceOnly => table.at(table.n_layers(), table.f_min, rates, &b),
            Strategy::StaticCut(c) => {
                let c = c.min(table.n_layers());
                table.at(c, table.optimal_frequency(&b), rates, &b)
            }
            Strategy::RandomCut => {
                let c = rng.below(table.n_layers() as u64 + 1) as usize;
                table.at(c, table.optimal_frequency(&b), rates, &b)
            }
            Strategy::EpsGreedy | Strategy::Ucb1 | Strategy::Thompson => {
                panic!("learned strategies decide through the Scheduler's policy bank")
            }
        }
    }

    /// Decide (cut, frequency) for one device-round, building a
    /// one-shot table (convenience for callers without a fleet).
    pub fn decide(
        &self,
        cm: &CostModel,
        server: &ServerSpec,
        dev: &DeviceSpec,
        rates: LinkRates,
        rng: &mut Rng,
    ) -> Decision {
        self.decide_on(&CutTable::for_device(cm, server, dev), rates, rng)
    }

    /// The pre-kernel reference path (O(I) model re-evaluation per cost
    /// call) — kept as the bit-compat oracle and `card-bench` baseline.
    pub fn decide_ref(
        &self,
        cm: &CostModel,
        server: &ServerSpec,
        dev: &DeviceSpec,
        rates: LinkRates,
        rng: &mut Rng,
    ) -> Decision {
        let card = Card::new(cm, server);
        let b = cm.bounds(dev, server, rates);
        let fixed = |c: usize, f: f64| {
            let (d, e) = cm.delay_energy(c, f, dev, server, rates);
            Decision {
                cut: c,
                freq_hz: f,
                cost: cm.cost(c, f, dev, server, rates, &b),
                delay_s: d,
                energy_j: e,
            }
        };
        match *self {
            Strategy::Card => card.decide_ref(dev, rates),
            Strategy::ServerOnly => fixed(0, server.max_freq_hz),
            Strategy::DeviceOnly => fixed(cm.n_layers(), dev.server_freq_floor(server)),
            Strategy::StaticCut(c) => {
                let c = c.min(cm.n_layers());
                fixed(c, card.optimal_frequency(dev, &b))
            }
            Strategy::RandomCut => {
                let c = rng.below(cm.n_layers() as u64 + 1) as usize;
                fixed(c, card.optimal_frequency(dev, &b))
            }
            Strategy::EpsGreedy | Strategy::Ucb1 | Strategy::Thompson => {
                panic!("learned strategies decide through the Scheduler's policy bank")
            }
        }
    }
}

/// Evaluate a fixed cut at CARD's optimal frequency on the kernel path —
/// the arithmetic every learned decision shares with `StaticCut`, so a
/// bandit that has converged on cut c prices bit-identically to
/// `Strategy::StaticCut(c)`.
pub(crate) fn kernel_fixed_cut(table: &CutTable, cut: usize, rates: LinkRates) -> Decision {
    let b = table.bounds(rates);
    table.at(cut, table.optimal_frequency(&b), rates, &b)
}

/// Reference-path twin of [`kernel_fixed_cut`] (legacy O(I) models).
pub(crate) fn ref_fixed_cut(
    cm: &CostModel,
    server: &ServerSpec,
    dev: &DeviceSpec,
    rates: LinkRates,
    cut: usize,
) -> Decision {
    let card = Card::new(cm, server);
    let b = cm.bounds(dev, server, rates);
    let f = card.optimal_frequency(dev, &b);
    let (d, e) = cm.delay_energy(cut, f, dev, server, rates);
    Decision {
        cut,
        freq_hz: f,
        cost: cm.cost(cut, f, dev, server, rates, &b),
        delay_s: d,
        energy_j: e,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExpConfig;
    use crate::model::{DataSizeModel, DelayModel, EnergyModel, FlopModel, LlmArch};

    fn setup() -> (CostModel, ExpConfig) {
        let cfg = ExpConfig::paper();
        let arch = LlmArch::llama1b();
        let fl = FlopModel::new(&arch, &cfg.workload);
        let cm = CostModel::new(
            DelayModel::new(
                fl.clone(),
                DataSizeModel::new(&arch, &cfg.workload),
                &cfg.workload,
            ),
            EnergyModel::new(fl, cfg.workload.local_epochs),
            cfg.card.w,
        );
        (cm, cfg)
    }

    const RATES: LinkRates = LinkRates {
        up_bps: 300e6,
        down_bps: 500e6,
    };

    #[test]
    fn card_never_worse_than_baselines() {
        // CARD minimizes U over the joint feasible set that contains every
        // baseline's operating point ⇒ its cost must be ≤ all of them.
        let (cm, cfg) = setup();
        let mut rng = Rng::new(0);
        for dev in &cfg.devices {
            let u_card = Strategy::Card
                .decide(&cm, &cfg.server, dev, RATES, &mut rng)
                .cost;
            for s in [
                Strategy::ServerOnly,
                Strategy::DeviceOnly,
                Strategy::StaticCut(16),
                Strategy::RandomCut,
            ] {
                let u = s.decide(&cm, &cfg.server, dev, RATES, &mut rng).cost;
                assert!(
                    u_card <= u + 1e-9,
                    "{}: CARD {} > {} {}",
                    dev.name,
                    u_card,
                    s.name(),
                    u
                );
            }
        }
    }

    #[test]
    fn server_only_fastest_for_weak_devices() {
        let (cm, cfg) = setup();
        let mut rng = Rng::new(1);
        let weak = &cfg.devices[4];
        let so = Strategy::ServerOnly.decide(&cm, &cfg.server, weak, RATES, &mut rng);
        let do_ = Strategy::DeviceOnly.decide(&cm, &cfg.server, weak, RATES, &mut rng);
        assert!(so.delay_s < do_.delay_s);
    }

    #[test]
    fn device_only_lowest_server_energy() {
        let (cm, cfg) = setup();
        let mut rng = Rng::new(2);
        for dev in &cfg.devices {
            let so = Strategy::ServerOnly.decide(&cm, &cfg.server, dev, RATES, &mut rng);
            let do_ = Strategy::DeviceOnly.decide(&cm, &cfg.server, dev, RATES, &mut rng);
            assert!(do_.energy_j < so.energy_j, "{}", dev.name);
        }
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(Strategy::parse("card"), Some(Strategy::Card));
        assert_eq!(Strategy::parse("Server-Only"), Some(Strategy::ServerOnly));
        assert_eq!(Strategy::parse("static:16"), Some(Strategy::StaticCut(16)));
        assert_eq!(Strategy::parse("eps-greedy"), Some(Strategy::EpsGreedy));
        assert_eq!(Strategy::parse("Epsilon-Greedy"), Some(Strategy::EpsGreedy));
        assert_eq!(Strategy::parse("ucb"), Some(Strategy::Ucb1));
        assert_eq!(Strategy::parse("UCB1"), Some(Strategy::Ucb1));
        assert_eq!(Strategy::parse("thompson"), Some(Strategy::Thompson));
        assert_eq!(Strategy::parse("bogus"), None);
    }

    #[test]
    fn kernel_path_bitwise_matches_reference_for_every_strategy() {
        let (cm, cfg) = setup();
        for s in [
            Strategy::Card,
            Strategy::ServerOnly,
            Strategy::DeviceOnly,
            Strategy::StaticCut(16),
            Strategy::RandomCut,
        ] {
            for dev in &cfg.devices {
                // twin RNG streams so Random-cut draws identically
                let mut rng_a = Rng::new(99);
                let mut rng_b = Rng::new(99);
                let a = s.decide(&cm, &cfg.server, dev, RATES, &mut rng_a);
                let b = s.decide_ref(&cm, &cfg.server, dev, RATES, &mut rng_b);
                assert_eq!(a.cut, b.cut, "{} {}", s.name(), dev.name);
                assert_eq!(a.freq_hz.to_bits(), b.freq_hz.to_bits(), "{}", s.name());
                assert_eq!(a.cost.to_bits(), b.cost.to_bits(), "{}", s.name());
                assert_eq!(a.delay_s.to_bits(), b.delay_s.to_bits(), "{}", s.name());
                assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits(), "{}", s.name());
            }
        }
    }

    #[test]
    fn stateful_strategies_are_uncacheable() {
        assert!(Strategy::Card.cacheable());
        assert!(Strategy::ServerOnly.cacheable());
        assert!(Strategy::DeviceOnly.cacheable());
        assert!(Strategy::StaticCut(4).cacheable());
        assert!(!Strategy::RandomCut.cacheable());
        for s in [Strategy::EpsGreedy, Strategy::Ucb1, Strategy::Thompson] {
            assert!(!s.cacheable(), "{} is stateful", s.name());
            assert!(s.is_learned());
            assert!(s.policy_kind().is_some());
        }
        assert!(!Strategy::Card.is_learned());
        assert_eq!(Strategy::RandomCut.policy_kind(), None);
    }

    #[test]
    fn fixed_cut_helpers_match_static_cut_bitwise() {
        // a converged bandit playing cut c must price exactly like
        // StaticCut(c) on both the kernel and reference paths
        let (cm, cfg) = setup();
        for dev in &cfg.devices {
            let table = CutTable::for_device(&cm, &cfg.server, dev);
            for cut in [0, 8, 16, cm.n_layers()] {
                let mut rng = Rng::new(0);
                let want =
                    Strategy::StaticCut(cut).decide(&cm, &cfg.server, dev, RATES, &mut rng);
                let k = kernel_fixed_cut(&table, cut, RATES);
                let r = ref_fixed_cut(&cm, &cfg.server, dev, RATES, cut);
                for got in [&k, &r] {
                    assert_eq!(got.cut, want.cut);
                    assert_eq!(got.freq_hz.to_bits(), want.freq_hz.to_bits());
                    assert_eq!(got.cost.to_bits(), want.cost.to_bits());
                    assert_eq!(got.delay_s.to_bits(), want.delay_s.to_bits());
                    assert_eq!(got.energy_j.to_bits(), want.energy_j.to_bits());
                }
            }
        }
    }

    #[test]
    fn static_cut_clamps() {
        let (cm, cfg) = setup();
        let mut rng = Rng::new(3);
        let d = Strategy::StaticCut(999).decide(&cm, &cfg.server, &cfg.devices[0], RATES, &mut rng);
        assert_eq!(d.cut, cm.n_layers());
    }

    #[test]
    fn random_cut_varies() {
        let (cm, cfg) = setup();
        let mut rng = Rng::new(4);
        let cuts: Vec<usize> = (0..30)
            .map(|_| {
                Strategy::RandomCut
                    .decide(&cm, &cfg.server, &cfg.devices[0], RATES, &mut rng)
                    .cut
            })
            .collect();
        let mut uniq = cuts.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert!(uniq.len() > 5, "{cuts:?}");
    }
}
