//! Struct-of-arrays hot path for fleet-scale rounds (DESIGN.md §18).
//!
//! [`RoundBatch`] holds one bounded window of `(round, device)` cells
//! as parallel columns — one `Vec` per numeric [`RoundRecord`] field —
//! instead of a `Vec<RoundRecord>`.  The interned device/strategy
//! names are **not** stored per cell: the batch carries the
//! scheduler's shared name slab and materializes a full `RoundRecord`
//! only when a collecting sink asks ([`RoundBatch::record`]).
//!
//! Filling is a chunked scan over [`Scheduler::cell_values`] — link
//! realization, decision-cache probe, and the Eq. 8/10/11 kernel
//! evaluation fused per cell — with each [`SOA_CHUNK`]-cell chunk
//! claimed by one worker-pool participant that writes straight into
//! disjoint column slices.  Because every cell is a pure function of
//! `(config, seed, round, device)` (counter-based RNG streams), the
//! chunking and thread count can never change a bit of the output:
//! the columns are exactly the fields `device_round` would have
//! produced, in device order.
//!
//! The window is bounded ([`SOA_WINDOW`] cells) and the engine reuses
//! one batch across windows and rounds, so the streaming path holds
//! O(window) memory however large the fleet is — the memory ceiling
//! behind the mega-sweep tier.

use std::sync::Arc;

use crate::obs;
use crate::util::pool;

use super::scheduler::{CellValues, RoundRecord, Scheduler};

/// Cells per engine window: large enough to amortize fan-out, small
/// enough that 14 f64 columns stay ~1.8 MB however big the fleet is.
pub const SOA_WINDOW: usize = 16_384;

/// Cells per worker-pool claim inside a window fill.
pub const SOA_CHUNK: usize = 1_024;

/// One window of round cells, stored column-wise.  Columns are plain
/// `Vec`s resized (never reallocated down) by [`RoundBatch::fill`];
/// index `i` within every column belongs to device
/// `start_device + i` of round `round`.
#[derive(Clone, Debug)]
pub struct RoundBatch {
    pub round: usize,
    pub start_device: usize,
    pub cut: Vec<usize>,
    pub freq_hz: Vec<f64>,
    pub cost: Vec<f64>,
    pub snr_up_db: Vec<f64>,
    pub snr_down_db: Vec<f64>,
    pub rate_up_bps: Vec<f64>,
    pub rate_down_bps: Vec<f64>,
    pub delay_s: Vec<f64>,
    pub device_compute_s: Vec<f64>,
    pub server_compute_s: Vec<f64>,
    pub transmission_s: Vec<f64>,
    pub energy_j: Vec<f64>,
    pub adapter_bytes: Vec<f64>,
    pub smashed_bytes_round: Vec<f64>,
    /// the scheduler's interned name slab — touched only by `record`
    names: Arc<[Arc<str>]>,
    strategy: Arc<str>,
}

impl Default for RoundBatch {
    fn default() -> Self {
        Self::new()
    }
}

/// Raw column base pointers so pool participants can write disjoint
/// index ranges of a batch without aliasing a `&mut` borrow.
struct ColumnPtrs {
    cut: *mut usize,
    freq_hz: *mut f64,
    cost: *mut f64,
    snr_up_db: *mut f64,
    snr_down_db: *mut f64,
    rate_up_bps: *mut f64,
    rate_down_bps: *mut f64,
    delay_s: *mut f64,
    device_compute_s: *mut f64,
    server_compute_s: *mut f64,
    transmission_s: *mut f64,
    energy_j: *mut f64,
    adapter_bytes: *mut f64,
    smashed_bytes_round: *mut f64,
}

// SAFETY: the pointers stay valid for the whole fill (the batch
// outlives the pool job), and the chunk protocol hands each index to
// exactly one participant, so no slot is ever written twice or read
// during the fill.
unsafe impl Sync for ColumnPtrs {}

impl ColumnPtrs {
    /// SAFETY: caller must guarantee `i` is in bounds for every column
    /// and written by only one thread.
    #[inline]
    unsafe fn write(&self, i: usize, v: &CellValues) {
        *self.cut.add(i) = v.cut;
        *self.freq_hz.add(i) = v.freq_hz;
        *self.cost.add(i) = v.cost;
        *self.snr_up_db.add(i) = v.snr_up_db;
        *self.snr_down_db.add(i) = v.snr_down_db;
        *self.rate_up_bps.add(i) = v.rate_up_bps;
        *self.rate_down_bps.add(i) = v.rate_down_bps;
        *self.delay_s.add(i) = v.delay_s;
        *self.device_compute_s.add(i) = v.device_compute_s;
        *self.server_compute_s.add(i) = v.server_compute_s;
        *self.transmission_s.add(i) = v.transmission_s;
        *self.energy_j.add(i) = v.energy_j;
        *self.adapter_bytes.add(i) = v.adapter_bytes;
        *self.smashed_bytes_round.add(i) = v.smashed_bytes_round;
    }
}

impl RoundBatch {
    pub fn new() -> Self {
        RoundBatch {
            round: 0,
            start_device: 0,
            cut: Vec::new(),
            freq_hz: Vec::new(),
            cost: Vec::new(),
            snr_up_db: Vec::new(),
            snr_down_db: Vec::new(),
            rate_up_bps: Vec::new(),
            rate_down_bps: Vec::new(),
            delay_s: Vec::new(),
            device_compute_s: Vec::new(),
            server_compute_s: Vec::new(),
            transmission_s: Vec::new(),
            energy_j: Vec::new(),
            adapter_bytes: Vec::new(),
            smashed_bytes_round: Vec::new(),
            names: Arc::from(Vec::new()),
            strategy: Arc::from(""),
        }
    }

    /// Cells in the current window.
    pub fn len(&self) -> usize {
        self.cut.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cut.is_empty()
    }

    /// Fleet index of cell `i`.
    pub fn device_idx(&self, i: usize) -> usize {
        self.start_device + i
    }

    /// Materialize cell `i` as a full [`RoundRecord`] — the only place
    /// the batch touches the interned names (lazy, for collect sinks).
    pub fn record(&self, i: usize) -> RoundRecord {
        let device_idx = self.start_device + i;
        RoundRecord {
            round: self.round,
            device_idx,
            device_name: self.names[device_idx].clone(),
            strategy: self.strategy.clone(),
            cut: self.cut[i],
            freq_hz: self.freq_hz[i],
            cost: self.cost[i],
            snr_up_db: self.snr_up_db[i],
            snr_down_db: self.snr_down_db[i],
            rate_up_bps: self.rate_up_bps[i],
            rate_down_bps: self.rate_down_bps[i],
            delay_s: self.delay_s[i],
            device_compute_s: self.device_compute_s[i],
            server_compute_s: self.server_compute_s[i],
            transmission_s: self.transmission_s[i],
            energy_j: self.energy_j[i],
            adapter_bytes: self.adapter_bytes[i],
            smashed_bytes_round: self.smashed_bytes_round[i],
            loss: None,
            backend_wallclock_s: None,
        }
    }

    fn resize_columns(&mut self, len: usize) {
        self.cut.resize(len, 0);
        for col in [
            &mut self.freq_hz,
            &mut self.cost,
            &mut self.snr_up_db,
            &mut self.snr_down_db,
            &mut self.rate_up_bps,
            &mut self.rate_down_bps,
            &mut self.delay_s,
            &mut self.device_compute_s,
            &mut self.server_compute_s,
            &mut self.transmission_s,
            &mut self.energy_j,
            &mut self.adapter_bytes,
            &mut self.smashed_bytes_round,
        ] {
            col.resize(len, 0.0);
        }
    }

    /// Fill this batch with the window
    /// `devices[start_device .. start_device + len]` of `round`,
    /// fanning [`SOA_CHUNK`]-cell chunks across up to `threads` pool
    /// participants.  Reuses the column allocations across calls.
    /// Bit-identical at any thread count: every cell is pure
    /// (counter-based RNG streams) and each column slot is written by
    /// exactly one participant.
    pub fn fill(
        &mut self,
        sched: &Scheduler,
        round: usize,
        start_device: usize,
        len: usize,
        threads: usize,
    ) {
        self.round = round;
        self.start_device = start_device;
        self.names = sched.names().clone();
        self.strategy = sched.strategy_name().clone();
        self.resize_columns(len);
        let cols = ColumnPtrs {
            cut: self.cut.as_mut_ptr(),
            freq_hz: self.freq_hz.as_mut_ptr(),
            cost: self.cost.as_mut_ptr(),
            snr_up_db: self.snr_up_db.as_mut_ptr(),
            snr_down_db: self.snr_down_db.as_mut_ptr(),
            rate_up_bps: self.rate_up_bps.as_mut_ptr(),
            rate_down_bps: self.rate_down_bps.as_mut_ptr(),
            delay_s: self.delay_s.as_mut_ptr(),
            device_compute_s: self.device_compute_s.as_mut_ptr(),
            server_compute_s: self.server_compute_s.as_mut_ptr(),
            transmission_s: self.transmission_s.as_mut_ptr(),
            energy_j: self.energy_j.as_mut_ptr(),
            adapter_bytes: self.adapter_bytes.as_mut_ptr(),
            smashed_bytes_round: self.smashed_bytes_round.as_mut_ptr(),
        };
        let fill_chunk = |off: usize| {
            let end = (off + SOA_CHUNK).min(len);
            let t0 = obs::registry::timer_start();
            for i in off..end {
                let v = sched.cell_values(round, start_device + i);
                // SAFETY: i < len (columns were just resized to len)
                // and chunks partition [0, len) disjointly
                unsafe { cols.write(i, &v) };
            }
            obs::metrics().soa_chunks.inc(obs::registry::worker_slot());
            obs::registry::timer_record(&obs::metrics().soa_fill_s, t0);
        };
        if threads > 1 && len > SOA_CHUNK {
            let offsets: Vec<usize> = (0..len).step_by(SOA_CHUNK).collect();
            pool::par_map_indexed(threads, &offsets, |_, &off| fill_chunk(off));
        } else if len > 0 {
            for off in (0..len).step_by(SOA_CHUNK) {
                fill_chunk(off);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::scenario;
    use crate::coordinator::Strategy;

    fn sched(devices: usize, rounds: usize) -> Scheduler {
        let sc = scenario::DENSE_URBAN;
        let mut cfg = sc.config(devices, 7).unwrap();
        cfg.workload.rounds = rounds;
        Scheduler::new(cfg, sc.state, Strategy::Card)
    }

    fn assert_batch_matches_records(b: &RoundBatch, s: &Scheduler, round: usize) {
        for i in 0..b.len() {
            let want = s.device_round(round, b.device_idx(i));
            let got = b.record(i);
            assert_eq!(got.round, want.round);
            assert_eq!(got.device_idx, want.device_idx);
            assert_eq!(got.device_name, want.device_name);
            assert_eq!(got.strategy, want.strategy);
            assert_eq!(got.cut, want.cut);
            for (a, c) in [
                (got.freq_hz, want.freq_hz),
                (got.cost, want.cost),
                (got.snr_up_db, want.snr_up_db),
                (got.snr_down_db, want.snr_down_db),
                (got.rate_up_bps, want.rate_up_bps),
                (got.rate_down_bps, want.rate_down_bps),
                (got.delay_s, want.delay_s),
                (got.device_compute_s, want.device_compute_s),
                (got.server_compute_s, want.server_compute_s),
                (got.transmission_s, want.transmission_s),
                (got.energy_j, want.energy_j),
                (got.adapter_bytes, want.adapter_bytes),
                (got.smashed_bytes_round, want.smashed_bytes_round),
            ] {
                assert_eq!(a.to_bits(), c.to_bits(), "cell {i}");
            }
        }
    }

    #[test]
    fn fill_matches_device_round_bitwise() {
        let s = sched(7, 2);
        let mut b = RoundBatch::new();
        for round in 0..2 {
            b.fill(&s, round, 0, 7, 1);
            assert_eq!(b.len(), 7);
            assert_batch_matches_records(&b, &s, round);
        }
    }

    #[test]
    fn threaded_fill_is_bit_identical_to_serial() {
        let s = sched(9, 1);
        let mut serial = RoundBatch::new();
        serial.fill(&s, 0, 0, 9, 1);
        for threads in [2, 4, 8] {
            let mut par = RoundBatch::new();
            par.fill(&s, 0, 0, 9, threads);
            assert_eq!(serial.cut, par.cut);
            for (a, b) in serial.delay_s.iter().zip(&par.delay_s) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            for (a, b) in serial.energy_j.iter().zip(&par.energy_j) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn partial_windows_cover_the_fleet() {
        // window smaller than the fleet: two fills tile [0, 5) + [5, 7)
        let s = sched(7, 1);
        let mut b = RoundBatch::new();
        b.fill(&s, 0, 0, 5, 1);
        assert_eq!(b.len(), 5);
        assert_batch_matches_records(&b, &s, 0);
        b.fill(&s, 0, 5, 2, 1);
        assert_eq!(b.len(), 2);
        assert_eq!(b.device_idx(0), 5);
        assert_batch_matches_records(&b, &s, 0);
        // shrinking reuse: a larger refill after a smaller one is clean
        b.fill(&s, 0, 0, 7, 1);
        assert_eq!(b.len(), 7);
        assert_batch_matches_records(&b, &s, 0);
    }

    #[test]
    fn empty_fill_is_harmless() {
        let s = sched(3, 1);
        let mut b = RoundBatch::new();
        b.fill(&s, 0, 0, 0, 4);
        assert!(b.is_empty());
        assert_eq!(b.len(), 0);
    }
}
