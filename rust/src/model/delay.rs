//! Training-delay model — Eqs. (7)–(10).
//!
//!   d^{D,C} = η_D(c) / (f^D δ^D σ^D)                        (7)
//!   d^{S,C} = (η − η_D(c)) / (f^S δ^S σ^S)                  (8)
//!   D^V    = T(φS/R^D + φS̃/R^S) + A(c)/R^D + A(c)/R^S      (9)
//!   D      = T(d^{D,C} + d^{S,C}) + D^V                     (10)

use crate::config::{DeviceSpec, ServerSpec, WorkloadSpec};

use super::datasize::DataSizeModel;
use super::flops::FlopModel;

/// Realized link rates for one round [bit/s].
#[derive(Clone, Copy, Debug)]
pub struct LinkRates {
    /// R^D — uplink (device -> server)
    pub up_bps: f64,
    /// R^S — downlink (server -> device)
    pub down_bps: f64,
}

#[derive(Clone, Debug)]
pub struct DelayModel {
    pub flops: FlopModel,
    pub sizes: DataSizeModel,
    /// T — local epochs per round
    pub epochs: f64,
}

impl DelayModel {
    pub fn new(flops: FlopModel, sizes: DataSizeModel, w: &WorkloadSpec) -> Self {
        Self {
            flops,
            sizes,
            epochs: w.local_epochs as f64,
        }
    }

    /// Eq. (7): device compute delay per local epoch [s].
    pub fn device_compute(&self, c: usize, dev: &DeviceSpec) -> f64 {
        self.flops.eta_device(c) / dev.throughput()
    }

    /// Eq. (8): server compute delay per local epoch at frequency f [s].
    pub fn server_compute(&self, c: usize, server: &ServerSpec, f_hz: f64) -> f64 {
        self.flops.eta_server(c) / server.throughput(f_hz)
    }

    /// Eq. (9): total transmission delay for one round [s].
    pub fn transmission(&self, c: usize, rates: LinkRates) -> f64 {
        let per_epoch = 8.0 * self.sizes.smashed_wire_bytes(c) / rates.up_bps
            + 8.0 * self.sizes.grad_wire_bytes(c) / rates.down_bps;
        let adapters = 8.0 * self.sizes.adapter_bytes(c) / rates.up_bps
            + 8.0 * self.sizes.adapter_bytes(c) / rates.down_bps;
        self.epochs * per_epoch + adapters
    }

    /// Total compute delay for one round: T(d^{D,C} + d^{S,C}).
    pub fn compute(&self, c: usize, dev: &DeviceSpec, server: &ServerSpec, f_hz: f64) -> f64 {
        self.epochs * (self.device_compute(c, dev) + self.server_compute(c, server, f_hz))
    }

    /// Eq. (10): full round delay.
    pub fn round(
        &self,
        c: usize,
        dev: &DeviceSpec,
        server: &ServerSpec,
        f_hz: f64,
        rates: LinkRates,
    ) -> f64 {
        self.compute(c, dev, server, f_hz) + self.transmission(c, rates)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExpConfig;
    use crate::model::arch::LlmArch;

    fn setup() -> (DelayModel, ExpConfig) {
        let cfg = ExpConfig::paper();
        let arch = LlmArch::llama1b();
        let dm = DelayModel::new(
            FlopModel::new(&arch, &cfg.workload),
            DataSizeModel::new(&arch, &cfg.workload),
            &cfg.workload,
        );
        (dm, cfg)
    }

    const RATES: LinkRates = LinkRates {
        up_bps: 100e6,
        down_bps: 200e6,
    };

    #[test]
    fn device_delay_increases_with_cut() {
        let (dm, cfg) = setup();
        let d = &cfg.devices[0];
        assert!(dm.device_compute(32, d) > dm.device_compute(0, d));
    }

    #[test]
    fn server_delay_decreases_with_cut_and_freq() {
        let (dm, cfg) = setup();
        let s = &cfg.server;
        assert!(dm.server_compute(0, s, 2.46e9) > dm.server_compute(32, s, 2.46e9));
        assert!(dm.server_compute(8, s, 1.0e9) > dm.server_compute(8, s, 2.0e9));
    }

    #[test]
    fn weak_device_slower_than_strong() {
        let (dm, cfg) = setup();
        assert!(dm.device_compute(16, &cfg.devices[4]) > dm.device_compute(16, &cfg.devices[0]));
    }

    #[test]
    fn transmission_epochs_scale_smashed_not_adapters() {
        let (mut dm, _) = setup();
        let t1 = dm.transmission(8, RATES);
        dm.epochs = 10.0;
        let t2 = dm.transmission(8, RATES);
        // doubling epochs less than doubles total (adapter term fixed)
        assert!(t2 > t1 && t2 < 2.0 * t1 + 1e-9);
    }

    #[test]
    fn round_delay_composition() {
        let (dm, cfg) = setup();
        let d = &cfg.devices[2];
        let total = dm.round(8, d, &cfg.server, 2.0e9, RATES);
        let parts = dm.compute(8, d, &cfg.server, 2.0e9) + dm.transmission(8, RATES);
        assert!((total - parts).abs() < 1e-12);
        assert!(total > 0.0 && total.is_finite());
    }

    #[test]
    fn faster_link_lower_transmission() {
        let (dm, _) = setup();
        let slow = dm.transmission(
            8,
            LinkRates {
                up_bps: 10e6,
                down_bps: 10e6,
            },
        );
        let fast = dm.transmission(
            8,
            LinkRates {
                up_bps: 1e9,
                down_bps: 1e9,
            },
        );
        assert!(slow > fast * 10.0);
    }

    #[test]
    fn paper_magnitudes_plausible() {
        // Device 1 @ c=32 (device-only decoders): tens of seconds/epoch.
        let (dm, cfg) = setup();
        let d1 = dm.device_compute(32, &cfg.devices[0]);
        assert!(d1 > 1.0 && d1 < 100.0, "device-1 epoch delay {d1}s");
        // Server @ c=0, f_max: a few seconds/epoch.
        let ds = dm.server_compute(0, &cfg.server, cfg.server.max_freq_hz);
        assert!(ds > 0.5 && ds < 20.0, "server epoch delay {ds}s");
    }
}
