//! LLM architecture descriptor — parameterizes the FLOPs/byte cost
//! models (Eqs. 7–11) and mirrors `python/compile/configs.py`.
//!
//! `llama1b()` is the paper's model ("1B LLaMA 3.2 with 32-layer
//! transformer decoders", §V-A) used for every figure; `tiny`/`small`
//! match the compiled artifact configs and can also be loaded from a
//! manifest so the cost model and the real executor always agree.

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct LlmArch {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    /// I — number of transformer decoder layers
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    /// Z — LoRA rank
    pub lora_rank: usize,
    pub lora_alpha: f64,
    /// bytes per parameter/activation element on the wire & in FLOP
    /// accounting (fp32 = 4; the paper's φ compression is applied on
    /// top of this in the datasize model)
    pub dtype_bytes: usize,
}

impl LlmArch {
    /// The paper's model (§V-A).  LLaMA-3.2-1B dims with the paper's
    /// stated 32 decoder layers.
    pub fn llama1b() -> Self {
        Self {
            name: "llama1b".into(),
            vocab_size: 128_256,
            d_model: 2048,
            n_layers: 32,
            n_heads: 32,
            d_ff: 8192,
            lora_rank: 16,
            lora_alpha: 32.0,
            dtype_bytes: 4,
        }
    }

    /// Matches python/compile/configs.py `tiny` (compiled artifacts).
    pub fn tiny() -> Self {
        Self {
            name: "tiny".into(),
            vocab_size: 256,
            d_model: 128,
            n_layers: 6,
            n_heads: 8,
            d_ff: 384,
            lora_rank: 8,
            lora_alpha: 16.0,
            dtype_bytes: 4,
        }
    }

    /// Matches python/compile/configs.py `small` (compiled artifacts).
    pub fn small() -> Self {
        Self {
            name: "small".into(),
            vocab_size: 256,
            d_model: 256,
            n_layers: 8,
            n_heads: 8,
            d_ff: 704,
            lora_rank: 8,
            lora_alpha: 16.0,
            dtype_bytes: 4,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "llama1b" => Some(Self::llama1b()),
            "tiny" => Some(Self::tiny()),
            "small" => Some(Self::small()),
            _ => None,
        }
    }

    /// Build from an AOT manifest's `config` object, so the analytic
    /// cost model and the compiled artifacts can never drift apart.
    pub fn from_manifest(manifest: &Json) -> Option<Self> {
        let c = manifest.get("config")?;
        let g = |k: &str| c.get(k)?.as_usize();
        Some(Self {
            name: c.get("name")?.as_str()?.to_string(),
            vocab_size: g("vocab_size")?,
            d_model: g("d_model")?,
            n_layers: g("n_layers")?,
            n_heads: g("n_heads")?,
            d_ff: g("d_ff")?,
            lora_rank: g("lora_rank")?,
            lora_alpha: c.get("lora_alpha")?.as_f64()?,
            dtype_bytes: 4,
        })
    }

    // ---- parameter counts (mirror configs.py exactly) -----------------

    /// Frozen base parameters in one decoder layer.
    pub fn base_layer_params(&self) -> usize {
        let (d, f) = (self.d_model, self.d_ff);
        4 * d * d + 3 * d * f + 2 * d
    }

    /// Trainable LoRA parameters in one decoder layer (7 adapted
    /// projections: q,k,v,o,gate,up,down).
    pub fn lora_layer_params(&self) -> usize {
        let (d, f, r) = (self.d_model, self.d_ff, self.lora_rank);
        4 * (d * r + r * d) + 2 * (d * r + r * f) + (f * r + r * d)
    }

    pub fn head_params(&self) -> usize {
        self.d_model + self.d_model * self.vocab_size
    }

    pub fn embed_params(&self) -> usize {
        self.vocab_size * self.d_model
    }

    pub fn total_params(&self) -> usize {
        self.embed_params()
            + self.n_layers * (self.base_layer_params() + self.lora_layer_params())
            + self.head_params()
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama1b_matches_paper_parameterization() {
        // NOTE: real LLaMA-3.2-1B has 16 decoder layers; the paper states
        // "32-layer transformer decoders" and its figures sweep cuts
        // 0..=32, so we follow the paper. With LLaMA-1B dims that yields
        // ~2.7B params — the discrepancy is the paper's, documented in
        // DESIGN.md §6; only relative per-layer costs enter the figures.
        let a = LlmArch::llama1b();
        let p = a.total_params() as f64;
        assert!(p > 2.0e9 && p < 3.2e9, "params = {p:.3e}");
        assert_eq!(a.n_layers, 32); // paper's stated layer count
    }

    #[test]
    fn lora_params_tiny_match_python() {
        // python: nano r(11d+3f) etc. — cross-check the closed form
        let a = LlmArch::tiny();
        let expect = a.lora_rank * (11 * a.d_model + 3 * a.d_ff);
        assert_eq!(a.lora_layer_params(), expect);
    }

    #[test]
    fn lora_is_small_fraction() {
        let a = LlmArch::llama1b();
        let frac = a.lora_layer_params() as f64 / a.base_layer_params() as f64;
        assert!(frac < 0.05, "LoRA fraction {frac}");
    }

    #[test]
    fn by_name_roundtrip() {
        for n in ["llama1b", "tiny", "small"] {
            assert_eq!(LlmArch::by_name(n).unwrap().name, n);
        }
        assert!(LlmArch::by_name("nope").is_none());
    }

    #[test]
    fn from_manifest_parses() {
        let j = Json::parse(
            r#"{"config":{"name":"tiny","vocab_size":256,"d_model":128,
                "n_layers":6,"n_heads":8,"d_ff":384,"lora_rank":8,
                "lora_alpha":16.0}}"#,
        )
        .unwrap();
        let a = LlmArch::from_manifest(&j).unwrap();
        assert_eq!(a.d_model, 128);
        assert_eq!(a.base_layer_params(), LlmArch::tiny().base_layer_params());
    }
}
