//! Server energy model — Eq. (11).
//!
//! GPU power follows the cubic law P = ξ·f³ (§III-B), so the energy for
//! one round of server-side fine-tuning is
//!
//!   E = T · d^{S,C} · P = T · ξ · f² · (η − η_D(c)) / (δ^S σ^S)
//!
//! Energy *increases* with f (∝ f²) while delay decreases (∝ 1/f) — the
//! tension CARD's Eq. (16) resolves in closed form.

use crate::config::ServerSpec;

use super::flops::FlopModel;

#[derive(Clone, Debug)]
pub struct EnergyModel {
    pub flops: FlopModel,
    /// T — local epochs per round
    pub epochs: f64,
}

impl EnergyModel {
    pub fn new(flops: FlopModel, epochs: usize) -> Self {
        Self {
            flops,
            epochs: epochs as f64,
        }
    }

    /// Instantaneous server GPU power at frequency f [W].
    pub fn power(&self, server: &ServerSpec, f_hz: f64) -> f64 {
        server.xi * f_hz.powi(3)
    }

    /// Eq. (11): server energy for one round at cut c, frequency f [J].
    pub fn round(&self, c: usize, server: &ServerSpec, f_hz: f64) -> f64 {
        self.epochs * server.xi * f_hz * f_hz * self.flops.eta_server(c)
            / (server.flops_per_cycle * server.cores)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExpConfig, WorkloadSpec};
    use crate::model::arch::LlmArch;

    fn setup() -> (EnergyModel, ExpConfig) {
        let cfg = ExpConfig::paper();
        let arch = LlmArch::llama1b();
        let em = EnergyModel::new(
            FlopModel::new(&arch, &cfg.workload),
            cfg.workload.local_epochs,
        );
        (em, cfg)
    }

    #[test]
    fn energy_is_delay_times_power() {
        let (em, cfg) = setup();
        let f = 2.0e9;
        let c = 8;
        let delay_per_epoch = em.flops.eta_server(c) / cfg.server.throughput(f);
        let expect = em.epochs * delay_per_epoch * em.power(&cfg.server, f);
        let got = em.round(c, &cfg.server, f);
        assert!((got - expect).abs() < expect * 1e-12);
    }

    #[test]
    fn energy_quadratic_in_frequency() {
        let (em, cfg) = setup();
        let e1 = em.round(8, &cfg.server, 1.0e9);
        let e2 = em.round(8, &cfg.server, 2.0e9);
        assert!((e2 / e1 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn energy_decreases_with_cut() {
        let (em, cfg) = setup();
        let f = cfg.server.max_freq_hz;
        assert!(em.round(0, &cfg.server, f) > em.round(16, &cfg.server, f));
        assert!(em.round(16, &cfg.server, f) > em.round(32, &cfg.server, f));
    }

    #[test]
    fn cubic_power_law() {
        let (em, cfg) = setup();
        let p1 = em.power(&cfg.server, 1.0e9);
        let p2 = em.power(&cfg.server, 2.0e9);
        assert!((p2 / p1 - 8.0).abs() < 1e-9);
    }

    #[test]
    fn paper_parameter_magnitude() {
        // ξ = 1e-25, f_max = 2.46 GHz ⇒ P ≈ 1.49 kW (the paper's own
        // parameterization; we reproduce their numbers, not TDP sheets)
        let (em, cfg) = setup();
        let p = em.power(&cfg.server, cfg.server.max_freq_hz);
        assert!(p > 1000.0 && p < 2000.0, "P = {p} W");
    }
}
