//! FLOP counting for split LoRA fine-tuning — the η(·) terms of
//! Eqs. (7)–(8).
//!
//! η_D(c) = embedding + c × (per-layer training FLOPs); the server gets
//! η − η_D(c) = (I − c) × layer + head.  Every decoder layer costs the
//! same (uniform dims), which yields exactly the paper's observation
//! that delay is linear in c and the optimum sits at an endpoint
//! (Fig. 3 discussion).
//!
//! Accounting (per token, one decoder layer, LoRA-frozen base):
//!   forward:   QKV/O projections 8d², scores+AV 4·s·d, SwiGLU 6·d·f,
//!              LoRA 2·Σ(d_in·r + r·d_out)
//!   backward:  activation-gradient matmuls mirror every forward matmul
//!              (≈ 1× forward), adapter weight-grads ≈ 2× LoRA forward,
//!              NO base weight-grads (frozen — the whole point of LoRA)
//!   recompute: the split executor stashes only layer *inputs* and
//!              recomputes internals in layer_bwd (+1× forward)

use crate::config::WorkloadSpec;

use super::arch::LlmArch;

/// Workload-specialized FLOP model.
#[derive(Clone, Debug)]
pub struct FlopModel {
    pub arch: LlmArch,
    /// tokens per mini-batch = batch_size × seq_len
    pub tokens: f64,
    pub seq_len: f64,
    /// extra forward pass for activation recomputation in backward
    pub recompute: bool,
}

impl FlopModel {
    pub fn new(arch: &LlmArch, w: &WorkloadSpec) -> Self {
        Self {
            arch: arch.clone(),
            tokens: (w.batch_size * w.seq_len) as f64,
            seq_len: w.seq_len as f64,
            recompute: true,
        }
    }

    /// Forward FLOPs of one decoder layer for the whole mini-batch.
    pub fn layer_fwd(&self) -> f64 {
        let d = self.arch.d_model as f64;
        let f = self.arch.d_ff as f64;
        let r = self.arch.lora_rank as f64;
        let s = self.seq_len;
        let proj = 8.0 * d * d; // wq,wk,wv,wo: 4 × 2d²
        let attn = 4.0 * s * d; // QKᵀ + AV: 2 × 2·s·d per token
        let mlp = 6.0 * d * f; // gate,up,down: 3 × 2·d·f
        // LoRA: q,k,v,o (d->d), gate,up (d->f), down (f->d)
        let lora = 2.0 * (4.0 * (d * r + r * d) + 2.0 * (d * r + r * f) + (f * r + r * d));
        self.tokens * (proj + attn + mlp + lora)
    }

    /// Backward FLOPs of one decoder layer (LoRA-frozen base).
    pub fn layer_bwd(&self) -> f64 {
        let d = self.arch.d_model as f64;
        let f = self.arch.d_ff as f64;
        let r = self.arch.lora_rank as f64;
        let s = self.seq_len;
        // activation-grad matmuls mirror the forward ones
        let dgrad = self.tokens * (8.0 * d * d + 8.0 * s * d + 6.0 * d * f);
        // adapter weight-grads: dA and dB per projection ≈ 2× lora fwd
        let dadapter =
            2.0 * 2.0 * self.tokens * (4.0 * 2.0 * d * r + 2.0 * (d * r + r * f) + (f * r + r * d));
        let recomp = if self.recompute { self.layer_fwd() } else { 0.0 };
        dgrad + dadapter + recomp
    }

    /// Full fwd+bwd training FLOPs of one decoder layer.
    pub fn layer_train(&self) -> f64 {
        self.layer_fwd() + self.layer_bwd()
    }

    /// Embedding cost (memory-bound gather; copy-equivalent accounting).
    pub fn embed(&self) -> f64 {
        2.0 * self.tokens * self.arch.d_model as f64
    }

    /// LM head + softmax CE + its backward to the activations.
    pub fn head(&self) -> f64 {
        let d = self.arch.d_model as f64;
        let v = self.arch.vocab_size as f64;
        // fwd logits 2dv, softmax ~5v, bwd dlogits ~3v, dh 2dv
        self.tokens * (4.0 * d * v + 8.0 * v)
    }

    /// η_D(c): device-side training FLOPs at cut layer c (embedding is
    /// always on the device — both paper baselines keep it there, §V-B).
    pub fn eta_device(&self, c: usize) -> f64 {
        self.embed() + c as f64 * self.layer_train()
    }

    /// η: total training FLOPs of the whole model.
    pub fn eta_total(&self) -> f64 {
        self.embed() + self.arch.n_layers as f64 * self.layer_train() + self.head()
    }

    /// η − η_D(c): server-side FLOPs at cut layer c.
    pub fn eta_server(&self, c: usize) -> f64 {
        debug_assert!(c <= self.arch.n_layers);
        self.eta_total() - self.eta_device(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadSpec;

    fn model() -> FlopModel {
        FlopModel::new(&LlmArch::llama1b(), &WorkloadSpec::default())
    }

    #[test]
    fn eta_linear_in_cut() {
        let m = model();
        let i = m.arch.n_layers;
        let d0 = m.eta_device(0);
        let step = m.eta_device(1) - d0;
        for c in 0..=i {
            let expect = d0 + c as f64 * step;
            assert!((m.eta_device(c) - expect).abs() < expect.abs() * 1e-12 + 1.0);
        }
    }

    #[test]
    fn eta_device_plus_server_is_total() {
        let m = model();
        for c in [0, 7, 32] {
            let sum = m.eta_device(c) + m.eta_server(c);
            assert!((sum - m.eta_total()).abs() < m.eta_total() * 1e-12);
        }
    }

    #[test]
    fn server_share_decreases_with_cut() {
        let m = model();
        assert!(m.eta_server(0) > m.eta_server(16));
        assert!(m.eta_server(16) > m.eta_server(32));
    }

    #[test]
    fn training_step_magnitude_sane() {
        // ~1B params, 4096 tokens: fwd ≈ 2·N·T ≈ 8e12; train ≈ 3-4× that.
        let m = model();
        let eta = m.eta_total();
        assert!(eta > 5e12 && eta < 1e14, "eta = {eta:.3e}");
    }

    #[test]
    fn bwd_more_expensive_than_fwd() {
        let m = model();
        assert!(m.layer_bwd() > m.layer_fwd());
        // ...but less than 3× (frozen base weights save the dW GEMMs)
        assert!(m.layer_bwd() < 3.0 * m.layer_fwd());
    }

    #[test]
    fn lora_overhead_is_marginal() {
        let mut a = LlmArch::llama1b();
        let w = WorkloadSpec::default();
        let with = FlopModel::new(&a, &w).layer_fwd();
        a.lora_rank = 0;
        let without = FlopModel::new(&a, &w).layer_fwd();
        assert!((with - without) / without < 0.05);
    }

    #[test]
    fn head_dominated_by_vocab() {
        let m = model();
        assert!(m.head() > m.embed());
    }
}
