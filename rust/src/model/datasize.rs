//! Wire data sizes for the split protocol — the S(c), S̃(c), A(c) terms
//! of Eq. (9).
//!
//! The smashed data (and its gradient) is one activation tensor at the
//! cut: b × s × d elements regardless of WHERE the cut is — matching the
//! paper's observation that "each transformer layer has the same ...
//! data size as the smashed data" (Fig. 3 discussion).  The adapter
//! payload A(c) is linear in c (only device-side adapters travel,
//! Stages 2 & 5).

use crate::config::WorkloadSpec;

use super::arch::LlmArch;

#[derive(Clone, Debug)]
pub struct DataSizeModel {
    pub arch: LlmArch,
    pub batch: f64,
    pub seq: f64,
    /// φ — compression ratio applied to smashed data & gradients
    pub phi: f64,
}

impl DataSizeModel {
    pub fn new(arch: &LlmArch, w: &WorkloadSpec) -> Self {
        Self {
            arch: arch.clone(),
            batch: w.batch_size as f64,
            seq: w.seq_len as f64,
            phi: w.phi,
        }
    }

    /// S(c) — uncompressed smashed-data bytes per local epoch (uplink).
    /// Includes the labels that ride along with the activations
    /// (Stage 3: "transmits its smashed data and corresponding label").
    pub fn smashed_bytes(&self, c: usize) -> f64 {
        let _ = c; // cut-independent by architecture uniformity
        let act = self.batch * self.seq * self.arch.d_model as f64 * self.arch.dtype_bytes as f64;
        let labels = self.batch * self.seq * 4.0; // i32 token ids
        act + labels
    }

    /// S̃(c) — uncompressed smashed-gradient bytes per local epoch
    /// (downlink).
    pub fn grad_bytes(&self, c: usize) -> f64 {
        let _ = c;
        self.batch * self.seq * self.arch.d_model as f64 * self.arch.dtype_bytes as f64
    }

    /// A(c) — device-side LoRA adapter bytes (Stages 2 and 5).
    pub fn adapter_bytes(&self, c: usize) -> f64 {
        (c * self.arch.lora_layer_params() * self.arch.dtype_bytes) as f64
    }

    /// φ·S(c) — compressed uplink payload per local epoch.
    pub fn smashed_wire_bytes(&self, c: usize) -> f64 {
        self.phi * self.smashed_bytes(c)
    }

    /// φ·S̃(c) — compressed downlink payload per local epoch.
    pub fn grad_wire_bytes(&self, c: usize) -> f64 {
        self.phi * self.grad_bytes(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadSpec;

    fn model() -> DataSizeModel {
        DataSizeModel::new(&LlmArch::llama1b(), &WorkloadSpec::default())
    }

    #[test]
    fn smashed_size_cut_independent() {
        let m = model();
        assert_eq!(m.smashed_bytes(1), m.smashed_bytes(31));
        assert_eq!(m.grad_bytes(0), m.grad_bytes(32));
    }

    #[test]
    fn smashed_magnitude() {
        // 8×512×2048 fp32 ≈ 33.6 MB (+16 KB labels)
        let m = model();
        let mb = m.smashed_bytes(1) / 1e6;
        assert!(mb > 33.0 && mb < 34.5, "{mb} MB");
    }

    #[test]
    fn adapters_linear_in_cut_and_zero_at_zero() {
        let m = model();
        assert_eq!(m.adapter_bytes(0), 0.0);
        let one = m.adapter_bytes(1);
        assert!((m.adapter_bytes(8) - 8.0 * one).abs() < 1.0);
    }

    #[test]
    fn compression_applies_to_activations_only() {
        let m = model();
        assert!((m.smashed_wire_bytes(4) - 0.1 * m.smashed_bytes(4)).abs() < 1e-6);
        // adapters are parameters — never lossy-compressed
        assert_eq!(m.adapter_bytes(4), m.adapter_bytes(4));
    }

    #[test]
    fn grad_has_no_label_component() {
        let m = model();
        assert!(m.smashed_bytes(1) > m.grad_bytes(1));
    }
}
