//! Analytic cost models of the paper's system model (§III): FLOPs,
//! wire sizes, delay (Eqs. 7–10) and server energy (Eq. 11), all
//! parameterized by an `LlmArch`.

pub mod arch;
pub mod datasize;
pub mod delay;
pub mod energy;
pub mod flops;

pub use arch::LlmArch;
pub use datasize::DataSizeModel;
pub use delay::{DelayModel, LinkRates};
pub use energy::EnergyModel;
pub use flops::FlopModel;
