//! Scenario registry: TOML-driven presets that expand into full
//! fleet-scale experiment configs.
//!
//! A [`Scenario`] couples three things the TOML schema alone cannot
//! express: a preset file from `config/presets/` (channel/workload/CARD
//! overrides layered on the paper's Tables I+II), the channel *state*
//! (pathloss regime) the scenario runs under, and the device placement
//! band its synthetic fleet is sampled from.  `Scenario::config(n, seed)`
//! materializes an `n`-device heterogeneous fleet deterministically —
//! the fleet is a pure function of `(scenario, n, seed)`, so every
//! fleet-sweep point reproduces bit-identically.

use crate::devices::Fleet;
use crate::util::rng::{Rng, SplitMix64};

use super::schema::{ChannelState, ConfigError, ExpConfig};

/// A named fleet-scale experiment preset.
#[derive(Clone, Copy, Debug)]
pub struct Scenario {
    pub name: &'static str,
    pub summary: &'static str,
    /// pathloss regime the scenario runs under (Fig. 4 channel states)
    pub state: ChannelState,
    /// device placement band [m] for the synthetic fleet
    pub dist_range: (f64, f64),
    toml: &'static str,
}

pub const DENSE_URBAN: Scenario = Scenario {
    name: "dense-urban",
    summary: "many close-in devices on a 100 MHz carrier (alpha = 4)",
    state: ChannelState::Normal,
    dist_range: (5.0, 25.0),
    toml: include_str!("../../../config/presets/dense_urban.toml"),
};

pub const SPARSE_RURAL: Scenario = Scenario {
    name: "sparse-rural",
    summary: "far-out devices on a 20 MHz carrier, open-field pathloss (alpha = 2)",
    state: ChannelState::Good,
    dist_range: (40.0, 150.0),
    toml: include_str!("../../../config/presets/sparse_rural.toml"),
};

pub const HETEROGENEOUS_FLEET: Scenario = Scenario {
    name: "heterogeneous-fleet",
    summary: "full Table I capability spread over the paper's 5-45 m band (alpha = 4)",
    state: ChannelState::Normal,
    dist_range: (5.0, 45.0),
    toml: include_str!("../../../config/presets/heterogeneous_fleet.toml"),
};

pub const BURSTY_CHANNEL: Scenario = Scenario {
    name: "bursty-channel",
    summary: "heavy multipath (alpha = 6) with Rayleigh fading and phi = 0.05",
    state: ChannelState::Poor,
    dist_range: (5.0, 25.0),
    toml: include_str!("../../../config/presets/bursty_channel.toml"),
};

pub const CORRELATED_INDOOR: Scenario = Scenario {
    name: "correlated-indoor",
    summary: "Gauss-Markov fading (rho = 0.95): SNR drifts instead of resampling (alpha = 4)",
    state: ChannelState::Normal,
    dist_range: (5.0, 30.0),
    toml: include_str!("../../../config/presets/correlated_indoor.toml"),
};

pub const MOBILE_VEHICULAR: Scenario = Scenario {
    name: "mobile-vehicular",
    summary: "Jakes Doppler fading over 12 m/s waypoint-loop trajectories (alpha = 4)",
    state: ChannelState::Normal,
    dist_range: (20.0, 120.0),
    toml: include_str!("../../../config/presets/mobile_vehicular.toml"),
};

/// Every registered scenario, in presentation order.
pub const ALL: [Scenario; 6] = [
    DENSE_URBAN,
    SPARSE_RURAL,
    HETEROGENEOUS_FLEET,
    BURSTY_CHANNEL,
    CORRELATED_INDOOR,
    MOBILE_VEHICULAR,
];

impl Scenario {
    /// Case-insensitive lookup by registry name.
    pub fn by_name(name: &str) -> Option<Scenario> {
        ALL.into_iter().find(|s| s.name.eq_ignore_ascii_case(name))
    }

    /// Expand into a validated experiment config with an `n_devices`
    /// synthetic fleet placed in the scenario's distance band.
    pub fn config(&self, n_devices: usize, seed: u64) -> Result<ExpConfig, ConfigError> {
        let mut cfg = ExpConfig::from_toml_str(self.toml)?;
        cfg.seed = seed;
        // the fleet stream is tagged by the scenario name so presets
        // sharing a seed still realize distinct fleets
        let mut rng = Rng::new(SplitMix64::stream_seed(seed, &[name_tag(self.name)]));
        cfg.devices = Fleet::synthetic_within(n_devices, self.dist_range, &mut rng).devices;
        cfg.validate()?;
        Ok(cfg)
    }
}

/// FNV-1a over the scenario name — a stable 64-bit stream tag.
fn name_tag(name: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_parse_validate_and_place_fleets() {
        for sc in ALL {
            let cfg = sc.config(12, 3).unwrap_or_else(|e| panic!("{}: {e}", sc.name));
            assert_eq!(cfg.devices.len(), 12, "{}", sc.name);
            for d in &cfg.devices {
                assert!(
                    d.distance_m >= sc.dist_range.0 && d.distance_m < sc.dist_range.1,
                    "{}: {} outside {:?}",
                    sc.name,
                    d.distance_m,
                    sc.dist_range
                );
            }
        }
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(Scenario::by_name("dense-urban").unwrap().name, "dense-urban");
        assert_eq!(Scenario::by_name("BURSTY-CHANNEL").unwrap().name, "bursty-channel");
        assert!(Scenario::by_name("nope").is_none());
    }

    #[test]
    fn registry_names_unique() {
        let mut names: Vec<&str> = ALL.iter().map(|s| s.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), ALL.len());
    }

    #[test]
    fn same_seed_reproduces_fleet_bitwise() {
        let a = DENSE_URBAN.config(16, 11).unwrap();
        let b = DENSE_URBAN.config(16, 11).unwrap();
        for (x, y) in a.devices.iter().zip(&b.devices) {
            assert_eq!(x.freq_hz.to_bits(), y.freq_hz.to_bits());
            assert_eq!(x.distance_m.to_bits(), y.distance_m.to_bits());
        }
    }

    #[test]
    fn seeds_and_scenarios_differentiate_fleets() {
        let a = DENSE_URBAN.config(16, 1).unwrap();
        let b = DENSE_URBAN.config(16, 2).unwrap();
        assert!(a
            .devices
            .iter()
            .zip(&b.devices)
            .any(|(x, y)| x.freq_hz != y.freq_hz));
        // same seed, different scenario name -> different stream
        let c = BURSTY_CHANNEL.config(16, 1).unwrap();
        assert!(a
            .devices
            .iter()
            .zip(&c.devices)
            .any(|(x, y)| x.freq_hz != y.freq_hz));
    }

    #[test]
    fn presets_tune_the_channel() {
        let urban = DENSE_URBAN.config(4, 0).unwrap();
        let rural = SPARSE_RURAL.config(4, 0).unwrap();
        assert_eq!(urban.channel.bandwidth_hz, 100e6);
        assert_eq!(rural.channel.bandwidth_hz, 20e6);
        let bursty = BURSTY_CHANNEL.config(4, 0).unwrap();
        assert!(bursty.channel.fading);
        assert!((bursty.workload.phi - 0.05).abs() < 1e-12);
    }

    #[test]
    fn process_presets_select_their_channel_models() {
        use crate::config::{FadingModel, MobilityModel};
        let indoor = CORRELATED_INDOOR.config(4, 0).unwrap();
        assert_eq!(indoor.channel.process.model, FadingModel::Markov);
        assert_eq!(indoor.channel.process.rho, 0.95);
        assert_eq!(indoor.channel.process.window, 48);
        assert!(!indoor.mobility.enabled());
        let vehicular = MOBILE_VEHICULAR.config(4, 0).unwrap();
        assert_eq!(vehicular.channel.process.model, FadingModel::Jakes);
        assert_eq!(vehicular.channel.process.doppler, 0.12);
        assert_eq!(vehicular.mobility.model, MobilityModel::Waypoint);
        assert_eq!(vehicular.mobility.speed_mps, 12.0);
        assert!(vehicular.mobility.enabled());
        // the legacy presets stay on the memoryless default
        for sc in [DENSE_URBAN, SPARSE_RURAL, HETEROGENEOUS_FLEET, BURSTY_CHANNEL] {
            let cfg = sc.config(4, 0).unwrap();
            assert_eq!(cfg.channel.process.model, FadingModel::Iid, "{}", sc.name);
            assert!(!cfg.mobility.enabled(), "{}", sc.name);
        }
    }
}
